"""Built-in lint passes — importing this module registers them.

Each pass encodes one bug class this repo has actually paid for; the
rule catalogue with the historical incidents lives in
``docs/static_analysis.md``.  Adding a pass: subclass
:class:`repro.analysis.lint.core.LintPass`, decorate with
:func:`repro.analysis.lint.core.register`, import it here.
"""
from . import (dtype_discipline, event_taxonomy, exception_hygiene,  # noqa: F401
               jit_purity, schema_roundtrip)

__all__ = ["jit_purity", "dtype_discipline", "event_taxonomy",
           "schema_roundtrip", "exception_hygiene"]
