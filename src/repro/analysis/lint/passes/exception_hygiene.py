"""RA005 — exception hygiene: no silent broad catches.

A ``try: ... except Exception: <swallow>`` around a numeric kernel is
how implementation drift goes unnoticed: the fallback path keeps the
benchmark green while the primary path has been broken for weeks (the
"silent implementation drift" threat the runtime-prediction survey
calls out).  The rule:

* a **bare** ``except:`` is always flagged (it swallows
  ``KeyboardInterrupt`` / ``SystemExit`` too);
* ``except BaseException`` is flagged unless the handler re-raises;
* ``except Exception`` (alone or in a tuple) is flagged unless the
  handler either re-raises or *names* the exception (``as e``) and
  actually uses that name — record-and-continue semantics are fine,
  silent discards are not.

The real fix is usually narrowing to the concrete types the guarded
code can raise (see ``checkpoint/store.py`` / ``core/baselines.py`` /
``launch/dryrun.py`` for the reference fixes); naming-and-logging is
the floor, not the goal.
"""
from __future__ import annotations

import ast
from typing import Iterable

from ..core import Diagnostic, LintPass, Project, SourceFile, register
from .common import dotted

_BROAD = {"Exception"}
_FATAL = {"BaseException"}


def _caught_names(h: ast.ExceptHandler) -> set[str]:
    t = h.type
    nodes = t.elts if isinstance(t, ast.Tuple) else ([t] if t else [])
    out = set()
    for n in nodes:
        d = dotted(n)
        if d:
            out.add(d.split(".")[-1])
    return out


def _reraises(h: ast.ExceptHandler) -> bool:
    return any(isinstance(n, ast.Raise) for n in ast.walk(h))


def _uses_bound_name(h: ast.ExceptHandler) -> bool:
    if not h.name:
        return False
    return any(isinstance(n, ast.Name) and n.id == h.name
               and isinstance(n.ctx, ast.Load)
               for stmt in h.body for n in ast.walk(stmt))


@register
class ExceptionHygienePass(LintPass):
    rule = "RA005"
    doc = ("exception hygiene: no bare/broad `except Exception` without "
           "re-raise or a named-and-used cause")

    def check(self, src: SourceFile, project: Project) -> Iterable[Diagnostic]:
        for node in ast.walk(src.tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            caught = _caught_names(node)
            if node.type is None:
                yield self.diag(
                    src, node,
                    "bare `except:` swallows KeyboardInterrupt/SystemExit "
                    "— catch the concrete types this block can raise")
            elif caught & _FATAL and not _reraises(node):
                yield self.diag(
                    src, node,
                    "`except BaseException` without re-raise — nothing "
                    "below Exception should be handled here")
            elif caught & _BROAD and not _reraises(node) \
                    and not _uses_bound_name(node):
                yield self.diag(
                    src, node,
                    "broad `except Exception` silently discards the cause "
                    "— narrow to the concrete types, or at minimum bind "
                    "(`as e`) and record it")
