"""RA001 — host-side effects reachable from traced (jit/vmap/scan) code.

Historical bug this encodes: the PR 2 ``SampleLog`` leak — an untraced
host-side object attached to a model pytree was silently swallowed by
``jax.jit``, so its mutations vanished on the compiled path and the
median fallback went stale.  The same class covers ``print`` inside a
scan body (traces once, then never again), ``.item()`` / ``float()``
forced syncs on the hot path, and in-place mutation of captured
containers (the trace sees the pre-mutation snapshot).

Detection is scoped to *traced functions*: functions decorated with
``jax.jit`` (bare or via ``partial``), functions passed to
``jax.jit`` / ``jax.vmap`` / ``jax.lax.scan`` / ``cond`` /
``while_loop`` / ``fori_loop`` / ``jax.grad`` / ``pallas_call``, and —
transitively, within the same file — any function they call by name.
Inside those we flag:

* ``print(...)`` calls;
* ``.item()`` calls (device sync, silently unjits the hot path);
* ``float(x)`` / ``int(x)`` / ``bool(x)`` where ``x`` is (rooted at) a
  traced parameter — a concretization sync point.  Static-shape reads
  (``.shape`` / ``.ndim`` / ``len``) are exempt: shapes are not traced;
* in-place mutation of captured state: mutator-method calls
  (``.append`` / ``.update`` / ...), subscript stores, and attribute
  stores whose receiver is a free variable or ``self``.
"""
from __future__ import annotations

import ast
from typing import Iterable

from ..core import Diagnostic, LintPass, Project, SourceFile, register
from .common import assigned_names, dotted, func_params

#: callables whose function-valued arguments become traced
#: (argument positions holding functions)
_TRACE_ENTRIES: dict[str, tuple[int, ...]] = {
    "jax.jit": (0,), "jit": (0,),
    "jax.vmap": (0,), "vmap": (0,),
    "jax.pmap": (0,),
    "jax.grad": (0,), "jax.value_and_grad": (0,),
    "jax.checkpoint": (0,), "jax.remat": (0,),
    "jax.lax.scan": (0,), "lax.scan": (0,),
    "jax.lax.cond": (1, 2), "lax.cond": (1, 2),
    "jax.lax.while_loop": (0, 1), "lax.while_loop": (0, 1),
    "jax.lax.fori_loop": (2,), "lax.fori_loop": (2,),
    "jax.lax.switch": (1,), "lax.switch": (1,),
    "pl.pallas_call": (0,), "pallas_call": (0,),
}

#: decorator spellings that make the decorated def a trace root
_TRACE_DECOS = {"jax.jit", "jit", "jax.vmap", "vmap", "jax.pmap",
                "jax.checkpoint", "jax.remat"}

#: defs that are traced by CONTRACT, not (only) by visible jit/vmap
#: plumbing: the fused-tick kernels (``repro.core.tick``) and their
#: vmapped fleet twins (``repro.online.fleet``).  Their jit wrapping is a
#: module-level call-site the resolver also sees, but the seed list keeps
#: them covered even when the wrapping moves behind an indirection the
#: AST walk cannot follow (a factory, a config-chosen variant).
_SEED_TRACED = {"tick_step", "_tick_core", "_predict_state_core",
                "fleet_tick_step", "_fleet_tick_core", "_fleet_predict_core"}

_MUTATORS = {"append", "extend", "insert", "add", "update", "setdefault",
             "pop", "popitem", "remove", "discard", "clear", "write",
             "appendleft", "sort", "reverse"}

_SYNC_BUILTINS = {"float", "int", "bool", "complex"}

_SHAPE_ATTRS = {"shape", "ndim", "size", "dtype"}


def _root_name(node: ast.AST) -> str | None:
    """Leftmost Name of an attribute/subscript chain."""
    while isinstance(node, (ast.Attribute, ast.Subscript)):
        node = node.value
    return node.id if isinstance(node, ast.Name) else None


def _unwrap_partial(node: ast.AST) -> ast.AST:
    """``partial(f, ...)`` -> ``f`` (one level)."""
    if isinstance(node, ast.Call) and \
            dotted(node.func) in ("partial", "functools.partial") and node.args:
        return node.args[0]
    return node


def _mentions_shape(node: ast.AST) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Attribute) and sub.attr in _SHAPE_ATTRS:
            return True
        if isinstance(sub, ast.Call) and dotted(sub.func) == "len":
            return True
    return False


class _Fn:
    """One function-ish node with the scope facts the checks need."""

    def __init__(self, node: ast.FunctionDef | ast.AsyncFunctionDef | ast.Lambda):
        self.node = node
        self.params = func_params(node)
        body = node.body if isinstance(node.body, list) else [node.body]
        self.locals = self.params | assigned_names(body)
        self.name = getattr(node, "name", "<lambda>")


@register
class JitPurityPass(LintPass):
    rule = "RA001"
    doc = ("jit-purity: host-side effects (print/.item()/float()/captured-"
           "container mutation) inside jit/vmap/scan-traced functions")

    def check(self, src: SourceFile, project: Project) -> Iterable[Diagnostic]:
        # index every def in the file by name (for Name -> def resolution)
        defs: dict[str, list[ast.AST]] = {}
        for node in ast.walk(src.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                defs.setdefault(node.name, []).append(node)

        traced: dict[ast.AST, str] = {}          # fn node -> why it is traced

        def mark(fn_node: ast.AST, why: str) -> None:
            if isinstance(fn_node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                    ast.Lambda)) and fn_node not in traced:
                traced[fn_node] = why

        def resolve(arg: ast.AST, why: str) -> None:
            arg = _unwrap_partial(arg)
            if isinstance(arg, ast.Lambda):
                mark(arg, why)
            elif isinstance(arg, ast.Name):
                for d in defs.get(arg.id, ()):
                    mark(d, why)

        # 1) decorator roots
        for name, nodes in defs.items():
            for node in nodes:
                for deco in node.decorator_list:
                    target = deco.func if isinstance(deco, ast.Call) else deco
                    d = dotted(_unwrap_partial(deco)) \
                        if isinstance(deco, ast.Call) else dotted(target)
                    if isinstance(deco, ast.Call):
                        # @partial(jax.jit, ...) or @jax.jit(...)
                        inner = deco.args[0] if (
                            dotted(deco.func) in ("partial", "functools.partial")
                            and deco.args) else deco.func
                        d = dotted(inner)
                    if d in _TRACE_DECOS:
                        mark(node, f"decorated with {d}")

        # 1b) contract roots: the fused tick kernel family is traced by
        # name, wherever its jit wrapping happens to live
        for name, nodes in defs.items():
            if name in _SEED_TRACED:
                for node in nodes:
                    mark(node, "fused-tick seed list")

        # 2) call-site roots: jax.jit(f), lax.scan(body, ...), ...
        for node in ast.walk(src.tree):
            if isinstance(node, ast.Call):
                entry = dotted(node.func)
                for pos in _TRACE_ENTRIES.get(entry or "", ()):
                    if pos < len(node.args):
                        resolve(node.args[pos], f"passed to {entry}")

        # 3) same-file transitive closure over simple Name calls
        changed = True
        while changed:
            changed = False
            for fn, why in list(traced.items()):
                for sub in ast.walk(fn):
                    if isinstance(sub, ast.Call) and \
                            isinstance(sub.func, ast.Name):
                        for d in defs.get(sub.func.id, ()):
                            if d not in traced:
                                traced[d] = (f"called from traced "
                                             f"{getattr(fn, 'name', '<lambda>')}")
                                changed = True

        for fn_node, why in traced.items():
            yield from self._check_traced(src, _Fn(fn_node), why)

    # ------------------------------------------------------------------
    def _check_traced(self, src: SourceFile, fn: _Fn,
                      why: str) -> Iterable[Diagnostic]:
        ctx = f"in traced `{fn.name}` ({why})"
        body = fn.node.body if isinstance(fn.node.body, list) else [fn.node.body]
        for stmt in body:
            for node in ast.walk(stmt):
                # nested defs are re-visited as their own traced entries
                # by the closure above only when called; their bodies
                # still execute at trace time, so keep walking them.
                if isinstance(node, ast.Call):
                    d = dotted(node.func)
                    if d == "print":
                        yield self.diag(src, node,
                                        f"print() {ctx} runs at trace time "
                                        "only — use jax.debug.print or hoist "
                                        "it out of the traced region")
                    elif isinstance(node.func, ast.Attribute) and \
                            node.func.attr == "item" and not node.args:
                        yield self.diag(src, node,
                                        f".item() {ctx} forces a device sync "
                                        "and fails under trace — return the "
                                        "array and read it host-side")
                    elif d in _SYNC_BUILTINS and len(node.args) == 1 and \
                            not isinstance(node.args[0], ast.Constant):
                        arg = node.args[0]
                        root = _root_name(arg)
                        if root in fn.params and not _mentions_shape(arg):
                            yield self.diag(
                                src, node,
                                f"{d}() on traced parameter `{root}` {ctx} "
                                "is a concretization sync point — keep the "
                                "value as an array under trace")
                    elif isinstance(node.func, ast.Attribute) and \
                            node.func.attr in _MUTATORS:
                        root = _root_name(node.func.value)
                        if root is not None and (
                                root == "self" or root not in fn.locals):
                            yield self.diag(
                                src, node,
                                f".{node.func.attr}() mutates captured "
                                f"`{root}` {ctx} — the trace sees a one-time "
                                "snapshot; mutations are lost on the "
                                "compiled path (the SampleLog bug class)")
                elif isinstance(node, (ast.Assign, ast.AnnAssign)):
                    targets = node.targets if isinstance(node, ast.Assign) \
                        else [node.target]
                    for t in targets:
                        if isinstance(t, (ast.Attribute, ast.Subscript)):
                            root = _root_name(t)
                            if root is not None and (
                                    root == "self" or root not in fn.locals):
                                kind = ("attribute" if isinstance(t, ast.Attribute)
                                        else "subscript")
                                yield self.diag(
                                    src, t,
                                    f"{kind} store on captured `{root}` "
                                    f"{ctx} — host-side state mutated under "
                                    "trace is silently dropped; use "
                                    "functional updates (.at[].set) or "
                                    "re-attach after jit")
