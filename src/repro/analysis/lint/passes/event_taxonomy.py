"""RA003 — closure of the trace-event taxonomy.

``repro.obs.trace`` deliberately keeps :data:`EVENT_KINDS` a *closed*
frozenset: every consumer (the Chrome exporter's lane routing, the
calibration reader, the report roll-up) switches on the kind string,
so an emit site inventing a kind silently falls out of every view —
``EventLog.emit`` only warns at runtime, and only if that code path
runs under a tracer in some test.  This pass proves the closure
statically, in both directions:

* every ``*.emit(...)`` call site whose kind is a string literal must
  name a registered kind;
* a kind whose value cannot be resolved statically (a variable) is
  flagged too — an unprovable emit site is a hole in the closure;
* every registered kind must be emitted somewhere in the linted tree,
  or listed in an optional ``RESERVED_EVENT_KINDS`` set next to the
  taxonomy (documented-but-not-yet-emitted kinds).

Cross-file by nature: the taxonomy lives in one module, the emit sites
in others, so the work happens in :meth:`finalize`.  When no
``EVENT_KINDS`` definition is in the linted file set the pass is inert
(linting a subtree that doesn't contain the taxonomy is not an error).
"""
from __future__ import annotations

import ast
from typing import Iterable

from ..core import Diagnostic, LintPass, Project, SourceFile, register
from .common import const_str, dotted

TAXONOMY_NAME = "EVENT_KINDS"
RESERVED_NAME = "RESERVED_EVENT_KINDS"


def _set_literal(node: ast.AST) -> set[str] | None:
    """String elements of ``frozenset({...})`` / ``{...}`` / ``[...]``."""
    if isinstance(node, ast.Call) and node.args:
        return _set_literal(node.args[0])
    if isinstance(node, (ast.Set, ast.List, ast.Tuple)):
        out = set()
        for el in node.elts:
            s = const_str(el)
            if s is None:
                return None
            out.add(s)
        return out
    return None


def _find_taxonomy(src: SourceFile, name: str
                   ) -> tuple[set[str], int] | None:
    for node in src.tree.body:
        targets = []
        if isinstance(node, ast.Assign):
            targets = node.targets
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            targets = [node.target]
        for t in targets:
            if isinstance(t, ast.Name) and t.id == name:
                kinds = _set_literal(node.value)
                if kinds is not None:
                    return kinds, node.lineno
    return None


def _emit_kind(call: ast.Call) -> tuple[str | None, bool]:
    """(kind, resolvable) of an ``emit`` call: the first positional arg
    or the ``kind=`` keyword."""
    for kw in call.keywords:
        if kw.arg == "kind":
            s = const_str(kw.value)
            return s, s is not None
    if call.args:
        s = const_str(call.args[0])
        return s, s is not None
    return None, False


@register
class EventTaxonomyPass(LintPass):
    rule = "RA003"
    doc = ("event-taxonomy closure: every emit() kind is registered in "
           "EVENT_KINDS and every registered kind is emitted (or reserved)")

    def finalize(self, project: Project) -> Iterable[Diagnostic]:
        taxonomy: set[str] | None = None
        reserved: set[str] = set()
        tax_src: SourceFile | None = None
        tax_line = 0
        for src in project.files:
            found = _find_taxonomy(src, TAXONOMY_NAME)
            if found is not None:
                taxonomy, tax_line = found
                tax_src = src
                res = _find_taxonomy(src, RESERVED_NAME)
                if res is not None:
                    reserved = res[0]
                break
        if taxonomy is None:
            return

        emitted: set[str] = set()
        for src in project.files:
            for node in ast.walk(src.tree):
                is_emit = (isinstance(node, ast.Call)
                           and isinstance(node.func, ast.Attribute)
                           and node.func.attr == "emit")
                # direct construction of a typed event IS an emission
                # (EventLog.span appends Event(kind="span") itself)
                is_event_ctor = (isinstance(node, ast.Call)
                                 and (dotted(node.func) or "").split(".")[-1]
                                 == "Event"
                                 and any(kw.arg == "kind"
                                         for kw in node.keywords))
                if not (is_emit or is_event_ctor):
                    continue
                kind, resolvable = _emit_kind(node)
                if not resolvable:
                    if is_event_ctor:
                        # the dispatcher (EventLog.emit) forwarding its
                        # own `kind` parameter into the Event record is
                        # plumbing, not an emit site
                        continue
                    yield self.diag(
                        src, node,
                        "emit() kind is not a string literal — the "
                        "taxonomy closure cannot be proven for this site; "
                        "pass the kind inline")
                    continue
                emitted.add(kind)
                if kind not in taxonomy:
                    yield self.diag(
                        src, node,
                        f"emit() kind {kind!r} is not in the closed "
                        f"{TAXONOMY_NAME} taxonomy — register it (and its "
                        "consumer routing) or fix the typo")

        for kind in sorted(taxonomy - emitted - reserved):
            yield self.diag(
                tax_src, tax_line,
                f"taxonomy kind {kind!r} is never emitted in the linted "
                f"tree and not listed in {RESERVED_NAME} — dead taxonomy "
                "entries hide typos at emit sites")
