"""RA002 — dtype discipline in the estimator plane and model einsums.

Two historical bugs, one rule:

* the PR 1 ``blr.predict`` bug: a hard-coded ``jnp.float32`` cast on
  the prediction path silently downcast the float64 posterior under
  ``jax_enable_x64``, costing ~7 decimal digits of agreement.  The fix
  is the ``blr._dtype()`` policy helper (float64 iff x64 is on) — so in
  the numeric estimator modules, any *literal* float-dtype in a cast /
  array-construction call is flagged;
* the PR 3 zamba2 mismatch: the decode path ran an fp32 conv einsum
  while prefill ran the same conv in bf16, and the drift compounded
  past tolerance.  Statically we catch the call-site-visible version:
  a ``jnp.einsum`` in ``models/`` whose operands carry *different*
  literal dtype casts (``.astype(jnp.float32)`` on one, bf16 or bare on
  another) without a ``preferred_element_type=`` accumulate annotation.

Scoping matters: Pallas kernels and the optimiser legitimately pin
fp32 accumulators, so the literal-dtype check only applies to the
estimator-plane modules in :data:`POLICY_MODULES`, and the einsum check
only to ``models/``.
"""
from __future__ import annotations

import ast
from typing import Iterable

from ..core import Diagnostic, LintPass, Project, SourceFile, register
from .common import dotted, literal_dtype

#: path fragments of modules under the `_dtype()` policy (the numeric
#: estimator plane, where x64-vs-x32 follows jax_enable_x64)
POLICY_MODULES = ("core/", "online/", "sched/")

#: path fragment for the mixed-einsum check
MODEL_MODULES = ("models/",)

#: calls whose dtype-position argument is checked (positional index of
#: the dtype arg, or None when dtype is keyword-only in our usage)
_CAST_CALLS = {"astype": 0, "asarray": 1, "array": 1, "zeros": 1,
               "ones": 1, "full": 2, "empty": 1, "arange": None,
               "zeros_like": 1, "ones_like": 1, "full_like": 2}


def _literal_dtype_args(call: ast.Call) -> list[tuple[ast.AST, str]]:
    """(node, dtype) for every literal float dtype in dtype position."""
    fn = call.func
    name = fn.attr if isinstance(fn, ast.Attribute) else (
        fn.id if isinstance(fn, ast.Name) else None)
    out: list[tuple[ast.AST, str]] = []
    if name in _CAST_CALLS:
        pos = _CAST_CALLS[name]
        if pos is not None and pos < len(call.args):
            dt = literal_dtype(call.args[pos])
            if dt:
                out.append((call.args[pos], dt))
        for kw in call.keywords:
            if kw.arg == "dtype":
                dt = literal_dtype(kw.value)
                if dt:
                    out.append((kw.value, dt))
    # np.float32(x) / jnp.float32(x) used as a cast constructor
    dt = literal_dtype(fn)
    if dt and call.args:
        out.append((fn, dt))
    return out


def _operand_cast(arg: ast.AST) -> str | None:
    """Literal dtype when the einsum operand is ``<expr>.astype(<literal>)``
    at its top level, else None."""
    if isinstance(arg, ast.Call) and isinstance(arg.func, ast.Attribute) \
            and arg.func.attr == "astype" and arg.args:
        return literal_dtype(arg.args[0])
    return None


@register
class DtypeDisciplinePass(LintPass):
    rule = "RA002"
    doc = ("dtype discipline: literal float32/bf16 casts in estimator-plane "
           "modules (use blr._dtype()), mixed-precision einsum operands in "
           "models/ without preferred_element_type")

    def check(self, src: SourceFile, project: Project) -> Iterable[Diagnostic]:
        path = src.path.replace("\\", "/")
        if any(m in path for m in POLICY_MODULES):
            yield from self._check_policy(src)
        if any(m in path for m in MODEL_MODULES):
            yield from self._check_einsums(src)

    def _check_policy(self, src: SourceFile) -> Iterable[Diagnostic]:
        for node in ast.walk(src.tree):
            if not isinstance(node, ast.Call):
                continue
            for dt_node, dt in _literal_dtype_args(node):
                if dt in ("float64",):
                    # float64 literals only appear in deliberate
                    # serialisation paths (JSON round-trips are written
                    # at full width regardless of the compute policy)
                    continue
                yield self.diag(
                    src, dt_node,
                    f"literal {dt} cast in an estimator-plane module — "
                    "the numeric dtype follows jax_enable_x64; use "
                    "blr._dtype() so x64 runs keep float64 (the PR 1 "
                    "blr.predict bug class)")

    def _check_einsums(self, src: SourceFile) -> Iterable[Diagnostic]:
        for node in ast.walk(src.tree):
            if not isinstance(node, ast.Call):
                continue
            if dotted(node.func) not in ("jnp.einsum", "jax.numpy.einsum",
                                         "np.einsum"):
                continue
            if any(kw.arg == "preferred_element_type"
                   for kw in node.keywords):
                continue          # sanctioned mixed-precision accumulate
            operands = [a for a in node.args
                        if not (isinstance(a, ast.Constant)
                                and isinstance(a.value, str))]
            casts = [_operand_cast(a) for a in operands]
            literal = [c for c in casts if c]
            if not literal:
                continue
            if len(set(literal)) > 1 or len(literal) != len(operands):
                got = [c or "<uncast>" for c in casts]
                yield self.diag(
                    src, node,
                    f"einsum mixes operand dtypes {got} — cast every "
                    "operand consistently or state the accumulator with "
                    "preferred_element_type= (the PR 3 zamba2 fp32/bf16 "
                    "conv mismatch class)")
