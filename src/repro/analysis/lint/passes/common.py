"""Small AST helpers shared by the concrete passes."""
from __future__ import annotations

import ast

__all__ = ["dotted", "const_str", "call_name", "func_params",
           "assigned_names", "literal_dtype"]


def dotted(node: ast.AST) -> str | None:
    """``jax.lax.scan`` -> "jax.lax.scan" for Name/Attribute chains,
    None for anything else."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def const_str(node: ast.AST) -> str | None:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None


def call_name(node: ast.Call) -> str | None:
    return dotted(node.func)


def func_params(fn: ast.FunctionDef | ast.AsyncFunctionDef | ast.Lambda
                ) -> set[str]:
    a = fn.args
    names = {p.arg for p in (*a.posonlyargs, *a.args, *a.kwonlyargs)}
    if a.vararg:
        names.add(a.vararg.arg)
    if a.kwarg:
        names.add(a.kwarg.arg)
    return names


def assigned_names(body: list[ast.stmt]) -> set[str]:
    """Every plain name bound anywhere inside ``body`` (assignments,
    for-targets, with-as, walrus, nested defs, imports)."""
    out: set[str] = set()

    class V(ast.NodeVisitor):
        def visit_Name(self, n: ast.Name):
            if isinstance(n.ctx, (ast.Store, ast.Del)):
                out.add(n.id)

        def visit_FunctionDef(self, n):
            out.add(n.name)
            self.generic_visit(n)

        visit_AsyncFunctionDef = visit_FunctionDef

        def visit_ClassDef(self, n):
            out.add(n.name)
            self.generic_visit(n)

        def visit_alias(self, n: ast.alias):
            out.add((n.asname or n.name).split(".")[0])

        def visit_NamedExpr(self, n):
            self.generic_visit(n)

    v = V()
    for stmt in body:
        v.visit(stmt)
    return out


#: dotted names that count as a literal dtype mention
_DTYPE_LITERALS = {
    "jnp.float32": "float32", "np.float32": "float32",
    "numpy.float32": "float32", "jax.numpy.float32": "float32",
    "jnp.bfloat16": "bfloat16", "jax.numpy.bfloat16": "bfloat16",
    "jnp.float16": "float16", "np.float16": "float16",
    "jnp.float64": "float64", "np.float64": "float64",
}


def literal_dtype(node: ast.AST) -> str | None:
    """"float32" for a literal float-dtype attribute (``jnp.float32``,
    ``np.float32``, ...), else None."""
    d = dotted(node)
    return _DTYPE_LITERALS.get(d) if d else None
