"""RA004 — schema round-trip completeness for to_dict/from_dict pairs.

The estimator checkpoint is now at schema v5 and every PR since v2 has
grown it; the failure mode this pass encodes is the quiet one where a
writer gains a key (``to_dict``/``save`` serialises new state) and the
matching reader never consumes it — the save→load round trip "works",
silently dropping the new state, and nothing notices until a loaded
estimator predicts differently from the one that was saved.

For every scope (class body or module top level) that defines BOTH a
writer (``to_dict`` / ``to_json`` / ``save``) and its reader
(``from_dict`` / ``from_json`` / ``load``), the pass collects:

* **written keys** — string keys of every dict literal inside the
  writer, plus ``out["key"] = ...`` constant subscript stores;
* **consumed keys** — constant keys read anywhere in the reader:
  ``d["key"]``, ``d.get("key", ...)``, ``d.pop("key")``,
  ``"key" in d``, and ``**``-splat loads are approximated by
  constructor-keyword names (``cls(freq_reduction=...)`` consumes
  nothing by itself — the reader must name the key).

Every written key must be consumed under *some* guard.  Version guards
themselves must be **monotone**: a reader may test ``version >= N``
(or ``> N``) with ``1 <= N <= SCHEMA_VERSION`` — an equality or
upper-bound pin (``version == 3``, ``version < 4``) silently drops
data written by every *newer* schema and is flagged, as is a guard
constant outside the known version range.
"""
from __future__ import annotations

import ast
from typing import Iterable

from ..core import Diagnostic, LintPass, Project, SourceFile, register

WRITERS = ("to_dict", "to_json", "save")
READERS = ("from_dict", "from_json", "load")

#: keys a writer may stamp purely for humans / external tools; never
#: required to be read back (Chrome trace viewers read "traceEvents",
#: our own loaders don't re-consume pretty-printed duplicates)
_DOC_ONLY_KEYS = frozenset()


def _schema_version_bound(src: SourceFile) -> int | None:
    """Largest module-level ``*SCHEMA_VERSION*`` int constant, if any."""
    best = None
    for node in src.tree.body:
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Constant) \
                and isinstance(node.value.value, int):
            for t in node.targets:
                if isinstance(t, ast.Name) and "SCHEMA_VERSION" in t.id:
                    best = max(best or 0, node.value.value)
    return best


def _written_keys(fn: ast.AST) -> dict[str, int]:
    """{key: first line} of every constant string dict key / constant
    subscript store inside the writer."""
    out: dict[str, int] = {}
    for node in ast.walk(fn):
        if isinstance(node, ast.Dict):
            for k in node.keys:
                if isinstance(k, ast.Constant) and isinstance(k.value, str):
                    out.setdefault(k.value, k.lineno)
        elif isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Subscript) and \
                        isinstance(t.slice, ast.Constant) and \
                        isinstance(t.slice.value, str):
                    out.setdefault(t.slice.value, t.lineno)
    return out


def _consumed_keys(fn: ast.AST) -> set[str]:
    out: set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Subscript) and \
                isinstance(node.slice, ast.Constant) and \
                isinstance(node.slice.value, str) and \
                isinstance(node.ctx, ast.Load):
            out.add(node.slice.value)
        elif isinstance(node, ast.Call) and \
                isinstance(node.func, ast.Attribute) and \
                node.func.attr in ("get", "pop") and node.args:
            a = node.args[0]
            if isinstance(a, ast.Constant) and isinstance(a.value, str):
                out.add(a.value)
        elif isinstance(node, ast.Compare) and \
                any(isinstance(op, (ast.In, ast.NotIn)) for op in node.ops):
            left = node.left
            if isinstance(left, ast.Constant) and isinstance(left.value, str):
                out.add(left.value)
    return out


def _version_guards(fn: ast.AST) -> Iterable[tuple[ast.Compare, ast.cmpop, int]]:
    """Compare nodes testing a ``version`` value against an int constant."""
    for node in ast.walk(fn):
        if not isinstance(node, ast.Compare) or len(node.ops) != 1:
            continue
        left, op, right = node.left, node.ops[0], node.comparators[0]

        def names_version(n: ast.AST) -> bool:
            if isinstance(n, ast.Name) and "version" in n.id.lower():
                return True
            if isinstance(n, ast.Call) and isinstance(n.func, ast.Attribute) \
                    and n.func.attr == "get" and n.args:
                a = n.args[0]
                return isinstance(a, ast.Constant) and a.value == "version"
            return False

        if names_version(left) and isinstance(right, ast.Constant) \
                and isinstance(right.value, int):
            yield node, op, right.value
        elif names_version(right) and isinstance(left, ast.Constant) \
                and isinstance(left.value, int):
            # mirrored form: 3 <= version — normalise the operator
            mirror = {ast.Lt: ast.Gt, ast.LtE: ast.GtE,
                      ast.Gt: ast.Lt, ast.GtE: ast.LtE}
            yield node, mirror.get(type(op), type(op))(), left.value


@register
class SchemaRoundTripPass(LintPass):
    rule = "RA004"
    doc = ("schema round-trip: every key a to_dict/save writer emits is "
           "consumed by the paired from_dict/load reader; version guards "
           "are monotone (>= N, N within the schema range)")

    def check(self, src: SourceFile, project: Project) -> Iterable[Diagnostic]:
        bound = _schema_version_bound(src)
        scopes: list[list[ast.stmt]] = [src.tree.body]
        scopes += [n.body for n in ast.walk(src.tree)
                   if isinstance(n, ast.ClassDef)]
        for body in scopes:
            fns = {n.name: n for n in body
                   if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}
            writers = [fns[w] for w in WRITERS if w in fns]
            readers = [fns[r] for r in READERS if r in fns]
            if not writers or not readers:
                continue
            consumed: set[str] = set()
            for r in readers:
                consumed |= _consumed_keys(r)
            for w in writers:
                for key, line in sorted(_written_keys(w).items(),
                                        key=lambda kv: kv[1]):
                    if key in consumed or key in _DOC_ONLY_KEYS:
                        continue
                    rnames = "/".join(r.name for r in readers)
                    yield self.diag(
                        src, line,
                        f"key {key!r} written by {w.name}() is never "
                        f"consumed by {rnames}() — the round trip silently "
                        "drops it; read it under a version guard or remove "
                        "the write")
            for r in readers:
                for node, op, const in _version_guards(r):
                    if isinstance(op, (ast.Eq, ast.NotEq, ast.Lt, ast.LtE)):
                        yield self.diag(
                            src, node,
                            f"version guard pins `{ast.unparse(node)}` — "
                            "non-monotone guards drop data from newer "
                            "schemas; use `version >= N` so every later "
                            "version satisfies earlier guards")
                    elif bound is not None and not (1 <= const <= bound):
                        yield self.diag(
                            src, node,
                            f"version guard constant {const} is outside "
                            f"the known schema range 1..{bound} — "
                            "unreachable guard (typo, or bump "
                            "SCHEMA_VERSION first)")
