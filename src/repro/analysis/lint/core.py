"""AST lint framework for the repo's recurring JAX bug classes.

The test suite can only spot-check the numerical invariants the
estimator's correctness story rests on; the bug classes that actually
cost us debugging cycles (the PR 1 ``blr.predict`` float32 cast, the
PR 2 untraced ``SampleLog`` leaking into jit, the PR 3 zamba2
fp32/bf16 conv mismatch) were all *statically* detectable.  This
module is the machinery that catches them before review:

* :class:`SourceFile` — a parsed file plus its suppression comments;
* :class:`LintPass` — the per-pass plugin base; concrete passes live in
  :mod:`repro.analysis.lint.passes` and self-register via
  :func:`register`;
* :func:`run_paths` / :func:`run_project` — the driver: parse, run
  per-file checks, run cross-file finalizers, apply suppressions.

Suppression syntax (one line, on the flagged line or the line above)::

    # repro: ignore[RA001] -- frozen reference impl, host print is the point
    x = noisy_thing()      # repro: ignore[RA002, RA005] -- <why>

The justification text after ``--`` is REQUIRED: a bare
``# repro: ignore[RA001]`` still suppresses the named rule (so the
finding is not double-reported) but is itself flagged as **RA000** —
an unjustified suppression fails the lint gate just like the finding
it hides would have.  Unknown rule ids in the bracket are RA000 too.
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Iterable, Iterator

__all__ = [
    "Diagnostic", "Suppression", "SourceFile", "Project", "LintPass",
    "register", "registered_passes", "run_paths", "run_project",
    "parse_file", "RULE_DOCS",
]

#: rule id -> one-line description (filled by pass registration; RA000
#: is emitted by the driver itself, not a pass)
RULE_DOCS: dict[str, str] = {
    "RA000": "suppression hygiene: ignore[...] without justification "
             "text, or naming an unknown rule",
}

_IGNORE_RE = re.compile(
    r"#\s*repro:\s*ignore\[([A-Za-z0-9_,\s]*)\]\s*(?:--|—)?\s*(.*)$")

#: minimum number of non-space characters for a justification to count
MIN_JUSTIFICATION = 8


@dataclass(frozen=True, order=True)
class Diagnostic:
    """One finding: ``path:line:col: RULE message``."""
    path: str
    line: int
    col: int
    rule: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"


@dataclass(frozen=True)
class Suppression:
    """A parsed ``# repro: ignore[...]`` comment."""
    line: int
    rules: frozenset[str]
    justification: str

    @property
    def justified(self) -> bool:
        return len(self.justification.replace(" ", "")) >= MIN_JUSTIFICATION


@dataclass
class SourceFile:
    """A parsed source file: AST, raw lines, and its suppressions."""
    path: str
    text: str
    tree: ast.Module
    suppressions: list[Suppression] = field(default_factory=list)

    @property
    def lines(self) -> list[str]:
        return self.text.splitlines()

    def suppressed_rules_at(self, line: int) -> set[str]:
        """Rules suppressed for ``line`` (comment on the line itself or
        the line directly above)."""
        out: set[str] = set()
        for s in self.suppressions:
            if s.line in (line, line - 1):
                out |= s.rules
        return out


@dataclass
class Project:
    """The full set of files one lint run sees (cross-file passes need
    the whole picture: RA003 reads the taxonomy from one file and the
    emit sites from others)."""
    files: list[SourceFile] = field(default_factory=list)

    def by_suffix(self, *suffixes: str) -> Iterator[SourceFile]:
        for f in self.files:
            if f.path.endswith(suffixes):
                yield f


class LintPass:
    """Base class for one lint rule.

    Subclasses set :attr:`rule` / :attr:`doc` and override either
    :meth:`check` (per-file; most rules) or :meth:`finalize`
    (cross-file; runs once after every file was parsed — RA003's
    taxonomy closure, for example, is a property of the *project*, not
    of any single file).
    """
    rule: str = "RA???"
    doc: str = ""

    def check(self, src: SourceFile, project: Project) -> Iterable[Diagnostic]:
        return ()

    def finalize(self, project: Project) -> Iterable[Diagnostic]:
        return ()

    # -- helpers shared by the concrete passes ---------------------------
    def diag(self, src_or_path, node_or_line, message: str) -> Diagnostic:
        path = src_or_path.path if isinstance(src_or_path, SourceFile) \
            else str(src_or_path)
        if isinstance(node_or_line, ast.AST):
            line = getattr(node_or_line, "lineno", 0)
            col = getattr(node_or_line, "col_offset", 0)
        else:
            line, col = int(node_or_line), 0
        return Diagnostic(path=path, line=line, col=col,
                          rule=self.rule, message=message)


_REGISTRY: dict[str, type[LintPass]] = {}


def register(cls: type[LintPass]) -> type[LintPass]:
    """Class decorator: add a pass to the global registry (keyed by its
    rule id; re-registering a rule id replaces the pass, which is what a
    downstream override wants)."""
    if not cls.rule or cls.rule == "RA???":
        raise ValueError(f"{cls.__name__} must set a rule id")
    _REGISTRY[cls.rule] = cls
    RULE_DOCS[cls.rule] = cls.doc.strip().splitlines()[0] if cls.doc else ""
    return cls


def registered_passes(select: Iterable[str] | None = None) -> list[LintPass]:
    """Instantiate the registered passes (optionally only ``select``)."""
    import repro.analysis.lint.passes  # noqa: F401  (self-registration)
    wanted = set(select) if select is not None else None
    if wanted is not None:
        unknown = wanted - set(_REGISTRY) - {"RA000"}
        if unknown:
            raise ValueError(f"unknown rule ids: {sorted(unknown)} "
                             f"(known: {sorted(_REGISTRY)})")
    return [cls() for rule, cls in sorted(_REGISTRY.items())
            if wanted is None or rule in wanted]


def _parse_suppressions(text: str) -> list[Suppression]:
    """Extract ``# repro: ignore[...]`` comments via :mod:`tokenize`, so
    the pattern never matches inside string literals or docstrings (a
    lint framework whose own documentation trips its suppressions is no
    framework at all)."""
    import io
    import tokenize
    out = []
    try:
        tokens = list(tokenize.generate_tokens(io.StringIO(text).readline))
    except (tokenize.TokenError, SyntaxError, IndentationError):
        tokens = []
    for tok in tokens:
        if tok.type != tokenize.COMMENT or "repro:" not in tok.string:
            continue
        m = _IGNORE_RE.search(tok.string)
        if not m:
            continue
        rules = frozenset(r.strip().upper()
                          for r in m.group(1).split(",") if r.strip())
        out.append(Suppression(line=tok.start[0], rules=rules,
                               justification=m.group(2).strip()))
    return out


def parse_file(path: str | Path) -> SourceFile:
    p = Path(path)
    text = p.read_text()
    tree = ast.parse(text, filename=str(p))
    return SourceFile(path=str(p), text=text, tree=tree,
                      suppressions=_parse_suppressions(text))


def _iter_py_files(paths: Iterable[str | Path]) -> Iterator[Path]:
    for raw in paths:
        p = Path(raw)
        if p.is_dir():
            yield from sorted(q for q in p.rglob("*.py")
                              if "__pycache__" not in q.parts)
        elif p.suffix == ".py":
            yield p


def run_project(project: Project,
                select: Iterable[str] | None = None) -> list[Diagnostic]:
    """Run the (selected) registered passes over an already-parsed
    project and apply suppression comments.  Returns sorted diagnostics:
    pass findings minus suppressed ones, plus RA000 for every
    unjustified or unknown-rule suppression."""
    passes = registered_passes(select)
    raw: list[Diagnostic] = []
    for pa in passes:
        for src in project.files:
            raw.extend(pa.check(src, project))
        raw.extend(pa.finalize(project))

    by_path = {f.path: f for f in project.files}
    out: list[Diagnostic] = []
    for d in raw:
        src = by_path.get(d.path)
        if src is not None and d.rule in src.suppressed_rules_at(d.line):
            continue
        out.append(d)

    # RA000: suppression hygiene (never itself suppressible)
    want_ra000 = select is None or "RA000" in set(select)
    if want_ra000:
        known = set(_REGISTRY) | {"RA000"}
        for src in project.files:
            for s in src.suppressions:
                unknown = s.rules - known
                if unknown:
                    out.append(Diagnostic(
                        path=src.path, line=s.line, col=0, rule="RA000",
                        message=f"ignore[] names unknown rule(s) "
                                f"{sorted(unknown)} (known: {sorted(known)})"))
                if not s.justified:
                    out.append(Diagnostic(
                        path=src.path, line=s.line, col=0, rule="RA000",
                        message="suppression without justification — write "
                                "'# repro: ignore[RULE] -- <why this is "
                                "safe here>'"))
    return sorted(set(out))


def run_paths(paths: Iterable[str | Path],
              select: Iterable[str] | None = None,
              ) -> tuple[list[Diagnostic], Project]:
    """Parse every ``.py`` under ``paths`` and lint them as one project.
    Unparseable files become a synthetic RA000-style diagnostic rather
    than an exception: the lint gate must report, not crash."""
    project = Project()
    errors: list[Diagnostic] = []
    for p in _iter_py_files(paths):
        try:
            project.files.append(parse_file(p))
        except (SyntaxError, UnicodeDecodeError, OSError) as e:
            errors.append(Diagnostic(
                path=str(p), line=getattr(e, "lineno", 0) or 0, col=0,
                rule="RA000", message=f"unparseable file: {e}"))
    return sorted(set(errors) | set(run_project(project, select))), project
