"""Repo-native static analysis: the recurring JAX bug classes as
enforced lint passes (RA001–RA005, plus RA000 suppression hygiene).

Entry points:

>>> from repro.analysis.lint import run_paths
>>> diagnostics, project = run_paths(["src"])

or the CLI: ``python scripts/lint_repro.py``.
"""
from .core import (Diagnostic, LintPass, Project, RULE_DOCS, SourceFile,
                   Suppression, parse_file, register, registered_passes,
                   run_paths, run_project)

__all__ = ["Diagnostic", "LintPass", "Project", "RULE_DOCS", "SourceFile",
           "Suppression", "parse_file", "register", "registered_passes",
           "run_paths", "run_project"]
