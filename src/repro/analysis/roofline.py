"""Three-term roofline model over dry-run artifacts (TPU v5e target).

  compute term    = HLO_FLOPs_total    / (chips * PEAK_FLOPS)
  memory term     = HLO_bytes_total    / (chips * HBM_BW)
  collective term = collective_bytes_total / (chips * LINK_BW)

cost_analysis() on the SPMD-partitioned module reports *per device* flops
and bytes, and the HLO parse gives *per device* collective bytes, so each
term reduces to per_device_quantity / per_chip_rate — we keep both views.

MODEL_FLOPS uses the 6·N·D convention (N params — active params for MoE —
D tokens processed) so the "useful fraction" HLO ratio catches remat and
dispatch waste.
"""
from __future__ import annotations

from dataclasses import dataclass, asdict

# TPU v5e hardware constants (per chip) — from the assignment.
PEAK_FLOPS = 197e12         # bf16 FLOP/s
HBM_BW = 819e9              # bytes/s
LINK_BW = 50e9              # bytes/s per ICI link


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    model_flops_total: float
    step_tokens: int

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes_per_device / LINK_BW

    @property
    def bound(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step-time estimate: max of the three overlappable terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flop_fraction(self) -> float:
        hlo_total = self.flops_per_device * self.chips
        return self.model_flops_total / hlo_total if hlo_total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """MODEL_FLOPS-time / achieved step time — the headline perf score."""
        ideal = self.model_flops_total / (self.chips * PEAK_FLOPS)
        t = self.step_time_s
        return ideal / t if t else 0.0

    def to_dict(self) -> dict:
        d = asdict(self)
        d.update(compute_s=self.compute_s, memory_s=self.memory_s,
                 collective_s=self.collective_s, bound=self.bound,
                 step_time_s=self.step_time_s,
                 useful_flop_fraction=self.useful_flop_fraction,
                 roofline_fraction=self.roofline_fraction)
        return d


def model_flops(cfg, kind: str, seq: int, global_batch: int) -> tuple[float, int]:
    """(6·N_active·tokens for train, 2·N·tokens for inference), tokens."""
    n_active = cfg.active_param_count()
    if kind == "train":
        tokens = seq * global_batch
        return 6.0 * n_active * tokens, tokens
    if kind == "prefill":
        tokens = seq * global_batch
        return 2.0 * n_active * tokens, tokens
    # decode: one token per sequence
    tokens = global_batch
    return 2.0 * n_active * tokens, tokens
