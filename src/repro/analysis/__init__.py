from .hlo_stats import HloStats, analyze_hlo, parse_hlo
from .roofline import HBM_BW, LINK_BW, PEAK_FLOPS, Roofline, model_flops

__all__ = ["HloStats", "analyze_hlo", "parse_hlo", "HBM_BW", "LINK_BW",
           "PEAK_FLOPS", "Roofline", "model_flops"]
