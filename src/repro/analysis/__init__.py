from .hlo_stats import HloStats, analyze_hlo, parse_hlo
from .roofline import HBM_BW, LINK_BW, PEAK_FLOPS, Roofline, model_flops

#: repro.analysis.lint (the static-analysis suite) is intentionally NOT
#: imported here: the runtime analysis tools above are jax-adjacent,
#: the linter is pure-stdlib and must import fast in CI.

__all__ = ["HloStats", "analyze_hlo", "parse_hlo", "HBM_BW", "LINK_BW",
           "PEAK_FLOPS", "Roofline", "model_flops"]
