"""Trip-count-aware statistics over compiled (SPMD, per-device) HLO text.

XLA's HloCostAnalysis counts ``while`` bodies once, so for scan-over-layers
models its flops/bytes are ~n_layers too low.  We parse the module text:

  * computation blocks + a module-wide symbol table (instr name -> shape),
  * ``while`` instructions with ``known_trip_count`` backend configs
    (fallback: largest s32 constant in the condition block),
  * per-block multipliers = product of enclosing loop trip counts,

and accumulate, per device:
  * dot/conv FLOPs   : 2 * prod(out) * prod(lhs contracting dims) * mult
  * collective bytes : ring-model wire traffic (all-reduce 2x(g-1)/g, etc.)
  * hbm bytes        : sum of (output + operand) bytes of top-level ops —
    an upper bound on HBM traffic (CPU-backend fusion is coarser than TPU).
"""
from __future__ import annotations

import json
import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1, "token": 0,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")
_FREE_OPS = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
             "after-all", "iota"}
# ops whose operand/output traffic counts toward the HBM term: compute and
# data-movement kernels.  Pure elementwise/broadcast/convert ops are assumed
# fused into their consumers (TPU XLA behaviour); CPU-backend leaves them
# top-level, which would otherwise overcount ~5-10x.
_MEM_OPS = {"dot", "convolution", "fusion", "scatter", "gather",
            "dynamic-slice", "dynamic-update-slice", "reduce", "reduce-window",
            "sort", "copy", "concatenate", "pad", "slice", "select-and-scatter",
            "custom-call", "cholesky", "triangular-solve", "fft", "rng",
            "transpose"}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
# einsum signatures of loops whose production path is a Pallas kernel
# (flash attention fwd/bwd, SSD scan): their loop-internal tensors live in
# VMEM on TPU, so with kernel_vmem=True their HBM charge reduces to the
# streamed slices (K/V chunk reads, output writes).
_KERNEL_SIG_RE = re.compile(r"(bthg|bchd->|->bthgc|blmh|bmhp->|blhn|bhpn->)")
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*"
    r"((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s*"
    r"([a-z0-9\-]+)\(")
_BLOCK_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->.*{")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_BODY_RE = re.compile(r"body=%([\w.\-]+)")
_COND_RE = re.compile(r"condition=%([\w.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_LHS_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_WINDOW_RE = re.compile(r"window=\{size=([0-9x]+)")


def _shape_bytes(shape_text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(shape_text: str) -> list[int]:
    m = _SHAPE_RE.search(shape_text)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


def _operand_names(line: str, opcode: str) -> list[str]:
    start = line.find(opcode + "(")
    if start < 0:
        return []
    i = start + len(opcode) + 1
    depth = 1
    j = i
    while j < len(line) and depth:
        if line[j] == "(":
            depth += 1
        elif line[j] == ")":
            depth -= 1
        j += 1
    return re.findall(r"%([\w.\-]+)", line[i:j - 1])


@dataclass
class HloStats:
    flops: float = 0.0                           # per device, trip-aware
    collective_bytes: int = 0                    # wire bytes per device
    collective_counts: dict = field(default_factory=dict)   # dynamic counts
    collective_bytes_by_op: dict = field(default_factory=dict)
    hbm_bytes: float = 0.0                       # fusion-aware traffic proxy
    hbm_bytes_naive: float = 0.0                 # all-top-level-ops upper bound
    hbm_bytes_kernel_adj: float = 0.0            # Pallas-kernel-aware (VMEM)
    kernel_blocks: int = 0
    n_while_loops: int = 0
    static_collectives: int = 0
    dot_flops_by_block: dict = field(default_factory=dict)


def _split_blocks(text: str) -> dict[str, list[str]]:
    blocks: dict[str, list[str]] = {}
    cur = None
    for line in text.splitlines():
        if not line.startswith(" ") and "{" in line:
            m = _BLOCK_RE.match(line.strip())
            if m:
                cur = m.group(1)
                blocks[cur] = []
                continue
        if cur is not None:
            if line.startswith("}"):
                cur = None
                continue
            blocks[cur].append(line)
    return blocks


def analyze_hlo(text: str, default_group: int = 1) -> HloStats:
    blocks = _split_blocks(text)
    entry = None
    for name in blocks:
        if ".clone" not in name and "_spmd" in name and name.startswith("main"):
            entry = name
    if entry is None:  # fall back: the block containing whiles or last block
        for name in blocks:
            if name.startswith("main") or name == "ENTRY":
                entry = name
        entry = entry or (list(blocks)[-1] if blocks else None)

    # symbol table: instr -> shape text (module-wide; names are unique)
    shapes: dict[str, str] = {}
    producers: dict[str, tuple[str, list[str]]] = {}
    # whiles: (container_block, body, cond, trip)
    whiles: list[tuple[str, str, str, int]] = []
    for bname, lines in blocks.items():
        for line in lines:
            m = _DEF_RE.match(line)
            if not m:
                continue
            name, shape_text, opcode = m.groups()
            shapes[name] = shape_text
            if opcode == "convert":
                producers[name] = (opcode, _operand_names(line, opcode))
            if opcode == "while":
                body = _BODY_RE.search(line)
                cond = _COND_RE.search(line)
                trip_m = _TRIP_RE.search(line)
                trip = int(trip_m.group(1)) if trip_m else 0
                whiles.append((bname, body.group(1) if body else "",
                               cond.group(1) if cond else "", trip))

    # fallback trip counts from condition constants
    def cond_trip(cond: str) -> int:
        best = 1
        for line in blocks.get(cond, []):
            for m in re.finditer(r"s32\[\] constant\((\d+)\)", line):
                best = max(best, int(m.group(1)))
        return best

    mult: dict[str, float] = defaultdict(float)
    if entry:
        mult[entry] = 1.0
    # fixpoint propagation through (possibly nested) loops
    for _ in range(len(whiles) + 2):
        changed = False
        for container, body, cond, trip in whiles:
            if mult[container] <= 0:
                continue
            t = trip if trip > 0 else cond_trip(cond)
            want = mult[container] * max(t, 1)
            if body and abs(mult[body] - want) > 1e-9:
                mult[body] = want
                changed = True
            if cond and abs(mult[cond] - want) > 1e-9:
                mult[cond] = want
        if not changed:
            break

    stats = HloStats(n_while_loops=len(whiles))
    counts: dict[str, float] = defaultdict(float)
    by_op: dict[str, float] = defaultdict(float)
    flops_by_block: dict[str, float] = defaultdict(float)

    # blocks whose production path is a fused Pallas kernel (flash attn /
    # SSD): loop-internal tensors are VMEM-resident on TPU
    kernel_blocks = set()
    for bname, lines in blocks.items():
        if mult.get(bname, 0.0) > 0 and any(
                _KERNEL_SIG_RE.search(l) for l in lines if " dot(" in l):
            kernel_blocks.add(bname)
    stats.kernel_blocks = len(kernel_blocks)

    for bname, lines in blocks.items():
        m_b = mult.get(bname, 0.0)
        if m_b <= 0:
            continue
        in_kernel = bname in kernel_blocks
        # each named buffer is charged once per block execution for the
        # kernel-adjusted view: CPU-backend fusion fragmentation otherwise
        # bills one tensor through many small fusions (TPU fuses wider)
        seen_buffers: set[str] = set()
        for line in lines:
            m = _DEF_RE.match(line)
            if not m:
                continue
            name, shape_text, opcode = m.groups()
            if opcode in _FREE_OPS:
                continue
            out_bytes = _shape_bytes(shape_text)
            opnds = _operand_names(line, opcode)
            in_bytes = sum(_shape_bytes(shapes.get(o, "")) for o in opnds)

            if opcode == "dot":
                out_dims = _shape_dims(shape_text)
                cd = _LHS_CDIMS_RE.search(line)
                lhs_shape = _shape_dims(shapes.get(opnds[0], "")) if opnds else []
                k = 1
                if cd and lhs_shape:
                    for idx in cd.group(1).split(","):
                        if idx:
                            k *= lhs_shape[int(idx)]
                f = 2.0 * k
                for d in out_dims:
                    f *= d
                stats.flops += f * m_b
                flops_by_block[bname] += f * m_b
            elif opcode == "convolution":
                out_dims = _shape_dims(shape_text)
                w = _WINDOW_RE.search(line)
                ksz = 1
                if w:
                    for d in w.group(1).split("x"):
                        ksz *= int(d)
                f = 2.0 * ksz
                for d in out_dims:
                    f *= d
                stats.flops += f * m_b
            elif opcode in COLLECTIVE_OPS or any(
                    opcode == c + sfx for c in COLLECTIVE_OPS
                    for sfx in ("-start", "-done")):
                base = next(c for c in COLLECTIVE_OPS if opcode.startswith(c))
                if opcode.endswith("-done"):
                    continue
                g = default_group
                gm = _GROUPS_RE.search(line)
                if gm:
                    g = max(int(gm.group(2)), 1)
                payload = max(out_bytes, in_bytes)
                # XLA:CPU has no native bf16 matmul, so it converts to f32
                # *before* the SPMD gather; XLA:TPU gathers bf16 and
                # converts after.  When the collective operand is a direct
                # convert of a half-width tensor, charge the narrow size.
                for o in opnds:
                    o_shape = shapes.get(o, "")
                    prod = producers.get(o)
                    if prod and prod[1]:
                        src_b = _shape_bytes(shapes.get(prod[1][0], ""))
                        if 0 < src_b <= _shape_bytes(o_shape) // 2:
                            payload //= 2
                            break
                    # CPU backend wraps the widening convert in a fusion
                    # ("convert_bitcast_fusion"): same correction applies.
                    if "convert" in o and o_shape.startswith(("f32", "s32")):
                        payload //= 2
                        break
                if base == "all-reduce":
                    wire = 2.0 * payload * (g - 1) / g
                elif base == "collective-permute":
                    wire = float(payload)
                else:
                    wire = payload * (g - 1) / g
                stats.collective_bytes += int(wire * m_b)
                counts[base] += m_b
                by_op[base] += wire * m_b
                stats.static_collectives += 1
                stats.hbm_bytes += (out_bytes + in_bytes) * m_b
                stats.hbm_bytes_naive += (out_bytes + in_bytes) * m_b
                stats.hbm_bytes_kernel_adj += (out_bytes + in_bytes) * m_b
                continue
            stats.hbm_bytes_naive += (out_bytes + in_bytes) * m_b
            if opcode not in _MEM_OPS:
                continue
            # data-movement ops: charge moved bytes, not full operand buffers
            # (a dynamic-slice inside a scan body must not be charged the
            # whole stacked parameter every iteration).
            slice_like = opcode in ("dynamic-slice", "gather",
                                    "dynamic-update-slice", "scatter")
            if opcode in ("dynamic-slice", "gather"):
                traffic = 2 * out_bytes
            elif opcode in ("dynamic-update-slice", "scatter"):
                op_sizes = [_shape_bytes(shapes.get(o, "")) for o in opnds]
                upd = min([s for s in op_sizes if s > 0], default=out_bytes)
                traffic = 2 * upd
            elif opcode in ("copy", "transpose", "concatenate", "pad", "slice"):
                traffic = 2 * out_bytes
            else:
                traffic = out_bytes + in_bytes
            stats.hbm_bytes += traffic * m_b
            # kernel-adjusted view: inside a flash/SSD loop only the
            # streamed slices (K/V chunk reads, cache writes) touch HBM;
            # outside, each buffer streams once per block execution.
            if in_kernel and not slice_like:
                continue
            if slice_like:
                stats.hbm_bytes_kernel_adj += traffic * m_b
            else:
                adj = out_bytes if name not in seen_buffers else 0
                seen_buffers.add(name)
                for o in opnds:
                    if o not in seen_buffers:
                        seen_buffers.add(o)
                        adj += _shape_bytes(shapes.get(o, ""))
                stats.hbm_bytes_kernel_adj += adj * m_b

    stats.collective_counts = {k: int(v) for k, v in counts.items()}
    stats.collective_bytes_by_op = {k: int(v) for k, v in by_op.items()}
    stats.dot_flops_by_block = {k: v for k, v in
                                sorted(flops_by_block.items(),
                                       key=lambda kv: -kv[1])[:8]}
    return stats


# Back-compat simple parser (tests exercise both paths)
def parse_hlo(hlo_text: str) -> HloStats:
    return analyze_hlo(hlo_text)
