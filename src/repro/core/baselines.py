"""Baseline estimators (paper §4.3): Naive, Online-M, Online-P.

All three are *node-unaware*: they predict the same runtime for every
target node — exactly how the paper evaluates them in the heterogeneous
scenario (their errors blow up on nodes unlike the training machine).
"""
from __future__ import annotations

import numpy as np
from scipy import stats

from .blr import pearson


class NaiveEstimator:
    """mean ratio r = mean(run_q / d_q); prediction = r * d."""

    def fit(self, sizes, runtimes):
        sizes = np.asarray(sizes, np.float64)
        runtimes = np.asarray(runtimes, np.float64)
        self.ratio_ = float(np.mean(runtimes / np.maximum(sizes, 1e-12)))
        return self

    def predict(self, size):
        return self.ratio_ * np.asarray(size, np.float64)


class OnlineM:
    """Da Silva et al. (Online-M): nearest data point (density clustering is
    impossible on the sparse local data, per the paper), ratio prediction if
    input-output correlation is significant, mean otherwise."""

    threshold = 0.75

    def fit(self, sizes, runtimes):
        self.sizes_ = np.asarray(sizes, np.float64)
        self.runtimes_ = np.asarray(runtimes, np.float64)
        self.corr_ = pearson(self.sizes_, self.runtimes_)
        self.mean_ = float(np.mean(self.runtimes_))
        return self

    def _ratio_pred(self, size):
        size = np.asarray(size, np.float64)
        idx = np.argmin(np.abs(self.sizes_[None, ...]
                               - np.atleast_1d(size)[..., None]), axis=-1)
        r = self.runtimes_[idx] / np.maximum(self.sizes_[idx], 1e-12)
        out = r * size
        return out if out.shape else float(out)

    def _uncorrelated(self, size):
        return np.full(np.shape(size), self.mean_) if np.shape(size) else self.mean_

    def predict(self, size):
        if self.corr_ > self.threshold:
            return self._ratio_pred(size)
        return self._uncorrelated(size)


class OnlineP(OnlineM):
    """Online-P: like Online-M but fits a Normal or Gamma distribution for
    the uncorrelated case and predicts its mean."""

    def fit(self, sizes, runtimes):
        super().fit(sizes, runtimes)
        y = self.runtimes_
        if len(y) >= 3 and np.std(y) > 0 and np.all(y > 0):
            # pick Normal vs Gamma by log-likelihood
            mu, sd = float(np.mean(y)), float(np.std(y, ddof=1) + 1e-12)
            ll_norm = float(np.sum(stats.norm.logpdf(y, mu, sd)))
            try:
                a, loc, scale = stats.gamma.fit(y, floc=0.0)
                ll_gamma = float(np.sum(stats.gamma.logpdf(y, a, loc, scale)))
            except (ValueError, RuntimeError):
                # scipy's MLE raises ValueError on degenerate samples and
                # FitError (a RuntimeError) on non-convergence; either way
                # the Gamma candidate simply loses the model selection
                ll_gamma = -np.inf
            if ll_gamma > ll_norm:
                self.dist_mean_ = float(a * scale)
            else:
                self.dist_mean_ = mu
        else:
            self.dist_mean_ = float(np.mean(y))
        return self

    def _uncorrelated(self, size):
        return (np.full(np.shape(size), self.dist_mean_)
                if np.shape(size) else self.dist_mean_)


BASELINES = {"naive": NaiveEstimator, "online_m": OnlineM, "online_p": OnlineP}
