"""Phase 1: infrastructure profiling with short, uniform microbenchmarks.

Paper analogue:  sysbench CPU  -> prime verification events/s (real, Python)
                 LINPACK       -> JAX matmul GFLOP/s (real, this host)
                 sysbench mem  -> JAX streaming bandwidth (real)
                 fio seq RW    -> tempfile sequential write/read MB/s (real)
plus the accelerator axis the 2022 paper didn't need:
                 collective    -> ICI/DCN link bandwidth (simulated for
                                  remote node types; measured constants).

Remote accelerator nodes cannot be touched from this container, so their
benchmarks are *simulated measurements*: the node's hidden true rates with
multiplicative measurement noise — exactly the information a real
microbenchmark would return.  Single-chip scores (the paper normalises to
single-core for comparability); the resource manager assigns whole chips.
"""
from __future__ import annotations

import os
import tempfile
import time
from dataclasses import dataclass, asdict

import numpy as np

from .nodes import NodeType

_BENCH_NOISE = 0.03   # relative measurement noise of a ~1 minute benchmark


@dataclass(frozen=True)
class BenchResult:
    node: str
    cpu_events_s: float       # sysbench analogue
    matmul_gflops: float      # LINPACK analogue (MXU/AVX peak proxy)
    mem_gbps: float           # memory stream
    io_read_mbps: float       # fio seq read
    io_write_mbps: float      # fio seq write
    link_gbps: float          # collective bandwidth (accelerators)

    def to_dict(self) -> dict:
        return asdict(self)


# ---------------------------------------------------------------------------
# Real benchmarks (the local node)
# ---------------------------------------------------------------------------
def _bench_primes(limit: int = 20_000, budget_s: float = 1.0) -> float:
    """sysbench-style: verify primes up to `limit`; return events/s."""
    def count_primes(n: int) -> int:
        cnt = 0
        for c in range(2, n):
            is_p = True
            d = 2
            while d * d <= c:
                if c % d == 0:
                    is_p = False
                    break
                d += 1
            cnt += is_p
        return cnt
    t0 = time.perf_counter()
    events = 0
    while time.perf_counter() - t0 < budget_s:
        count_primes(limit // 10)
        events += 1
    return events / (time.perf_counter() - t0)


def _bench_matmul(n: int = 512, reps: int = 8) -> float:
    import jax
    import jax.numpy as jnp
    # repro: ignore[RA002] -- hardware probe measures fp32 MXU throughput; the GFLOP/s figure is defined at this width, independent of the estimator's x64 policy
    x = jnp.ones((n, n), jnp.float32)
    f = jax.jit(lambda a: a @ a)
    f(x).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        x = f(x)
    x.block_until_ready()
    dt = time.perf_counter() - t0
    return 2.0 * n ** 3 * reps / dt / 1e9


def _bench_memory(mb: int = 256, reps: int = 8) -> float:
    import jax
    import jax.numpy as jnp
    n = mb * 1024 * 1024 // 4
    # repro: ignore[RA002] -- bandwidth probe: the MB->element count above assumes 4-byte lanes, so the buffer must stay fp32 regardless of x64 mode
    x = jnp.ones((n,), jnp.float32)
    f = jax.jit(lambda a: a * 1.000001 + 1.0)
    f(x).block_until_ready()
    t0 = time.perf_counter()
    y = x
    for _ in range(reps):
        y = f(y)
    y.block_until_ready()
    dt = time.perf_counter() - t0
    return 2.0 * n * 4 * reps / dt / 1e9     # read + write per element


def _bench_io(mb: int = 64) -> tuple[float, float]:
    buf = os.urandom(1024 * 1024)
    with tempfile.NamedTemporaryFile(delete=False) as f:
        path = f.name
        t0 = time.perf_counter()
        for _ in range(mb):
            f.write(buf)
        f.flush()
        os.fsync(f.fileno())
        w = mb / (time.perf_counter() - t0)
    t0 = time.perf_counter()
    with open(path, "rb") as f:
        while f.read(1024 * 1024):
            pass
    r = mb / (time.perf_counter() - t0)
    os.unlink(path)
    return r, w


def profile_local(node_name: str = "local-cpu", fast: bool = True) -> BenchResult:
    """Run the real microbenchmark suite on this host (sub-minute)."""
    r, w = _bench_io(16 if fast else 64)
    return BenchResult(
        node=node_name,
        cpu_events_s=_bench_primes(budget_s=0.5 if fast else 2.0),
        matmul_gflops=_bench_matmul(256 if fast else 512),
        mem_gbps=_bench_memory(64 if fast else 256),
        io_read_mbps=r, io_write_mbps=w,
        link_gbps=0.0)


# ---------------------------------------------------------------------------
# Simulated benchmarks (remote node types)
# ---------------------------------------------------------------------------
def profile_node(node: NodeType, rng: np.random.Generator | None = None,
                 noise: float = _BENCH_NOISE) -> BenchResult:
    rng = rng or np.random.default_rng(0)
    def meas(x):
        return float(x * rng.lognormal(0.0, noise))
    return BenchResult(
        node=node.name,
        cpu_events_s=meas(node.cpu_score),
        matmul_gflops=meas(node.peak_flops / 1e9),
        mem_gbps=meas(node.hbm_bw / 1e9),
        io_read_mbps=meas(node.io_bw),
        io_write_mbps=meas(node.io_bw * 0.98),
        link_gbps=meas(node.link_bw / 1e9))


def profile_cluster(nodes: list[NodeType], seed: int = 0) -> dict[str, BenchResult]:
    rng = np.random.default_rng(seed)
    return {n.name: profile_node(n, rng) for n in nodes}
