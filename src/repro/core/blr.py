"""Bayesian linear regression (the paper's §3.3 predictor), in JAX.

Conjugate Normal–Inverse-Gamma model:

    y_i = x_i^T b + eps_i,   eps_i ~ N(0, sigma^2)
    b | sigma^2 ~ N(mu0, sigma^2 V0),   sigma^2 ~ InvGamma(a0, b0)

with a Gaussian (L2 / ridge) prior on the weights, exactly as in the paper
("we decided to set the prior to a Gaussian distribution, which results in
an L2-regressor for our Bayesian regression").  The posterior predictive at
x* is a Student-t: mean x*^T mu_n, scale^2 = b_n/a_n (1 + x*^T V_n x*),
2 a_n degrees of freedom — this is where Lotaru's uncertainty estimates
come from.

Features are 1D (uncompressed input size / token count) plus an intercept;
everything is closed-form, tiny, and jit-able.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class BLRPosterior:
    mu: jnp.ndarray          # (d,) posterior mean of weights
    V: jnp.ndarray           # (d, d) posterior covariance factor
    a: jnp.ndarray           # shape of InvGamma
    b: jnp.ndarray           # scale of InvGamma
    x_scale: jnp.ndarray     # feature normalisation
    y_scale: jnp.ndarray

    @property
    def dof(self):
        return 2.0 * self.a

    @property
    def sigma2_mean(self):
        return self.b / jnp.maximum(self.a - 1.0, 1e-6)


def _design(x: jnp.ndarray, x_scale) -> jnp.ndarray:
    x = jnp.atleast_1d(x) / x_scale
    return jnp.stack([jnp.ones_like(x), x], axis=-1)


def fit(x: jnp.ndarray, y: jnp.ndarray, *, prior_scale: float = 10.0,
        a0: float = 1.0, b0: float = 1.0) -> BLRPosterior:
    """Fit runtime ~ input_size.  x, y: (n,) fp arrays (n may be tiny)."""
    x = jnp.asarray(x, jnp.float64 if jax.config.jax_enable_x64 else jnp.float32)
    y = jnp.asarray(y, x.dtype)
    x_scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12)
    y_scale = jnp.maximum(jnp.max(jnp.abs(y)), 1e-12)
    X = _design(x, x_scale)                      # (n, 2)
    yn = y / y_scale
    n, d = X.shape
    V0_inv = jnp.eye(d) / (prior_scale ** 2)
    mu0 = jnp.zeros(d)
    Vn_inv = V0_inv + X.T @ X
    Vn = jnp.linalg.inv(Vn_inv)
    mun = Vn @ (V0_inv @ mu0 + X.T @ yn)
    an = a0 + n / 2.0
    resid = yn - X @ mun
    bn = b0 + 0.5 * (resid @ yn + (mu0 - mun) @ (V0_inv @ mu0))
    bn = jnp.maximum(bn, 1e-12)
    return BLRPosterior(mu=mun, V=Vn, a=jnp.asarray(an), b=bn,
                        x_scale=x_scale, y_scale=y_scale)


def predict(post: BLRPosterior, x_star) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Posterior predictive mean and standard deviation at x_star."""
    Xs = _design(jnp.asarray(x_star, jnp.float32), post.x_scale)
    mean = Xs @ post.mu
    s2 = (post.b / post.a) * (1.0 + jnp.einsum("...i,ij,...j->...", Xs, post.V, Xs))
    dof = post.dof
    var = s2 * dof / jnp.maximum(dof - 2.0, 1e-6)   # Student-t variance
    mean = mean * post.y_scale
    std = jnp.sqrt(jnp.maximum(var, 0.0)) * post.y_scale
    if jnp.ndim(x_star) == 0:
        return mean.reshape(())[()], std.reshape(-1)[0]
    return mean, std


def predict_interval(post: BLRPosterior, x_star, confidence: float = 0.5):
    """Equal-tailed predictive interval via the Student-t quantile."""
    from scipy import stats
    mean, _ = predict(post, x_star)
    Xs = _design(jnp.asarray(x_star, jnp.float32), post.x_scale)
    scale = jnp.sqrt((post.b / post.a)
                     * (1.0 + jnp.einsum("...i,ij,...j->...", Xs, post.V, Xs)))
    tq = stats.t.ppf(0.5 + confidence / 2.0, df=float(post.dof))
    half = tq * scale * post.y_scale
    lo, hi = mean - half, mean + half
    if np.ndim(x_star) == 0:
        return (np.float64(np.asarray(lo).reshape(-1)[0]),
                np.float64(np.asarray(hi).reshape(-1)[0]))
    return lo, hi


def pearson(x, y) -> float:
    """Pearson correlation coefficient (paper eq. 1)."""
    x = np.asarray(x, np.float64)
    y = np.asarray(y, np.float64)
    xd = x - x.mean()
    yd = y - y.mean()
    denom = np.sqrt((xd ** 2).sum() * (yd ** 2).sum())
    if denom == 0:
        return 0.0
    return float((xd * yd).sum() / denom)


CORRELATION_THRESHOLD = 0.8   # paper: "significant if p greater than 0.8"


@dataclass(frozen=True)
class TaskModel:
    """Per-task predictor: BLR when size-runtime correlation is significant,
    median fallback otherwise (paper §3.3)."""
    correlated: bool
    post: BLRPosterior | None
    median: float
    spread: float               # robust std (MAD) for the median fallback

    def predict(self, x_star):
        if self.correlated:
            mean, std = predict(self.post, x_star)
            mean = np.maximum(np.asarray(mean, np.float64), 0.0)
            std = np.asarray(std, np.float64)
            if np.ndim(x_star) == 0:
                return np.float64(mean.reshape(-1)[0]), np.float64(std.reshape(-1)[0])
            return mean, std
        x = np.asarray(x_star, np.float64)
        shape = x.shape if x.ndim else ()
        return (np.full(shape, self.median) if shape else np.float64(self.median),
                np.full(shape, self.spread) if shape else np.float64(self.spread))


def fit_task(sizes, runtimes, *, threshold: float = CORRELATION_THRESHOLD) -> TaskModel:
    sizes = np.asarray(sizes, np.float64)
    runtimes = np.asarray(runtimes, np.float64)
    p = pearson(sizes, runtimes)
    if p > threshold and len(sizes) >= 2:
        post = fit(jnp.asarray(sizes), jnp.asarray(runtimes))
        return TaskModel(correlated=True, post=post,
                         median=float(np.median(runtimes)),
                         spread=float(1.4826 * np.median(
                             np.abs(runtimes - np.median(runtimes))) + 1e-12))
    return TaskModel(correlated=False, post=None,
                     median=float(np.median(runtimes)),
                     spread=float(1.4826 * np.median(
                         np.abs(runtimes - np.median(runtimes))) + 1e-12))
