"""Bayesian linear regression (the paper's §3.3 predictor), in JAX.

Conjugate Normal–Inverse-Gamma model:

    y_i = x_i^T b + eps_i,   eps_i ~ N(0, sigma^2)
    b | sigma^2 ~ N(mu0, sigma^2 V0),   sigma^2 ~ InvGamma(a0, b0)

with a Gaussian (L2 / ridge) prior on the weights, exactly as in the paper
("we decided to set the prior to a Gaussian distribution, which results in
an L2-regressor for our Bayesian regression").  The posterior predictive at
x* is a Student-t: mean x*^T mu_n, scale^2 = b_n/a_n (1 + x*^T V_n x*),
2 a_n degrees of freedom — this is where Lotaru's uncertainty estimates
come from.

Features are 1D (uncompressed input size / token count) plus an intercept;
everything is closed-form, tiny, and jit-able.

The batched engine: HEFT-class consumers need estimates for every
(task x node) pair, so all T per-task posteriors are fitted in ONE vmapped
closed-form solve (``fit_batch`` / ``fit_task_batch``; ragged sample counts
are handled by zeroing masked design rows so they contribute nothing to
X^T X, X^T y or n) and queried with a batched Student-t predictive
(``predict_batch`` returns (T,), ``predict_batch_grid`` returns (T, S)).
The scalar ``fit`` / ``predict`` are thin wrappers over the same core.

The online engine: conjugacy makes the NIG posterior a function of the
streamed sufficient statistics (n, Σx, Σy, Σx², Σy², Σxy, max|x|, max|y|),
so one new (size, runtime) observation is a rank-1 moment update plus an
O(d²) posterior recompute of the affected row — no refit over the history.
``fit_task_batch`` stows those statistics (plus the padded raw sample
buffers that the median fallback needs) in ``BatchedTaskModel.stats``;
``update_task_batch`` absorbs one observation in a single jitted call that
is mathematically identical to refitting on the concatenated data, with
the Pearson gate re-evaluated from the streamed moments.
``update_task_batch_stream`` scans a whole observation stream.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from scipy import stats as _scipy_stats


def _default_dtype():
    """The repo-wide numeric dtype policy: float64 iff ``jax_enable_x64``.

    Every cast in the estimator plane routes through here — a literal
    ``jnp.float32`` on a numeric path silently downcasts x64 runs (the
    PR 1 ``predict`` bug), which is why RA002 in
    ``repro.analysis.lint`` flags literal float dtypes in these
    modules.  This definition is the policy itself, not a cast call, so
    the literal below is the one sanctioned mention.
    """
    return jnp.float64 if jax.config.jax_enable_x64 else jnp.float32


#: short alias used by lint docs/messages ("the blr._dtype() policy")
_dtype = _default_dtype


@dataclass(frozen=True)
class BLRPosterior:
    mu: jnp.ndarray          # (d,) posterior mean of weights; (T, d) batched
    V: jnp.ndarray           # (d, d) posterior covariance factor
    a: jnp.ndarray           # shape of InvGamma
    b: jnp.ndarray           # scale of InvGamma
    x_scale: jnp.ndarray     # feature normalisation
    y_scale: jnp.ndarray

    @property
    def dof(self):
        return 2.0 * self.a

    @property
    def sigma2_mean(self):
        return self.b / jnp.maximum(self.a - 1.0, 1e-6)


jax.tree_util.register_dataclass(
    BLRPosterior,
    data_fields=["mu", "V", "a", "b", "x_scale", "y_scale"],
    meta_fields=[])


def _design(x: jnp.ndarray, x_scale) -> jnp.ndarray:
    x = jnp.atleast_1d(x) / x_scale
    return jnp.stack([jnp.ones_like(x), x], axis=-1)


def _fit_core(x, y, mask, prior_scale, a0, b0):
    """Closed-form NIG update over one task's (possibly padded) samples.

    ``mask`` rows set to 0 contribute nothing: the design row, the target
    and the effective sample count all vanish, so a padded batch solve is
    exactly the ragged per-task solve.
    """
    xm = x * mask
    ym = y * mask
    x_scale = jnp.maximum(jnp.max(jnp.abs(xm)), 1e-12)
    y_scale = jnp.maximum(jnp.max(jnp.abs(ym)), 1e-12)
    X = jnp.stack([mask, xm / x_scale], axis=-1)        # masked design rows
    yn = ym / y_scale
    n = jnp.sum(mask)
    d = 2
    V0_inv = jnp.eye(d, dtype=x.dtype) / (prior_scale ** 2)
    Vn_inv = V0_inv + X.T @ X
    Vn = jnp.linalg.inv(Vn_inv)
    mun = Vn @ (X.T @ yn)                               # mu0 = 0
    an = a0 + n / 2.0
    resid = yn - X @ mun
    bn = jnp.maximum(b0 + 0.5 * (resid @ yn), 1e-12)
    return mun, Vn, an, bn, x_scale, y_scale


def fit(x: jnp.ndarray, y: jnp.ndarray, *, prior_scale: float = 10.0,
        a0: float = 1.0, b0: float = 1.0) -> BLRPosterior:
    """Fit runtime ~ input_size.  x, y: (n,) fp arrays (n may be tiny)."""
    x = jnp.asarray(x, _default_dtype())
    y = jnp.asarray(y, x.dtype)
    mun, Vn, an, bn, xs, ys = _fit_core(x, y, jnp.ones_like(x),
                                        prior_scale, a0, b0)
    return BLRPosterior(mu=mun, V=Vn, a=jnp.asarray(an), b=bn,
                        x_scale=xs, y_scale=ys)


def fit_batch(x, y, mask=None, *, prior_scale: float = 10.0,
              a0: float = 1.0, b0: float = 1.0) -> BLRPosterior:
    """Fit T independent BLRs in one vmapped solve.

    x, y: (T, n) padded sample arrays; mask: (T, n) validity (1 = real
    sample, 0 = padding).  Returns a ``BLRPosterior`` whose fields carry a
    leading (T,) batch axis.
    """
    x = jnp.asarray(x, _default_dtype())
    y = jnp.asarray(y, x.dtype)
    mask = jnp.ones_like(x) if mask is None else jnp.asarray(mask, x.dtype)
    solve = jax.vmap(partial(_fit_core, prior_scale=prior_scale,
                             a0=a0, b0=b0))
    mun, Vn, an, bn, xs, ys = solve(x, y, mask)
    return BLRPosterior(mu=mun, V=Vn, a=an, b=bn, x_scale=xs, y_scale=ys)


def _predict_core(mu, V, a, b, x_scale, y_scale, x_star):
    """Student-t predictive mean/std for one posterior; x_star any shape."""
    X = jnp.stack([jnp.ones_like(x_star), x_star / x_scale], axis=-1)
    mean = X @ mu
    s2 = (b / a) * (1.0 + jnp.einsum("...i,ij,...j->...", X, V, X))
    dof = 2.0 * a
    var = s2 * dof / jnp.maximum(dof - 2.0, 1e-6)   # Student-t variance
    return mean * y_scale, jnp.sqrt(jnp.maximum(var, 0.0)) * y_scale


def predict(post: BLRPosterior, x_star) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Posterior predictive mean and standard deviation at x_star."""
    xs = jnp.atleast_1d(jnp.asarray(x_star, post.mu.dtype))
    mean, std = _predict_core(post.mu, post.V, post.a, post.b,
                              post.x_scale, post.y_scale, xs)
    if jnp.ndim(x_star) == 0:
        return mean.reshape(())[()], std.reshape(-1)[0]
    return mean, std


def predict_batch(post: BLRPosterior, x_star):
    """Batched predictive at one point per task.

    ``post`` carries a leading (T,) axis (from ``fit_batch``); ``x_star`` is
    a scalar (broadcast to every task) or a (T,) array.  Returns (T,) mean
    and std.
    """
    x = jnp.broadcast_to(jnp.asarray(x_star, post.mu.dtype), post.a.shape)
    return jax.vmap(_predict_core)(post.mu, post.V, post.a, post.b,
                                   post.x_scale, post.y_scale, x)


def predict_batch_grid(post: BLRPosterior, xs):
    """Batched predictive on a shared grid: xs (S,) -> (T, S) mean/std."""
    x = jnp.asarray(xs, post.mu.dtype)
    return jax.vmap(_predict_core,
                    in_axes=(0, 0, 0, 0, 0, 0, None))(
        post.mu, post.V, post.a, post.b, post.x_scale, post.y_scale, x)


def predict_interval(post: BLRPosterior, x_star, confidence: float = 0.5):
    """Equal-tailed predictive interval via the Student-t quantile.

    Vectorised: works on a scalar posterior with scalar/vector x_star, and
    on batched posteriors (leading (T,) axis) without a Python loop.
    """
    batched = jnp.ndim(post.a) > 0
    if batched:
        mean, _ = predict_batch(post, x_star)
        xq = jnp.broadcast_to(jnp.asarray(x_star, post.mu.dtype),
                              post.a.shape)
        X = jnp.stack([jnp.ones_like(xq), xq / post.x_scale], axis=-1)
        quad = jnp.einsum("ti,tij,tj->t", X, post.V, X)
    else:
        mean, _ = predict(post, x_star)
        X = _design(jnp.asarray(x_star, post.mu.dtype), post.x_scale)
        quad = jnp.einsum("...i,ij,...j->...", X, post.V, X)
    scale = np.asarray(jnp.sqrt((post.b / post.a) * (1.0 + quad)))
    tq = _scipy_stats.t.ppf(0.5 + confidence / 2.0, df=np.asarray(post.dof))
    half = tq * scale * np.asarray(post.y_scale)
    lo = np.asarray(mean) - half
    hi = np.asarray(mean) + half
    if np.ndim(x_star) == 0 and not batched:
        return (np.float64(lo.reshape(-1)[0]), np.float64(hi.reshape(-1)[0]))
    return lo, hi


def predict_cdf(post: BLRPosterior, x_star, y) -> float:
    """CDF of the posterior predictive at ``y`` — the probability the
    predictive Student-t at input ``x_star`` assigns to runtimes ≤ ``y``.

    This is the PIT (probability integral transform) primitive the
    calibration diagnostics consume: if the predictive distribution is
    calibrated, ``predict_cdf(post, x, y_observed)`` over a stream of
    realised runtimes is uniform on [0, 1].  Uses the exact same location
    / scale / dof as ``predict_interval`` (scalar path), so interval
    coverage and PIT agree by construction: ``lo <= y <= hi`` at
    confidence c  ⇔  PIT in [0.5 - c/2, 0.5 + c/2].
    """
    mean, _ = predict(post, x_star)
    X = _design(jnp.asarray(x_star, post.mu.dtype), post.x_scale)
    quad = jnp.einsum("...i,ij,...j->...", X, post.V, X)
    scale = float(np.asarray(jnp.sqrt((post.b / post.a) * (1.0 + quad)))
                  .reshape(-1)[0]) * float(np.asarray(post.y_scale))
    z = (float(y) - float(np.asarray(mean).reshape(-1)[0])) \
        / max(scale, 1e-300)
    return float(_scipy_stats.t.cdf(z, df=float(np.asarray(post.dof))))


def pearson(x, y) -> float:
    """Pearson correlation coefficient (paper eq. 1)."""
    x = np.asarray(x, np.float64)
    y = np.asarray(y, np.float64)
    xd = x - x.mean()
    yd = y - y.mean()
    denom = np.sqrt((xd ** 2).sum() * (yd ** 2).sum())
    if denom == 0:
        return 0.0
    return float((xd * yd).sum() / denom)


def pearson_batch(x, y, mask=None) -> np.ndarray:
    """Vectorised Pearson over (T, n) rows with an optional validity mask."""
    x = np.asarray(x, np.float64)
    y = np.asarray(y, np.float64)
    m = np.ones_like(x) if mask is None else np.asarray(mask, np.float64)
    n = np.maximum(m.sum(axis=-1), 1.0)
    xd = (x - (x * m).sum(axis=-1, keepdims=True) / n[..., None]) * m
    yd = (y - (y * m).sum(axis=-1, keepdims=True) / n[..., None]) * m
    denom = np.sqrt((xd ** 2).sum(axis=-1) * (yd ** 2).sum(axis=-1))
    num = (xd * yd).sum(axis=-1)
    return np.where(denom == 0, 0.0, num / np.where(denom == 0, 1.0, denom))


CORRELATION_THRESHOLD = 0.8   # paper: "significant if p greater than 0.8"


@dataclass(frozen=True)
class TaskModel:
    """Per-task predictor: BLR when size-runtime correlation is significant,
    median fallback otherwise (paper §3.3)."""
    correlated: bool
    post: BLRPosterior | None
    median: float
    spread: float               # robust std (MAD) for the median fallback

    def predict(self, x_star):
        if self.correlated:
            mean, std = predict(self.post, x_star)
            mean = np.maximum(np.asarray(mean, np.float64), 0.0)
            std = np.asarray(std, np.float64)
            if np.ndim(x_star) == 0:
                return np.float64(mean.reshape(-1)[0]), np.float64(std.reshape(-1)[0])
            return mean, std
        x = np.asarray(x_star, np.float64)
        shape = x.shape if x.ndim else ()
        return (np.full(shape, self.median) if shape else np.float64(self.median),
                np.full(shape, self.spread) if shape else np.float64(self.spread))


def fit_task(sizes, runtimes, *, threshold: float = CORRELATION_THRESHOLD) -> TaskModel:
    sizes = np.asarray(sizes, np.float64)
    runtimes = np.asarray(runtimes, np.float64)
    p = pearson(sizes, runtimes)
    if p > threshold and len(sizes) >= 2:
        post = fit(jnp.asarray(sizes), jnp.asarray(runtimes))
        return TaskModel(correlated=True, post=post,
                         median=float(np.median(runtimes)),
                         spread=float(1.4826 * np.median(
                             np.abs(runtimes - np.median(runtimes))) + 1e-12))
    return TaskModel(correlated=False, post=None,
                     median=float(np.median(runtimes)),
                     spread=float(1.4826 * np.median(
                         np.abs(runtimes - np.median(runtimes))) + 1e-12))


# ---------------------------------------------------------------------------
# Batched per-task models (BLR + median fallback) — one vmapped solve
# ---------------------------------------------------------------------------
class SampleLog:
    """Host-side mutable raw-sample history of T tasks.

    Only the median/MAD fallback needs the raw samples (order statistics
    are not a function of fixed-size moments), and it needs exactly one
    row per update — so the history lives OUTSIDE the traced pytree as
    plain numpy, mutated in place with amortised-O(1) appends.  This keeps
    the jitted update free of large buffer scatters and of host↔device
    syncs for capacity checks.

    The log rides along as a pytree *meta* field; equality/hash are
    class-level so treedefs (and therefore jit caches) are shared across
    fits — no jitted function may read its contents.
    """
    __slots__ = ("x", "y", "count")

    def __init__(self, x: np.ndarray, y: np.ndarray, count: np.ndarray):
        self.x = x            # (T, C) float64, padded
        self.y = y            # (T, C)
        self.count = count    # (T,) int64

    def __eq__(self, other):
        return isinstance(other, SampleLog)

    def __hash__(self):
        return 0

    def append(self, i: int, xv: float, yv: float) -> None:
        cap = self.x.shape[1]
        if self.count[i] >= cap:
            pad = ((0, 0), (0, cap))            # double the capacity
            self.x = np.pad(self.x, pad)
            self.y = np.pad(self.y, pad)
        k = self.count[i]
        self.x[i, k] = xv
        self.y[i, k] = yv
        self.count[i] = k + 1

    def median_spread(self, i: int) -> tuple[float, float]:
        row = self.y[i, :self.count[i]]
        med = float(np.median(row))
        return med, float(1.4826 * np.median(np.abs(row - med)) + 1e-12)

    def copy(self) -> "SampleLog":
        return SampleLog(self.x.copy(), self.y.copy(), self.count.copy())


@dataclass(frozen=True)
class OnlineStats:
    """Streamed sufficient statistics of T tasks' (size, runtime) samples.

    ``moments[t] = [n, Σx, Σy, Σx², Σy², Σxy, max|x|, max|y|]`` — one
    (T, 8) array so the rank-1 update is a single gather + scatter.  The
    moments determine the NIG posterior exactly (see
    ``_posterior_from_stats``); ``log`` is the untraced raw history the
    median fallback reads host-side.

    CAUTION: ``log`` is pytree *meta* with class-level equality, so jitted
    functions returning a model resurrect whatever log was captured at
    trace time — always re-attach the live log after a jit boundary
    (``_attach_log``), and never read ``log`` inside jit.
    """
    moments: jnp.ndarray    # (T, 8)
    log: SampleLog | None = None

    @property
    def n(self):
        return self.moments[..., 0]

    @property
    def x_absmax(self):
        return self.moments[..., 6]

    @property
    def y_absmax(self):
        return self.moments[..., 7]


jax.tree_util.register_dataclass(
    OnlineStats, data_fields=["moments"], meta_fields=["log"])


def _stats_from_padded(X, Y, M, dt) -> OnlineStats:
    """Initial sufficient statistics from the padded (T, C) fit arrays."""
    xm = np.asarray(X, np.float64) * M
    ym = np.asarray(Y, np.float64) * M
    moments = np.stack([
        M.sum(axis=-1), xm.sum(axis=-1), ym.sum(axis=-1),
        (xm * xm).sum(axis=-1), (ym * ym).sum(axis=-1),
        (xm * ym).sum(axis=-1),
        np.abs(xm).max(axis=-1), np.abs(ym).max(axis=-1)], axis=-1)
    log = SampleLog(np.asarray(X, np.float64).copy(),
                    np.asarray(Y, np.float64).copy(),
                    np.asarray(np.sum(M, axis=-1), np.int64))
    return OnlineStats(moments=jnp.asarray(moments, dt), log=log)


def _attach_log(model: BatchedTaskModel, log: SampleLog) -> BatchedTaskModel:
    """Re-bind the live host-side log after a jit boundary (see
    ``OnlineStats``: jit outputs carry the trace-time log object)."""
    return BatchedTaskModel(
        correlated=model.correlated, post=model.post, median=model.median,
        spread=model.spread,
        stats=OnlineStats(moments=model.stats.moments, log=log))


def _posterior_from_stats(m, prior_scale, a0, b0):
    """One task's NIG posterior from its moment row — the same quantities
    ``_fit_core`` builds from design rows:  X^T X, X^T y and y^T y are
    linear in the moments, so the result is mathematically identical to
    refitting on the full sample history."""
    n, sx, sy, sxx, syy, sxy = m[0], m[1], m[2], m[3], m[4], m[5]
    dt = m.dtype
    x_scale = jnp.maximum(m[6], 1e-12)
    y_scale = jnp.maximum(m[7], 1e-12)
    XtX = jnp.array([[n, sx / x_scale],
                     [sx / x_scale, sxx / (x_scale * x_scale)]], dt)
    Xty = jnp.array([sy, sxy / x_scale], dt) / y_scale
    V0_inv = jnp.eye(2, dtype=dt) / (prior_scale ** 2)
    Vn = jnp.linalg.inv(V0_inv + XtX)
    mun = Vn @ Xty
    an = a0 + n / 2.0
    # resid @ yn = yn·yn − mun·(X^T yn), with yn·yn = Σy² / y_scale²
    bn = jnp.maximum(b0 + 0.5 * (syy / (y_scale * y_scale) - mun @ Xty),
                     1e-12)
    return mun, Vn, an, bn, x_scale, y_scale


@dataclass(frozen=True)
class BatchedTaskModel:
    """T per-task predictors fitted at once; Pearson gating vectorised.

    ``post`` is a batched ``BLRPosterior`` (leading (T,) axis).  Tasks whose
    size-runtime correlation fails the gate fall back to (median, spread)
    exactly like the scalar ``TaskModel``.  ``stats`` (when present) are the
    streamed sufficient statistics that let ``update_task_batch`` absorb new
    observations without a refit; models assembled from bare posteriors
    (``stack_task_models``) carry ``stats=None`` and cannot be updated.
    """
    correlated: jnp.ndarray     # (T,) bool
    post: BLRPosterior          # batched fields, (T, ...)
    median: jnp.ndarray         # (T,)
    spread: jnp.ndarray         # (T,)
    stats: OnlineStats | None = None


jax.tree_util.register_dataclass(
    BatchedTaskModel,
    data_fields=["correlated", "post", "median", "spread", "stats"],
    meta_fields=[])


def fit_task_batch(sizes_list, runtimes_list, *,
                   threshold: float = CORRELATION_THRESHOLD) -> BatchedTaskModel:
    """Fit all T tasks in one vmapped closed-form solve.

    ``sizes_list`` / ``runtimes_list``: length-T sequences of per-task 1-D
    sample arrays; ragged sample counts are padded and masked out of the
    design, so the result matches T scalar ``fit_task`` calls.
    """
    T = len(sizes_list)
    if T == 0:
        raise ValueError("fit_task_batch needs at least one task")
    nmax = max(len(np.atleast_1d(s)) for s in sizes_list)
    X = np.zeros((T, nmax))
    Y = np.zeros((T, nmax))
    M = np.zeros((T, nmax))
    for i, (s, r) in enumerate(zip(sizes_list, runtimes_list)):
        s = np.atleast_1d(np.asarray(s, np.float64))
        r = np.atleast_1d(np.asarray(r, np.float64))
        if len(s) != len(r):
            raise ValueError(
                f"task {i}: {len(s)} sizes vs {len(r)} runtimes — padding "
                "would silently count zeros as real samples")
        X[i, :len(s)] = s
        Y[i, :len(r)] = r
        M[i, :len(s)] = 1.0
    p = pearson_batch(X, Y, M)
    counts = M.sum(axis=-1)
    correlated = (p > threshold) & (counts >= 2)
    post = fit_batch(X, Y, M)
    Yv = np.where(M > 0, Y, np.nan)
    med = np.nanmedian(Yv, axis=-1)
    spread = 1.4826 * np.nanmedian(np.abs(Yv - med[:, None]), axis=-1) + 1e-12
    dt = post.mu.dtype
    stats = _stats_from_padded(X, Y, M, dt)
    return BatchedTaskModel(correlated=jnp.asarray(correlated),
                            post=post,
                            median=jnp.asarray(med, dt),
                            spread=jnp.asarray(spread, dt),
                            stats=stats)


def stack_task_models(models) -> BatchedTaskModel:
    """Stack already-fitted scalar ``TaskModel``s into the batched container
    (posterior-exact: no refit; uncorrelated slots get inert placeholders)."""
    dt = _default_dtype()
    d = 2
    mus, Vs, As, Bs, xs, ys = [], [], [], [], [], []
    for m in models:
        if m.post is not None:
            mus.append(np.asarray(m.post.mu, np.float64))
            Vs.append(np.asarray(m.post.V, np.float64))
            As.append(float(m.post.a))
            Bs.append(float(m.post.b))
            xs.append(float(m.post.x_scale))
            ys.append(float(m.post.y_scale))
        else:
            mus.append(np.zeros(d))
            Vs.append(np.eye(d))
            As.append(1.5)
            Bs.append(1.0)
            xs.append(1.0)
            ys.append(1.0)
    post = BLRPosterior(mu=jnp.asarray(np.stack(mus), dt),
                        V=jnp.asarray(np.stack(Vs), dt),
                        a=jnp.asarray(As, dt), b=jnp.asarray(Bs, dt),
                        x_scale=jnp.asarray(xs, dt),
                        y_scale=jnp.asarray(ys, dt))
    return BatchedTaskModel(
        correlated=jnp.asarray([m.correlated for m in models]),
        post=post,
        median=jnp.asarray([m.median for m in models], dt),
        spread=jnp.asarray([m.spread for m in models], dt))


def predict_task_batch(model: BatchedTaskModel, x_star):
    """Batched ``TaskModel.predict``: (T,) mean/std at one point per task.

    ``x_star`` scalar or (T,).  BLR mean is clamped at 0 exactly like the
    scalar path; uncorrelated tasks return (median, spread).
    """
    mean_b, std_b = predict_batch(model.post, x_star)
    mean = jnp.where(model.correlated, jnp.maximum(mean_b, 0.0), model.median)
    std = jnp.where(model.correlated, std_b, model.spread)
    return mean, std


def predict_task_batch_grid(model: BatchedTaskModel, xs):
    """Batched predictive on a shared grid: xs (S,) -> (T, S) mean/std."""
    mean_b, std_b = predict_batch_grid(model.post, xs)
    corr = model.correlated[:, None]
    mean = jnp.where(corr, jnp.maximum(mean_b, 0.0), model.median[:, None])
    std = jnp.where(corr, std_b, model.spread[:, None])
    return mean, std


def slice_task_model(model: BatchedTaskModel, i: int) -> TaskModel:
    """One row of a batched model as a scalar ``TaskModel``
    (posterior-exact: the row is a view of the batched fit, no refit)."""
    p = model.post
    return TaskModel(
        correlated=bool(model.correlated[i]),
        post=BLRPosterior(mu=p.mu[i], V=p.V[i], a=p.a[i], b=p.b[i],
                          x_scale=p.x_scale[i], y_scale=p.y_scale[i]),
        median=float(model.median[i]), spread=float(model.spread[i]))


def unstack_task_models(model: BatchedTaskModel) -> list[TaskModel]:
    """Slice a batched model back into T scalar ``TaskModel``s."""
    return [slice_task_model(model, i)
            for i in range(model.correlated.shape[0])]


# ---------------------------------------------------------------------------
# Incremental (online) updates — rank-1 conjugate absorption of one sample
# ---------------------------------------------------------------------------
def _update_core_impl(model: BatchedTaskModel, obs,
                      prior_scale, a0, b0, threshold) -> BatchedTaskModel:
    """Absorb one observation, packed as ``obs = [row, x, y, med, spr]``.

    A rank-1 moment update plus an O(d²) posterior recompute of the row —
    functional scatters into the batched arrays, jit-compiled once and
    scan-friendly (fixed shapes; the row is a traced index).  Packing the
    five scalars into one vector keeps the hot path at a single
    host→device transfer (per-scalar ``device_put`` costs ~60µs each).
    ``med`` / ``spr`` are the row's refreshed median/MAD, computed
    host-side from the untraced ``SampleLog`` (order statistics are not
    moments).

    Unjitted body so larger fused kernels (``repro.core.tick``) can scan
    it inside their own trace; standalone callers go through
    ``_update_core`` below.
    """
    i = obs[0].astype(jnp.int32)
    x, y, med, spr = obs[1], obs[2], obs[3], obs[4]
    st = model.stats
    row = st.moments[i]
    one = jnp.ones_like(x)
    m = jnp.concatenate([
        row[:6] + jnp.stack([one, x, y, x * x, y * y, x * y]),
        jnp.maximum(row[6:], jnp.stack([jnp.abs(x), jnp.abs(y)]))])
    n = m[0]
    mun, Vn, an, bn, xs, ys = _posterior_from_stats(m, prior_scale, a0, b0)
    p = model.post
    post = BLRPosterior(mu=p.mu.at[i].set(mun), V=p.V.at[i].set(Vn),
                        a=p.a.at[i].set(an), b=p.b.at[i].set(bn),
                        x_scale=p.x_scale.at[i].set(xs),
                        y_scale=p.y_scale.at[i].set(ys))
    # Pearson gate from the streamed moments (identical to pearson_batch's
    # centred form: Σ(x-x̄)(y-ȳ) = Σxy − ΣxΣy/n)
    num = m[5] - m[1] * m[2] / n
    den2 = (m[3] - m[1] ** 2 / n) * (m[4] - m[2] ** 2 / n)
    den = jnp.sqrt(jnp.maximum(den2, 0.0))
    pear = jnp.where(den > 0, num / jnp.where(den > 0, den, 1.0), 0.0)
    corr = (pear > threshold) & (n >= 2)
    return BatchedTaskModel(
        correlated=model.correlated.at[i].set(corr), post=post,
        median=model.median.at[i].set(med),
        spread=model.spread.at[i].set(spr),
        stats=OnlineStats(moments=st.moments.at[i].set(m), log=st.log))


_update_core = jax.jit(_update_core_impl,
                       static_argnames=("prior_scale", "a0", "b0",
                                        "threshold"))


def _require_stats(model: BatchedTaskModel) -> None:
    if model.stats is None or model.stats.log is None:
        raise ValueError(
            "model carries no sufficient statistics (built via "
            "stack_task_models?) — refit with fit_task_batch to enable "
            "incremental updates")


def update_task_batch(model: BatchedTaskModel, task_idx: int, x, y, *,
                      prior_scale: float = 10.0, a0: float = 1.0,
                      b0: float = 1.0,
                      threshold: float = CORRELATION_THRESHOLD
                      ) -> BatchedTaskModel:
    """Absorb one (size, runtime) observation into task ``task_idx``.

    Mathematically identical to ``fit_task_batch`` on the concatenated
    sample history (same hyperparameters), but O(d²) on the affected row
    instead of a full refit, with no host↔device sync on the hot path.
    Returns a new model.  The posterior arrays of the input are unchanged;
    the raw-sample ``SampleLog`` is shared and mutated in place (treat the
    input model as consumed, like an optimiser state).
    """
    _require_stats(model)
    log = model.stats.log
    i = int(task_idx)
    log.append(i, float(x), float(y))
    med, spr = log.median_spread(i)
    # hand jit the raw numpy vector: one transfer, no eager device_put
    obs = np.array([i, x, y, med, spr], np.float64)
    return _attach_log(_update_core(model, obs, prior_scale, a0, b0,
                                    threshold), log)


# ---------------------------------------------------------------------------
# Per-(task, node) multiplicative bias — conjugate posterior on log-residuals
# ---------------------------------------------------------------------------
class BiasModel:
    """Systematic per-(task, node) residual learned online.

    The factor adjustment transfers the *average* hardware ratio, but real
    tasks hit different codepaths per machine, leaving a stable per-pair
    residual the factor cannot capture (the paper's Tables 4-6 error
    floor).  Model the multiplicative bias ``b[t, n]`` of task ``t`` on
    node ``n`` through its log:

        log r_k ~ N(beta, sigma_r^2),   beta ~ N(0, tau0^2)

    where ``r_k = measured / (factor x local prediction)`` is the k-th
    observed residual of the pair.  Conjugacy gives the closed-form
    posterior ``beta | r_1..r_n ~ N(mu, v)`` with

        lam = 1/tau0^2 + n/sigma_r^2,  mu = (sum log r)/(sigma_r^2 lam),
        v = 1/lam

    so the point estimate ``exp(mu)`` shrinks toward 1.0 under few
    observations and ``v`` quantifies how unsure the bias still is —
    consumers widen their predictive std/interval by it.  Pairs with zero
    observations are INERT (bias 1, no widening): the layer only activates
    where evidence exists, so a freshly fitted estimator predicts exactly
    like the pure factor-scaled path.

    State is three (T, N) float64 host arrays (counts, sum log r,
    sum (log r)^2) — sufficient statistics, so updates are O(batch) numpy
    scatters and the whole object serialises to JSON losslessly.  Row
    order follows the estimator's ``task_names()``; column order is the
    estimator's fixed node universe.

    Two online refinements, both inert at their defaults:

    * ``decay`` — exponential forgetting on the sufficient statistics:
      every ``update`` batch first multiplies (counts, log_sum, log_sq)
      by ``decay``, so older residuals carry weight ``decay^age`` and the
      posterior tracks slow hardware drift (thermal throttling, creeping
      contention) instead of averaging it away.  ``decay=1.0`` (default)
      is bit-exact with the decay-free model: the multiply is skipped
      entirely, not merely a multiply-by-one.
    * ``empirical_bayes`` — pool the residual noise scale from the data:
      ``effective_sigma_r()`` replaces the fixed ``sigma_r`` with the
      pooled within-pair spread of the observed log-residuals
      (``residual_spread``), so shrinkage weights match the cluster's
      actual noise level rather than a guessed 0.25.  Until any pair has
      two observations the configured ``sigma_r`` is used unchanged.
    """

    __slots__ = ("counts", "log_sum", "log_sq", "tau0", "sigma_r",
                 "decay", "empirical_bayes", "_sigma_r_cache")

    #: floor for the empirical-Bayes pooled noise scale — a cluster whose
    #: observed residuals are (near-)deterministic would otherwise drive
    #: sigma_r -> 0 and make a single residual look infinitely informative
    SIGMA_R_FLOOR = 0.02

    def __init__(self, n_tasks: int, n_nodes: int, *, tau0: float = 0.5,
                 sigma_r: float = 0.25, decay: float = 1.0,
                 empirical_bayes: bool = False, counts=None, log_sum=None,
                 log_sq=None):
        shape = (n_tasks, n_nodes)
        self.counts = (np.zeros(shape) if counts is None
                       else np.asarray(counts, np.float64).reshape(shape))
        self.log_sum = (np.zeros(shape) if log_sum is None
                        else np.asarray(log_sum, np.float64).reshape(shape))
        self.log_sq = (np.zeros(shape) if log_sq is None
                       else np.asarray(log_sq, np.float64).reshape(shape))
        self.tau0 = float(tau0)
        self.sigma_r = float(sigma_r)
        if not 0.0 < decay <= 1.0:
            raise ValueError(f"decay must be in (0, 1], got {decay}")
        self.decay = float(decay)
        self.empirical_bayes = bool(empirical_bayes)
        self._sigma_r_cache: float | None = None

    @property
    def shape(self) -> tuple[int, int]:
        return self.counts.shape

    def effective_sigma_r(self) -> float:
        """The residual noise scale the posterior actually uses: the fixed
        ``sigma_r``, or — with ``empirical_bayes`` — the pooled empirical
        spread of the observed log-residuals (floored at
        ``SIGMA_R_FLOOR``), falling back to the fixed value while no pair
        has two observations yet.

        Memoised between updates: scalar consumers (``point`` /
        ``tail_mass`` / ``interval_scale``) may be called per running
        task per executor tick, and the pooled spread is an O(T·N)
        reduction — ``update`` invalidates the cache."""
        if not self.empirical_bayes:
            return self.sigma_r
        if self._sigma_r_cache is None:
            s = self.residual_spread()
            self._sigma_r_cache = (self.sigma_r if not np.isfinite(s)
                                   else max(s, self.SIGMA_R_FLOOR))
        return self._sigma_r_cache

    def update(self, rows, cols, log_resid) -> None:
        """Absorb a batch of log-residuals at (rows[k], cols[k]) — repeated
        pairs accumulate (``np.add.at`` scatter).

        With ``decay < 1`` the whole sufficient-statistic state is decayed
        once per call, *before* the batch is absorbed — one ``update`` is
        one forgetting step, so callers batching a simulation tick decay
        per tick, not per observation."""
        rows = np.asarray(rows, np.int64)
        cols = np.asarray(cols, np.int64)
        lr = np.asarray(log_resid, np.float64)
        if self.decay != 1.0:
            self.counts *= self.decay
            self.log_sum *= self.decay
            self.log_sq *= self.decay
        np.add.at(self.counts, (rows, cols), 1.0)
        np.add.at(self.log_sum, (rows, cols), lr)
        np.add.at(self.log_sq, (rows, cols), lr * lr)
        self._sigma_r_cache = None

    def posterior(self) -> tuple[np.ndarray, np.ndarray]:
        """(mu, v): posterior mean and variance of the log-bias, (T, N)."""
        sr = self.effective_sigma_r()
        lam = 1.0 / self.tau0 ** 2 + self.counts / sr ** 2
        mu = self.log_sum / (sr ** 2 * lam)
        return mu, 1.0 / lam

    def matrix(self, cols=None) -> np.ndarray:
        """(T, N') multiplicative bias point estimates, inert (1.0) where
        unobserved; ``cols`` selects/reorders node columns."""
        mu, _ = self.posterior()
        b = np.where(self.counts > 0, np.exp(mu), 1.0)
        return b if cols is None else b[:, cols]

    def widen_std(self, mean, std, cols=None) -> np.ndarray:
        """Fold the bias into a predictive std: the bias-scaled std plus
        the residual uncertainty of the bias itself (delta method on
        ``exp(beta)``), inert where unobserved.

        ``mean`` / ``std`` are the bias-free (T, N') prediction arrays.
        """
        mu, v = self.posterior()
        if cols is not None:
            mu, v = mu[:, cols], v[:, cols]
            n = self.counts[:, cols]
        else:
            n = self.counts
        widened = np.exp(mu) * np.sqrt(
            np.asarray(std, np.float64) ** 2
            + np.asarray(mean, np.float64) ** 2 * np.expm1(v))
        return np.where(n > 0, widened, std)

    def _pair(self, i: int, j: int) -> tuple[float, float, float]:
        """(n, mu, v) of one (task, node) pair without building matrices."""
        n = float(self.counts[i, j])
        sr = self.effective_sigma_r()
        lam = 1.0 / self.tau0 ** 2 + n / sr ** 2
        mu = float(self.log_sum[i, j]) / (sr ** 2 * lam)
        return n, mu, 1.0 / lam

    def point(self, i: int, j: int) -> float:
        """Scalar bias point estimate for one pair (1.0 when unobserved)."""
        n, mu, _ = self._pair(i, j)
        return float(np.exp(mu)) if n > 0 else 1.0

    def fold_scalar(self, i: int, j: int, mean: float, std: float
                    ) -> tuple[float, float]:
        """Scalar twin of ``matrix``/``widen_std`` (the matrix consumers'
        equivalence oracle — keep the two in lock-step)."""
        n, mu, v = self._pair(i, j)
        if n <= 0:
            return float(mean), float(std)
        b = float(np.exp(mu))
        return (float(mean) * b,
                b * float(np.sqrt(std ** 2 + mean ** 2 * np.expm1(v))))

    def interval_scale(self, i: int, j: int, z: float
                       ) -> tuple[float, float]:
        """Multiplicative (lo, hi) scales for an equal-tailed predictive
        interval: the bias point estimate spread by ``z`` posterior sds of
        the log-bias — (1, 1) when the pair is unobserved."""
        n, mu, v = self._pair(i, j)
        if n <= 0:
            return 1.0, 1.0
        sd = float(np.sqrt(v))
        return float(np.exp(mu - z * sd)), float(np.exp(mu + z * sd))

    def tail_mass(self, i: int, j: int, threshold: float) -> float:
        """Posterior probability that the pair's multiplicative bias
        exceeds ``threshold``: ``P(exp(beta) > threshold)`` under the
        Normal posterior on the log-bias.

        This is the admission statistic for risk-aware speculation: the
        point estimate ``exp(mu)`` crosses a threshold the moment ``mu``
        does (tail mass 0.5), while requiring more tail mass demands the
        whole posterior — not just its centre — to sit above the drift
        line, so a single noisy residual cannot trigger a copy.  Returns
        0.0 for unobserved pairs (no evidence of drift); an observed
        pair's bias ``exp(beta)`` is almost-surely positive, so any
        ``threshold <= 0`` yields the full mass 1.0 (matching the
        point-estimate comparison at the same threshold)."""
        n, mu, v = self._pair(i, j)
        if n <= 0:
            return 0.0
        if threshold <= 0.0:
            return 1.0
        z = (np.log(threshold) - mu) / np.sqrt(v)
        return float(_scipy_stats.norm.sf(z))

    def residual_spread(self) -> float:
        """Pooled empirical sd of the log-residuals around their per-pair
        means — the data-driven counterpart of ``sigma_r``, and the
        quantity ``effective_sigma_r`` substitutes for it under
        ``empirical_bayes``.  A spread far from the configured ``sigma_r``
        means the shrinkage weights are mis-calibrated for this cluster.
        NaN until some pair has at least two observations."""
        n = self.counts
        mask = n >= 2
        if not mask.any():
            return float("nan")
        ss = self.log_sq[mask] - self.log_sum[mask] ** 2 / n[mask]
        dof = (n[mask] - 1).sum()
        return float(np.sqrt(max(ss.sum(), 0.0) / max(dof, 1.0)))

    def expand_rows(self, n_tasks: int) -> None:
        """Grow the task axis (new tasks appended) preserving history."""
        t0, n0 = self.counts.shape
        if n_tasks < t0:
            raise ValueError(f"cannot shrink bias rows {t0} -> {n_tasks}")
        if n_tasks == t0:
            return
        pad = ((0, n_tasks - t0), (0, 0))
        self.counts = np.pad(self.counts, pad)
        self.log_sum = np.pad(self.log_sum, pad)
        self.log_sq = np.pad(self.log_sq, pad)

    def to_dict(self) -> dict:
        return {"tau0": self.tau0, "sigma_r": self.sigma_r,
                "decay": self.decay,
                "empirical_bayes": self.empirical_bayes,
                "counts": self.counts.tolist(),
                "log_sum": self.log_sum.tolist(),
                "log_sq": self.log_sq.tolist()}

    @classmethod
    def from_dict(cls, d: dict) -> "BiasModel":
        counts = np.asarray(d["counts"], np.float64)
        # decay / empirical_bayes landed in schema v4; v3 files predate
        # them and get the (bit-exact) inert defaults
        return cls(counts.shape[0], counts.shape[1], tau0=d["tau0"],
                   sigma_r=d["sigma_r"], decay=d.get("decay", 1.0),
                   empirical_bayes=d.get("empirical_bayes", False),
                   counts=counts, log_sum=d["log_sum"], log_sq=d["log_sq"])


# ---------------------------------------------------------------------------
# Per-node attempt reliability — Beta–Binomial posterior on success rate
# ---------------------------------------------------------------------------
class ReliabilityModel:
    """Per-node attempt-success posterior learned online.

    The runtime posterior prices how LONG a task runs on a node; this
    prices whether an attempt there FINISHES at all.  Model each node's
    attempt-success probability with the conjugate Beta–Binomial:

        p_j ~ Beta(a0, b0),   attempt outcomes ~ Bernoulli(p_j)

    so after s successes and f failures the posterior is
    ``Beta(a0 + s, b0 + f)`` in closed form — the same Bayesian story the
    estimator tells for runtimes, extended to availability.  A task whose
    attempts fail must be retried, so with independent attempts the
    expected number of tries until success is ``1/p`` and the expected
    time-to-success on node j is ``mean_j / p_j``.  Schedulers therefore
    consume the multiplicative **reliability factor**

        factor(j, k) = 1 / max(E[p_j] - k * sd[p_j], P_FLOOR)

    where ``k`` widens by the posterior sd exactly like the runtime
    plane's ``risk_k`` — a node with few observed attempts keeps a wide
    posterior and is priced cautiously until evidence narrows it, and a
    flaky node's factor grows as failures accrue, pricing it out of HEFT
    placements.

    The prior (``a0=8, b0=1`` → E[p] ≈ 0.89) is deliberately optimistic
    and UNIFORM across nodes: before any evidence every node carries the
    same factor, so relative placement is (near-)unchanged and the layer
    only differentiates nodes as attempt outcomes stream in.  State is a
    plain ``{node: [successes, failures]}`` dict — JSON-serialisable for
    the estimator checkpoint (schema v5).
    """

    __slots__ = ("a0", "b0", "state")

    #: floor on the widened success probability — a node that failed every
    #: observed attempt must stay priceable (finite factor), not divide by
    #: zero; 0.05 caps the factor at 20x
    P_FLOOR = 0.05

    def __init__(self, a0: float = 8.0, b0: float = 1.0, state=None):
        if a0 <= 0 or b0 <= 0:
            raise ValueError(f"Beta prior needs a0, b0 > 0, got {a0}, {b0}")
        self.a0 = float(a0)
        self.b0 = float(b0)
        self.state: dict[str, list[float]] = {
            str(k): [float(v[0]), float(v[1])]
            for k, v in (state or {}).items()}

    def record(self, node: str, success: bool, weight: float = 1.0) -> None:
        """Absorb one attempt outcome on ``node`` (a kill the *scheduler*
        ordered — e.g. a lost speculative race — is not a node failure
        and must not be recorded)."""
        s, f = self.state.setdefault(str(node), [0.0, 0.0])
        if success:
            self.state[str(node)][0] = s + weight
        else:
            self.state[str(node)][1] = f + weight

    def counts(self, node: str) -> tuple[float, float]:
        s, f = self.state.get(str(node), (0.0, 0.0))
        return float(s), float(f)

    def _ab(self, node: str) -> tuple[float, float]:
        s, f = self.counts(node)
        return self.a0 + s, self.b0 + f

    def p_mean(self, node: str) -> float:
        """Posterior mean success probability E[p] = a/(a+b)."""
        a, b = self._ab(node)
        return a / (a + b)

    def p_sd(self, node: str) -> float:
        """Posterior sd of p: sqrt(ab / ((a+b)^2 (a+b+1)))."""
        a, b = self._ab(node)
        return float(np.sqrt(a * b / ((a + b) ** 2 * (a + b + 1.0))))

    def factor(self, node: str, k: float = 1.0) -> float:
        """Expected time-to-success multiplier ``1 / p_eff`` with the
        uncertainty-widened ``p_eff = max(E[p] - k*sd[p], P_FLOOR)``.
        Always finite (>= 1, capped at 1/P_FLOOR); what matters is the
        ORDERING: flakier and less-certain nodes price higher."""
        p_eff = max(self.p_mean(node) - k * self.p_sd(node), self.P_FLOOR)
        return 1.0 / p_eff

    def factors(self, nodes, k: float = 1.0) -> np.ndarray:
        """(N,) reliability factors in ``nodes`` order."""
        return np.array([self.factor(n, k) for n in nodes], np.float64)

    def to_dict(self) -> dict:
        return {"a0": self.a0, "b0": self.b0,
                "state": {k: list(v) for k, v in self.state.items()}}

    @classmethod
    def from_dict(cls, d: dict) -> "ReliabilityModel":
        return cls(a0=d["a0"], b0=d["b0"], state=d["state"])


def update_task_batch_stream(model: BatchedTaskModel, task_idx, x, y, *,
                             prior_scale: float = 10.0, a0: float = 1.0,
                             b0: float = 1.0,
                             threshold: float = CORRELATION_THRESHOLD
                             ) -> BatchedTaskModel:
    """Scan a whole observation stream through the single-update core.

    ``task_idx`` (S,) int, ``x`` / ``y`` (S,) — the medians are replayed
    host-side (the log is untraced), then one ``lax.scan`` absorbs the
    stream, so throughput is not bounded by Python dispatch.

    Like ``update_task_batch``, the input model is CONSUMED: its
    ``SampleLog`` is shared with the returned model and mutated in
    place.  Keep only the returned model (see docs/api.md).
    """
    _require_stats(model)
    task_idx = np.asarray(task_idx, np.int64)
    x = np.asarray(x, np.float64)
    y = np.asarray(y, np.float64)
    log = model.stats.log
    obs = np.empty((len(task_idx), 5))
    obs[:, 0] = task_idx
    obs[:, 1] = x
    obs[:, 2] = y
    for k, (i, xv, yv) in enumerate(zip(task_idx, x, y)):
        log.append(int(i), float(xv), float(yv))
        obs[k, 3], obs[k, 4] = log.median_spread(int(i))
    dt = model.post.mu.dtype

    def step(m, o):
        return _update_core(m, o, prior_scale, a0, b0, threshold), None

    model, _ = jax.lax.scan(step, model, jnp.asarray(obs, dt))
    return _attach_log(model, log)
