"""Bayesian linear regression (the paper's §3.3 predictor), in JAX.

Conjugate Normal–Inverse-Gamma model:

    y_i = x_i^T b + eps_i,   eps_i ~ N(0, sigma^2)
    b | sigma^2 ~ N(mu0, sigma^2 V0),   sigma^2 ~ InvGamma(a0, b0)

with a Gaussian (L2 / ridge) prior on the weights, exactly as in the paper
("we decided to set the prior to a Gaussian distribution, which results in
an L2-regressor for our Bayesian regression").  The posterior predictive at
x* is a Student-t: mean x*^T mu_n, scale^2 = b_n/a_n (1 + x*^T V_n x*),
2 a_n degrees of freedom — this is where Lotaru's uncertainty estimates
come from.

Features are 1D (uncompressed input size / token count) plus an intercept;
everything is closed-form, tiny, and jit-able.

The batched engine: HEFT-class consumers need estimates for every
(task x node) pair, so all T per-task posteriors are fitted in ONE vmapped
closed-form solve (``fit_batch`` / ``fit_task_batch``; ragged sample counts
are handled by zeroing masked design rows so they contribute nothing to
X^T X, X^T y or n) and queried with a batched Student-t predictive
(``predict_batch`` returns (T,), ``predict_batch_grid`` returns (T, S)).
The scalar ``fit`` / ``predict`` are thin wrappers over the same core.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from scipy import stats as _scipy_stats


def _default_dtype():
    return jnp.float64 if jax.config.jax_enable_x64 else jnp.float32


@dataclass(frozen=True)
class BLRPosterior:
    mu: jnp.ndarray          # (d,) posterior mean of weights; (T, d) batched
    V: jnp.ndarray           # (d, d) posterior covariance factor
    a: jnp.ndarray           # shape of InvGamma
    b: jnp.ndarray           # scale of InvGamma
    x_scale: jnp.ndarray     # feature normalisation
    y_scale: jnp.ndarray

    @property
    def dof(self):
        return 2.0 * self.a

    @property
    def sigma2_mean(self):
        return self.b / jnp.maximum(self.a - 1.0, 1e-6)


jax.tree_util.register_dataclass(
    BLRPosterior,
    data_fields=["mu", "V", "a", "b", "x_scale", "y_scale"],
    meta_fields=[])


def _design(x: jnp.ndarray, x_scale) -> jnp.ndarray:
    x = jnp.atleast_1d(x) / x_scale
    return jnp.stack([jnp.ones_like(x), x], axis=-1)


def _fit_core(x, y, mask, prior_scale, a0, b0):
    """Closed-form NIG update over one task's (possibly padded) samples.

    ``mask`` rows set to 0 contribute nothing: the design row, the target
    and the effective sample count all vanish, so a padded batch solve is
    exactly the ragged per-task solve.
    """
    xm = x * mask
    ym = y * mask
    x_scale = jnp.maximum(jnp.max(jnp.abs(xm)), 1e-12)
    y_scale = jnp.maximum(jnp.max(jnp.abs(ym)), 1e-12)
    X = jnp.stack([mask, xm / x_scale], axis=-1)        # masked design rows
    yn = ym / y_scale
    n = jnp.sum(mask)
    d = 2
    V0_inv = jnp.eye(d, dtype=x.dtype) / (prior_scale ** 2)
    Vn_inv = V0_inv + X.T @ X
    Vn = jnp.linalg.inv(Vn_inv)
    mun = Vn @ (X.T @ yn)                               # mu0 = 0
    an = a0 + n / 2.0
    resid = yn - X @ mun
    bn = jnp.maximum(b0 + 0.5 * (resid @ yn), 1e-12)
    return mun, Vn, an, bn, x_scale, y_scale


def fit(x: jnp.ndarray, y: jnp.ndarray, *, prior_scale: float = 10.0,
        a0: float = 1.0, b0: float = 1.0) -> BLRPosterior:
    """Fit runtime ~ input_size.  x, y: (n,) fp arrays (n may be tiny)."""
    x = jnp.asarray(x, _default_dtype())
    y = jnp.asarray(y, x.dtype)
    mun, Vn, an, bn, xs, ys = _fit_core(x, y, jnp.ones_like(x),
                                        prior_scale, a0, b0)
    return BLRPosterior(mu=mun, V=Vn, a=jnp.asarray(an), b=bn,
                        x_scale=xs, y_scale=ys)


def fit_batch(x, y, mask=None, *, prior_scale: float = 10.0,
              a0: float = 1.0, b0: float = 1.0) -> BLRPosterior:
    """Fit T independent BLRs in one vmapped solve.

    x, y: (T, n) padded sample arrays; mask: (T, n) validity (1 = real
    sample, 0 = padding).  Returns a ``BLRPosterior`` whose fields carry a
    leading (T,) batch axis.
    """
    x = jnp.asarray(x, _default_dtype())
    y = jnp.asarray(y, x.dtype)
    mask = jnp.ones_like(x) if mask is None else jnp.asarray(mask, x.dtype)
    solve = jax.vmap(partial(_fit_core, prior_scale=prior_scale,
                             a0=a0, b0=b0))
    mun, Vn, an, bn, xs, ys = solve(x, y, mask)
    return BLRPosterior(mu=mun, V=Vn, a=an, b=bn, x_scale=xs, y_scale=ys)


def _predict_core(mu, V, a, b, x_scale, y_scale, x_star):
    """Student-t predictive mean/std for one posterior; x_star any shape."""
    X = jnp.stack([jnp.ones_like(x_star), x_star / x_scale], axis=-1)
    mean = X @ mu
    s2 = (b / a) * (1.0 + jnp.einsum("...i,ij,...j->...", X, V, X))
    dof = 2.0 * a
    var = s2 * dof / jnp.maximum(dof - 2.0, 1e-6)   # Student-t variance
    return mean * y_scale, jnp.sqrt(jnp.maximum(var, 0.0)) * y_scale


def predict(post: BLRPosterior, x_star) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Posterior predictive mean and standard deviation at x_star."""
    xs = jnp.atleast_1d(jnp.asarray(x_star, post.mu.dtype))
    mean, std = _predict_core(post.mu, post.V, post.a, post.b,
                              post.x_scale, post.y_scale, xs)
    if jnp.ndim(x_star) == 0:
        return mean.reshape(())[()], std.reshape(-1)[0]
    return mean, std


def predict_batch(post: BLRPosterior, x_star):
    """Batched predictive at one point per task.

    ``post`` carries a leading (T,) axis (from ``fit_batch``); ``x_star`` is
    a scalar (broadcast to every task) or a (T,) array.  Returns (T,) mean
    and std.
    """
    x = jnp.broadcast_to(jnp.asarray(x_star, post.mu.dtype), post.a.shape)
    return jax.vmap(_predict_core)(post.mu, post.V, post.a, post.b,
                                   post.x_scale, post.y_scale, x)


def predict_batch_grid(post: BLRPosterior, xs):
    """Batched predictive on a shared grid: xs (S,) -> (T, S) mean/std."""
    x = jnp.asarray(xs, post.mu.dtype)
    return jax.vmap(_predict_core,
                    in_axes=(0, 0, 0, 0, 0, 0, None))(
        post.mu, post.V, post.a, post.b, post.x_scale, post.y_scale, x)


def predict_interval(post: BLRPosterior, x_star, confidence: float = 0.5):
    """Equal-tailed predictive interval via the Student-t quantile.

    Vectorised: works on a scalar posterior with scalar/vector x_star, and
    on batched posteriors (leading (T,) axis) without a Python loop.
    """
    batched = jnp.ndim(post.a) > 0
    if batched:
        mean, _ = predict_batch(post, x_star)
        xq = jnp.broadcast_to(jnp.asarray(x_star, post.mu.dtype),
                              post.a.shape)
        X = jnp.stack([jnp.ones_like(xq), xq / post.x_scale], axis=-1)
        quad = jnp.einsum("ti,tij,tj->t", X, post.V, X)
    else:
        mean, _ = predict(post, x_star)
        X = _design(jnp.asarray(x_star, post.mu.dtype), post.x_scale)
        quad = jnp.einsum("...i,ij,...j->...", X, post.V, X)
    scale = np.asarray(jnp.sqrt((post.b / post.a) * (1.0 + quad)))
    tq = _scipy_stats.t.ppf(0.5 + confidence / 2.0, df=np.asarray(post.dof))
    half = tq * scale * np.asarray(post.y_scale)
    lo = np.asarray(mean) - half
    hi = np.asarray(mean) + half
    if np.ndim(x_star) == 0 and not batched:
        return (np.float64(lo.reshape(-1)[0]), np.float64(hi.reshape(-1)[0]))
    return lo, hi


def pearson(x, y) -> float:
    """Pearson correlation coefficient (paper eq. 1)."""
    x = np.asarray(x, np.float64)
    y = np.asarray(y, np.float64)
    xd = x - x.mean()
    yd = y - y.mean()
    denom = np.sqrt((xd ** 2).sum() * (yd ** 2).sum())
    if denom == 0:
        return 0.0
    return float((xd * yd).sum() / denom)


def pearson_batch(x, y, mask=None) -> np.ndarray:
    """Vectorised Pearson over (T, n) rows with an optional validity mask."""
    x = np.asarray(x, np.float64)
    y = np.asarray(y, np.float64)
    m = np.ones_like(x) if mask is None else np.asarray(mask, np.float64)
    n = np.maximum(m.sum(axis=-1), 1.0)
    xd = (x - (x * m).sum(axis=-1, keepdims=True) / n[..., None]) * m
    yd = (y - (y * m).sum(axis=-1, keepdims=True) / n[..., None]) * m
    denom = np.sqrt((xd ** 2).sum(axis=-1) * (yd ** 2).sum(axis=-1))
    num = (xd * yd).sum(axis=-1)
    return np.where(denom == 0, 0.0, num / np.where(denom == 0, 1.0, denom))


CORRELATION_THRESHOLD = 0.8   # paper: "significant if p greater than 0.8"


@dataclass(frozen=True)
class TaskModel:
    """Per-task predictor: BLR when size-runtime correlation is significant,
    median fallback otherwise (paper §3.3)."""
    correlated: bool
    post: BLRPosterior | None
    median: float
    spread: float               # robust std (MAD) for the median fallback

    def predict(self, x_star):
        if self.correlated:
            mean, std = predict(self.post, x_star)
            mean = np.maximum(np.asarray(mean, np.float64), 0.0)
            std = np.asarray(std, np.float64)
            if np.ndim(x_star) == 0:
                return np.float64(mean.reshape(-1)[0]), np.float64(std.reshape(-1)[0])
            return mean, std
        x = np.asarray(x_star, np.float64)
        shape = x.shape if x.ndim else ()
        return (np.full(shape, self.median) if shape else np.float64(self.median),
                np.full(shape, self.spread) if shape else np.float64(self.spread))


def fit_task(sizes, runtimes, *, threshold: float = CORRELATION_THRESHOLD) -> TaskModel:
    sizes = np.asarray(sizes, np.float64)
    runtimes = np.asarray(runtimes, np.float64)
    p = pearson(sizes, runtimes)
    if p > threshold and len(sizes) >= 2:
        post = fit(jnp.asarray(sizes), jnp.asarray(runtimes))
        return TaskModel(correlated=True, post=post,
                         median=float(np.median(runtimes)),
                         spread=float(1.4826 * np.median(
                             np.abs(runtimes - np.median(runtimes))) + 1e-12))
    return TaskModel(correlated=False, post=None,
                     median=float(np.median(runtimes)),
                     spread=float(1.4826 * np.median(
                         np.abs(runtimes - np.median(runtimes))) + 1e-12))


# ---------------------------------------------------------------------------
# Batched per-task models (BLR + median fallback) — one vmapped solve
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class BatchedTaskModel:
    """T per-task predictors fitted at once; Pearson gating vectorised.

    ``post`` is a batched ``BLRPosterior`` (leading (T,) axis).  Tasks whose
    size-runtime correlation fails the gate fall back to (median, spread)
    exactly like the scalar ``TaskModel``.
    """
    correlated: jnp.ndarray     # (T,) bool
    post: BLRPosterior          # batched fields, (T, ...)
    median: jnp.ndarray         # (T,)
    spread: jnp.ndarray         # (T,)


jax.tree_util.register_dataclass(
    BatchedTaskModel,
    data_fields=["correlated", "post", "median", "spread"],
    meta_fields=[])


def fit_task_batch(sizes_list, runtimes_list, *,
                   threshold: float = CORRELATION_THRESHOLD) -> BatchedTaskModel:
    """Fit all T tasks in one vmapped closed-form solve.

    ``sizes_list`` / ``runtimes_list``: length-T sequences of per-task 1-D
    sample arrays; ragged sample counts are padded and masked out of the
    design, so the result matches T scalar ``fit_task`` calls.
    """
    T = len(sizes_list)
    if T == 0:
        raise ValueError("fit_task_batch needs at least one task")
    nmax = max(len(np.atleast_1d(s)) for s in sizes_list)
    X = np.zeros((T, nmax))
    Y = np.zeros((T, nmax))
    M = np.zeros((T, nmax))
    for i, (s, r) in enumerate(zip(sizes_list, runtimes_list)):
        s = np.atleast_1d(np.asarray(s, np.float64))
        r = np.atleast_1d(np.asarray(r, np.float64))
        if len(s) != len(r):
            raise ValueError(
                f"task {i}: {len(s)} sizes vs {len(r)} runtimes — padding "
                "would silently count zeros as real samples")
        X[i, :len(s)] = s
        Y[i, :len(r)] = r
        M[i, :len(s)] = 1.0
    p = pearson_batch(X, Y, M)
    counts = M.sum(axis=-1)
    correlated = (p > threshold) & (counts >= 2)
    post = fit_batch(X, Y, M)
    Yv = np.where(M > 0, Y, np.nan)
    med = np.nanmedian(Yv, axis=-1)
    spread = 1.4826 * np.nanmedian(np.abs(Yv - med[:, None]), axis=-1) + 1e-12
    dt = post.mu.dtype
    return BatchedTaskModel(correlated=jnp.asarray(correlated),
                            post=post,
                            median=jnp.asarray(med, dt),
                            spread=jnp.asarray(spread, dt))


def stack_task_models(models) -> BatchedTaskModel:
    """Stack already-fitted scalar ``TaskModel``s into the batched container
    (posterior-exact: no refit; uncorrelated slots get inert placeholders)."""
    dt = _default_dtype()
    d = 2
    mus, Vs, As, Bs, xs, ys = [], [], [], [], [], []
    for m in models:
        if m.post is not None:
            mus.append(np.asarray(m.post.mu, np.float64))
            Vs.append(np.asarray(m.post.V, np.float64))
            As.append(float(m.post.a))
            Bs.append(float(m.post.b))
            xs.append(float(m.post.x_scale))
            ys.append(float(m.post.y_scale))
        else:
            mus.append(np.zeros(d))
            Vs.append(np.eye(d))
            As.append(1.5)
            Bs.append(1.0)
            xs.append(1.0)
            ys.append(1.0)
    post = BLRPosterior(mu=jnp.asarray(np.stack(mus), dt),
                        V=jnp.asarray(np.stack(Vs), dt),
                        a=jnp.asarray(As, dt), b=jnp.asarray(Bs, dt),
                        x_scale=jnp.asarray(xs, dt),
                        y_scale=jnp.asarray(ys, dt))
    return BatchedTaskModel(
        correlated=jnp.asarray([m.correlated for m in models]),
        post=post,
        median=jnp.asarray([m.median for m in models], dt),
        spread=jnp.asarray([m.spread for m in models], dt))


def predict_task_batch(model: BatchedTaskModel, x_star):
    """Batched ``TaskModel.predict``: (T,) mean/std at one point per task.

    ``x_star`` scalar or (T,).  BLR mean is clamped at 0 exactly like the
    scalar path; uncorrelated tasks return (median, spread).
    """
    mean_b, std_b = predict_batch(model.post, x_star)
    mean = jnp.where(model.correlated, jnp.maximum(mean_b, 0.0), model.median)
    std = jnp.where(model.correlated, std_b, model.spread)
    return mean, std


def predict_task_batch_grid(model: BatchedTaskModel, xs):
    """Batched predictive on a shared grid: xs (S,) -> (T, S) mean/std."""
    mean_b, std_b = predict_batch_grid(model.post, xs)
    corr = model.correlated[:, None]
    mean = jnp.where(corr, jnp.maximum(mean_b, 0.0), model.median[:, None])
    std = jnp.where(corr, std_b, model.spread[:, None])
    return mean, std
