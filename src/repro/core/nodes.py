"""Heterogeneous node-type registry.

The paper's cluster has 6 machine types (local, A1, A2, N1, N2, C2) that
differ in CPU and I/O capability.  Our accelerator analogue is a fleet of
TPU generations differing in peak FLOP/s, HBM and interconnect bandwidth —
plus the local CPU developer node where Lotaru's downsampled runs execute.

``true_*`` fields are the simulator's hidden ground truth; Lotaru only ever
sees microbenchmark *measurements* of them (with noise).  ``family_eff``
models per-task-family efficiency differences (e.g. scatter-heavy MoE
dispatch achieves a lower fraction of peak on older generations) — this is
what makes a single scalar factor per node *imperfect*, exactly the regime
the paper studies.
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class NodeType:
    name: str
    # accelerator plane (per chip)
    peak_flops: float
    hbm_bw: float
    link_bw: float
    # host plane (genomics workload analogue, per core)
    cpu_score: float          # sysbench-like events/s
    io_bw: float              # MB/s sequential
    mem_score: float
    chips_per_node: int = 4
    # hidden per-family efficiency (fraction of roofline actually achieved)
    family_eff: dict = field(default_factory=dict)

    def eff(self, family: str) -> float:
        return self.family_eff.get(family, self.family_eff.get("*", 0.55))


# The six machine types (paper Table 2 analogue).  "local" mirrors the
# paper's developer workstation; A1/A2 are old commodity nodes (TPUv2/v3
# analogue), N1/N2/C2 map to v4/v5e/v5p.
NODE_TYPES: dict[str, NodeType] = {
    "local-cpu": NodeType(
        name="local-cpu", peak_flops=0.15e12, hbm_bw=40e9, link_bw=8e9,
        cpu_score=458, io_bw=415.0, mem_score=18_700, chips_per_node=1,
        family_eff={"*": 0.50, "moe": 0.35, "ssm": 0.45}),
    "tpu-v2": NodeType(
        name="tpu-v2", peak_flops=46e12, hbm_bw=700e9, link_bw=25e9,
        cpu_score=223, io_bw=303.0, mem_score=11_000,
        family_eff={"*": 0.40, "moe": 0.25, "ssm": 0.30, "dense": 0.45}),
    "tpu-v3": NodeType(
        name="tpu-v3", peak_flops=123e12, hbm_bw=900e9, link_bw=35e9,
        cpu_score=223, io_bw=338.0, mem_score=11_000,
        family_eff={"*": 0.45, "moe": 0.30, "ssm": 0.35, "dense": 0.50}),
    "tpu-v4": NodeType(
        name="tpu-v4", peak_flops=275e12, hbm_bw=1228e9, link_bw=50e9,
        cpu_score=369, io_bw=482.0, mem_score=13_400,
        family_eff={"*": 0.52, "moe": 0.40, "ssm": 0.45, "dense": 0.58}),
    "tpu-v5e": NodeType(
        name="tpu-v5e", peak_flops=197e12, hbm_bw=819e9, link_bw=50e9,
        cpu_score=468, io_bw=482.0, mem_score=17_000,
        family_eff={"*": 0.55, "moe": 0.42, "ssm": 0.48, "dense": 0.62}),
    "tpu-v5p": NodeType(
        name="tpu-v5p", peak_flops=459e12, hbm_bw=2765e9, link_bw=100e9,
        cpu_score=523, io_bw=482.0, mem_score=18_900,
        family_eff={"*": 0.58, "moe": 0.45, "ssm": 0.50, "dense": 0.65}),
}

# paper-machine aliases (for the genomics plane benchmarks)
PAPER_ALIAS = {"Local": "local-cpu", "A1": "tpu-v2", "A2": "tpu-v3",
               "N1": "tpu-v4", "N2": "tpu-v5e", "C2": "tpu-v5p"}


def get_node(name: str) -> NodeType:
    return NODE_TYPES[PAPER_ALIAS.get(name, name)]


def target_nodes() -> list[NodeType]:
    return [n for k, n in NODE_TYPES.items() if k != "local-cpu"]
