"""Device-resident estimator state — one registered pytree for the tick.

PR 9 consolidates everything the online loop mutates per tick — the
NIG ``(T, 8)`` streamed moments and batched posterior (``blr``), the
per-(task, node) bias sufficient statistics (``BiasModel``), the
per-node reliability counts (``ReliabilityModel``) and the static
``(T, N)`` runtime-factor matrix — into a single ``EstimatorState``
pytree, so the whole observe → update → bias scatter → re-predict
sequence can run as ONE jitted, donated-buffer dispatch
(``repro.core.tick.tick_step``) and gain a leading workflow axis under
``vmap`` (``repro.online.fleet``).

Design split, mirroring ``BatchedTaskModel``'s data/meta convention:

* array leaves — everything jit/vmap/shard-able;
* ``StateMeta`` — the frozen, hashable hyperparameter record (bias
  prior scales, decay, NIG priors...). Meta, not data: python branches
  on it specialise the compiled tick (``decay == 1.0`` skips the
  forgetting multiply entirely, exactly like ``BiasModel.update``);
* ``StateNames`` — host-side row/column labels (task order, prediction
  node order, bias-column universe).  Deliberately OUTSIDE the pytree:
  strings never cross the device boundary.

The OO classes stay the public API as thin *views* over this state —
``bias_view`` / ``reliability_view`` rebuild bit-exact ``BiasModel`` /
``ReliabilityModel`` objects from the leaves, and ``write_back``
returns a mutated state into a live ``LotaruEstimator``.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import numpy as np
from jax import numpy as jnp

from .blr import (BatchedTaskModel, BiasModel, ReliabilityModel,
                  _default_dtype)


@dataclass(frozen=True)
class StateMeta:
    """Static hyperparameters of one estimator — hashable, so it rides
    the pytree as a meta field and jit specialises on it."""
    bias_correction: bool
    tau0: float
    sigma_r: float
    decay: float
    empirical_bayes: bool
    prior_scale: float = 10.0
    a0: float = 1.0
    b0: float = 1.0
    threshold: float = 0.8
    rel_a0: float = 8.0
    rel_b0: float = 1.0


@dataclass(frozen=True)
class StateNames:
    """Host-side label universe of an ``EstimatorState`` (not a pytree)."""
    tasks: tuple[str, ...]          # row order (estimator task_names())
    nodes: tuple[str, ...]          # prediction-column order (N axis)
    bias_nodes: tuple[str, ...]     # bias-column universe (Nb axis)
    rel_nodes: tuple[str, ...]      # reliability slot order (R axis)


@dataclass(frozen=True)
class EstimatorState:
    """All per-tick mutable estimator state as one pytree.

    Leaves (T tasks, N prediction nodes, Nb bias columns, R rel slots):

    * ``model``      — nested ``BatchedTaskModel`` (moments, posterior,
      Pearson gate, median/spread);
    * ``factors``    — (T, N) static runtime-factor matrix;
    * ``node_cols``  — (N,) int32 bias column of each prediction node,
      ``-1`` outside the bias universe;
    * ``bias_counts`` / ``bias_log_sum`` / ``bias_log_sq`` — (T, Nb)
      ``BiasModel`` sufficient statistics;
    * ``rel_succ`` / ``rel_fail`` — (R,) Beta-Binomial attempt counts.
    """
    model: BatchedTaskModel
    factors: jnp.ndarray
    node_cols: jnp.ndarray
    bias_counts: jnp.ndarray
    bias_log_sum: jnp.ndarray
    bias_log_sq: jnp.ndarray
    rel_succ: jnp.ndarray
    rel_fail: jnp.ndarray
    meta: StateMeta


jax.tree_util.register_dataclass(
    EstimatorState,
    data_fields=["model", "factors", "node_cols", "bias_counts",
                 "bias_log_sum", "bias_log_sq", "rel_succ", "rel_fail"],
    meta_fields=["meta"])


def build_state(est, nodes, rel_nodes=()) -> tuple[EstimatorState,
                                                   StateNames]:
    """Snapshot a fitted ``LotaruEstimator`` into an ``EstimatorState``.

    ``nodes`` fixes the prediction-column order (the executor's node
    *type* universe); ``rel_nodes`` the reliability slots (node
    *instances* — availability is a machine property).  The batched
    model is shared, not copied: its ``SampleLog`` stays the live
    host-side raw-sample history, exactly as in the legacy path.
    """
    names, model, _w = est._batched()
    dt = _default_dtype()
    nodes = tuple(nodes)
    rel_nodes = tuple(rel_nodes)
    factors = jnp.asarray(est.factor_matrix(list(nodes)), dt)
    if est.bias_correction:
        bias = est._ensure_bias()
        tau0, sigma_r = bias.tau0, bias.sigma_r
        decay, eb = bias.decay, bias.empirical_bayes
        counts = jnp.asarray(bias.counts, dt)
        log_sum = jnp.asarray(bias.log_sum, dt)
        log_sq = jnp.asarray(bias.log_sq, dt)
    else:
        opts = est._bias_opts
        tau0, sigma_r = 0.5, opts["sigma_r"]
        decay, eb = opts["decay"], opts["empirical_bayes"]
        counts = jnp.zeros((len(names), len(est.bias_nodes)), dt)
        log_sum = jnp.zeros_like(counts)
        log_sq = jnp.zeros_like(counts)
    node_cols = jnp.asarray([est._bias_col.get(n, -1) for n in nodes],
                            jnp.int32)
    rel = est.reliability
    rel_a0 = rel.a0 if rel is not None else 8.0
    rel_b0 = rel.b0 if rel is not None else 1.0
    succ = np.zeros(len(rel_nodes), np.float64)
    fail = np.zeros(len(rel_nodes), np.float64)
    if rel is not None:
        for k, n in enumerate(rel_nodes):
            succ[k], fail[k] = rel.counts(n)
    meta = StateMeta(bias_correction=bool(est.bias_correction),
                     tau0=float(tau0), sigma_r=float(sigma_r),
                     decay=float(decay), empirical_bayes=bool(eb),
                     rel_a0=float(rel_a0), rel_b0=float(rel_b0))
    state = EstimatorState(
        model=model, factors=factors, node_cols=node_cols,
        bias_counts=counts, bias_log_sum=log_sum, bias_log_sq=log_sq,
        rel_succ=jnp.asarray(succ, dt), rel_fail=jnp.asarray(fail, dt),
        meta=meta)
    return state, StateNames(tasks=tuple(names), nodes=nodes,
                             bias_nodes=tuple(est.bias_nodes),
                             rel_nodes=rel_nodes)


def bias_view(state: EstimatorState) -> BiasModel:
    """Rebuild the host ``BiasModel`` view of the state's bias leaves —
    bit-exact: the sufficient statistics are copied at float64 and the
    hyperparameters come from ``StateMeta``."""
    m = state.meta
    counts = np.asarray(state.bias_counts, np.float64)
    return BiasModel(counts.shape[0], counts.shape[1], tau0=m.tau0,
                     sigma_r=m.sigma_r, decay=m.decay,
                     empirical_bayes=m.empirical_bayes, counts=counts,
                     log_sum=np.asarray(state.bias_log_sum, np.float64),
                     log_sq=np.asarray(state.bias_log_sq, np.float64))


def reliability_view(state: EstimatorState,
                     names: StateNames) -> ReliabilityModel | None:
    """Rebuild the host ``ReliabilityModel`` view (``None`` while no
    attempt was ever recorded, matching the estimator's lazy layer)."""
    succ = np.asarray(state.rel_succ, np.float64)
    fail = np.asarray(state.rel_fail, np.float64)
    if not np.any(succ + fail > 0):
        return None
    seen = {n: [float(succ[k]), float(fail[k])]
            for k, n in enumerate(names.rel_nodes) if succ[k] + fail[k] > 0}
    return ReliabilityModel(a0=state.meta.rel_a0, b0=state.meta.rel_b0,
                            state=seen)


def write_back(state: EstimatorState, names: StateNames, est,
               rows=None) -> None:
    """Fold a mutated state back into a live ``LotaruEstimator`` so the
    legacy OO surface (scalar predicts, save/load, further
    ``observe_batch`` calls) continues from exactly where the fused tick
    left off.  ``rows`` limits the per-task scalar-model writeback to
    the rows the tick actually touched (the batch cache itself is always
    swapped whole)."""
    from .blr import slice_task_model

    model = state.model
    fts = [est.tasks[n] for n in names.tasks]
    w = np.array([ft.w for ft in fts], np.float64)
    est._batch_cache = (list(names.tasks), fts, model, w)
    touched = range(len(names.tasks)) if rows is None else sorted(rows)
    for i in touched:
        est.tasks[names.tasks[i]].model = slice_task_model(model, i)
    est._mat_cache = None
    est._dirty_rows.clear()
    if est.bias_correction:
        view = bias_view(state)
        bias = est._ensure_bias()
        bias.counts = view.counts
        bias.log_sum = view.log_sum
        bias.log_sq = view.log_sq
        bias._sigma_r_cache = None
    rel = reliability_view(state, names)
    if rel is not None:
        est.reliability = rel
