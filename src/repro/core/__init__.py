"""The paper's primary contribution: Lotaru's four phases as a composable
system — infrastructure profiling, downsampled local execution, Bayesian
linear regression with Pearson gating, per-node factor adjustment — plus
the accelerator-plane integration (LotaruML) that feeds the scheduler."""
from .blr import (BatchedTaskModel, BiasModel, BLRPosterior, OnlineStats,
                  ReliabilityModel, TaskModel,
                  fit, fit_batch, fit_task, fit_task_batch, pearson,
                  pearson_batch, predict, predict_batch, predict_batch_grid,
                  predict_interval, predict_task_batch,
                  predict_task_batch_grid, slice_task_model,
                  stack_task_models, unstack_task_models, update_task_batch,
                  update_task_batch_stream, CORRELATION_THRESHOLD)
from .adjust import (BenchArrays, cpu_weight, deviation, roofline_weights,
                     runtime_factor, runtime_factor3, stack_benches)
from .baselines import BASELINES, NaiveEstimator, OnlineM, OnlineP
from .downsample import (WorkloadPartition, downsample_workload,
                         partition_sizes, reduced_model_factor)
from .estimator import (FittedCell, FittedTask, LotaruEstimator, LotaruML,
                        SCHEMA_VERSION, young_daly_interval)
from .nodes import NODE_TYPES, NodeType, PAPER_ALIAS, get_node, target_nodes
from .profiler import BenchResult, profile_cluster, profile_local, profile_node
from .state import (EstimatorState, StateMeta, StateNames, bias_view,
                    build_state, reliability_view, write_back)
from .tick import TickEngine, predict_state, tick_step

__all__ = [
    "BatchedTaskModel", "BiasModel", "BLRPosterior", "OnlineStats",
    "ReliabilityModel", "TaskModel", "fit",
    "fit_batch", "fit_task", "fit_task_batch", "pearson", "pearson_batch",
    "predict", "predict_batch", "predict_batch_grid", "predict_interval",
    "predict_task_batch", "predict_task_batch_grid", "slice_task_model",
    "stack_task_models", "unstack_task_models", "update_task_batch",
    "update_task_batch_stream", "SCHEMA_VERSION",
    "CORRELATION_THRESHOLD", "BenchArrays", "stack_benches",
    "cpu_weight", "deviation",
    "roofline_weights", "runtime_factor", "runtime_factor3", "BASELINES",
    "NaiveEstimator", "OnlineM", "OnlineP", "WorkloadPartition",
    "downsample_workload", "partition_sizes", "reduced_model_factor",
    "FittedCell", "FittedTask", "LotaruEstimator", "LotaruML",
    "young_daly_interval", "NODE_TYPES", "NodeType", "PAPER_ALIAS",
    "get_node", "target_nodes", "BenchResult", "profile_cluster",
    "profile_local", "profile_node", "EstimatorState", "StateMeta",
    "StateNames", "bias_view", "build_state", "reliability_view",
    "write_back", "TickEngine", "predict_state", "tick_step",
]
