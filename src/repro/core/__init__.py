"""The paper's primary contribution: Lotaru's four phases as a composable
system — infrastructure profiling, downsampled local execution, Bayesian
linear regression with Pearson gating, per-node factor adjustment — plus
the accelerator-plane integration (LotaruML) that feeds the scheduler."""
from .blr import (BLRPosterior, TaskModel, fit, fit_task, pearson, predict,
                  predict_interval, CORRELATION_THRESHOLD)
from .adjust import (cpu_weight, deviation, roofline_weights, runtime_factor,
                     runtime_factor3)
from .baselines import BASELINES, NaiveEstimator, OnlineM, OnlineP
from .downsample import (WorkloadPartition, downsample_workload,
                         partition_sizes, reduced_model_factor)
from .estimator import (FittedCell, FittedTask, LotaruEstimator, LotaruML,
                        young_daly_interval)
from .nodes import NODE_TYPES, NodeType, PAPER_ALIAS, get_node, target_nodes
from .profiler import BenchResult, profile_cluster, profile_local, profile_node

__all__ = [
    "BLRPosterior", "TaskModel", "fit", "fit_task", "pearson", "predict",
    "predict_interval", "CORRELATION_THRESHOLD", "cpu_weight", "deviation",
    "roofline_weights", "runtime_factor", "runtime_factor3", "BASELINES",
    "NaiveEstimator", "OnlineM", "OnlineP", "WorkloadPartition",
    "downsample_workload", "partition_sizes", "reduced_model_factor",
    "FittedCell", "FittedTask", "LotaruEstimator", "LotaruML",
    "young_daly_interval", "NODE_TYPES", "NodeType", "PAPER_ALIAS",
    "get_node", "target_nodes", "BenchResult", "profile_cluster",
    "profile_local", "profile_node",
]
