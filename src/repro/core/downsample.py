"""Phase 2: input downsampling (paper §3.2 / §5.1).

The paper splits one input file geometrically: s1 = X/2, s_n = s_{n-1}/2
(10 partitions; 16 for Chipseq).  Two domains here:

* genomics plane — partition sizes in GB of one input sample;
* ML-workload plane — token counts of a workload cell: the "input size" of
  a training/prefill step is its token count; downsampling produces reduced
  (seq, batch) pairs whose product follows the same geometric ladder, run
  for real on the local CPU with a reduced-but-same-family model config.

``partition_sizes`` is shared by both planes.
"""
from __future__ import annotations

from dataclasses import dataclass


def partition_sizes(original: float, n: int = 10) -> list[float]:
    """Geometric ladder: [X/2, X/4, ..., X/2^n] (paper §5.1)."""
    out = []
    s = original / 2.0
    for _ in range(n):
        out.append(s)
        s /= 2.0
    return out


@dataclass(frozen=True)
class WorkloadPartition:
    """A reduced run of a workload cell on the local machine."""
    seq: int
    batch: int

    @property
    def tokens(self) -> int:
        return self.seq * self.batch


def downsample_workload(seq: int, global_batch: int, n: int = 6,
                        min_seq: int = 32) -> list[WorkloadPartition]:
    """Geometric token ladder for an (arch x shape) cell.

    Halve batch first (keeps per-step shape identical), then sequence —
    mirroring how the paper halves file contents while keeping the format.
    """
    parts = []
    b, s = global_batch, seq
    for _ in range(n):
        if b > 1:
            b = max(1, b // 2)
        elif s > min_seq:
            s = max(min_seq, s // 2)
        else:
            break
        parts.append(WorkloadPartition(seq=s, batch=b))
    return parts


def reduced_model_factor(full_params: int, local_params: int) -> float:
    """Scale factor between the locally-runnable reduced model and the full
    config (Lotaru extrapolates runtime linearly in model FLOPs; the paper's
    linear size→runtime assumption, applied along the parameter axis)."""
    return full_params / max(local_params, 1)
