"""LotaruEstimator — the paper's four phases, end to end.

``LotaruEstimator`` is the faithful reproduction (genomics plane): profile
-> downsample + dual local runs (normal / CPU-throttled) -> per-task BLR
with Pearson gating -> per-node factor adjustment, with Bayesian
uncertainty propagated to every (task x node) prediction.

``LotaruML`` is the accelerator-plane integration: workload cells from the
multi-pod dry-run are the tasks, token count is the input size, the local
runs execute on the developer CPU node, and the adjustment uses the
three-term (FLOPs/HBM/link) factor with weights from the cell's own
compiled roofline decomposition (DESIGN.md §2).  Its predictions (mean and
uncertainty) feed the HEFT scheduler, straggler thresholds, and Young/Daly
checkpoint intervals.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

import numpy as np

from .adjust import (cpu_weight, deviation, roofline_weights, runtime_factor,
                     runtime_factor3)
from .blr import TaskModel, fit_task
from .downsample import partition_sizes
from .profiler import BenchResult


@dataclass
class FittedTask:
    model: TaskModel
    w: float                      # CPU-vs-IO weight (paper eq. 5)
    sizes: np.ndarray
    runtimes: np.ndarray


class LotaruEstimator:
    """Paper-faithful estimator over black-box tasks."""

    def __init__(self, local_bench: BenchResult,
                 target_benches: dict[str, BenchResult],
                 freq_reduction: float = 0.2):
        self.local_bench = local_bench
        self.target_benches = target_benches
        self.freq_reduction = freq_reduction
        self.tasks: dict[str, FittedTask] = {}

    # ---- phases 2+3: local downsampled runs + model fit -------------------
    def fit_tasks(self, task_names: list[str], input_size: float,
                  run_local: Callable[[str, float, float], float],
                  n_partitions: int = 10, slow_partitions: int = 3) -> None:
        """run_local(task_name, size, cpu_factor) -> measured runtime."""
        sizes = np.array(partition_sizes(input_size, n_partitions))
        slow_factor = 1.0 - self.freq_reduction          # 20% CPU reduction
        for name in task_names:
            normal = np.array([run_local(name, s, 1.0) for s in sizes])
            # second execution with reduced CPU speed on a few partitions
            sub = sizes[:slow_partitions]
            slow = np.array([run_local(name, s, slow_factor) for s in sub])
            devs = [deviation(t_new, t_old)
                    for t_new, t_old in zip(slow, normal[:slow_partitions])]
            w = cpu_weight(float(np.median(devs)), 1.0, slow_factor)
            model = fit_task(sizes, normal)
            self.tasks[name] = FittedTask(model=model, w=w, sizes=sizes,
                                          runtimes=normal)

    # ---- phase 4: adjusted prediction --------------------------------------
    def factor(self, task_name: str, node: str) -> float:
        if node == self.local_bench.node:
            return 1.0
        ft = self.tasks[task_name]
        return runtime_factor(ft.w, self.local_bench,
                              self.target_benches[node])

    def predict(self, task_name: str, node: str, size: float):
        """(mean, std) for task on node at input size."""
        ft = self.tasks[task_name]
        mean, std = ft.model.predict(size)
        f = self.factor(task_name, node)
        return float(mean) * f, float(std) * f

    def predict_local(self, task_name: str, size: float):
        ft = self.tasks[task_name]
        mean, std = ft.model.predict(size)
        return float(mean), float(std)

    # ---- offline reuse (paper §1: "allows for offline scenarios where the
    # learned models are reused for future executions") -----------------
    def save(self, path) -> None:
        import json
        from pathlib import Path
        out = {"local_bench": self.local_bench.to_dict(),
               "target_benches": {k: v.to_dict()
                                  for k, v in self.target_benches.items()},
               "tasks": {}}
        for name, ft in self.tasks.items():
            out["tasks"][name] = {
                "w": ft.w,
                "sizes": list(map(float, ft.sizes)),
                "runtimes": list(map(float, ft.runtimes)),
            }
        Path(path).write_text(json.dumps(out))

    @classmethod
    def load(cls, path) -> "LotaruEstimator":
        import json
        from pathlib import Path
        from .blr import fit_task
        d = json.loads(Path(path).read_text())
        local = BenchResult(**d["local_bench"])
        targets = {k: BenchResult(**v) for k, v in d["target_benches"].items()}
        est = cls(local, targets)
        for name, rec in d["tasks"].items():
            sizes = np.asarray(rec["sizes"])
            runtimes = np.asarray(rec["runtimes"])
            est.tasks[name] = FittedTask(model=fit_task(sizes, runtimes),
                                         w=rec["w"], sizes=sizes,
                                         runtimes=runtimes)
        return est


# ---------------------------------------------------------------------------
# Accelerator-plane estimator
# ---------------------------------------------------------------------------
@dataclass
class FittedCell:
    model: TaskModel
    weights: tuple[float, float, float]
    full_tokens: int
    flops: float = 0.0            # per device, from the compiled artifact
    bytes_: float = 0.0
    coll: float = 0.0
    w_compute: float | None = None  # measured compute share (dual-run probe)


class LotaruML:
    """Lotaru over (arch x shape) workload cells (beyond-paper integration).

    The CPU-frequency probe does not transfer to TPUs; instead the cell's
    compiled artifact supplies per-device (FLOPs, bytes, collective bytes)
    and the *decomposed* predictor scales each resource term by its own
    microbenchmark ratio, recombining with the roofline max — this handles
    the bottleneck *switching* between the local CPU (compute-bound) and
    accelerator targets (often memory-bound).  ``predict_scalar`` keeps the
    paper's single-factor form as an ablation (it fails exactly when the
    bound switches; see benchmarks/tpu_cells.py)."""

    _MIX = 0.35   # secondary-term overlap coefficient of the roofline model

    def __init__(self, local_bench: BenchResult,
                 target_benches: dict[str, BenchResult]):
        self.local_bench = local_bench
        self.target_benches = target_benches
        self.cells: dict[str, FittedCell] = {}

    def fit_cell(self, cell: dict,
                 run_local: Callable[[dict, float], float],
                 n_partitions: int = 6,
                 run_local_throttled: Callable[[dict, float], float] | None = None,
                 freq_reduction: float = 0.2,
                 slow_partitions: int = 3) -> None:
        """run_local(cell, token_fraction) -> measured local runtime.

        ``run_local_throttled`` is the paper's second execution at reduced
        compute speed (phase 2): the deviation separates the compute share
        w (paper eq. 5), which the decomposed predictor then transfers
        per-resource."""
        r = cell["roofline"]
        name = f"{cell['arch']}__{cell['shape']}"
        fracs = np.array(partition_sizes(1.0, n_partitions))
        runtimes = np.array([run_local(cell, f) for f in fracs])
        tokens = fracs * r["step_tokens"]
        model = fit_task(tokens, runtimes)
        weights = roofline_weights(r["compute_s"], r["memory_s"],
                                   r["collective_s"])
        w_compute = None
        if run_local_throttled is not None:
            devs = []
            for f, t_old in zip(fracs[:slow_partitions],
                                runtimes[:slow_partitions]):
                t_new = run_local_throttled(cell, f)
                devs.append(deviation(t_new, t_old))
            w_compute = cpu_weight(float(np.median(devs)), 1.0,
                                   1.0 - freq_reduction)
        self.cells[name] = FittedCell(
            model=model, weights=weights, full_tokens=int(r["step_tokens"]),
            flops=r["flops_per_device"], bytes_=r["bytes_per_device"],
            coll=r["coll_bytes_per_device"], w_compute=w_compute)

    # ---- helpers -----------------------------------------------------------
    def _terms(self, fc: FittedCell, bench: BenchResult) -> tuple:
        link = bench.link_gbps if bench.link_gbps > 0 else bench.mem_gbps / 10
        return (fc.flops / (bench.matmul_gflops * 1e9),
                fc.bytes_ / (bench.mem_gbps * 1e9),
                fc.coll / (link * 1e9))

    def _combine(self, terms) -> float:
        return max(terms) + self._MIX * min(terms)

    # ---- predictors ---------------------------------------------------------
    def predict(self, cell_name: str, node: str, tokens: float | None = None):
        """Decomposed (per-resource) prediction: the local measurement
        calibrates an efficiency alpha; each term re-scales by its own
        benchmark ratio."""
        fc = self.cells[cell_name]
        tokens = fc.full_tokens if tokens is None else tokens
        mean, std = fc.model.predict(tokens)
        if node == self.local_bench.node:
            return float(mean), float(std)
        tb = self.target_benches[node]
        if fc.w_compute is not None:
            # Dual-run decomposition (paper phase 2, per-resource transfer):
            # the measured compute share w splits the *measured* local time
            # into a compute part and a rest part; the rest splits between
            # memory and interconnect by the artifact's raw term ratio.
            # Each part scales by its own microbenchmark ratio.
            lc = self._terms(fc, self.local_bench)
            t_c = fc.w_compute * float(mean)
            rest = (1.0 - fc.w_compute) * float(mean)
            mn = lc[1] + lc[2]
            t_m = rest * (lc[1] / mn if mn > 0 else 1.0)
            t_n = rest - t_m
            link_l = (self.local_bench.link_gbps or
                      self.local_bench.mem_gbps / 10)
            link_t = tb.link_gbps or tb.mem_gbps / 10
            parts = (
                t_c * self.local_bench.matmul_gflops / max(tb.matmul_gflops, 1e-9),
                t_m * self.local_bench.mem_gbps / max(tb.mem_gbps, 1e-9),
                t_n * link_l / max(link_t, 1e-9),
            )
            pred = max(parts) + self._MIX * min(parts)
            rel = float(std) / max(float(mean), 1e-12)
            return pred, pred * rel
        # no throttle probe available: whole-time ratio transfer
        ratio = (self._combine(self._terms(fc, tb))
                 / max(self._combine(self._terms(fc, self.local_bench)), 1e-12))
        return float(mean) * ratio, float(std) * ratio

    def predict_scalar(self, cell_name: str, node: str,
                       tokens: float | None = None):
        """Paper-form single scalar factor (ablation)."""
        fc = self.cells[cell_name]
        tokens = fc.full_tokens if tokens is None else tokens
        mean, std = fc.model.predict(tokens)
        if node == self.local_bench.node:
            return float(mean), float(std)
        f = runtime_factor3(fc.weights, self.local_bench,
                            self.target_benches[node])
        return float(mean) * f, float(std) * f

    def straggler_threshold(self, cell_name: str, node: str,
                            k: float = 3.0) -> float:
        """mean + k*sigma: tasks exceeding this are treated as stragglers."""
        mean, std = self.predict(cell_name, node)
        return mean + k * std


def young_daly_interval(step_time_s: float, mtbf_s: float,
                        checkpoint_cost_s: float) -> float:
    """Young/Daly optimal checkpoint interval, from predicted step time."""
    opt = float(np.sqrt(2.0 * checkpoint_cost_s * mtbf_s))
    return max(opt, step_time_s)
