"""LotaruEstimator — the paper's four phases, end to end.

``LotaruEstimator`` is the faithful reproduction (genomics plane): profile
-> downsample + dual local runs (normal / CPU-throttled) -> per-task BLR
with Pearson gating -> per-node factor adjustment, with Bayesian
uncertainty propagated to every (task x node) prediction.

``LotaruML`` is the accelerator-plane integration: workload cells from the
multi-pod dry-run are the tasks, token count is the input size, the local
runs execute on the developer CPU node, and the adjustment uses the
three-term (FLOPs/HBM/link) factor with weights from the cell's own
compiled roofline decomposition (DESIGN.md §2).  Its predictions (mean and
uncertainty) feed the HEFT scheduler, straggler thresholds, and Young/Daly
checkpoint intervals.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from .adjust import (cpu_weight, deviation, roofline_weights, runtime_factor,
                     runtime_factor3, stack_benches)
from .blr import (BatchedTaskModel, TaskModel, fit_task, fit_task_batch,
                  predict_task_batch, stack_task_models)
from .downsample import partition_sizes
from .profiler import BenchResult


@jax.jit
def _scaled_matrix_core(model: BatchedTaskModel, factors, size):
    """One jitted call: batched Student-t predictive × (T, N) factors."""
    mean_t, std_t = predict_task_batch(model, size)
    return mean_t[:, None] * factors, std_t[:, None] * factors


@jax.jit
def _ml_matrix_core(model: BatchedTaskModel, tokens, w_c, has_w,
                    flops, bytes_, coll, l_mat, l_mem, l_link,
                    t_mat, t_mem, t_link, is_local, mix):
    """Jitted (cell × node) estimate matrix for the decomposed predictor.

    Vectorises ``LotaruML.predict`` over both axes: the dual-run
    per-resource transfer where a compute share is available, the
    whole-time roofline-ratio transfer elsewhere, identity on the local
    node.  Shapes: cell arrays (T,), target bench arrays (N,).

    ``LotaruML.predict`` is the scalar oracle for this kernel — keep the
    two in lock-step (equivalence is test-enforced)."""
    mean, std = predict_task_batch(model, tokens)              # (T,)
    l_link_f = jnp.where(l_link > 0, l_link, l_mem / 10)
    t_link_f = jnp.where(t_link > 0, t_link, t_mem / 10)       # (N,)
    lc = jnp.stack([flops / (l_mat * 1e9),
                    bytes_ / (l_mem * 1e9),
                    coll / (l_link_f * 1e9)], axis=-1)         # (T, 3)
    # dual-run decomposition: measured compute share splits the local time
    t_c = w_c * mean
    rest = (1.0 - w_c) * mean
    mn = lc[:, 1] + lc[:, 2]
    t_m = rest * jnp.where(mn > 0, lc[:, 1] / jnp.where(mn > 0, mn, 1.0), 1.0)
    t_n = rest - t_m
    parts = jnp.stack([
        t_c[:, None] * l_mat / jnp.maximum(t_mat, 1e-9)[None, :],
        t_m[:, None] * l_mem / jnp.maximum(t_mem, 1e-9)[None, :],
        t_n[:, None] * l_link_f / jnp.maximum(t_link_f, 1e-9)[None, :],
    ], axis=-1)                                                # (T, N, 3)
    pred_dual = parts.max(axis=-1) + mix * parts.min(axis=-1)
    rel = std / jnp.maximum(mean, 1e-12)
    std_dual = pred_dual * rel[:, None]
    # whole-time roofline-ratio transfer (no throttle probe)
    tt = jnp.stack([flops[:, None] / (t_mat[None, :] * 1e9),
                    bytes_[:, None] / (t_mem[None, :] * 1e9),
                    coll[:, None] / (t_link_f[None, :] * 1e9)], axis=-1)
    comb_t = tt.max(axis=-1) + mix * tt.min(axis=-1)
    comb_l = lc.max(axis=-1) + mix * lc.min(axis=-1)
    ratio = comb_t / jnp.maximum(comb_l, 1e-12)[:, None]
    mean_m = jnp.where(has_w[:, None], pred_dual, mean[:, None] * ratio)
    std_m = jnp.where(has_w[:, None], std_dual, std[:, None] * ratio)
    mean_m = jnp.where(is_local[None, :], mean[:, None], mean_m)
    std_m = jnp.where(is_local[None, :], std[:, None], std_m)
    return mean_m, std_m


@dataclass
class FittedTask:
    model: TaskModel
    w: float                      # CPU-vs-IO weight (paper eq. 5)
    sizes: np.ndarray
    runtimes: np.ndarray


class LotaruEstimator:
    """Paper-faithful estimator over black-box tasks."""

    def __init__(self, local_bench: BenchResult,
                 target_benches: dict[str, BenchResult],
                 freq_reduction: float = 0.2):
        self.local_bench = local_bench
        self.target_benches = target_benches
        self.freq_reduction = freq_reduction
        self.tasks: dict[str, FittedTask] = {}
        self._batch_cache: tuple | None = None

    # ---- phases 2+3: local downsampled runs + model fit -------------------
    def fit_tasks(self, task_names: list[str], input_size: float,
                  run_local: Callable[[str, float, float], float],
                  n_partitions: int = 10, slow_partitions: int = 3) -> None:
        """run_local(task_name, size, cpu_factor) -> measured runtime."""
        sizes = np.array(partition_sizes(input_size, n_partitions))
        slow_factor = 1.0 - self.freq_reduction          # 20% CPU reduction
        for name in task_names:
            normal = np.array([run_local(name, s, 1.0) for s in sizes])
            # second execution with reduced CPU speed on a few partitions
            sub = sizes[:slow_partitions]
            slow = np.array([run_local(name, s, slow_factor) for s in sub])
            devs = [deviation(t_new, t_old)
                    for t_new, t_old in zip(slow, normal[:slow_partitions])]
            w = cpu_weight(float(np.median(devs)), 1.0, slow_factor)
            model = fit_task(sizes, normal)
            self.tasks[name] = FittedTask(model=model, w=w, sizes=sizes,
                                          runtimes=normal)
        self._batch_cache = None

    # ---- phase 4: adjusted prediction --------------------------------------
    def factor(self, task_name: str, node: str) -> float:
        if node == self.local_bench.node:
            return 1.0
        ft = self.tasks[task_name]
        return runtime_factor(ft.w, self.local_bench,
                              self.target_benches[node])

    def predict(self, task_name: str, node: str, size: float):
        """(mean, std) for task on node at input size."""
        ft = self.tasks[task_name]
        mean, std = ft.model.predict(size)
        f = self.factor(task_name, node)
        return float(mean) * f, float(std) * f

    def predict_local(self, task_name: str, size: float):
        ft = self.tasks[task_name]
        mean, std = ft.model.predict(size)
        return float(mean), float(std)

    # ---- batched (task × node) matrix API ----------------------------------
    def _batched(self) -> tuple[list[str], BatchedTaskModel, np.ndarray]:
        """All T task models stacked into one vmapped fit.

        Cached; invalidated when the task set OR any ``FittedTask`` object
        changes (identity check, so replacing ``est.tasks[name]`` in place
        is picked up — the cache holds the refs, keeping ids stable)."""
        names = list(self.tasks)
        fts = [self.tasks[n] for n in names]
        c = self._batch_cache
        if (c is None or c[0] != names
                or any(a is not b for a, b in zip(c[1], fts))):
            model = fit_task_batch([ft.sizes for ft in fts],
                                   [ft.runtimes for ft in fts])
            w = np.array([ft.w for ft in fts], np.float64)
            self._batch_cache = (names, fts, model, w)
        return (self._batch_cache[0], self._batch_cache[2],
                self._batch_cache[3])

    def task_names(self) -> list[str]:
        """Row order of ``predict_matrix`` / ``factor_matrix``."""
        return list(self.tasks)

    def factor_matrix(self, nodes: list[str]) -> np.ndarray:
        """(T, N) adjustment factors, rows in ``task_names()`` order."""
        names, _, w = self._batched()
        F = np.ones((len(names), len(nodes)))
        targets = [n for n in nodes if n != self.local_bench.node]
        if targets:
            Ft = runtime_factor(w, self.local_bench,
                                stack_benches([self.target_benches[n]
                                               for n in targets]))
            k = 0
            for j, n in enumerate(nodes):
                if n != self.local_bench.node:
                    F[:, j] = Ft[:, k]
                    k += 1
        return F

    def predict_matrix(self, nodes: list[str], size):
        """Full (task × node) estimate matrix in one jitted call.

        ``size`` is a scalar (shared input size) or a (T,) per-task array.
        Returns (mean, std) arrays of shape (T, N): rows follow
        ``task_names()``, columns follow ``nodes`` (the local node gets
        factor 1, matching ``predict_local``)."""
        _, model, _ = self._batched()
        F = jnp.asarray(self.factor_matrix(nodes), model.post.mu.dtype)
        size = jnp.asarray(size, model.post.mu.dtype)
        mean, std = _scaled_matrix_core(model, F, size)
        return np.asarray(mean, np.float64), np.asarray(std, np.float64)

    # ---- offline reuse (paper §1: "allows for offline scenarios where the
    # learned models are reused for future executions") -----------------
    def save(self, path) -> None:
        import json
        from pathlib import Path
        out = {"local_bench": self.local_bench.to_dict(),
               "target_benches": {k: v.to_dict()
                                  for k, v in self.target_benches.items()},
               "tasks": {}}
        for name, ft in self.tasks.items():
            out["tasks"][name] = {
                "w": ft.w,
                "sizes": list(map(float, ft.sizes)),
                "runtimes": list(map(float, ft.runtimes)),
            }
        Path(path).write_text(json.dumps(out))

    @classmethod
    def load(cls, path) -> "LotaruEstimator":
        import json
        from pathlib import Path
        from .blr import fit_task
        d = json.loads(Path(path).read_text())
        local = BenchResult(**d["local_bench"])
        targets = {k: BenchResult(**v) for k, v in d["target_benches"].items()}
        est = cls(local, targets)
        for name, rec in d["tasks"].items():
            sizes = np.asarray(rec["sizes"])
            runtimes = np.asarray(rec["runtimes"])
            est.tasks[name] = FittedTask(model=fit_task(sizes, runtimes),
                                         w=rec["w"], sizes=sizes,
                                         runtimes=runtimes)
        return est


# ---------------------------------------------------------------------------
# Accelerator-plane estimator
# ---------------------------------------------------------------------------
@dataclass
class FittedCell:
    model: TaskModel
    weights: tuple[float, float, float]
    full_tokens: int
    flops: float = 0.0            # per device, from the compiled artifact
    bytes_: float = 0.0
    coll: float = 0.0
    w_compute: float | None = None  # measured compute share (dual-run probe)
    tokens: np.ndarray | None = None     # raw local samples (batched refit)
    runtimes: np.ndarray | None = None


class LotaruML:
    """Lotaru over (arch x shape) workload cells (beyond-paper integration).

    The CPU-frequency probe does not transfer to TPUs; instead the cell's
    compiled artifact supplies per-device (FLOPs, bytes, collective bytes)
    and the *decomposed* predictor scales each resource term by its own
    microbenchmark ratio, recombining with the roofline max — this handles
    the bottleneck *switching* between the local CPU (compute-bound) and
    accelerator targets (often memory-bound).  ``predict_scalar`` keeps the
    paper's single-factor form as an ablation (it fails exactly when the
    bound switches; see benchmarks/tpu_cells.py)."""

    _MIX = 0.35   # secondary-term overlap coefficient of the roofline model

    def __init__(self, local_bench: BenchResult,
                 target_benches: dict[str, BenchResult]):
        self.local_bench = local_bench
        self.target_benches = target_benches
        self.cells: dict[str, FittedCell] = {}
        self._batch_cache: tuple | None = None

    def fit_cell(self, cell: dict,
                 run_local: Callable[[dict, float], float],
                 n_partitions: int = 6,
                 run_local_throttled: Callable[[dict, float], float] | None = None,
                 freq_reduction: float = 0.2,
                 slow_partitions: int = 3) -> None:
        """run_local(cell, token_fraction) -> measured local runtime.

        ``run_local_throttled`` is the paper's second execution at reduced
        compute speed (phase 2): the deviation separates the compute share
        w (paper eq. 5), which the decomposed predictor then transfers
        per-resource."""
        r = cell["roofline"]
        name = f"{cell['arch']}__{cell['shape']}"
        fracs = np.array(partition_sizes(1.0, n_partitions))
        runtimes = np.array([run_local(cell, f) for f in fracs])
        tokens = fracs * r["step_tokens"]
        model = fit_task(tokens, runtimes)
        weights = roofline_weights(r["compute_s"], r["memory_s"],
                                   r["collective_s"])
        w_compute = None
        if run_local_throttled is not None:
            devs = []
            for f, t_old in zip(fracs[:slow_partitions],
                                runtimes[:slow_partitions]):
                t_new = run_local_throttled(cell, f)
                devs.append(deviation(t_new, t_old))
            w_compute = cpu_weight(float(np.median(devs)), 1.0,
                                   1.0 - freq_reduction)
        self.cells[name] = FittedCell(
            model=model, weights=weights, full_tokens=int(r["step_tokens"]),
            flops=r["flops_per_device"], bytes_=r["bytes_per_device"],
            coll=r["coll_bytes_per_device"], w_compute=w_compute,
            tokens=tokens, runtimes=runtimes)
        self._batch_cache = None

    # ---- helpers -----------------------------------------------------------
    def _terms(self, fc: FittedCell, bench: BenchResult) -> tuple:
        link = bench.link_gbps if bench.link_gbps > 0 else bench.mem_gbps / 10
        return (fc.flops / (bench.matmul_gflops * 1e9),
                fc.bytes_ / (bench.mem_gbps * 1e9),
                fc.coll / (link * 1e9))

    def _combine(self, terms) -> float:
        return max(terms) + self._MIX * min(terms)

    # ---- predictors ---------------------------------------------------------
    def predict(self, cell_name: str, node: str, tokens: float | None = None):
        """Decomposed (per-resource) prediction: the local measurement
        calibrates an efficiency alpha; each term re-scales by its own
        benchmark ratio.

        This scalar path is the equivalence oracle for the vectorised
        ``_ml_matrix_core`` (tests assert they agree): any change to the
        dual-run split, the link fallback or ``_MIX`` must be mirrored
        there."""
        fc = self.cells[cell_name]
        tokens = fc.full_tokens if tokens is None else tokens
        mean, std = fc.model.predict(tokens)
        if node == self.local_bench.node:
            return float(mean), float(std)
        tb = self.target_benches[node]
        if fc.w_compute is not None:
            # Dual-run decomposition (paper phase 2, per-resource transfer):
            # the measured compute share w splits the *measured* local time
            # into a compute part and a rest part; the rest splits between
            # memory and interconnect by the artifact's raw term ratio.
            # Each part scales by its own microbenchmark ratio.
            lc = self._terms(fc, self.local_bench)
            t_c = fc.w_compute * float(mean)
            rest = (1.0 - fc.w_compute) * float(mean)
            mn = lc[1] + lc[2]
            t_m = rest * (lc[1] / mn if mn > 0 else 1.0)
            t_n = rest - t_m
            link_l = (self.local_bench.link_gbps or
                      self.local_bench.mem_gbps / 10)
            link_t = tb.link_gbps or tb.mem_gbps / 10
            parts = (
                t_c * self.local_bench.matmul_gflops / max(tb.matmul_gflops, 1e-9),
                t_m * self.local_bench.mem_gbps / max(tb.mem_gbps, 1e-9),
                t_n * link_l / max(link_t, 1e-9),
            )
            pred = max(parts) + self._MIX * min(parts)
            rel = float(std) / max(float(mean), 1e-12)
            return pred, pred * rel
        # no throttle probe available: whole-time ratio transfer
        ratio = (self._combine(self._terms(fc, tb))
                 / max(self._combine(self._terms(fc, self.local_bench)), 1e-12))
        return float(mean) * ratio, float(std) * ratio

    def predict_scalar(self, cell_name: str, node: str,
                       tokens: float | None = None):
        """Paper-form single scalar factor (ablation)."""
        fc = self.cells[cell_name]
        tokens = fc.full_tokens if tokens is None else tokens
        mean, std = fc.model.predict(tokens)
        if node == self.local_bench.node:
            return float(mean), float(std)
        f = runtime_factor3(fc.weights, self.local_bench,
                            self.target_benches[node])
        return float(mean) * f, float(std) * f

    # ---- batched (cell × node) matrix API ----------------------------------
    def _batched(self):
        """Stack all cells for the vmapped path.

        Cached; invalidated when the cell set OR any ``FittedCell`` object
        changes (identity check, like ``LotaruEstimator._batched``).  Cells
        fitted via ``fit_cell`` carry raw local samples and are refitted in
        one vmapped solve; cells constructed by hand fall back to
        posterior-exact stacking of their scalar models."""
        names = list(self.cells)
        cells = [self.cells[n] for n in names]
        c = self._batch_cache
        if (c is None or c[0] != names
                or any(a is not b for a, b in zip(c[1], cells))):
            if all(c.tokens is not None and c.runtimes is not None
                   for c in cells):
                model = fit_task_batch([c.tokens for c in cells],
                                       [c.runtimes for c in cells])
            else:
                model = stack_task_models([c.model for c in cells])
            arrays = {
                "full_tokens": np.array([c.full_tokens for c in cells],
                                        np.float64),
                "flops": np.array([c.flops for c in cells], np.float64),
                "bytes_": np.array([c.bytes_ for c in cells], np.float64),
                "coll": np.array([c.coll for c in cells], np.float64),
                "w_c": np.array([c.w_compute if c.w_compute is not None
                                 else 0.0 for c in cells], np.float64),
                "has_w": np.array([c.w_compute is not None for c in cells]),
                "weights": np.array([c.weights for c in cells], np.float64),
            }
            self._batch_cache = (names, cells, model, arrays)
        return (self._batch_cache[0], self._batch_cache[2],
                self._batch_cache[3])

    def cell_names(self) -> list[str]:
        """Row order of ``predict_matrix`` / ``predict_matrix_scalar``."""
        return list(self.cells)

    def _node_arrays(self, nodes: list[str]):
        benches = [self.local_bench if n == self.local_bench.node
                   else self.target_benches[n] for n in nodes]
        ba = stack_benches(benches)
        is_local = np.array([n == self.local_bench.node for n in nodes])
        return ba, is_local

    def predict_matrix(self, nodes: list[str], tokens=None):
        """Full (cell × node) decomposed estimate matrix, one jitted call.

        ``tokens``: None (each cell's full step tokens), a scalar, or a
        (T,) per-cell array.  Returns (mean, std) of shape (T, N); rows in
        ``cell_names()`` order, columns in ``nodes`` order."""
        _, model, arr = self._batched()
        toks = arr["full_tokens"] if tokens is None else np.broadcast_to(
            np.asarray(tokens, np.float64), arr["full_tokens"].shape)
        ba, is_local = self._node_arrays(nodes)
        lb = self.local_bench
        mean, std = _ml_matrix_core(
            model, jnp.asarray(toks), jnp.asarray(arr["w_c"]),
            jnp.asarray(arr["has_w"]), jnp.asarray(arr["flops"]),
            jnp.asarray(arr["bytes_"]), jnp.asarray(arr["coll"]),
            jnp.asarray(float(lb.matmul_gflops)),
            jnp.asarray(float(lb.mem_gbps)), jnp.asarray(float(lb.link_gbps)),
            jnp.asarray(ba.matmul_gflops), jnp.asarray(ba.mem_gbps),
            jnp.asarray(ba.link_gbps), jnp.asarray(is_local),
            jnp.asarray(self._MIX))
        return np.asarray(mean, np.float64), np.asarray(std, np.float64)

    def predict_matrix_scalar(self, nodes: list[str], tokens=None):
        """Paper-form single-factor (cell × node) matrix (ablation): the
        vectorised ``runtime_factor3`` over stacked bench arrays."""
        _, model, arr = self._batched()
        toks = arr["full_tokens"] if tokens is None else np.broadcast_to(
            np.asarray(tokens, np.float64), arr["full_tokens"].shape)
        mean_t, std_t = predict_task_batch(model, jnp.asarray(toks))
        mean_t = np.asarray(mean_t, np.float64)
        std_t = np.asarray(std_t, np.float64)
        ba, is_local = self._node_arrays(nodes)
        F = runtime_factor3(arr["weights"], self.local_bench, ba)  # (T, N)
        F = np.where(is_local[None, :], 1.0, F)
        return mean_t[:, None] * F, std_t[:, None] * F

    def straggler_threshold(self, cell_name: str, node: str,
                            k: float = 3.0) -> float:
        """mean + k*sigma: tasks exceeding this are treated as stragglers."""
        mean, std = self.predict(cell_name, node)
        return mean + k * std


def young_daly_interval(step_time_s: float, mtbf_s: float,
                        checkpoint_cost_s: float) -> float:
    """Young/Daly optimal checkpoint interval, from predicted step time."""
    opt = float(np.sqrt(2.0 * checkpoint_cost_s * mtbf_s))
    return max(opt, step_time_s)
