"""LotaruEstimator — the paper's four phases, end to end.

``LotaruEstimator`` is the faithful reproduction (genomics plane): profile
-> downsample + dual local runs (normal / CPU-throttled) -> per-task BLR
with Pearson gating -> per-node factor adjustment, with Bayesian
uncertainty propagated to every (task x node) prediction.

``LotaruML`` is the accelerator-plane integration: workload cells from the
multi-pod dry-run are the tasks, token count is the input size, the local
runs execute on the developer CPU node, and the adjustment uses the
three-term (FLOPs/HBM/link) factor with weights from the cell's own
compiled roofline decomposition (DESIGN.md §2).  Its predictions (mean and
uncertainty) feed the HEFT scheduler, straggler thresholds, and Young/Daly
checkpoint intervals.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from scipy import stats as _scipy_stats

from repro.obs.trace import NULL_TRACER

from .adjust import (cpu_weight, deviation, roofline_weights, runtime_factor,
                     runtime_factor3, stack_benches)
from .blr import (BatchedTaskModel, BiasModel, ReliabilityModel, TaskModel,
                  fit_task, fit_task_batch, predict_cdf, predict_interval,
                  predict_task_batch, slice_task_model, stack_task_models,
                  unstack_task_models, update_task_batch_stream)
from .downsample import partition_sizes
from .profiler import BenchResult

SCHEMA_VERSION = 6   # LotaruEstimator.save/load on-disk format
# v1: raw samples only (refit on load)     v2: + fitted posteriors
# v3: + per-(task, node) bias state        v4: + bias hyperparameters
# v5: + per-node reliability posterior          (decay, empirical_bayes)
#      (Beta-Binomial attempt-success state)
# Every version still loads; see docs/architecture.md for the field map.


def _fold_bias_matrix(bias: BiasModel, bias_col: dict[str, int],
                      nodes: list[str], mean: np.ndarray, std: np.ndarray,
                      with_std: bool = True):
    """Fold a learned (row × node) bias into a bias-free estimate matrix:
    mean scaled by the posterior point estimate, std widened by the
    posterior uncertainty.  Unobserved pairs and nodes outside the bias
    universe pass through untouched (bitwise), so dirty-row caches stay
    valid.  ``with_std=False`` skips the (comparatively costly) widening
    and returns ``(mean, None)`` for mean-only consumers."""
    known = [k for k, n in enumerate(nodes) if n in bias_col]
    if not known:
        return mean.copy(), (std.copy() if with_std else None)
    cols = [bias_col[nodes[k]] for k in known]
    out_mean = mean.copy()
    out_std = None
    if with_std:
        out_std = std.copy()
        out_std[:, known] = bias.widen_std(mean[:, known], std[:, known],
                                           cols)
    out_mean[:, known] = mean[:, known] * bias.matrix(cols)
    return out_mean, out_std


def _as_obs_tuple(o) -> tuple[str, str, float, float]:
    """Accept (task, node, size, runtime) tuples or Observation-likes."""
    if isinstance(o, (tuple, list)):
        task, node, size, runtime = o
        return str(task), str(node), float(size), float(runtime)
    return str(o.task), str(o.node), float(o.size), float(o.runtime)


class _BiasLayer:
    """Shared per-(row, node) bias plumbing of the two estimators.

    The concrete class exposes its ordered row registry via
    ``_bias_rows()`` (``tasks`` for the genomics plane, ``cells`` for the
    ML plane); everything else — node-column universe, lazy state
    creation, matrix/scalar folding, row lookup — lives here once, so the
    two planes cannot drift apart."""

    def _bias_setup(self, bias_correction: bool, *, decay: float = 1.0,
                    sigma_r: float = 0.25,
                    empirical_bayes: bool = False) -> None:
        """``decay`` / ``sigma_r`` / ``empirical_bayes`` are forwarded to
        the lazily-created ``BiasModel`` (see its docstring); the defaults
        are bit-exact with the hyperparameter-free layer."""
        self.bias_correction = bias_correction
        self.bias: BiasModel | None = None
        # observability: spans around the jitted matrix dispatch and the
        # update/bias scatters go through this tracer (NULL_TRACER = the
        # zero-cost disabled path; set_tracer attaches a live EventLog)
        self._tracer = NULL_TRACER
        # per-node attempt-reliability posterior (lazily created on the
        # first recorded attempt, like the bias state): keyed by node
        # *instance* name, since availability is a property of the
        # machine, not its hardware type
        self.reliability: ReliabilityModel | None = None
        self._bias_opts = {"decay": float(decay), "sigma_r": float(sigma_r),
                           "empirical_bayes": bool(empirical_bayes)}
        self.bias_nodes = ([self.local_bench.node]
                           + list(self.target_benches))
        self._bias_col = {n: j for j, n in enumerate(self.bias_nodes)}
        self._row_map: dict[str, int] | None = None

    def _bias_rows(self) -> dict:
        raise NotImplementedError

    def set_tracer(self, tracer) -> None:
        """Attach a ``repro.obs`` tracer: the estimator's jitted
        ``predict_matrix`` dispatches and its update/bias scatters emit
        wall-clock spans through it.  Tracing is read-only — it never
        changes a prediction (``None`` restores the no-op tracer)."""
        self._tracer = tracer if tracer is not None else NULL_TRACER

    def _row_of(self, name: str) -> int:
        """Row index of a task/cell — cached: the executor hits this per
        completion and per running task, and a linear scan per call would
        make every tick O(T²)."""
        rows = self._bias_rows()
        if self._row_map is None or len(self._row_map) != len(rows):
            self._row_map = {n: i for i, n in enumerate(rows)}
        return self._row_map[name]

    def _ensure_bias(self) -> BiasModel:
        """Bias state sized to the current row set (rows grow with it).
        The node universe snapshots ``target_benches`` the moment the
        first state is created — until then a swapped-out bench dict is
        picked up; after, columns are pinned so accumulated pair stats
        never silently misalign."""
        if self.bias is None:
            self.bias_nodes = ([self.local_bench.node]
                               + list(self.target_benches))
            self._bias_col = {n: j for j, n in enumerate(self.bias_nodes)}
            self.bias = BiasModel(len(self._bias_rows()),
                                  len(self.bias_nodes), **self._bias_opts)
        else:
            self.bias.expand_rows(len(self._bias_rows()))
        return self.bias

    def _bias_fold(self, nodes: list[str], mean: np.ndarray,
                   std: np.ndarray, with_std: bool = True):
        if not self.bias_correction:
            return mean.copy(), (std.copy() if with_std else None)
        return _fold_bias_matrix(self._ensure_bias(), self._bias_col,
                                 nodes, mean, std, with_std)

    def _bias_fold_scalar(self, name: str, node: str, mean: float,
                          std: float) -> tuple[float, float]:
        if self.bias_correction:
            bias = self._ensure_bias()
            j = self._bias_col.get(node)
            if j is not None:
                return bias.fold_scalar(self._row_of(name), j, mean, std)
        return mean, std

    def bias_point(self, name: str, node: str) -> float:
        """Current multiplicative bias point estimate for the
        (task/cell, node) pair — 1.0 when the pair is unobserved or bias
        correction is off.  The straggler coupling reads this: a pair
        whose bias has drifted high is systematically slower than its
        prediction admits."""
        if not self.bias_correction or self.bias is None:
            return 1.0
        j = self._bias_col.get(node)
        if j is None:
            return 1.0
        return self.bias.point(self._row_of(name), j)

    def bias_tail_mass(self, name: str, node: str,
                       threshold: float) -> float:
        """Posterior probability that the (task/cell, node) bias exceeds
        ``threshold`` — the admission statistic for risk-aware
        speculative copies (``OnlineExecutor(spec_tail=...)``).  Unlike
        ``bias_point`` (a point estimate that crosses a threshold the
        moment the posterior mean does), this demands the posterior
        *mass* to sit above the drift line, so barely-observed pairs
        with wide posteriors do not trigger copies.  Returns 0.0 when
        the pair is unobserved, the node is outside the bias universe,
        or bias correction is off."""
        if not self.bias_correction or self.bias is None:
            return 0.0
        j = self._bias_col.get(node)
        if j is None:
            return 0.0
        return self.bias.tail_mass(self._row_of(name), j, threshold)

    # ---- per-node attempt reliability (availability plane) ----------------
    def record_attempt(self, node: str, success: bool) -> None:
        """Feed one attempt outcome on ``node`` into the Beta–Binomial
        reliability posterior (created lazily on first use).  Crashed
        or failed attempts count as failures; scheduler-ordered kills
        (a lost speculative race) must NOT be recorded — the node did
        nothing wrong."""
        if self.reliability is None:
            self.reliability = ReliabilityModel()
        self.reliability.record(node, success)

    def reliability_factor(self, node: str, k: float = 1.0) -> float:
        """Expected time-to-success multiplier for ``node`` —
        ``1 / (E[p_success] - k·sd)``, floored; 1.0 while no attempt has
        ever been recorded (the layer is inert until evidence exists,
        like the bias posterior)."""
        if self.reliability is None:
            return 1.0
        return self.reliability.factor(node, k)

    def reliability_factors(self, nodes, k: float = 1.0) -> np.ndarray:
        """(N,) reliability factors in ``nodes`` order (all-ones while
        the reliability state is empty)."""
        if self.reliability is None:
            return np.ones(len(nodes), np.float64)
        return self.reliability.factors(nodes, k)


@jax.jit
def _scaled_matrix_core(model: BatchedTaskModel, factors, size):
    """One jitted call: batched Student-t predictive × (T, N) factors."""
    mean_t, std_t = predict_task_batch(model, size)
    return mean_t[:, None] * factors, std_t[:, None] * factors


@jax.jit
def _ml_matrix_core(model: BatchedTaskModel, tokens, w_c, has_w,
                    flops, bytes_, coll, l_mat, l_mem, l_link,
                    t_mat, t_mem, t_link, is_local, mix):
    """Jitted (cell × node) estimate matrix for the decomposed predictor.

    Vectorises ``LotaruML.predict`` over both axes: the dual-run
    per-resource transfer where a compute share is available, the
    whole-time roofline-ratio transfer elsewhere, identity on the local
    node.  Shapes: cell arrays (T,), target bench arrays (N,).

    ``LotaruML.predict`` is the scalar oracle for this kernel — keep the
    two in lock-step (equivalence is test-enforced)."""
    mean, std = predict_task_batch(model, tokens)              # (T,)
    l_link_f = jnp.where(l_link > 0, l_link, l_mem / 10)
    t_link_f = jnp.where(t_link > 0, t_link, t_mem / 10)       # (N,)
    lc = jnp.stack([flops / (l_mat * 1e9),
                    bytes_ / (l_mem * 1e9),
                    coll / (l_link_f * 1e9)], axis=-1)         # (T, 3)
    # dual-run decomposition: measured compute share splits the local time
    t_c = w_c * mean
    rest = (1.0 - w_c) * mean
    mn = lc[:, 1] + lc[:, 2]
    t_m = rest * jnp.where(mn > 0, lc[:, 1] / jnp.where(mn > 0, mn, 1.0), 1.0)
    t_n = rest - t_m
    parts = jnp.stack([
        t_c[:, None] * l_mat / jnp.maximum(t_mat, 1e-9)[None, :],
        t_m[:, None] * l_mem / jnp.maximum(t_mem, 1e-9)[None, :],
        t_n[:, None] * l_link_f / jnp.maximum(t_link_f, 1e-9)[None, :],
    ], axis=-1)                                                # (T, N, 3)
    pred_dual = parts.max(axis=-1) + mix * parts.min(axis=-1)
    rel = std / jnp.maximum(mean, 1e-12)
    std_dual = pred_dual * rel[:, None]
    # whole-time roofline-ratio transfer (no throttle probe)
    tt = jnp.stack([flops[:, None] / (t_mat[None, :] * 1e9),
                    bytes_[:, None] / (t_mem[None, :] * 1e9),
                    coll[:, None] / (t_link_f[None, :] * 1e9)], axis=-1)
    comb_t = tt.max(axis=-1) + mix * tt.min(axis=-1)
    comb_l = lc.max(axis=-1) + mix * lc.min(axis=-1)
    ratio = comb_t / jnp.maximum(comb_l, 1e-12)[:, None]
    mean_m = jnp.where(has_w[:, None], pred_dual, mean[:, None] * ratio)
    std_m = jnp.where(has_w[:, None], std_dual, std[:, None] * ratio)
    mean_m = jnp.where(is_local[None, :], mean[:, None], mean_m)
    std_m = jnp.where(is_local[None, :], std[:, None], std_m)
    return mean_m, std_m


@dataclass
class FittedTask:
    model: TaskModel
    w: float                      # CPU-vs-IO weight (paper eq. 5)
    sizes: np.ndarray
    runtimes: np.ndarray


class LotaruEstimator(_BiasLayer):
    """Paper-faithful estimator over black-box tasks."""

    def __init__(self, local_bench: BenchResult,
                 target_benches: dict[str, BenchResult],
                 freq_reduction: float = 0.2, bias_correction: bool = True,
                 bias_decay: float = 1.0, bias_sigma_r: float = 0.25,
                 bias_empirical_bayes: bool = False):
        self.local_bench = local_bench
        self.target_benches = target_benches
        self.freq_reduction = freq_reduction
        self.tasks: dict[str, FittedTask] = {}
        self._batch_cache: tuple | None = None
        self._mat_cache: dict | None = None    # last (T, N) estimate matrix
        self._dirty_rows: set[int] = set()     # rows invalidated by observe()
        # online heterogeneity correction: per-(task, node) multiplicative
        # bias posterior fed by observe(); bias_correction=False keeps the
        # pure factor-scaled path (the paper-faithful / PR-2 ablation).
        # bias_decay < 1 forgets old residuals exponentially (hardware
        # drift); bias_empirical_bayes pools sigma_r from the observed
        # residual spread.  The defaults are bit-exact with PR 3.
        self._bias_setup(bias_correction, decay=bias_decay,
                         sigma_r=bias_sigma_r,
                         empirical_bayes=bias_empirical_bayes)

    def _bias_rows(self) -> dict:
        return self.tasks

    # ---- phases 2+3: local downsampled runs + model fit -------------------
    def fit_tasks(self, task_names: list[str], input_size: float,
                  run_local: Callable[[str, float, float], float],
                  n_partitions: int = 10, slow_partitions: int = 3) -> None:
        """run_local(task_name, size, cpu_factor) -> measured runtime.

        Collects every (task × partition) measurement first, then fits all
        T tasks in one vmapped ``fit_task_batch`` solve; the per-task
        scalar models are posterior-exact slices of that batch, and the
        batched cache is primed with the same fit (no second solve)."""
        sizes = np.array(partition_sizes(input_size, n_partitions))
        slow_factor = 1.0 - self.freq_reduction          # 20% CPU reduction
        runs, ws = [], []
        for name in task_names:
            normal = np.array([run_local(name, s, 1.0) for s in sizes])
            # second execution with reduced CPU speed on a few partitions
            sub = sizes[:slow_partitions]
            slow = np.array([run_local(name, s, slow_factor) for s in sub])
            devs = [deviation(t_new, t_old)
                    for t_new, t_old in zip(slow, normal[:slow_partitions])]
            ws.append(cpu_weight(float(np.median(devs)), 1.0, slow_factor))
            runs.append(normal)
        batch = fit_task_batch([sizes] * len(task_names), runs)
        for name, model, w, normal in zip(task_names,
                                          unstack_task_models(batch),
                                          ws, runs):
            self.tasks[name] = FittedTask(model=model, w=w, sizes=sizes,
                                          runtimes=normal)
        self._batch_cache = None
        self._mat_cache = None
        self._dirty_rows.clear()
        self._row_map = None
        names = list(self.tasks)
        if names == list(task_names):    # batch covers the whole task set
            fts = [self.tasks[n] for n in names]
            self._batch_cache = (names, fts, batch,
                                 np.array(ws, np.float64))

    # ---- phase 4: adjusted prediction --------------------------------------
    def factor(self, task_name: str, node: str) -> float:
        if node == self.local_bench.node:
            return 1.0
        ft = self.tasks[task_name]
        return runtime_factor(ft.w, self.local_bench,
                              self.target_benches[node])

    def predict(self, task_name: str, node: str, size: float):
        """(mean, std) for task on node at input size.

        The factor-scaled Student-t prediction, with the learned
        per-(task, node) bias folded in when the pair has been observed
        (scalar oracle of ``predict_matrix`` — test-enforced)."""
        ft = self.tasks[task_name]
        mean, std = ft.model.predict(size)
        f = self.factor(task_name, node)
        mean, std = float(mean) * f, float(std) * f
        return self._bias_fold_scalar(task_name, node, mean, std)

    def predict_local(self, task_name: str, size: float):
        ft = self.tasks[task_name]
        mean, std = ft.model.predict(size)
        return float(mean), float(std)

    # ---- batched (task × node) matrix API ----------------------------------
    def _batched(self) -> tuple[list[str], BatchedTaskModel, np.ndarray]:
        """All T task models stacked into one vmapped fit.

        Cached; invalidated when the task set OR any ``FittedTask`` object
        changes (identity check, so replacing ``est.tasks[name]`` in place
        is picked up — the cache holds the refs, keeping ids stable)."""
        names = list(self.tasks)
        fts = [self.tasks[n] for n in names]
        c = self._batch_cache
        if (c is None or c[0] != names
                or any(a is not b for a, b in zip(c[1], fts))):
            model = fit_task_batch([ft.sizes for ft in fts],
                                   [ft.runtimes for ft in fts])
            w = np.array([ft.w for ft in fts], np.float64)
            self._batch_cache = (names, fts, model, w)
        return (self._batch_cache[0], self._batch_cache[2],
                self._batch_cache[3])

    def task_names(self) -> list[str]:
        """Row order of ``predict_matrix`` / ``factor_matrix``."""
        return list(self.tasks)

    def factor_matrix(self, nodes: list[str]) -> np.ndarray:
        """(T, N) adjustment factors, rows in ``task_names()`` order."""
        names, _, w = self._batched()
        F = np.ones((len(names), len(nodes)))
        targets = [n for n in nodes if n != self.local_bench.node]
        if targets:
            Ft = runtime_factor(w, self.local_bench,
                                stack_benches([self.target_benches[n]
                                               for n in targets]))
            k = 0
            for j, n in enumerate(nodes):
                if n != self.local_bench.node:
                    F[:, j] = Ft[:, k]
                    k += 1
        return F

    def predict_matrix(self, nodes: list[str], size, with_std: bool = True):
        """Full (task × node) estimate matrix in one jitted call.

        ``size`` is a scalar (shared input size) or a (T,) per-task array.
        Returns (mean, std) arrays of shape (T, N): rows follow
        ``task_names()``, columns follow ``nodes`` (the local node gets
        factor 1, matching ``predict_local``).  With ``with_std=False``
        the std slot is ``None`` and the bias widening is skipped — for
        mean-only consumers (e.g. a risk-neutral HEFT rank) that don't
        want to pay for the delta-method fold.  ``with_std=True`` is the
        risk-aware path: the returned std already carries the bias
        posterior's own uncertainty, which is exactly the sigma a
        ``risk_k``-weighted scheduler should consume.

        The matrix is cached per (nodes, size); ``observe`` invalidates
        only the observed task's row, so an online re-predict recomputes
        the dirty rows instead of the whole matrix.  The cache holds the
        bias-free factor-scaled matrix; the (cheap, host-side) bias fold
        is applied on the way out so bias updates never force a jitted
        recompute of clean rows."""
        _, model, _ = self._batched()
        dt = model.post.mu.dtype
        key = (tuple(nodes), np.asarray(size, np.float64).tobytes())
        c = self._mat_cache
        if c is not None and c["key"] == key and c["model"] is model:
            rows = sorted(self._dirty_rows)
            if rows:
                idx = np.asarray(rows)
                sub = jax.tree_util.tree_map(lambda a: a[idx], model)
                sz = size if np.ndim(size) == 0 else np.asarray(size)[idx]
                with self._tracer.span("predict_matrix", rows=len(rows),
                                       mode="dirty"):
                    mean_r, std_r = _scaled_matrix_core(
                        sub, jnp.asarray(c["F"][idx], dt),
                        jnp.asarray(sz, dt))
                    c["mean"][idx] = np.asarray(mean_r, np.float64)
                    c["std"][idx] = np.asarray(std_r, np.float64)
                self._dirty_rows.clear()
            return self._bias_fold(nodes, c["mean"], c["std"], with_std)
        F = self.factor_matrix(nodes)
        with self._tracer.span("predict_matrix", rows=len(self.tasks),
                               mode="full"):
            mean, std = _scaled_matrix_core(model, jnp.asarray(F, dt),
                                            jnp.asarray(size, dt))
            mean, std = np.array(mean, np.float64), np.array(std, np.float64)
        # np.array (not asarray) above: jax arrays view as read-only
        # buffers and the cache must stay patchable row-by-row
        self._mat_cache = {"key": key, "model": model, "F": F,
                           "mean": mean, "std": std}
        self._dirty_rows.clear()
        return self._bias_fold(nodes, self._mat_cache["mean"],
                               self._mat_cache["std"], with_std)

    # ---- phase 5 (beyond paper): online estimation ------------------------
    def observe(self, task_name: str, node: str, size: float,
                runtime: float) -> float:
        """Feed one realised (size, runtime) from ``node`` back in.

        Single-observation convenience over ``observe_batch`` — returns
        the de-adjusted local-equivalent runtime that entered the model."""
        return self.observe_batch([(task_name, node, size, runtime)])[0]

    def observe_batch(self, observations) -> list[float]:
        """Absorb a whole tick's completions in one scanned stream.

        ``observations``: iterable of ``(task, node, size, runtime)``
        tuples or ``Observation``-likes (``.task/.node/.size/.runtime``) —
        e.g. everything that finished at the same simulation time.  Per
        observation:

        * the measured runtime is de-adjusted by factor × tick-start bias
          to the local-machine scale and queued for the model update;
        * after ONE ``update_task_batch_stream`` scan absorbs the queued
          stream (identical math to sequential ``update_task_batch``
          calls, no per-observation Python dispatch), each observation's
          residual against the POST-update factor-scaled prediction feeds
          the conjugate per-(task, node) bias posterior — what the
          refreshed model still cannot explain is the pair-specific part.

        Only the affected rows of any cached estimate matrix are
        invalidated.  Tick semantics: all residuals in the batch are
        evaluated against the post-tick posterior, so two same-task
        observations in one tick see the same model mean — sequential
        ``observe`` calls refresh it in between (batches over distinct
        tasks are exactly equivalent to sequential calls).  Returns the
        de-adjusted local runtimes in input order."""
        obs = [_as_obs_tuple(o) for o in observations]
        if not obs:
            return []
        names, model, _ = self._batched()
        row = {n: k for k, n in enumerate(names)}
        bias = self._ensure_bias() if self.bias_correction else None
        idx = np.empty(len(obs), np.int64)
        xs = np.empty(len(obs), np.float64)
        ys = np.empty(len(obs), np.float64)
        factors = np.empty(len(obs), np.float64)
        for k, (task, node, size, runtime) in enumerate(obs):
            i = row[task]
            f = max(float(self.factor(task, node)), 1e-12)
            b = 1.0
            if bias is not None and node in self._bias_col:
                b = bias.point(i, self._bias_col[node])
            idx[k] = i
            xs[k] = size
            ys[k] = runtime / (f * max(b, 1e-12))
            factors[k] = f
        with self._tracer.span("update_stream", n=len(obs)):
            new_model = update_task_batch_stream(model, idx, xs, ys)
        affected = []
        for k, (task, _, _, _) in enumerate(obs):
            ft = self.tasks[task]
            # keep the raw history on the FittedTask (same object, so the
            # batched cache's identity check stays valid) — a later full
            # refit over these arrays reproduces the incremental state
            ft.sizes = np.append(ft.sizes, xs[k])
            ft.runtimes = np.append(ft.runtimes, ys[k])
            affected.append(int(idx[k]))
        for i in set(affected):
            self.tasks[names[i]].model = slice_task_model(new_model, i)
        if bias is not None:
            # bias residuals against the POST-update factor-scaled means:
            # the model has already absorbed everything it can explain
            # from this tick (the task-common part), so what is left is
            # the pair-specific residual — charging the PRE-update means
            # instead would double-count the model's own transient misfit
            # into whichever pair happened to report first.  The whole
            # tick goes through ONE BiasModel.update scatter: one update
            # is one forgetting step, so the decay clock ticks per
            # simulation tick, not per completion within it
            rows, cols, lrs = [], [], []
            for k, (task, node, size, runtime) in enumerate(obs):
                if node not in self._bias_col:
                    continue
                m_post, _ = self.tasks[task].model.predict(size)
                scaled = factors[k] * float(m_post)
                if runtime > 0.0 and scaled > 1e-12:
                    rows.append(int(idx[k]))
                    cols.append(self._bias_col[node])
                    lrs.append(np.log(runtime / scaled))
            if rows:
                with self._tracer.span("bias_update", n=len(rows)):
                    bias.update(rows, cols, lrs)
        c = self._batch_cache
        self._batch_cache = (c[0], c[1], new_model, c[3])
        if self._mat_cache is not None and self._mat_cache["model"] is model:
            self._mat_cache["model"] = new_model
            self._dirty_rows.update(affected)
        else:
            self._mat_cache = None
        return [float(y) for y in ys]

    def predict_interval_node(self, task_name: str, node: str, size: float,
                              confidence: float = 0.9) -> tuple[float, float]:
        """Equal-tailed predictive interval for the task on ``node``.

        Student-t interval (factor-scaled) for correlated tasks; a normal
        median ± z·spread envelope for the median fallback.  When the
        (task, node) bias pair has been observed, the interval is shifted
        by the bias point estimate and WIDENED by the bias posterior's
        own uncertainty (± z posterior sds of the log-bias), so a pair
        whose bias is still unsettled admits a broader range before the
        surprise gate fires."""
        ft = self.tasks[task_name]
        f = self.factor(task_name, node)
        z = float(_scipy_stats.norm.ppf(0.5 + confidence / 2.0))
        if ft.model.correlated:
            lo, hi = predict_interval(ft.model.post, size, confidence)
            lo, hi = float(lo), float(hi)
        else:
            lo = ft.model.median - z * ft.model.spread
            hi = ft.model.median + z * ft.model.spread
        s_lo = s_hi = 1.0
        if self.bias_correction:
            bias = self._ensure_bias()
            j = self._bias_col.get(node)
            if j is not None:
                s_lo, s_hi = bias.interval_scale(self._row_of(task_name),
                                                 j, z)
        return max(lo * f * s_lo, 0.0), hi * f * s_hi

    def predict_pit_node(self, task_name: str, node: str, size: float,
                         runtime: float) -> float:
        """Probability integral transform of a realised runtime under the
        predictive distribution on ``node``: ``F(runtime)`` with the same
        location/scale/dof family as ``predict_interval_node`` — the
        Student-t predictive for correlated tasks, the normal
        median/spread envelope for the fallback, shifted by the factor
        and the bias *point* estimate (the bias posterior's own widening
        is deliberately not folded in: PIT judges the core predictive
        σ the scheduler prices with).  A calibrated stream of PITs is
        uniform on [0, 1]; ``repro.obs.calibration`` histograms them.
        Read-only: never creates bias state or touches any cache the
        predictions depend on."""
        ft = self.tasks[task_name]
        f = max(float(self.factor(task_name, node)), 1e-12)
        b = 1.0
        if self.bias_correction and self.bias is not None:
            j = self._bias_col.get(node)
            if j is not None:
                b = self.bias.point(self._row_of(task_name), j)
        y_local = float(runtime) / (f * max(b, 1e-12))
        if ft.model.correlated:
            return predict_cdf(ft.model.post, size, y_local)
        z = (y_local - ft.model.median) / max(ft.model.spread, 1e-300)
        return float(_scipy_stats.norm.cdf(z))

    # ---- offline reuse (paper §1: "allows for offline scenarios where the
    # learned models are reused for future executions") -----------------
    def save(self, path) -> None:
        """Schema v6: persists the fitted posteriors themselves (v2), the
        online per-(task, node) bias state (v3), the bias
        hyperparameters — forgetting factor ``decay`` and the
        ``empirical_bayes`` noise pooling (v4) — the per-node
        Beta–Binomial reliability posterior (v5), and the consolidated
        batched state (v6: the streamed (T, 8) moment matrix plus the
        stacked posterior, the exact arrays an ``EstimatorState``
        carries), so a save → load round trip reproduces predictions AND
        availability pricing bit-exactly, including everything learned
        from streamed observations and attempt outcomes — and a loaded
        estimator resumes the fused tick MOMENT-exact, not refit-close
        (re-deriving moments from raw samples sums in a different order).
        Earlier files still load: missing v4/v5 fields default to the
        inert (bit-exact) values, missing v6 state falls back to the
        refit path."""
        import json
        from pathlib import Path
        state = None
        if self.tasks:
            names, model, _w = self._batched()
            if model.stats is not None:
                p = model.post
                state = {
                    "tasks": list(names),
                    "moments": np.asarray(model.stats.moments,
                                          np.float64).tolist(),
                    "correlated": np.asarray(model.correlated,
                                             bool).tolist(),
                    "median": np.asarray(model.median, np.float64).tolist(),
                    "spread": np.asarray(model.spread, np.float64).tolist(),
                    "post": {"mu": np.asarray(p.mu, np.float64).tolist(),
                             "V": np.asarray(p.V, np.float64).tolist(),
                             "a": np.asarray(p.a, np.float64).tolist(),
                             "b": np.asarray(p.b, np.float64).tolist(),
                             "x_scale": np.asarray(p.x_scale,
                                                   np.float64).tolist(),
                             "y_scale": np.asarray(p.y_scale,
                                                   np.float64).tolist()}}
        out = {"version": SCHEMA_VERSION,
               "state": state,
               "freq_reduction": self.freq_reduction,
               "bias_correction": self.bias_correction,
               "bias_opts": dict(self._bias_opts),
               "bias": None if self.bias is None else {
                   "nodes": list(self.bias_nodes),
                   "state": self.bias.to_dict()},
               "reliability": (None if self.reliability is None
                               else self.reliability.to_dict()),
               "local_bench": self.local_bench.to_dict(),
               "target_benches": {k: v.to_dict()
                                  for k, v in self.target_benches.items()},
               "tasks": {}}
        for name, ft in self.tasks.items():
            m = ft.model
            post = None
            if m.post is not None:
                post = {"mu": np.asarray(m.post.mu, np.float64).tolist(),
                        "V": np.asarray(m.post.V, np.float64).tolist(),
                        "a": float(m.post.a), "b": float(m.post.b),
                        "x_scale": float(m.post.x_scale),
                        "y_scale": float(m.post.y_scale)}
            out["tasks"][name] = {
                "w": ft.w,
                "sizes": list(map(float, ft.sizes)),
                "runtimes": list(map(float, ft.runtimes)),
                "model": {"correlated": bool(m.correlated),
                          "median": float(m.median),
                          "spread": float(m.spread),
                          "post": post},
            }
        Path(path).write_text(json.dumps(out))

    @classmethod
    def load(cls, path) -> "LotaruEstimator":
        import json
        from pathlib import Path
        from .blr import BLRPosterior, _default_dtype, fit_task
        d = json.loads(Path(path).read_text())
        version = d.get("version", 1)
        local = BenchResult(**d["local_bench"])
        targets = {k: BenchResult(**v) for k, v in d["target_benches"].items()}
        opts = d.get("bias_opts", {})       # v4; absent in v1-v3 files
        est = cls(local, targets,
                  freq_reduction=d.get("freq_reduction", 0.2),
                  bias_correction=d.get("bias_correction", True),
                  bias_decay=opts.get("decay", 1.0),
                  bias_sigma_r=opts.get("sigma_r", 0.25),
                  bias_empirical_bayes=opts.get("empirical_bayes", False))
        if version >= 3 and d.get("bias") is not None:
            est.bias_nodes = list(d["bias"]["nodes"])
            est._bias_col = {n: j for j, n in enumerate(est.bias_nodes)}
            est.bias = BiasModel.from_dict(d["bias"]["state"])
        if version >= 5 and d.get("reliability") is not None:
            est.reliability = ReliabilityModel.from_dict(d["reliability"])
        dt = _default_dtype()
        for name, rec in d["tasks"].items():
            sizes = np.asarray(rec["sizes"])
            runtimes = np.asarray(rec["runtimes"])
            if version >= 2:
                md = rec["model"]
                post = None
                if md["post"] is not None:
                    p = md["post"]
                    post = BLRPosterior(
                        mu=jnp.asarray(p["mu"], dt),
                        V=jnp.asarray(p["V"], dt),
                        a=jnp.asarray(p["a"], dt), b=jnp.asarray(p["b"], dt),
                        x_scale=jnp.asarray(p["x_scale"], dt),
                        y_scale=jnp.asarray(p["y_scale"], dt))
                model = TaskModel(correlated=md["correlated"], post=post,
                                  median=md["median"], spread=md["spread"])
            else:              # v1 files carried only the raw samples
                model = fit_task(sizes, runtimes)
            est.tasks[name] = FittedTask(model=model,
                                         w=rec["w"], sizes=sizes,
                                         runtimes=runtimes)
        if version >= 6 and d.get("state") is not None:
            st = d["state"]
            est._prime_batch_cache(st, st["moments"], dt)
        return est

    def _prime_batch_cache(self, st: dict, moments, dt) -> None:
        """v6 fast path: rebuild the batched model from the persisted
        moment matrix and stacked posterior — bit-exact to the saved
        in-memory state — instead of refitting from raw samples (whose
        different summation order perturbs the last ulp of the moments).
        The raw-sample ``SampleLog`` (median-fallback history) is
        reconstructed from the per-task arrays, which carry every
        streamed observation."""
        from .blr import (BatchedTaskModel, BLRPosterior, OnlineStats,
                          SampleLog)
        names = list(st["tasks"])
        if names != list(self.tasks):
            return                       # stale block: fall back to refit
        fts = [self.tasks[n] for n in names]
        p = st["post"]
        post = BLRPosterior(
            mu=jnp.asarray(p["mu"], dt), V=jnp.asarray(p["V"], dt),
            a=jnp.asarray(p["a"], dt), b=jnp.asarray(p["b"], dt),
            x_scale=jnp.asarray(p["x_scale"], dt),
            y_scale=jnp.asarray(p["y_scale"], dt))
        count = np.array([len(ft.sizes) for ft in fts], np.int64)
        cap = max(1, int(count.max(initial=1)))
        X = np.zeros((len(fts), cap), np.float64)
        Y = np.zeros_like(X)
        for i, ft in enumerate(fts):
            X[i, :count[i]] = np.asarray(ft.sizes, np.float64)
            Y[i, :count[i]] = np.asarray(ft.runtimes, np.float64)
        stats = OnlineStats(moments=jnp.asarray(moments, dt),
                            log=SampleLog(X, Y, count))
        model = BatchedTaskModel(
            correlated=jnp.asarray(st["correlated"]), post=post,
            median=jnp.asarray(st["median"], dt),
            spread=jnp.asarray(st["spread"], dt), stats=stats)
        w = np.array([ft.w for ft in fts], np.float64)
        self._batch_cache = (names, fts, model, w)


# ---------------------------------------------------------------------------
# Accelerator-plane estimator
# ---------------------------------------------------------------------------
@dataclass
class FittedCell:
    model: TaskModel
    weights: tuple[float, float, float]
    full_tokens: int
    flops: float = 0.0            # per device, from the compiled artifact
    bytes_: float = 0.0
    coll: float = 0.0
    w_compute: float | None = None  # measured compute share (dual-run probe)
    tokens: np.ndarray | None = None     # raw local samples (batched refit)
    runtimes: np.ndarray | None = None


class LotaruML(_BiasLayer):
    """Lotaru over (arch x shape) workload cells (beyond-paper integration).

    The CPU-frequency probe does not transfer to TPUs; instead the cell's
    compiled artifact supplies per-device (FLOPs, bytes, collective bytes)
    and the *decomposed* predictor scales each resource term by its own
    microbenchmark ratio, recombining with the roofline max — this handles
    the bottleneck *switching* between the local CPU (compute-bound) and
    accelerator targets (often memory-bound).  ``predict_scalar`` keeps the
    paper's single-factor form as an ablation (it fails exactly when the
    bound switches; see benchmarks/tpu_cells.py)."""

    _MIX = 0.35   # secondary-term overlap coefficient of the roofline model

    def __init__(self, local_bench: BenchResult,
                 target_benches: dict[str, BenchResult],
                 bias_correction: bool = True, bias_decay: float = 1.0,
                 bias_sigma_r: float = 0.25,
                 bias_empirical_bayes: bool = False):
        self.local_bench = local_bench
        self.target_benches = target_benches
        self.cells: dict[str, FittedCell] = {}
        self._batch_cache: tuple | None = None
        self._mat_cache: dict | None = None
        self._dirty_rows: set[int] = set()
        # same online heterogeneity correction as LotaruEstimator: the
        # decomposed transfer linearises real cells imperfectly, and the
        # per-(cell, node) residual of that transfer is itself systematic
        # (decay / empirical-Bayes knobs as in LotaruEstimator)
        self._bias_setup(bias_correction, decay=bias_decay,
                         sigma_r=bias_sigma_r,
                         empirical_bayes=bias_empirical_bayes)

    def _bias_rows(self) -> dict:
        return self.cells

    def fit_cell(self, cell: dict,
                 run_local: Callable[[dict, float], float],
                 n_partitions: int = 6,
                 run_local_throttled: Callable[[dict, float], float] | None = None,
                 freq_reduction: float = 0.2,
                 slow_partitions: int = 3) -> None:
        """run_local(cell, token_fraction) -> measured local runtime.

        ``run_local_throttled`` is the paper's second execution at reduced
        compute speed (phase 2): the deviation separates the compute share
        w (paper eq. 5), which the decomposed predictor then transfers
        per-resource."""
        r = cell["roofline"]
        name = f"{cell['arch']}__{cell['shape']}"
        fracs = np.array(partition_sizes(1.0, n_partitions))
        runtimes = np.array([run_local(cell, f) for f in fracs])
        tokens = fracs * r["step_tokens"]
        model = fit_task(tokens, runtimes)
        weights = roofline_weights(r["compute_s"], r["memory_s"],
                                   r["collective_s"])
        w_compute = None
        if run_local_throttled is not None:
            devs = []
            for f, t_old in zip(fracs[:slow_partitions],
                                runtimes[:slow_partitions]):
                t_new = run_local_throttled(cell, f)
                devs.append(deviation(t_new, t_old))
            w_compute = cpu_weight(float(np.median(devs)), 1.0,
                                   1.0 - freq_reduction)
        self.cells[name] = FittedCell(
            model=model, weights=weights, full_tokens=int(r["step_tokens"]),
            flops=r["flops_per_device"], bytes_=r["bytes_per_device"],
            coll=r["coll_bytes_per_device"], w_compute=w_compute,
            tokens=tokens, runtimes=runtimes)
        self._batch_cache = None
        self._row_map = None

    # ---- helpers -----------------------------------------------------------
    def _terms(self, fc: FittedCell, bench: BenchResult) -> tuple:
        link = bench.link_gbps if bench.link_gbps > 0 else bench.mem_gbps / 10
        return (fc.flops / (bench.matmul_gflops * 1e9),
                fc.bytes_ / (bench.mem_gbps * 1e9),
                fc.coll / (link * 1e9))

    def _combine(self, terms) -> float:
        return max(terms) + self._MIX * min(terms)

    # ---- predictors ---------------------------------------------------------
    def predict(self, cell_name: str, node: str, tokens: float | None = None):
        """Decomposed (per-resource) prediction with the learned
        per-(cell, node) bias folded in (scalar oracle of
        ``predict_matrix`` — test-enforced)."""
        mean, std = self._predict_base(cell_name, node, tokens)
        return self._bias_fold_scalar(cell_name, node, mean, std)

    def _predict_base(self, cell_name: str, node: str,
                      tokens: float | None = None):
        """Bias-free decomposed prediction: the local measurement
        calibrates an efficiency alpha; each term re-scales by its own
        benchmark ratio.

        This scalar path is the equivalence oracle for the vectorised
        ``_ml_matrix_core`` (tests assert they agree): any change to the
        dual-run split, the link fallback or ``_MIX`` must be mirrored
        there."""
        fc = self.cells[cell_name]
        tokens = fc.full_tokens if tokens is None else tokens
        mean, std = fc.model.predict(tokens)
        if node == self.local_bench.node:
            return float(mean), float(std)
        tb = self.target_benches[node]
        if fc.w_compute is not None:
            # Dual-run decomposition (paper phase 2, per-resource transfer):
            # the measured compute share w splits the *measured* local time
            # into a compute part and a rest part; the rest splits between
            # memory and interconnect by the artifact's raw term ratio.
            # Each part scales by its own microbenchmark ratio.
            lc = self._terms(fc, self.local_bench)
            t_c = fc.w_compute * float(mean)
            rest = (1.0 - fc.w_compute) * float(mean)
            mn = lc[1] + lc[2]
            t_m = rest * (lc[1] / mn if mn > 0 else 1.0)
            t_n = rest - t_m
            link_l = (self.local_bench.link_gbps or
                      self.local_bench.mem_gbps / 10)
            link_t = tb.link_gbps or tb.mem_gbps / 10
            parts = (
                t_c * self.local_bench.matmul_gflops / max(tb.matmul_gflops, 1e-9),
                t_m * self.local_bench.mem_gbps / max(tb.mem_gbps, 1e-9),
                t_n * link_l / max(link_t, 1e-9),
            )
            pred = max(parts) + self._MIX * min(parts)
            rel = float(std) / max(float(mean), 1e-12)
            return pred, pred * rel
        # no throttle probe available: whole-time ratio transfer
        ratio = (self._combine(self._terms(fc, tb))
                 / max(self._combine(self._terms(fc, self.local_bench)), 1e-12))
        return float(mean) * ratio, float(std) * ratio

    def predict_scalar(self, cell_name: str, node: str,
                       tokens: float | None = None):
        """Paper-form single scalar factor (ablation)."""
        fc = self.cells[cell_name]
        tokens = fc.full_tokens if tokens is None else tokens
        mean, std = fc.model.predict(tokens)
        if node == self.local_bench.node:
            return float(mean), float(std)
        f = runtime_factor3(fc.weights, self.local_bench,
                            self.target_benches[node])
        return float(mean) * f, float(std) * f

    # ---- batched (cell × node) matrix API ----------------------------------
    def _batched(self):
        """Stack all cells for the vmapped path.

        Cached; invalidated when the cell set OR any ``FittedCell`` object
        changes (identity check, like ``LotaruEstimator._batched``).  Cells
        fitted via ``fit_cell`` carry raw local samples and are refitted in
        one vmapped solve; cells constructed by hand fall back to
        posterior-exact stacking of their scalar models."""
        names = list(self.cells)
        cells = [self.cells[n] for n in names]
        c = self._batch_cache
        if (c is None or c[0] != names
                or any(a is not b for a, b in zip(c[1], cells))):
            if all(c.tokens is not None and c.runtimes is not None
                   for c in cells):
                model = fit_task_batch([c.tokens for c in cells],
                                       [c.runtimes for c in cells])
            else:
                model = stack_task_models([c.model for c in cells])
            arrays = {
                "full_tokens": np.array([c.full_tokens for c in cells],
                                        np.float64),
                "flops": np.array([c.flops for c in cells], np.float64),
                "bytes_": np.array([c.bytes_ for c in cells], np.float64),
                "coll": np.array([c.coll for c in cells], np.float64),
                "w_c": np.array([c.w_compute if c.w_compute is not None
                                 else 0.0 for c in cells], np.float64),
                "has_w": np.array([c.w_compute is not None for c in cells]),
                "weights": np.array([c.weights for c in cells], np.float64),
            }
            self._batch_cache = (names, cells, model, arrays)
        return (self._batch_cache[0], self._batch_cache[2],
                self._batch_cache[3])

    def cell_names(self) -> list[str]:
        """Row order of ``predict_matrix`` / ``predict_matrix_scalar``."""
        return list(self.cells)

    def _node_arrays(self, nodes: list[str]):
        benches = [self.local_bench if n == self.local_bench.node
                   else self.target_benches[n] for n in nodes]
        ba = stack_benches(benches)
        is_local = np.array([n == self.local_bench.node for n in nodes])
        return ba, is_local

    def _matrix_rows(self, model, arr, toks, nodes, row_idx=None):
        """(mean, std) of ``_ml_matrix_core`` for all rows, or a subset
        when ``row_idx`` is given (online partial refresh)."""
        ba, is_local = self._node_arrays(nodes)
        lb = self.local_bench
        sel = (lambda a: a) if row_idx is None else (lambda a: a[row_idx])
        if row_idx is not None:
            model = jax.tree_util.tree_map(sel, model)
        mean, std = _ml_matrix_core(
            model, jnp.asarray(sel(toks)), jnp.asarray(sel(arr["w_c"])),
            jnp.asarray(sel(arr["has_w"])), jnp.asarray(sel(arr["flops"])),
            jnp.asarray(sel(arr["bytes_"])), jnp.asarray(sel(arr["coll"])),
            jnp.asarray(float(lb.matmul_gflops)),
            jnp.asarray(float(lb.mem_gbps)), jnp.asarray(float(lb.link_gbps)),
            jnp.asarray(ba.matmul_gflops), jnp.asarray(ba.mem_gbps),
            jnp.asarray(ba.link_gbps), jnp.asarray(is_local),
            jnp.asarray(self._MIX))
        # np.array (not asarray): the row cache patches these in place
        return np.array(mean, np.float64), np.array(std, np.float64)

    def predict_matrix(self, nodes: list[str], tokens=None,
                       with_std: bool = True):
        """Full (cell × node) decomposed estimate matrix, one jitted call.

        ``tokens``: None (each cell's full step tokens), a scalar, or a
        (T,) per-cell array.  Returns (mean, std) of shape (T, N); rows in
        ``cell_names()`` order, columns in ``nodes`` order; with
        ``with_std=False`` the std slot is ``None`` and the bias widening
        is skipped (mean-only fast path — see
        ``LotaruEstimator.predict_matrix``).  Cached per (nodes, tokens)
        bias-free; the bias fold happens on the way out; ``observe``
        dirties only the affected row."""
        _, model, arr = self._batched()
        toks = arr["full_tokens"] if tokens is None else np.broadcast_to(
            np.asarray(tokens, np.float64), arr["full_tokens"].shape)
        key = (tuple(nodes), toks.tobytes())
        c = self._mat_cache
        if c is not None and c["key"] == key and c["model"] is model:
            rows = sorted(self._dirty_rows)
            if rows:
                idx = np.asarray(rows)
                with self._tracer.span("predict_matrix", rows=len(rows),
                                       mode="dirty"):
                    mean_r, std_r = self._matrix_rows(model, arr, toks,
                                                      nodes, row_idx=idx)
                    c["mean"][idx] = mean_r
                    c["std"][idx] = std_r
                self._dirty_rows.clear()
            return self._bias_fold(nodes, c["mean"], c["std"], with_std)
        with self._tracer.span("predict_matrix", rows=len(self.cells),
                               mode="full"):
            mean, std = self._matrix_rows(model, arr, toks, nodes)
        self._mat_cache = {"key": key, "model": model,
                           "mean": mean, "std": std}
        self._dirty_rows.clear()
        return self._bias_fold(nodes, mean, std, with_std)

    def observe(self, cell_name: str, node: str, tokens: float,
                runtime: float) -> float:
        """Feed one realised (tokens, runtime) from ``node`` back in
        (single-observation convenience over ``observe_batch``)."""
        return self.observe_batch([(cell_name, node, tokens, runtime)])[0]

    def observe_batch(self, observations) -> list[float]:
        """Absorb a tick's realised (tokens, runtime) completions at once.

        The decomposed transfer is nonlinear in the local mean, so each
        measured runtime is de-adjusted by the *implied* factor at the
        tick-start posterior mean (bias-free prediction-on-node /
        local-mean) — exact for the ratio path, a linearisation for the
        dual-run path — times the current bias estimate; the residual
        against the implied prediction feeds the per-(cell, node) bias
        posterior, and one ``update_task_batch_stream`` scan absorbs the
        whole de-adjusted stream (see ``LotaruEstimator.observe_batch``
        for the tick semantics)."""
        obs = [_as_obs_tuple(o) for o in observations]
        if not obs:
            return []
        names, model, arr = self._batched()
        row = {n: k for k, n in enumerate(names)}
        bias = self._ensure_bias() if self.bias_correction else None
        idx = np.empty(len(obs), np.int64)
        xs = np.empty(len(obs), np.float64)
        ys = np.empty(len(obs), np.float64)
        for k, (cell_name, node, tokens, runtime) in enumerate(obs):
            i = row[cell_name]
            fc = self.cells[cell_name]
            if fc.tokens is None or fc.runtimes is None:
                raise ValueError(f"cell {cell_name!r} carries no raw local "
                                 "samples; online updates need "
                                 "fit_cell-built cells")
            m_node, _ = self._predict_base(cell_name, node, tokens)
            m_local, _ = fc.model.predict(tokens)
            if float(m_local) <= 1e-9:
                # the clamped-at-zero mean makes the transfer
                # un-invertible; absorbing runtime/f with f ~ 1e12 would
                # drag the posterior to zero — reject instead of silently
                # corrupting it
                raise ValueError(
                    f"cell {cell_name!r}: local predictive mean is ~0 at "
                    f"tokens={tokens}; cannot de-adjust the observation")
            f = max(float(m_node) / float(m_local), 1e-12)
            b = 1.0
            if bias is not None and node in self._bias_col:
                b = bias.point(i, self._bias_col[node])
            idx[k] = i
            xs[k] = tokens
            ys[k] = runtime / (f * max(b, 1e-12))
        with self._tracer.span("update_stream", n=len(obs)):
            new_model = update_task_batch_stream(model, idx, xs, ys)
        affected = []
        for k, (cell_name, _, _, _) in enumerate(obs):
            fc = self.cells[cell_name]
            fc.tokens = np.append(fc.tokens, xs[k])
            fc.runtimes = np.append(fc.runtimes, ys[k])
            affected.append(int(idx[k]))
        for i in set(affected):
            self.cells[names[i]].model = slice_task_model(new_model, i)
        if bias is not None:
            # bias residuals against the POST-update implied predictions —
            # same invariant as LotaruEstimator.observe_batch: the pair
            # term only absorbs what the refreshed cell model still
            # cannot explain.  One BiasModel.update per tick so the
            # forgetting factor decays per tick, not per completion
            rows, cols, lrs = [], [], []
            for k, (cell_name, node, tokens, runtime) in enumerate(obs):
                if node not in self._bias_col:
                    continue
                m_post, _ = self._predict_base(cell_name, node, tokens)
                if runtime > 0.0 and float(m_post) > 1e-12:
                    rows.append(int(idx[k]))
                    cols.append(self._bias_col[node])
                    lrs.append(np.log(runtime / float(m_post)))
            if rows:
                with self._tracer.span("bias_update", n=len(rows)):
                    bias.update(rows, cols, lrs)
        c = self._batch_cache
        self._batch_cache = (c[0], c[1], new_model, c[3])
        if self._mat_cache is not None and self._mat_cache["model"] is model:
            self._mat_cache["model"] = new_model
            self._dirty_rows.update(affected)
        else:
            self._mat_cache = None
        return [float(y) for y in ys]

    def predict_matrix_scalar(self, nodes: list[str], tokens=None):
        """Paper-form single-factor (cell × node) matrix (ablation): the
        vectorised ``runtime_factor3`` over stacked bench arrays."""
        _, model, arr = self._batched()
        toks = arr["full_tokens"] if tokens is None else np.broadcast_to(
            np.asarray(tokens, np.float64), arr["full_tokens"].shape)
        mean_t, std_t = predict_task_batch(model, jnp.asarray(toks))
        mean_t = np.asarray(mean_t, np.float64)
        std_t = np.asarray(std_t, np.float64)
        ba, is_local = self._node_arrays(nodes)
        F = runtime_factor3(arr["weights"], self.local_bench, ba)  # (T, N)
        F = np.where(is_local[None, :], 1.0, F)
        return mean_t[:, None] * F, std_t[:, None] * F

    def straggler_threshold(self, cell_name: str, node: str,
                            k: float = 3.0) -> float:
        """mean + k*sigma: tasks exceeding this are treated as stragglers."""
        mean, std = self.predict(cell_name, node)
        return mean + k * std


def young_daly_interval(step_time_s: float, mtbf_s: float,
                        checkpoint_cost_s: float) -> float:
    """Young/Daly optimal checkpoint interval, from predicted step time."""
    opt = float(np.sqrt(2.0 * checkpoint_cost_s * mtbf_s))
    return max(opt, step_time_s)
