"""Phase 4: model adjustment for the target infrastructure (paper §3.4).

    dev  = (t_reducedCPU - t_normal) / t_normal                (per sample)
    w    = clamp( median(dev) / (f_old/f_new - 1), 0, 1 )      (eq. 5)
    f_t  = w * cpu_local/cpu_target + (1-w) * io_local/io_target   (eq. 6)
    t(node) = t(local) * f_t

Beyond-paper extension for the accelerator plane: a *three-term* factor
over (FLOPs, HBM, interconnect) with weights taken from the workload's
roofline shares (derived from the compiled dry-run) — TPUs expose no
userspace DVFS, and the roofline decomposition carries strictly more
information than the paper's single frequency probe (DESIGN.md §2).
"""
from __future__ import annotations

import numpy as np

from .profiler import BenchResult


def deviation(t_new: float, t_old: float) -> float:
    return (t_new - t_old) / t_old


def cpu_weight(median_dev: float, freq_old: float, freq_new: float) -> float:
    """Paper eq. 5.  freq_old/freq_new > 1 (CPU was slowed down)."""
    denom = freq_old / freq_new - 1.0
    if denom <= 0:
        return 0.0
    return float(np.clip(median_dev / denom, 0.0, 1.0))


def runtime_factor(w: float, local: BenchResult, target: BenchResult) -> float:
    """Paper eq. 6 — CPU/I-O two-term factor."""
    cpu = local.cpu_events_s / max(target.cpu_events_s, 1e-9)
    io = _io_score(local) / max(_io_score(target), 1e-9)
    return w * cpu + (1.0 - w) * io


def _io_score(b: BenchResult) -> float:
    return 0.5 * (b.io_read_mbps + b.io_write_mbps)


def roofline_weights(compute_s: float, memory_s: float,
                     collective_s: float) -> tuple[float, float, float]:
    """Normalised shares of the three roofline terms."""
    tot = compute_s + memory_s + collective_s
    if tot <= 0:
        return (1.0, 0.0, 0.0)
    return (compute_s / tot, memory_s / tot, collective_s / tot)


def runtime_factor3(weights: tuple[float, float, float],
                    local: BenchResult, target: BenchResult) -> float:
    """Three-term factor: FLOPs / HBM / interconnect (beyond paper)."""
    wc, wm, wn = weights
    fc = local.matmul_gflops / max(target.matmul_gflops, 1e-9)
    fm = local.mem_gbps / max(target.mem_gbps, 1e-9)
    ln_local = local.link_gbps if local.link_gbps > 0 else local.mem_gbps / 10
    ln_tgt = target.link_gbps if target.link_gbps > 0 else target.mem_gbps / 10
    fn = ln_local / max(ln_tgt, 1e-9)
    return wc * fc + wm * fm + wn * fn
