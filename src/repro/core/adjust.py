"""Phase 4: model adjustment for the target infrastructure (paper §3.4).

    dev  = (t_reducedCPU - t_normal) / t_normal                (per sample)
    w    = clamp( median(dev) / (f_old/f_new - 1), 0, 1 )      (eq. 5)
    f_t  = w * cpu_local/cpu_target + (1-w) * io_local/io_target   (eq. 6)
    t(node) = t(local) * f_t

Beyond-paper extension for the accelerator plane: a *three-term* factor
over (FLOPs, HBM, interconnect) with weights taken from the workload's
roofline shares (derived from the compiled dry-run) — TPUs expose no
userspace DVFS, and the roofline decomposition carries strictly more
information than the paper's single frequency probe (DESIGN.md §2).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import numpy as np

from .profiler import BenchResult


@dataclass(frozen=True)
class BenchArrays:
    """A stack of N ``BenchResult``s as (N,) arrays.

    Duck-types the ``BenchResult`` fields, so ``runtime_factor`` /
    ``runtime_factor3`` accept it wherever a single bench is accepted and
    broadcast over the node axis — one call yields the whole factor row
    (or the full (T, N) matrix when the weights carry a task axis)."""
    nodes: tuple
    cpu_events_s: np.ndarray
    matmul_gflops: np.ndarray
    mem_gbps: np.ndarray
    io_read_mbps: np.ndarray
    io_write_mbps: np.ndarray
    link_gbps: np.ndarray


def stack_benches(benches: Sequence[BenchResult]) -> BenchArrays:
    return BenchArrays(
        nodes=tuple(b.node for b in benches),
        cpu_events_s=np.array([b.cpu_events_s for b in benches], np.float64),
        matmul_gflops=np.array([b.matmul_gflops for b in benches], np.float64),
        mem_gbps=np.array([b.mem_gbps for b in benches], np.float64),
        io_read_mbps=np.array([b.io_read_mbps for b in benches], np.float64),
        io_write_mbps=np.array([b.io_write_mbps for b in benches], np.float64),
        link_gbps=np.array([b.link_gbps for b in benches], np.float64))


def deviation(t_new: float, t_old: float) -> float:
    return (t_new - t_old) / t_old


def cpu_weight(median_dev: float, freq_old: float, freq_new: float) -> float:
    """Paper eq. 5.  freq_old/freq_new > 1 (CPU was slowed down)."""
    denom = freq_old / freq_new - 1.0
    if denom <= 0:
        return 0.0
    return float(np.clip(median_dev / denom, 0.0, 1.0))


def runtime_factor(w, local: BenchResult, target):
    """Paper eq. 6 — CPU/I-O two-term factor.

    ``w`` may be a scalar or a (T,) array; ``target`` a single
    ``BenchResult`` or a stacked ``BenchArrays``.  Broadcasting yields a
    float, (T,), (N,) or (T, N) — one call per estimate matrix."""
    cpu = np.asarray(local.cpu_events_s) / np.maximum(
        np.asarray(target.cpu_events_s, np.float64), 1e-9)
    io = np.asarray(_io_score(local)) / np.maximum(
        np.asarray(_io_score(target), np.float64), 1e-9)
    w = np.asarray(w, np.float64)
    if w.ndim and cpu.ndim:
        out = np.multiply.outer(w, cpu) + np.multiply.outer(1.0 - w, io)
    else:
        out = w * cpu + (1.0 - w) * io
    return float(out) if np.ndim(out) == 0 else out


def _io_score(b: BenchResult) -> float:
    return 0.5 * (b.io_read_mbps + b.io_write_mbps)


def roofline_weights(compute_s: float, memory_s: float,
                     collective_s: float) -> tuple[float, float, float]:
    """Normalised shares of the three roofline terms."""
    tot = compute_s + memory_s + collective_s
    if tot <= 0:
        return (1.0, 0.0, 0.0)
    return (compute_s / tot, memory_s / tot, collective_s / tot)


def runtime_factor3(weights, local: BenchResult, target):
    """Three-term factor: FLOPs / HBM / interconnect (beyond paper).

    ``weights`` is a (3,) tuple/array or a stacked (T, 3) array; ``target``
    a ``BenchResult`` or ``BenchArrays``.  Returns float, (T,), (N,) or
    (T, N) accordingly."""
    w = np.asarray(weights, np.float64)
    fc = np.asarray(local.matmul_gflops) / np.maximum(
        np.asarray(target.matmul_gflops, np.float64), 1e-9)
    fm = np.asarray(local.mem_gbps) / np.maximum(
        np.asarray(target.mem_gbps, np.float64), 1e-9)
    ln_local = np.where(np.asarray(local.link_gbps) > 0,
                        local.link_gbps, np.asarray(local.mem_gbps) / 10)
    ln_tgt = np.where(np.asarray(target.link_gbps, np.float64) > 0,
                      np.asarray(target.link_gbps, np.float64),
                      np.asarray(target.mem_gbps, np.float64) / 10)
    fn = ln_local / np.maximum(ln_tgt, 1e-9)
    ratios = np.stack(np.broadcast_arrays(fc, fm, fn), axis=-1)  # (..., 3)
    out = np.tensordot(w, ratios, axes=([-1], [-1]))
    return float(out) if np.ndim(out) == 0 else out
