"""The fused tick: observe → update → bias scatter → re-predict in ONE
jitted, donated-buffer dispatch over ``EstimatorState``.

The legacy online path is four separate dispatches per simulation tick
(``update_task_batch_stream`` scan, per-row ``slice_task_model``
writebacks, a host-side ``BiasModel.update`` scatter, and a dirty-row
``predict_matrix`` re-predict), stitched together by Python in
``LotaruEstimator.observe_batch``.  ``tick_step`` fuses the whole
sequence into one ``state -> state`` function the scheduler can sit
inside — and, because it is pure over a registered pytree, one that
``vmap``s over a leading workflow axis (``repro.online.fleet``) and
shards under ``jax.sharding.NamedSharding``.

Observation batches are packed as an ``(B, 8)`` array::

    [row, col, x, y_raw, y_local, med, spr, valid]

* ``row``/``col`` — task row and prediction-node column (``state``'s
  ``factors`` axes); ``x`` the input size; ``y_raw`` the measured
  runtime on the node;
* ``y_local`` — the host-de-adjusted local-equivalent runtime.  With
  ``host_deadjust=True`` (the ``TickEngine`` executor path) it is used
  verbatim, keeping the engine bit-compatible with
  ``observe_batch``'s host float64 de-adjust; with ``False`` (the pure
  device / fleet path) it is recomputed on device from ``y_raw`` and
  the tick-start bias, and the packed value is ignored;
* ``med``/``spr`` — the row's refreshed median/MAD (order statistics
  live host-side in the ``SampleLog``, exactly as in the legacy path);
* ``valid`` — padding mask (0 rows are no-ops), so fleet batches can
  pad ragged per-workflow ticks to one shape.

Rows flagged invalid leave every leaf bitwise untouched.
"""
from __future__ import annotations

import numpy as np
from scipy import stats as _scipy_stats

import jax
from jax import numpy as jnp

from .blr import (BiasModel, _attach_log, _default_dtype, _predict_core,
                  _update_core_impl, predict_cdf, predict_interval,
                  predict_task_batch, slice_task_model)
from .state import EstimatorState, bias_view, build_state, write_back


def _sigma_r(meta, counts, log_sum, log_sq, dt):
    """Device twin of ``BiasModel.effective_sigma_r`` — the fixed
    ``sigma_r``, or the pooled empirical spread of the observed
    log-residuals (floored) once any pair has two observations."""
    if not meta.empirical_bayes:
        return jnp.asarray(meta.sigma_r, dt)
    mask = counts >= 2
    safe_n = jnp.where(mask, counts, 1.0)
    ss = jnp.where(mask, log_sq - log_sum ** 2 / safe_n, 0.0).sum()
    dof = jnp.where(mask, counts - 1.0, 0.0).sum()
    s = jnp.sqrt(jnp.maximum(ss, 0.0) / jnp.maximum(dof, 1.0))
    pooled = jnp.maximum(s, BiasModel.SIGMA_R_FLOOR)
    return jnp.where(mask.any(), pooled, jnp.asarray(meta.sigma_r, dt))


def _fold_predict(model, state, counts, log_sum, log_sq, size):
    """Full (T, N) factor-scaled predictive with the bias posterior
    folded in — device twin of ``_scaled_matrix_core`` +
    ``_fold_bias_matrix`` (point-scale the mean, delta-method-widen the
    std, inert where unobserved or outside the bias universe)."""
    meta = state.meta
    dt = state.factors.dtype
    mean_t, std_t = predict_task_batch(model, size)
    mean = mean_t[:, None] * state.factors
    std = std_t[:, None] * state.factors
    if not meta.bias_correction:
        return mean, std
    sr = _sigma_r(meta, counts, log_sum, log_sq, dt)
    safe_cols = jnp.maximum(state.node_cols, 0)
    lam = 1.0 / meta.tau0 ** 2 + counts / sr ** 2
    mu = log_sum / (sr ** 2 * lam)
    v = 1.0 / lam
    mu_g, v_g = mu[:, safe_cols], v[:, safe_cols]
    n_g = counts[:, safe_cols]
    active = (state.node_cols >= 0)[None, :] & (n_g > 0)
    point = jnp.exp(mu_g)
    out_mean = jnp.where(active, mean * point, mean)
    widened = point * jnp.sqrt(std ** 2 + mean ** 2 * jnp.expm1(v_g))
    out_std = jnp.where(active, widened, std)
    return out_mean, out_std


def _tick_core(state: EstimatorState, obs, size, host_deadjust):
    """One fused tick.  Returns ``(state', mean, std, y_local)`` where
    ``mean``/``std`` are the refreshed post-tick (T, N) estimate matrix
    and ``y_local`` the (B,) local-equivalent runtimes that entered the
    model (input order)."""
    meta = state.meta
    dt = state.factors.dtype
    rows = obs[:, 0].astype(jnp.int32)
    cols = obs[:, 1].astype(jnp.int32)
    x, y_raw = obs[:, 2], obs[:, 3]
    med, spr, valid = obs[:, 5], obs[:, 6], obs[:, 7] > 0
    bcol = state.node_cols[cols]
    safe_b = jnp.maximum(bcol, 0)
    f = jnp.maximum(state.factors[rows, cols], 1e-12)
    if meta.bias_correction:
        # tick-START bias point estimates (the same values the legacy
        # path reads via ``BiasModel.point`` before updating anything)
        sr0 = _sigma_r(meta, state.bias_counts, state.bias_log_sum,
                       state.bias_log_sq, dt)
        n0 = state.bias_counts[rows, safe_b]
        lam0 = 1.0 / meta.tau0 ** 2 + n0 / sr0 ** 2
        mu0 = state.bias_log_sum[rows, safe_b] / (sr0 ** 2 * lam0)
        b_pt = jnp.where((bcol >= 0) & (n0 > 0), jnp.exp(mu0), 1.0)
    else:
        b_pt = jnp.ones_like(y_raw)
    if host_deadjust:
        y = obs[:, 4]
    else:
        y = y_raw / (f * jnp.maximum(b_pt, 1e-12))

    # --- streamed NIG moment/posterior update (masked scan) -------------
    packed = jnp.stack([rows.astype(dt), x, y, med, spr,
                        valid.astype(dt)], axis=-1)

    def step(m, o):
        upd = _update_core_impl(m, o[:5], meta.prior_scale, meta.a0,
                                meta.b0, meta.threshold)
        keep = o[5] > 0
        return jax.tree_util.tree_map(
            lambda new, old: jnp.where(keep, new, old), upd, m), None

    model, _ = jax.lax.scan(step, state.model, packed)

    counts = state.bias_counts
    log_sum = state.bias_log_sum
    log_sq = state.bias_log_sq
    if meta.bias_correction:
        # --- bias residuals vs the POST-update means (one scatter) ------
        p = model.post
        mean_b, _ = jax.vmap(_predict_core)(
            p.mu[rows], p.V[rows], p.a[rows], p.b[rows],
            p.x_scale[rows], p.y_scale[rows], x)
        m_post = jnp.where(model.correlated[rows],
                           jnp.maximum(mean_b, 0.0), model.median[rows])
        scaled = f * m_post
        resid_ok = valid & (bcol >= 0) & (y_raw > 0.0) & (scaled > 1e-12)
        ratio = jnp.where(resid_ok,
                          y_raw / jnp.where(resid_ok, scaled, 1.0), 1.0)
        lr = jnp.log(ratio)
        if meta.decay != 1.0:
            # one update is one forgetting step: decay fires iff the tick
            # contributes any residual, exactly like ``BiasModel.update``
            mult = jnp.where(resid_ok.any(), jnp.asarray(meta.decay, dt),
                             jnp.asarray(1.0, dt))
            counts, log_sum, log_sq = (counts * mult, log_sum * mult,
                                       log_sq * mult)
        zero = jnp.zeros_like(lr)
        counts = counts.at[rows, safe_b].add(
            jnp.where(resid_ok, jnp.ones_like(lr), zero))
        log_sum = log_sum.at[rows, safe_b].add(
            jnp.where(resid_ok, lr, zero))
        log_sq = log_sq.at[rows, safe_b].add(
            jnp.where(resid_ok, lr * lr, zero))

    mean, std = _fold_predict(model, state, counts, log_sum, log_sq, size)
    new_state = EstimatorState(
        model=model, factors=state.factors, node_cols=state.node_cols,
        bias_counts=counts, bias_log_sum=log_sum, bias_log_sq=log_sq,
        rel_succ=state.rel_succ, rel_fail=state.rel_fail, meta=meta)
    return new_state, mean, std, y


def _predict_state_core(state: EstimatorState, size):
    """Estimate matrix of a state without absorbing anything — the
    tick-zero twin of ``tick_step``'s (mean, std) outputs."""
    return _fold_predict(state.model, state, state.bias_counts,
                         state.bias_log_sum, state.bias_log_sq, size)


#: the fused tick entry point: donated state buffers (the input state is
#: consumed, like an optimiser state), one compile per (B, T, N) shape
tick_step = jax.jit(_tick_core, static_argnames=("host_deadjust",),
                    donate_argnums=(0,))

predict_state = jax.jit(_predict_state_core)


class TickEngine:
    """Executor-facing driver of the fused tick.

    Owns an ``EstimatorState`` snapshot of a fitted estimator and
    replaces the estimator's per-tick surface (``observe_batch`` +
    ``predict_matrix`` + the scalar interval/PIT/bias consumers) with
    ``tick_step`` outputs, while keeping the host-side pieces the legacy
    path keeps host-side: the raw-sample ``SampleLog`` (order
    statistics), the de-adjust of measured runtimes (bit-compatible
    float64, ``host_deadjust=True``) and the Beta-Binomial reliability
    plane (consumed by the scheduler, not the tick).

    The wrapped estimator is NOT updated per tick — call ``finalize()``
    when the run ends to write the final state back through the thin
    views, after which the estimator continues (scalar predicts,
    save/load, further ``observe_batch`` ticks) from exactly where the
    engine left off.
    """

    def __init__(self, est, nodes, *, size: float, tracer=None):
        from ..obs.trace import NULL_TRACER
        self.est = est
        self.size = float(size)
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.state, self.names = build_state(est, nodes)
        self._rowmap = {n: i for i, n in enumerate(self.names.tasks)}
        self._colmap = {n: j for j, n in enumerate(self.names.nodes)}
        self._log = self.state.model.stats.log
        self._model = self.state.model
        self._bias = bias_view(self.state) if est.bias_correction else None
        self._bias_col = dict(est._bias_col)
        self._touched: set[int] = set()
        self._rel_dirty = False
        mean, std = predict_state(self.state, self.size)
        self._mean = np.asarray(mean, np.float64)
        self._std = np.asarray(std, np.float64)

    # ---- estimator-compatible per-tick surface ------------------------
    def predict_matrix(self, nodes, size, with_std: bool = True):
        if list(nodes) != self.names_nodes or float(size) != self.size:
            raise ValueError(
                "TickEngine serves one (nodes, size) configuration; got "
                f"{list(nodes)}/{size}, engine holds "
                f"{self.names_nodes}/{self.size}")
        return self._mean, (self._std if with_std else None)

    @property
    def names_nodes(self) -> list[str]:
        return list(self.names.nodes)

    def observe_batch(self, observations) -> list[float]:
        """One fused tick: de-adjust host-side (bitwise the legacy
        float64 math), append the raw history, then dispatch ONE
        ``tick_step`` that absorbs the stream, scatters the bias
        residuals and re-predicts the full (T, N) matrix."""
        obs = list(observations)
        if not obs:
            return []
        est = self.est
        dt = _default_dtype()
        packed = np.zeros((len(obs), 8), np.float64)
        ys = np.empty(len(obs), np.float64)
        for k, o in enumerate(obs):
            task, node, size, runtime = (o if isinstance(o, (tuple, list))
                                         else (o.task, o.node, o.size,
                                               o.runtime))
            task, node = str(task), str(node)
            size, runtime = float(size), float(runtime)
            i = self._rowmap[task]
            f = max(float(est.factor(task, node)), 1e-12)
            b = 1.0
            if self._bias is not None and node in self._bias_col:
                b = self._bias.point(i, self._bias_col[node])
            y = runtime / (f * max(b, 1e-12))
            self._log.append(i, size, y)
            med, spr = self._log.median_spread(i)
            packed[k] = (i, self._colmap[node], size, runtime, y, med,
                         spr, 1.0)
            ys[k] = y
            ft = est.tasks[task]
            ft.sizes = np.append(ft.sizes, size)
            ft.runtimes = np.append(ft.runtimes, y)
            self._touched.add(i)
        if self._rel_dirty:
            self._sync_reliability()
        with self.tracer.span("tick_step", n=len(obs)):
            state, mean, std, _y = tick_step(self.state,
                                             jnp.asarray(packed, dt),
                                             self.size, host_deadjust=True)
            self._mean = np.asarray(mean, np.float64)
            self._std = np.asarray(std, np.float64)
        self.state = state
        self._model = _attach_log(state.model, self._log)
        if self._bias is not None:
            self._bias.counts = np.asarray(state.bias_counts, np.float64)
            self._bias.log_sum = np.asarray(state.bias_log_sum, np.float64)
            self._bias.log_sq = np.asarray(state.bias_log_sq, np.float64)
            self._bias._sigma_r_cache = None
        return [float(v) for v in ys]

    # ---- scalar consumers (tick-start belief) -------------------------
    def predict_interval_node(self, task_name: str, node: str, size: float,
                              confidence: float = 0.9):
        i = self._rowmap[task_name]
        tm = slice_task_model(self._model, i)
        f = self.est.factor(task_name, node)
        z = float(_scipy_stats.norm.ppf(0.5 + confidence / 2.0))
        if tm.correlated:
            lo, hi = predict_interval(tm.post, size, confidence)
            lo, hi = float(lo), float(hi)
        else:
            lo = tm.median - z * tm.spread
            hi = tm.median + z * tm.spread
        s_lo = s_hi = 1.0
        if self._bias is not None:
            j = self._bias_col.get(node)
            if j is not None:
                s_lo, s_hi = self._bias.interval_scale(i, j, z)
        return max(lo * f * s_lo, 0.0), hi * f * s_hi

    def predict_pit_node(self, task_name: str, node: str, size: float,
                         runtime: float) -> float:
        i = self._rowmap[task_name]
        tm = slice_task_model(self._model, i)
        f = max(float(self.est.factor(task_name, node)), 1e-12)
        b = 1.0
        if self._bias is not None:
            j = self._bias_col.get(node)
            if j is not None:
                b = self._bias.point(i, j)
        y_local = float(runtime) / (f * max(b, 1e-12))
        if tm.correlated:
            return predict_cdf(tm.post, size, y_local)
        z = (y_local - tm.median) / max(tm.spread, 1e-300)
        return float(_scipy_stats.norm.cdf(z))

    def bias_point(self, name: str, node: str) -> float:
        if self._bias is None:
            return 1.0
        j = self._bias_col.get(node)
        if j is None:
            return 1.0
        return self._bias.point(self._rowmap[name], j)

    def bias_tail_mass(self, name: str, node: str,
                       threshold: float) -> float:
        if self._bias is None:
            return 0.0
        j = self._bias_col.get(node)
        if j is None:
            return 0.0
        return self._bias.tail_mass(self._rowmap[name], j, threshold)

    # ---- reliability plane (host, scheduler-consumed) -----------------
    def record_attempt(self, node: str, success: bool) -> None:
        self.est.record_attempt(node, success)
        self._rel_dirty = True

    def reliability_factors(self, nodes, k: float = 1.0):
        return self.est.reliability_factors(nodes, k)

    def _sync_reliability(self) -> None:
        """Mirror the host reliability counts into the state leaves so
        the consolidated pytree stays authoritative for save/fleet
        consumers (the tick itself never reads them)."""
        import dataclasses as _dc
        rel = self.est.reliability
        names = self.names
        if rel is None or not names.rel_nodes:
            self._rel_dirty = False
            return
        dt = self.state.rel_succ.dtype
        succ = np.zeros(len(names.rel_nodes), np.float64)
        fail = np.zeros(len(names.rel_nodes), np.float64)
        for kk, n in enumerate(names.rel_nodes):
            succ[kk], fail[kk] = rel.counts(n)
        self.state = _dc.replace(self.state,
                                 rel_succ=jnp.asarray(succ, dt),
                                 rel_fail=jnp.asarray(fail, dt))
        self._rel_dirty = False

    # ---- writeback ----------------------------------------------------
    def finalize(self) -> None:
        """Fold the final state back into the wrapped estimator (batch
        cache, touched scalar models, bias posterior) — after this the
        legacy OO surface continues bit-compatibly."""
        if self._rel_dirty:
            self._sync_reliability()
        state = EstimatorState(
            model=self._model, factors=self.state.factors,
            node_cols=self.state.node_cols,
            bias_counts=self.state.bias_counts,
            bias_log_sum=self.state.bias_log_sum,
            bias_log_sq=self.state.bias_log_sq,
            rel_succ=self.state.rel_succ, rel_fail=self.state.rel_fail,
            meta=self.state.meta)
        write_back(state, self.names, self.est, rows=self._touched)
        self._touched.clear()
