"""TPU hot-spot kernels (Pallas): blocked flash attention + Mamba-2 SSD.
Validated in interpret mode against pure-jnp oracles (ref.py)."""
from . import flash_attention, ssd

__all__ = ["flash_attention", "ssd"]
