"""Mamba-2 SSD (state-space duality) chunked scan, TPU Pallas.

Grid: (batch, n_chunks) — chunks iterate minor-most so the inter-chunk
recurrent state (H, P, N) persists in VMEM scratch across the sequential
grid steps (TPU cores execute the grid in order; this is the TPU-native
replacement for the CUDA kernel's cross-block state passing).

Per chunk the kernel computes, entirely in VMEM:
  * cumulative log-decays (cumsum over the chunk),
  * the intra-chunk quadratic term  C_l (sum_m exp(A_l..m) B_m dt_m x_m)
    via two MXU matmuls (L x L scores, masked lower-triangular),
  * the inter-chunk term  C_l exp(A_l..0) . state,
  * the state update      state <- exp(A_L..0) state + B^T (decay dt x).

Head dim and state dim (P=64/128, N=64/128) are MXU-friendly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, y_ref, state_ref, *,
                chunk: int, n_heads: int, head_dim: int, d_state: int,
                n_groups: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)          # (L, H, P)
    dt = dt_ref[0].astype(jnp.float32)        # (L, H)
    B_ = b_ref[0].astype(jnp.float32)         # (L, G, N)
    C_ = c_ref[0].astype(jnp.float32)         # (L, G, N)
    a = a_ref[...].astype(jnp.float32)        # (H,)

    L, H, P = chunk, n_heads, head_dim
    G, N = n_groups, d_state
    rep = H // G

    da = dt * a[None, :]                      # (L, H) negative
    css = jnp.cumsum(da, axis=0)              # inclusive
    seg_end = css[-1]                         # (H,)

    Bh = jnp.repeat(B_, rep, axis=1)          # (L, H, N)
    Ch = jnp.repeat(C_, rep, axis=1)

    # inter-chunk: y_inter[l] = (C_l * exp(css_l)) . state
    Cd = Ch * jnp.exp(css)[..., None]         # (L, H, N)
    state = state_ref[...]                    # (H, P, N)
    y_inter = jnp.einsum("lhn,hpn->lhp", Cd, state,
                         preferred_element_type=jnp.float32)

    # intra-chunk quadratic form
    scores = jnp.einsum("lhn,mhn->lmh", Ch, Bh,
                        preferred_element_type=jnp.float32)   # (L, L, H)
    decay = jnp.exp(css[:, None, :] - css[None, :, :])        # (L, L, H)
    mask = (jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
            >= jax.lax.broadcasted_iota(jnp.int32, (L, L), 1))
    att = jnp.where(mask[..., None], scores * decay, 0.0)
    att = att * dt[None, :, :]                                # dt_m
    y_intra = jnp.einsum("lmh,mhp->lhp", att, x,
                         preferred_element_type=jnp.float32)

    # state update
    sdecay = jnp.exp(seg_end[None, :] - css)                  # (L, H)
    xw = x * (dt * sdecay)[..., None]                         # (L, H, P)
    chunk_state = jnp.einsum("lhn,lhp->hpn", Bh, xw,
                             preferred_element_type=jnp.float32)
    state_ref[...] = state * jnp.exp(seg_end)[:, None, None] + chunk_state

    y_ref[0] = (y_inter + y_intra).astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, a, B_, C_, *, chunk: int = 128, interpret: bool = True):
    """x: (B, T, H, P); dt: (B, T, H) (post-softplus); a: (H,) negative;
    B_, C_: (B, T, G, N).  Returns y: (B, T, H, P) fp32.

    T is padded to a chunk multiple with dt=0 (identity decay, no input).
    """
    Bb, T, H, P = x.shape
    G, N = B_.shape[2], B_.shape[3]
    L = min(chunk, T)
    pad = -T % L
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C_ = jnp.pad(C_, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_chunks = x.shape[1] // L

    kernel = functools.partial(_ssd_kernel, chunk=L, n_heads=H, head_dim=P,
                               d_state=N, n_groups=G)
    y = pl.pallas_call(
        kernel,
        grid=(Bb, n_chunks),
        in_specs=[
            pl.BlockSpec((1, L, H, P), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, L, H), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, L, G, N), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, L, G, N), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((H,), lambda b, c: (0,)),
        ],
        out_specs=pl.BlockSpec((1, L, H, P), lambda b, c: (b, c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((Bb, n_chunks * L, H, P), jnp.float32),
        scratch_shapes=[pltpu.VMEM((H, P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, B_, C_, a)
    if pad:
        y = y[:, :T]
    return y
