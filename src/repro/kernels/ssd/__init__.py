from .kernel import ssd_scan
from .ops import ssd
from .ref import ssd_ref

__all__ = ["ssd_scan", "ssd", "ssd_ref"]
