"""jit'd public wrapper for the SSD kernel."""
from __future__ import annotations

from .kernel import ssd_scan
from .ref import ssd_ref


def ssd(x, dt, a, B_, C_, *, chunk: int = 128, mode: str = "pallas",
        interpret: bool = True):
    if mode == "pallas":
        return ssd_scan(x, dt, a, B_, C_, chunk=chunk, interpret=interpret)
    return ssd_ref(x, dt, a, B_, C_)
