"""Pure-jnp oracle for the SSD scan: the naive O(T) recurrence.

    state_t = exp(dt_t * a) * state_{t-1} + dt_t * B_t (outer) x_t
    y_t     = C_t . state_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x, dt, a, B_, C_):
    """Shapes as kernel.ssd_scan. Returns (B, T, H, P) fp32."""
    Bb, T, H, P = x.shape
    G, N = B_.shape[2], B_.shape[3]
    rep = H // G
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bh = jnp.repeat(B_.astype(jnp.float32), rep, axis=2)   # (B, T, H, N)
    Ch = jnp.repeat(C_.astype(jnp.float32), rep, axis=2)

    def step(state, t):
        decay = jnp.exp(dtf[:, t] * a[None, :])            # (B, H)
        inp = jnp.einsum("bhn,bhp->bhpn", Bh[:, t],
                         xf[:, t] * dtf[:, t][..., None])
        state = state * decay[..., None, None] + inp
        y = jnp.einsum("bhn,bhpn->bhp", Ch[:, t], state)
        return state, y

    state0 = jnp.zeros((Bb, H, P, N), jnp.float32)
    _, ys = jax.lax.scan(step, state0, jnp.arange(T))
    return jnp.moveaxis(ys, 0, 1)                          # (B, T, H, P)
