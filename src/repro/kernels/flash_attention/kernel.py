"""Blocked (flash) attention forward, TPU Pallas.

TPU-native design (not a CUDA port): the grid is (batch, q_heads, q_blocks,
kv_blocks) with the kv dimension iterated minor-most — TPU grids execute
sequentially per core, so the online-softmax running state lives in VMEM
scratch across kv iterations (no warp semantics, no shared-memory banking).
Block shapes default to 128x128 (MXU tile aligned); GQA is handled in the
*index map* (q head h reads kv head h // group), so grouped KV is never
materialised in HBM.

Softmax statistics and the output accumulator are fp32; QK^T and PV run
with bf16 inputs + fp32 accumulation (MXU-native mixed precision).
Fully-masked causal blocks are skipped via ``pl.when`` (the compute —
though not the prefetch — of the upper triangle vanishes).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                      sm_scale: float, causal: bool, block_q: int,
                      block_k: int, n_k: int, kv_len: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    if causal:
        run = ik * block_k <= iq * block_q + block_q - 1
    else:
        run = True

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0]                                   # (bq, d)
        k = k_ref[0, 0]                                   # (bk, d)
        v = v_ref[0, 0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * sm_scale
        q_pos = iq * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        k_pos = ik * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        valid = k_pos < kv_len
        if causal:
            valid = valid & (k_pos <= q_pos)
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.where(valid, jnp.exp(s - m_new[:, None]), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        pv = jax.lax.dot_general(p.astype(v.dtype), v,
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr[:, None] + pv
        m_ref[...] = m_new

    @pl.when(ik == n_k - 1)
    def _finish():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret", "kv_len"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, block_q: int = 128,
                    block_k: int = 128, kv_len: int | None = None,
                    interpret: bool = True) -> jnp.ndarray:
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D); Hq % Hkv == 0.

    Returns (B, Hq, Sq, D) in q.dtype.  ``kv_len`` masks a padded KV tail
    (decode caches).  ``interpret=True`` runs the kernel body on CPU for
    validation; ``False`` targets real TPU.
    """
    B, Hq, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    assert Hq % Hkv == 0, (Hq, Hkv)
    group = Hq // Hkv
    kv_len = Sk if kv_len is None else min(kv_len, Sk)

    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    pq = -Sq % bq
    pk = -Sk % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    n_q = q.shape[2] // bq
    n_k = k.shape[2] // bk

    kernel = functools.partial(
        _flash_fwd_kernel, sm_scale=1.0 / math.sqrt(D), causal=causal,
        block_q=bq, block_k=bk, n_k=n_k, kv_len=kv_len)
    out = pl.pallas_call(
        kernel,
        grid=(B, Hq, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j: (b, h // group, j, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j: (b, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    if pq:
        out = out[:, :, :Sq]
    return out
