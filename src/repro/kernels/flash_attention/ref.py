"""Pure-jnp oracle for flash attention (fp32 throughout)."""
from __future__ import annotations

import math

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True,
                  kv_len: int | None = None) -> jnp.ndarray:
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D). Materialises full scores."""
    B, Hq, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    group = Hq // Hkv
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(D)
    kv_len = Sk if kv_len is None else kv_len
    valid = jnp.arange(Sk)[None, :] < kv_len
    if causal:
        valid = valid & (jnp.arange(Sk)[None, :] <= jnp.arange(Sq)[:, None])
    s = jnp.where(valid[None, None], s, -1e30)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p * valid[None, None]
    denom = jnp.sum(p, axis=-1, keepdims=True)
    p = p / jnp.where(denom == 0, 1.0, denom)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
