"""jit'd public wrapper: layout adaptation + kernel/XLA-path dispatch."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import flash_attention
from .ref import attention_ref


def mha(q, k, v, *, causal: bool = True, kv_len=None, mode: str = "pallas",
        interpret: bool = True, block_q: int = 128, block_k: int = 128):
    """Layout (B, S, H, D) — the model-stack convention.

    mode="pallas": blocked kernel (interpret=True on CPU, False on TPU);
    mode="xla": pure-jnp oracle (used by the dry-run path).
    """
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    if mode == "pallas":
        out = flash_attention(qt, kt, vt, causal=causal, kv_len=kv_len,
                              block_q=block_q, block_k=block_k,
                              interpret=interpret)
    else:
        out = attention_ref(qt, kt, vt, causal=causal, kv_len=kv_len)
    return jnp.swapaxes(out, 1, 2)
