"""Gradient compression for cross-pod reduction (beyond-paper lever).

int8 blockwise quantisation with error feedback: the quantisation residual
is carried to the next step so the compressed SGD direction stays unbiased
in the long run (1-bit Adam / EF-SGD family).  Under pjit the quantised
tensors are what cross the "pod" axis in the gradient all-reduce.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_BLK = 256


def _enc(x: jnp.ndarray):
    flat = x.reshape(-1)
    pad = (-flat.size) % _BLK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, _BLK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dec(q, scale, shape):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def _is_packed(x) -> bool:
    return isinstance(x, dict) and set(x) == {"q", "s"}


def compress_grads(grads, error_feedback=None):
    """Returns (quantised_tree, new_error_feedback)."""
    leaves, treedef = jax.tree.flatten(grads)
    if error_feedback is None:
        e_leaves = [jnp.zeros_like(g, jnp.float32) for g in leaves]
    else:
        e_leaves = jax.tree.flatten(error_feedback)[0]
    qs, es = [], []
    for g, e in zip(leaves, e_leaves):
        corrected = g.astype(jnp.float32) + e
        q, s = _enc(corrected)
        deq = _dec(q, s, g.shape)
        qs.append({"q": q, "s": s})
        es.append(corrected - deq)
    return jax.tree.unflatten(treedef, qs), jax.tree.unflatten(treedef, es)


def decompress_grads(qtree, shapes_like):
    q_leaves = jax.tree.flatten(qtree, is_leaf=_is_packed)[0]
    ref_leaves, treedef = jax.tree.flatten(shapes_like)
    outs = [_dec(p["q"], p["s"], r.shape) for p, r in zip(q_leaves, ref_leaves)]
    return jax.tree.unflatten(treedef, outs)
