"""Sharded AdamW with configurable state dtype (fp32 / bf16 / int8-blockwise).

Optimizer states inherit parameter shardings (ZeRO-3 equivalent under
FSDP-sharded params).  ``state_dtype="bf16"`` halves optimizer HBM — the
400B MoE config needs it to fit 16 GB/chip at 512 devices;
``state_dtype="int8"`` quantises m/v blockwise (block 128 along the last
dim) with fp32 per-block scales, an error-bounded 4x reduction.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.common import ParamDef, is_def


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: str = "fp32"          # fp32 | bf16 | int8
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"           # cosine | constant
    # scan the update over the leading (scan-stacked layers) dim of big
    # leaves. Measured on the dry-run: XLA double-buffers the scan and temp
    # usage *rises* — keep False (kept as an ablation lever, §Perf).
    scan_stacked: bool = False
    # keep an fp32 master copy in the optimizer state (mixed-precision
    # training with bf16 params: grads, weight gathers and backward carries
    # all run in bf16; update math stays fp32)
    master_fp32: bool = False


def lr_at(cfg: AdamWConfig, step) -> jnp.ndarray:
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    if cfg.schedule == "constant":
        return cfg.lr * warm
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * t))


# --- blockwise int8 state codec --------------------------------------------
_BLK = 128


def _q8_encode(x: jnp.ndarray):
    flat = x.reshape(-1)
    pad = (-flat.size) % _BLK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, _BLK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _q8_decode(q: jnp.ndarray, scale: jnp.ndarray, shape) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


# ---------------------------------------------------------------------------
def state_defs(param_defs, cfg: AdamWConfig):
    """ParamDef tree for optimizer state (same logical axes as params)."""
    if cfg.state_dtype == "int8":
        def mk(d: ParamDef):
            n = 1
            for s in d.shape:
                n *= s
            nblk = -(-n // _BLK)
            return {
                "m_q": ParamDef((nblk, _BLK), (None, None), init="zeros", dtype=jnp.int8),
                "m_s": ParamDef((nblk, 1), (None, None), init="ones", dtype=jnp.float32),
                "v_q": ParamDef((nblk, _BLK), (None, None), init="zeros", dtype=jnp.int8),
                "v_s": ParamDef((nblk, 1), (None, None), init="ones", dtype=jnp.float32),
            }
        mv = jax.tree.map(mk, param_defs, is_leaf=is_def)
    else:
        dt = jnp.bfloat16 if cfg.state_dtype == "bf16" else jnp.float32
        def mk(d: ParamDef):
            out = {"m": ParamDef(d.shape, d.logical_axes, init="zeros", dtype=dt),
                   "v": ParamDef(d.shape, d.logical_axes, init="zeros", dtype=dt)}
            if cfg.master_fp32:
                out["master"] = ParamDef(d.shape, d.logical_axes,
                                         init=d.init, scale=d.scale,
                                         dtype=jnp.float32)
            return out
        mv = jax.tree.map(mk, param_defs, is_leaf=is_def)
    return {"mv": mv, "step": ParamDef((), (), init="zeros", dtype=jnp.int32)}


def _leaf_update(g, p, s, lr, cfg: AdamWConfig, bc1, bc2):
    g = g.astype(jnp.float32)
    if cfg.state_dtype == "int8":
        m = _q8_decode(s["m_q"], s["m_s"], p.shape)
        v = _q8_decode(s["v_q"], s["v_s"], p.shape)
    else:
        m = s["m"].astype(jnp.float32)
        v = s["v"].astype(jnp.float32)
    base = s["master"] if (isinstance(s, dict) and "master" in s) else p
    m = cfg.b1 * m + (1 - cfg.b1) * g
    v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
    update = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
    if p.ndim >= 2:  # decoupled weight decay on matrices only
        update = update + cfg.weight_decay * base.astype(jnp.float32)
    new_base = base.astype(jnp.float32) - lr * update
    new_p = new_base.astype(p.dtype)
    if cfg.state_dtype == "int8":
        mq, ms = _q8_encode(m)
        vq, vs = _q8_encode(v)
        new_s = {"m_q": mq, "m_s": ms, "v_q": vq, "v_s": vs}
    else:
        dt = jnp.bfloat16 if cfg.state_dtype == "bf16" else jnp.float32
        new_s = {"m": m.astype(dt), "v": v.astype(dt)}
    if isinstance(s, dict) and "master" in s:
        new_s["master"] = new_base
    return new_p, new_s


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(params, grads, state, cfg: AdamWConfig):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state["step"]
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9)) if cfg.clip_norm > 0 else 1.0
    grads = jax.tree.map(lambda g: g * scale, grads)
    lr = lr_at(cfg, step)
    bc1 = 1 - cfg.b1 ** (step.astype(jnp.float32) + 1)
    bc2 = 1 - cfg.b2 ** (step.astype(jnp.float32) + 1)

    is_state_leaf = lambda x: isinstance(x, dict) and ("m" in x or "m_q" in x)
    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.flatten(grads)[0]
    flat_s = jax.tree.flatten(state["mv"], is_leaf=is_state_leaf)[0]

    def upd(g, p, s):
        if (cfg.scan_stacked and cfg.state_dtype != "int8" and p.ndim >= 3
                and p.shape[0] <= 128):
            def body(_, xs):
                gi, pi, mi, vi = xs
                np_, ns = _leaf_update(gi, pi, {"m": mi, "v": vi}, lr, cfg,
                                       bc1, bc2)
                return None, (np_, ns["m"], ns["v"])
            _, (np_, nm, nv) = jax.lax.scan(body, None,
                                            (g, p, s["m"], s["v"]))
            return np_, {"m": nm, "v": nv}
        return _leaf_update(g, p, s, lr, cfg, bc1, bc2)

    out = [upd(g, p, s) for g, p, s in zip(flat_g, flat_p, flat_s)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    s_treedef = jax.tree.structure(state["mv"], is_leaf=is_state_leaf)
    new_mv = jax.tree.unflatten(s_treedef, [o[1] for o in out])
    new_state = {"mv": new_mv, "step": step + 1}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
