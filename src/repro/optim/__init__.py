from .adamw import AdamWConfig, apply_updates, global_norm, lr_at, state_defs
from .compress import compress_grads, decompress_grads

__all__ = ["AdamWConfig", "apply_updates", "global_norm", "lr_at",
           "state_defs", "compress_grads", "decompress_grads"]
