"""Model factory: ModelConfig -> uniform {init, loss, prefill, decode} API."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from .common import (AxisRules, ModelConfig, tree_defs_init,
                     tree_defs_to_abstract, tree_defs_to_specs)
from . import encdec as _encdec
from . import transformer as _tf


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    param_defs: Any

    # ---- parameters -------------------------------------------------------
    def init(self, key) -> Any:
        return tree_defs_init(self.param_defs, key)

    def param_specs(self, rules: AxisRules):
        return tree_defs_to_specs(self.param_defs, rules)

    def abstract_params(self, mesh, rules: AxisRules):
        return tree_defs_to_abstract(self.param_defs, mesh, rules)

    # ---- caches ------------------------------------------------------------
    def cache_defs(self, batch: int, max_len: int, cross_len: int = 0,
                   cache_dtype=jnp.bfloat16):
        if self.cfg.family == "encdec":
            return _encdec.encdec_cache_def(self.cfg, batch, max_len,
                                            cross_len or max_len, cache_dtype)
        return _tf.cache_def(self.cfg, batch, max_len, cache_dtype)

    def init_caches(self, batch: int, max_len: int, cross_len: int = 0,
                    cache_dtype=jnp.bfloat16):
        defs = self.cache_defs(batch, max_len, cross_len, cache_dtype)
        return tree_defs_init(defs, jax.random.PRNGKey(0))

    def cache_specs(self, rules: AxisRules, batch: int, max_len: int,
                    cross_len: int = 0, cache_dtype=jnp.bfloat16):
        defs = self.cache_defs(batch, max_len, cross_len, cache_dtype)
        return tree_defs_to_specs(defs, rules)

    def abstract_caches(self, mesh, rules: AxisRules, batch: int, max_len: int,
                        cross_len: int = 0, cache_dtype=jnp.bfloat16):
        defs = self.cache_defs(batch, max_len, cross_len, cache_dtype)
        return tree_defs_to_abstract(defs, mesh, rules)

    # ---- compute -----------------------------------------------------------
    def loss(self, params, batch: dict, rules: AxisRules):
        if self.cfg.family == "encdec":
            return _encdec.encdec_loss(params, self.cfg, batch, rules)
        return _tf.lm_loss(params, self.cfg, batch, rules)

    def prefill(self, params, batch: dict, caches, rules: AxisRules):
        if self.cfg.family == "encdec":
            return _encdec.encdec_prefill(params, self.cfg, batch, caches, rules)
        return _tf.lm_prefill(params, self.cfg, batch, caches, rules)

    def decode(self, params, batch: dict, caches, cache_index, rules: AxisRules):
        if self.cfg.family == "encdec":
            return _encdec.encdec_decode(params, self.cfg, batch, caches,
                                         cache_index, rules)
        return _tf.lm_decode(params, self.cfg, batch, caches, cache_index, rules)


def build_model(cfg: ModelConfig) -> Model:
    if cfg.family == "encdec":
        defs = _encdec.encdec_def(cfg)
    else:
        defs = _tf.lm_def(cfg)
    return Model(cfg=cfg, param_defs=defs)
