"""Encoder-decoder backbone (seamless-m4t-large-v2 assignment).

The modality frontend is a stub per the assignment: ``input_specs()``
provides precomputed frame embeddings (B, T_src, d_model) for the encoder.
Decoder = causal self-attention + cross-attention + MLP, scan-stacked.

Shape semantics for the inference cells (recorded in EXPERIMENTS.md):
  prefill_32k  -> encode 32k source frames, build per-layer cross-KV caches,
                  decode position 0.
  decode_32k   -> one decoder step with a 32k self-KV cache + 32k cross-KV.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .common import AxisRules, ModelConfig, ParamDef, logical_constraint
from .layers import (apply_mlp, apply_norm, attention_def, cross_attention,
                     cross_attention_def, mlp_def, self_attention)
from .transformer import (chunked_xent, norm_def, stack_defs, unembed_matrix,
                          _remat)


def _enc_layer_def(cfg: ModelConfig) -> dict:
    return {"ln1": norm_def(cfg), "attn": attention_def(cfg),
            "ln2": norm_def(cfg), "mlp": mlp_def(cfg)}


def _dec_layer_def(cfg: ModelConfig) -> dict:
    return {"ln1": norm_def(cfg), "self_attn": attention_def(cfg),
            "ln2": norm_def(cfg), "cross_attn": cross_attention_def(cfg),
            "ln3": norm_def(cfg), "mlp": mlp_def(cfg)}


def encdec_def(cfg: ModelConfig) -> dict:
    return {
        "embed": ParamDef((cfg.vocab, cfg.d_model), ("vocab", "embed"), dtype=cfg.param_dtype),
        "enc_blocks": stack_defs(_enc_layer_def(cfg), cfg.enc_layers),
        "dec_blocks": stack_defs(_dec_layer_def(cfg), cfg.dec_layers),
        "ln_enc": norm_def(cfg),
        "ln_dec": norm_def(cfg),
        "unembed": ParamDef((cfg.d_model, cfg.vocab), ("embed", "vocab"), dtype=cfg.param_dtype),
    }


def encdec_cache_def(cfg: ModelConfig, batch: int, max_len: int,
                     cross_len: int, cache_dtype=jnp.bfloat16) -> dict:
    hd = cfg.resolved_head_dim()
    def kv(T):
        return {"k": ParamDef((batch, T, cfg.n_kv_heads, hd),
                              ("batch", "kv_seq", "kv_heads", "head_dim"),
                              init="zeros", dtype=cache_dtype),
                "v": ParamDef((batch, T, cfg.n_kv_heads, hd),
                              ("batch", "kv_seq", "kv_heads", "head_dim"),
                              init="zeros", dtype=cache_dtype)}
    return {"self": stack_defs(kv(max_len), cfg.dec_layers),
            "cross": stack_defs(kv(cross_len), cfg.dec_layers)}


def _positions(B: int, T: int, offset=0):
    return jnp.broadcast_to((offset + jnp.arange(T, dtype=jnp.int32))[None, :], (B, T))


def encode(params, cfg: ModelConfig, src_embeds: jnp.ndarray, rules: AxisRules):
    h = src_embeds.astype(cfg.dtype)
    h = logical_constraint(h, rules, "batch", None, "act_embed")
    B, T = h.shape[:2]
    pos = _positions(B, T)

    def layer(p, h):
        a, _ = self_attention(p["attn"], apply_norm(p["ln1"], h, cfg.norm),
                              cfg, causal=False, positions=pos, rules=rules)
        h = h + a
        return h + apply_mlp(p["mlp"], apply_norm(p["ln2"], h, cfg.norm), cfg)

    layer_r = _remat(layer, cfg)
    h, _ = lax.scan(lambda c, p: (layer_r(p, c), None), h, params["enc_blocks"])
    return apply_norm(params["ln_enc"], h, cfg.norm)


def decode_trunk(params, cfg: ModelConfig, tokens, enc_out, rules: AxisRules,
                 caches: dict | None = None, cache_index=None):
    """Decoder pass. With caches: cross caches must be prefilled (or enc_out
    given to build them on the fly when cache_index==0 is a fresh prefill)."""
    h = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    h = logical_constraint(h, rules, "batch", None, "act_embed")
    B, T = h.shape[:2]
    offset = cache_index if cache_index is not None else 0
    pos = _positions(B, T, offset)

    use_cache = caches is not None

    def layer(p, h, cache):
        a, nself = self_attention(p["self_attn"], apply_norm(p["ln1"], h, cfg.norm),
                                  cfg, causal=True, positions=pos,
                                  cache=cache["self"] if use_cache else None,
                                  cache_index=cache_index, rules=rules)
        h = h + a
        kv_cache = cache["cross"] if use_cache else None
        c, ncross = cross_attention(p["cross_attn"],
                                    apply_norm(p["ln2"], h, cfg.norm),
                                    enc_out, cfg, kv_cache=kv_cache)
        h = h + c
        h = h + apply_mlp(p["mlp"], apply_norm(p["ln3"], h, cfg.norm), cfg)
        return h, {"self": nself, "cross": ncross}

    layer_r = _remat(layer, cfg) if not use_cache else layer

    if use_cache:
        def body(h, xs):
            p, c = xs
            h, nc = layer_r(p, h, c)
            return h, nc
        h, new_caches = lax.scan(body, h, (params["dec_blocks"], caches))
    else:
        def body(h, p):
            h, _ = layer_r(p, h, {"self": None, "cross": None})
            return h, None
        h, _ = lax.scan(body, h, params["dec_blocks"])
        new_caches = None
    return apply_norm(params["ln_dec"], h, cfg.norm), new_caches


def encdec_loss(params, cfg: ModelConfig, batch: dict, rules: AxisRules):
    enc_out = encode(params, cfg, batch["src_embeds"], rules)
    h, _ = decode_trunk(params, cfg, batch["tokens"], enc_out, rules)
    labels = batch["labels"]
    mask = batch.get("mask", jnp.ones_like(labels, jnp.float32)).astype(jnp.float32)
    loss = chunked_xent(h, unembed_matrix(params, cfg), labels, mask, cfg, rules)
    return loss, {"xent": loss, "aux": jnp.zeros((), jnp.float32)}


def build_cross_caches(params, cfg: ModelConfig, enc_out, caches):
    """Fill per-decoder-layer cross-KV from encoder output (prefill)."""
    dt = caches["cross"]["k"].dtype

    def body(_, xs):
        p, c = xs
        k = jnp.einsum("btd,dhk->bthk", enc_out,
                       p["cross_attn"]["wk"].astype(enc_out.dtype))
        v = jnp.einsum("btd,dhk->bthk", enc_out,
                       p["cross_attn"]["wv"].astype(enc_out.dtype))
        Tc = c["k"].shape[1]
        k = k[:, :Tc].astype(dt)
        v = v[:, :Tc].astype(dt)
        pad = Tc - k.shape[1]
        if pad > 0:
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return None, {"k": k, "v": v}

    _, cross = lax.scan(body, None, (params["dec_blocks"], caches["cross"]))
    return cross


def encdec_prefill(params, cfg: ModelConfig, batch: dict, caches, rules: AxisRules):
    enc_out = encode(params, cfg, batch["src_embeds"], rules)
    caches = dict(caches)
    caches["cross"] = build_cross_caches(params, cfg, enc_out, caches)
    zipped = {"self": caches["self"], "cross": caches["cross"]}
    h, new_caches = decode_trunk(params, cfg, batch["tokens"], None, rules,
                                 caches=zipped, cache_index=jnp.zeros((), jnp.int32))
    logits = jnp.einsum("btd,dv->btv", h[:, -1:].astype(jnp.float32),
                        unembed_matrix(params, cfg).astype(jnp.float32))
    return logits, new_caches


def encdec_decode(params, cfg: ModelConfig, batch: dict, caches, cache_index,
                  rules: AxisRules):
    h, new_caches = decode_trunk(params, cfg, batch["tokens"], None, rules,
                                 caches=caches, cache_index=cache_index)
    logits = jnp.einsum("btd,dv->btv", h.astype(jnp.float32),
                        unembed_matrix(params, cfg).astype(jnp.float32))
    return logits, new_caches
