"""Shared model configuration and sharding vocabulary.

Models are plain pytrees of jnp arrays; every parameter leaf has a parallel
``PartitionSpec`` leaf built from *logical axis names* resolved against the
active mesh through ``AxisRules``.  No flax/haiku — the framework owns its
parameter system so that dry-run abstract lowering (ShapeDtypeStruct with
NamedSharding) and real initialization share one code path.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Mapping, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# ---------------------------------------------------------------------------
# Logical axis vocabulary
# ---------------------------------------------------------------------------
# layers    : scan-stacked layer dimension (never sharded; must stay local)
# vocab     : embedding / output-projection vocabulary dim     -> "model"
# embed     : d_model dim of weights                           -> fsdp axes
# heads     : query heads                                      -> "model"
# kv_heads  : KV heads (GQA)                                   -> "model"
# mlp       : feed-forward hidden dim                          -> "model"
# experts   : MoE expert dim                                   -> "model"
# batch     : activation batch dim                             -> data axes
# act_embed : activation d_model dim (usually unsharded)
# act_heads : activation heads dim                             -> "model"
# act_mlp   : activation ffn dim                               -> "model"
# act_vocab : activation vocab dim (chunked-xent logits)       -> "model"
# ssm_*     : mamba2 state dims (unsharded by default)

DEFAULT_RULES: dict[str, Any] = {
    "layers": None,
    "vocab": "model",
    "embed": "__fsdp__",      # resolved to ("data",) / ("pod","data") at mesh time
    "embed_noshard": None,
    "heads": "model",
    "kv_heads": "model",
    "kv_seq": None,           # cache seq dim; sharded when kv_heads % tp != 0
    "head_dim": None,
    "mlp": "model",
    "experts": "model",
    "expert_mlp": None,
    "batch": "__dp__",        # resolved to data axes
    "groups": "__dp__",
    "seq": None,
    "act_embed": None,
    "act_seq": "model",      # sequence-parallel activations between blocks
    "act_heads": "model",
    "act_kv_heads": "model",
    "act_mlp": "model",
    "act_vocab": "model",
    "embed_gather": "model",  # bf16 embed-table copy layout for the gather:
                              # d over "model" keeps the lookup collective-free
    "ssm_heads": "model",
    "ssm_state": None,
    "ssm_inner": "model",
    "conv_dim": "model",
}


@dataclass(frozen=True)
class AxisRules:
    """Resolves logical axis names to mesh axes for a given mesh layout.

    ``axis_sizes`` enables dimension-aware resolution: a sharded dim whose
    size does not divide the mesh-axis product is resolved to None (JAX
    rejects uneven input shardings).  The dropped sharding is compensated
    elsewhere (e.g. GQA caches shard ``kv_seq`` when kv_heads %% tp != 0).
    """

    fsdp_axes: tuple[str, ...] = ("data",)
    dp_axes: tuple[str, ...] = ("data",)
    overrides: Mapping[str, Any] = field(default_factory=dict)
    axis_sizes: Mapping[str, int] = field(default_factory=dict)

    def _mesh_axes(self, name: str):
        table = dict(DEFAULT_RULES)
        table.update(self.overrides)
        mesh_axis = table.get(name, None)
        if mesh_axis == "__fsdp__":
            mesh_axis = self.fsdp_axes if len(self.fsdp_axes) > 1 else (
                self.fsdp_axes[0] if self.fsdp_axes else None)
        elif mesh_axis == "__dp__":
            mesh_axis = self.dp_axes if len(self.dp_axes) > 1 else (
                self.dp_axes[0] if self.dp_axes else None)
        return mesh_axis

    def _shard_count(self, mesh_axis) -> int:
        if mesh_axis is None or not self.axis_sizes:
            return 1
        axes = mesh_axis if isinstance(mesh_axis, tuple) else (mesh_axis,)
        n = 1
        for a in axes:
            n *= self.axis_sizes.get(a, 1)
        return n

    def resolve(self, *logical: str | None,
                dims: Sequence[int] | None = None) -> P:
        out = []
        for i, name in enumerate(logical):
            if name is None:
                out.append(None)
                continue
            mesh_axis = self._mesh_axes(name)
            if dims is not None and mesh_axis is not None:
                n = self._shard_count(mesh_axis)
                if n > 1 and dims[i] % n != 0:
                    mesh_axis = None     # uneven: fall back to replication
            out.append(mesh_axis)
        return P(*out)


def mesh_axis_sizes(mesh) -> dict:
    return {name: int(mesh.shape[name]) for name in mesh.axis_names}


def rules_for_mesh(mesh) -> AxisRules:
    names = mesh.axis_names
    sizes = mesh_axis_sizes(mesh)
    if "pod" in names:
        return AxisRules(fsdp_axes=("pod", "data"), dp_axes=("pod", "data"),
                         axis_sizes=sizes)
    if "data" in names:
        return AxisRules(fsdp_axes=("data",), dp_axes=("data",),
                         axis_sizes=sizes)
    # single-device / test mesh
    return AxisRules(fsdp_axes=(), dp_axes=(), axis_sizes=sizes)


# ---------------------------------------------------------------------------
# Configs
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    every: int = 1                 # MoE block every N layers (llama4: 2)
    shared_expert: bool = False    # additional always-on expert (llama4)
    capacity_factor: float = 1.25
    router_jitter: float = 0.0


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2                # d_inner = expand * d_model
    head_dim: int = 64             # mamba2 P
    chunk: int = 128               # SSD chunk length
    n_groups: int = 1              # B/C groups

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_ssm_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class ModelConfig:
    arch: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    qkv_bias: bool = False
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    act: str = "swiglu"            # swiglu | gelu
    rope_theta: float = 10_000.0
    mrope: bool = False            # qwen2-vl multimodal RoPE
    tie_embeddings: bool = False
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    hybrid_attn_every: int = 0     # zamba2: shared attn block every N ssm blocks
    enc_layers: int = 0            # encdec only
    dec_layers: int = 0
    # numerics / execution
    dtype: Any = jnp.bfloat16      # activation/compute dtype
    param_dtype: Any = jnp.float32
    attn_chunk: int = 512          # KV block for chunked flash-style attention
    xent_chunk: int = 2048         # token block for chunked cross entropy
    remat: str = "full"            # none | full | dots
    moe_groups: int = 0            # 0 -> infer from mesh dp size
    kernel_mode: str = "xla"       # xla | pallas (pallas only on real TPU)
    seq_shard: bool = True         # sequence-parallel activations (Megatron-SP)

    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ----- parameter counting (analytic; used by roofline + Lotaru) -------
    def param_count(self) -> int:
        return _param_count(self)

    def active_param_count(self) -> int:
        return _param_count(self, active_only=True)


def _attn_params(cfg: ModelConfig) -> int:
    hd = cfg.resolved_head_dim()
    q = cfg.d_model * cfg.n_heads * hd
    kv = 2 * cfg.d_model * cfg.n_kv_heads * hd
    o = cfg.n_heads * hd * cfg.d_model
    b = (cfg.n_heads * hd + 2 * cfg.n_kv_heads * hd) if cfg.qkv_bias else 0
    return q + kv + o + b


def _mlp_params(d_model: int, d_ff: int, act: str) -> int:
    return (3 if act == "swiglu" else 2) * d_model * d_ff


def _param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    emb = cfg.vocab * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    if cfg.family == "encdec":
        enc = cfg.enc_layers * (_attn_params(cfg) + _mlp_params(cfg.d_model, cfg.d_ff, cfg.act))
        dec = cfg.dec_layers * (2 * _attn_params(cfg) + _mlp_params(cfg.d_model, cfg.d_ff, cfg.act))
        return emb + enc + dec
    if cfg.family == "ssm":
        s = cfg.ssm
        di = s.d_inner(cfg.d_model)
        nh = s.n_ssm_heads(cfg.d_model)
        per = (cfg.d_model * (2 * di + 2 * s.n_groups * s.d_state + nh)   # in_proj
               + s.d_conv * (di + 2 * s.n_groups * s.d_state)             # conv
               + nh * 2                                                   # A_log, D
               + di                                                       # norm gate
               + di * cfg.d_model)                                        # out_proj
        return emb + cfg.n_layers * per
    if cfg.family == "hybrid":
        ssm_cfg = cfg.with_(family="ssm")
        base = _param_count(ssm_cfg, active_only)
        shared = _attn_params(cfg) + _mlp_params(cfg.d_model, cfg.d_ff, cfg.act)
        return base + shared
    # dense / moe / vlm
    per_attn = _attn_params(cfg)
    total = emb
    for layer in range(cfg.n_layers):
        total += per_attn
        if cfg.moe is not None and layer % cfg.moe.every == cfg.moe.every - 1:
            n_active = cfg.moe.top_k + (1 if cfg.moe.shared_expert else 0)
            n_count = n_active if active_only else (
                cfg.moe.n_experts + (1 if cfg.moe.shared_expert else 0))
            total += n_count * _mlp_params(cfg.d_model, cfg.moe.d_ff_expert, cfg.act)
            total += cfg.d_model * cfg.moe.n_experts  # router
        else:
            total += _mlp_params(cfg.d_model, cfg.d_ff, cfg.act)
    return total


# ---------------------------------------------------------------------------
# Parameter/spec tree construction
# ---------------------------------------------------------------------------
@dataclass
class ParamDef:
    """Deferred parameter: shape + init + logical axes.

    Materialised either abstractly (ShapeDtypeStruct for the dry-run) or
    concretely (real arrays for training/examples).
    """
    shape: tuple[int, ...]
    logical_axes: tuple[str | None, ...]
    init: str = "normal"           # normal | zeros | ones | scaled
    scale: float = 1.0
    dtype: Any = jnp.float32

    def spec(self, rules: AxisRules) -> P:
        return rules.resolve(*self.logical_axes, dims=self.shape)


def init_leaf(key, d: ParamDef):
    if d.init == "zeros":
        return jnp.zeros(d.shape, d.dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, d.dtype)
    fan_in = d.shape[-2] if len(d.shape) >= 2 else max(d.shape[-1], 1)
    std = d.scale / (fan_in ** 0.5)
    return (jax.random.normal(key, d.shape, jnp.float32) * std).astype(d.dtype)


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def tree_defs_to_specs(defs, rules: AxisRules):
    return jax.tree.map(lambda d: d.spec(rules), defs, is_leaf=is_def)


def tree_defs_to_abstract(defs, mesh, rules: AxisRules):
    from jax.sharding import NamedSharding
    def mk(d: ParamDef):
        return jax.ShapeDtypeStruct(d.shape, d.dtype,
                                    sharding=NamedSharding(mesh, d.spec(rules)))
    return jax.tree.map(mk, defs, is_leaf=is_def)


def tree_defs_init(defs, key):
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_def)
    keys = jax.random.split(key, len(leaves))
    vals = [init_leaf(k, d) for k, d in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, vals)


def logical_constraint(x, rules: AxisRules, *logical: str | None):
    """sharding constraint by logical axes; no-op outside a mesh context."""
    try:
        return jax.lax.with_sharding_constraint(
            x, rules.resolve(*logical, dims=x.shape))
    except (ValueError, RuntimeError):
        return x
