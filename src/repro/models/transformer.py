"""Decoder-only LM assembly for all non-encdec families.

Layers are scan-stacked (one-layer HLO, fast multi-pod compiles).  The MoE
interleave pattern (llama4: MoE every 2nd layer) is handled by making the
scan unit = ``moe.every`` consecutive layers, so stacked params stay
homogeneous.  The zamba2 hybrid scans *super-units*: ``hybrid_attn_every``
Mamba2 layers followed by one application of a single weight-tied shared
attention block (per the Zamba2 design) — fully static, no ``lax.cond``
(keeps the HLO attributable for roofline accounting).  Remainder layers
(38 % 6 = 2) form a scanned tail without attention.

Loss uses chunked cross-entropy: logits are only ever materialised for one
token chunk at a time, with the vocab dim sharded over "model" — required
for vocab 256k × 1M-token global batches.

Activations between blocks are sequence-sharded over "model"
(Megatron-SP style) when ``cfg.seq_shard`` — the single biggest HBM lever
for the 16 GB/chip mesh (see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from .common import (AxisRules, ModelConfig, ParamDef, is_def,
                     logical_constraint)
from .layers import (apply_mlp, apply_norm, attention_def, mlp_def,
                     rmsnorm_def, layernorm_def, self_attention)
from .mamba2 import apply_mamba2, decode_mamba2, mamba2_def
from .moe import apply_moe, moe_def

AUX_LOSS_COEF = 0.01


def norm_def(cfg: ModelConfig) -> dict:
    return layernorm_def(cfg.d_model) if cfg.norm == "layernorm" else rmsnorm_def(cfg.d_model)


def stack_defs(defs, n: int):
    """Add a leading scan-stacked 'layers' dim to every ParamDef leaf."""
    return jax.tree.map(
        lambda d: ParamDef((n,) + d.shape, ("layers",) + d.logical_axes,
                           init=d.init, scale=d.scale, dtype=d.dtype),
        defs, is_leaf=is_def)


def _index_tree(tree, j: int):
    return jax.tree.map(lambda x: x[j], tree)


def _stack_tree(trees: list):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


# ---------------------------------------------------------------------------
# Scan-unit definitions
# ---------------------------------------------------------------------------
def _dense_layer_def(cfg: ModelConfig) -> dict:
    return {"ln1": norm_def(cfg), "attn": attention_def(cfg),
            "ln2": norm_def(cfg), "mlp": mlp_def(cfg)}


def _moe_layer_def(cfg: ModelConfig) -> dict:
    return {"ln1": norm_def(cfg), "attn": attention_def(cfg),
            "ln2": norm_def(cfg), "moe": moe_def(cfg)}


def _ssm_layer_def(cfg: ModelConfig) -> dict:
    return {"ln": norm_def(cfg), "mamba": mamba2_def(cfg)}


def scan_unit_def(cfg: ModelConfig) -> dict:
    if cfg.family in ("dense", "vlm"):
        return _dense_layer_def(cfg)
    if cfg.family == "moe":
        unit = {"moe_layer": _moe_layer_def(cfg)}
        for j in range(cfg.moe.every - 1):
            unit[f"dense_{j}"] = _dense_layer_def(cfg)
        return unit
    if cfg.family == "ssm":
        return _ssm_layer_def(cfg)
    if cfg.family == "hybrid":
        return {"ssm_layers": stack_defs(_ssm_layer_def(cfg), cfg.hybrid_attn_every)}
    raise ValueError(cfg.family)


def n_scan_units(cfg: ModelConfig) -> int:
    if cfg.family == "moe":
        assert cfg.n_layers % cfg.moe.every == 0
        return cfg.n_layers // cfg.moe.every
    if cfg.family == "hybrid":
        return cfg.n_layers // cfg.hybrid_attn_every
    return cfg.n_layers


def hybrid_tail_layers(cfg: ModelConfig) -> int:
    return cfg.n_layers % cfg.hybrid_attn_every if cfg.family == "hybrid" else 0


def lm_def(cfg: ModelConfig) -> dict:
    d: dict[str, Any] = {
        "embed": ParamDef((cfg.vocab, cfg.d_model), ("vocab", "embed"), dtype=cfg.param_dtype),
        "blocks": stack_defs(scan_unit_def(cfg), n_scan_units(cfg)),
        "ln_f": norm_def(cfg),
    }
    if not cfg.tie_embeddings:
        d["unembed"] = ParamDef((cfg.d_model, cfg.vocab), ("embed", "vocab"), dtype=cfg.param_dtype)
    if cfg.family == "hybrid":
        d["shared_attn"] = {"ln1": norm_def(cfg), "attn": attention_def(cfg),
                            "ln2": norm_def(cfg), "mlp": mlp_def(cfg)}
        tail = hybrid_tail_layers(cfg)
        if tail:
            d["tail_blocks"] = stack_defs(_ssm_layer_def(cfg), tail)
    return d


# ---------------------------------------------------------------------------
# Cache definitions (ParamDef so the dry-run can make abstract sharded caches)
# ---------------------------------------------------------------------------
def _kv_def(cfg: ModelConfig, batch: int, max_len: int, cache_dtype) -> dict:
    hd = cfg.resolved_head_dim()
    return {"k": ParamDef((batch, max_len, cfg.n_kv_heads, hd),
                          ("batch", "kv_seq", "kv_heads", "head_dim"),
                          init="zeros", dtype=cache_dtype),
            "v": ParamDef((batch, max_len, cfg.n_kv_heads, hd),
                          ("batch", "kv_seq", "kv_heads", "head_dim"),
                          init="zeros", dtype=cache_dtype)}


def _ssm_cache_def(cfg: ModelConfig, batch: int, cache_dtype) -> dict:
    s = cfg.ssm
    conv_ch = s.d_inner(cfg.d_model) + 2 * s.n_groups * s.d_state
    H = s.n_ssm_heads(cfg.d_model)
    return {"conv": ParamDef((batch, s.d_conv - 1, conv_ch),
                             ("batch", None, "conv_dim"), init="zeros", dtype=cache_dtype),
            "state": ParamDef((batch, H, s.head_dim, s.d_state),
                              ("batch", "ssm_heads", None, None),
                              init="zeros", dtype=jnp.float32)}


def cache_def(cfg: ModelConfig, batch: int, max_len: int,
              cache_dtype=jnp.bfloat16) -> dict:
    if cfg.family in ("dense", "vlm"):
        return {"blocks": stack_defs(_kv_def(cfg, batch, max_len, cache_dtype),
                                     n_scan_units(cfg))}
    if cfg.family == "moe":
        unit = {"moe_layer": _kv_def(cfg, batch, max_len, cache_dtype)}
        for j in range(cfg.moe.every - 1):
            unit[f"dense_{j}"] = _kv_def(cfg, batch, max_len, cache_dtype)
        return {"blocks": stack_defs(unit, n_scan_units(cfg))}
    if cfg.family == "ssm":
        return {"blocks": stack_defs(_ssm_cache_def(cfg, batch, cache_dtype),
                                     cfg.n_layers)}
    # hybrid
    unit = {"ssm": stack_defs(_ssm_cache_def(cfg, batch, cache_dtype),
                              cfg.hybrid_attn_every),
            "attn": _kv_def(cfg, batch, max_len, cache_dtype)}
    out = {"blocks": stack_defs(unit, n_scan_units(cfg))}
    tail = hybrid_tail_layers(cfg)
    if tail:
        out["tail"] = stack_defs(_ssm_cache_def(cfg, batch, cache_dtype), tail)
    return out


# ---------------------------------------------------------------------------
# Layer application
# ---------------------------------------------------------------------------
def _seq_constraint(h, cfg: ModelConfig, rules: AxisRules):
    if cfg.seq_shard and h.shape[1] > 1:
        return logical_constraint(h, rules, "batch", "act_seq", "act_embed")
    return logical_constraint(h, rules, "batch", None, "act_embed")


def _apply_dense_layer(p, h, cfg, rules, positions, cache=None, cache_index=None):
    a, new_cache = self_attention(p["attn"], apply_norm(p["ln1"], h, cfg.norm),
                                  cfg, causal=True, positions=positions,
                                  cache=cache, cache_index=cache_index,
                                  rules=rules)
    h = h + a
    h = h + apply_mlp(p["mlp"], apply_norm(p["ln2"], h, cfg.norm), cfg)
    h = _seq_constraint(h, cfg, rules)
    return h, new_cache


def _apply_moe_layer(p, h, cfg, rules, positions, cache=None, cache_index=None):
    a, new_cache = self_attention(p["attn"], apply_norm(p["ln1"], h, cfg.norm),
                                  cfg, causal=True, positions=positions,
                                  cache=cache, cache_index=cache_index,
                                  rules=rules)
    h = h + a
    mo, aux = apply_moe(p["moe"], apply_norm(p["ln2"], h, cfg.norm), cfg, rules)
    h = _seq_constraint(h + mo, cfg, rules)
    return h, new_cache, aux


def _apply_ssm_layer(p, h, cfg, rules, cache=None, cache_index=None,
                     decode: bool = False):
    x = apply_norm(p["ln"], h, cfg.norm)
    if decode:
        o, nc = decode_mamba2(p["mamba"], x, cfg, cache)
    else:
        o, nc = apply_mamba2(p["mamba"], x, cfg, cache=cache, cache_index=cache_index)
    h = _seq_constraint(h + o, cfg, rules)
    return h, nc


def _apply_shared_attn(p, h, cfg, rules, positions, cache=None, cache_index=None):
    a, new_cache = self_attention(p["attn"], apply_norm(p["ln1"], h, cfg.norm),
                                  cfg, causal=True, positions=positions,
                                  cache=cache, cache_index=cache_index,
                                  rules=rules)
    h = h + a
    h = h + apply_mlp(p["mlp"], apply_norm(p["ln2"], h, cfg.norm), cfg)
    h = _seq_constraint(h, cfg, rules)
    return h, new_cache


def _apply_unit(p, h, cfg, rules, positions, shared_attn=None, cache=None,
                cache_index=None, decode: bool = False):
    """One scan unit. Returns (h, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if cfg.family in ("dense", "vlm"):
        h, nc = _apply_dense_layer(p, h, cfg, rules, positions, cache, cache_index)
        return h, nc, aux
    if cfg.family == "moe":
        new_cache = {}
        for j in range(cfg.moe.every - 1):
            key = f"dense_{j}"
            h, nc = _apply_dense_layer(p[key], h, cfg, rules, positions,
                                       cache[key] if cache else None, cache_index)
            new_cache[key] = nc
        h, nc, aux = _apply_moe_layer(p["moe_layer"], h, cfg, rules, positions,
                                      cache["moe_layer"] if cache else None,
                                      cache_index)
        new_cache["moe_layer"] = nc
        return h, (new_cache if cache else None), aux
    if cfg.family == "ssm":
        h, nc = _apply_ssm_layer(p, h, cfg, rules, cache, cache_index, decode)
        return h, nc, aux
    # hybrid super-unit: `every` mamba layers + one shared-attn application
    new_ssm = []
    for j in range(cfg.hybrid_attn_every):
        pj = _index_tree(p["ssm_layers"], j)
        cj = _index_tree(cache["ssm"], j) if cache is not None else None
        h, ncj = _apply_ssm_layer(pj, h, cfg, rules, cj, cache_index, decode)
        new_ssm.append(ncj)
    h, nattn = _apply_shared_attn(shared_attn, h, cfg, rules, positions,
                                  cache["attn"] if cache is not None else None,
                                  cache_index)
    if cache is not None:
        return h, {"ssm": _stack_tree(new_ssm), "attn": nattn}, aux
    return h, None, aux


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


# ---------------------------------------------------------------------------
# Trunk: embeddings + scanned blocks + final norm
# ---------------------------------------------------------------------------
def _positions_for(cfg: ModelConfig, batch: dict, B: int, T: int, offset=0):
    if cfg.mrope:
        pos = batch.get("positions")
        if pos is None:
            base = offset + jnp.arange(T, dtype=jnp.int32)
            pos = jnp.broadcast_to(base[None, :, None], (B, T, 3))
        return pos
    base = offset + jnp.arange(T, dtype=jnp.int32)
    return jnp.broadcast_to(base[None, :], (B, T))


def _embed_inputs(params, cfg: ModelConfig, batch: dict, rules: AxisRules):
    tokens = batch["tokens"]
    # bf16 table copy laid out (vocab replicated, d over "model"): the
    # gather then needs no collective at all (tokens stay batch-sharded,
    # output is (batch/dp, T, d/tp)); the fsdp-sharded master layout would
    # otherwise force a ~1GB fp32 activation reshard per step.
    table = logical_constraint(params["embed"].astype(cfg.dtype), rules,
                               None, "embed_gather")
    h = jnp.take(table, tokens, axis=0)
    if cfg.family == "vlm" and "vision_embeds" in batch:
        h = jnp.concatenate([batch["vision_embeds"].astype(cfg.dtype), h], axis=1)
    return _seq_constraint(h, cfg, rules)


def trunk(params, cfg: ModelConfig, batch: dict, rules: AxisRules,
          caches: dict | None = None, cache_index=None, decode: bool = False):
    """Embed + all blocks + final norm. Returns (h, new_caches, aux_total)."""
    h = _embed_inputs(params, cfg, batch, rules)
    B, T = h.shape[0], h.shape[1]
    offset = cache_index if cache_index is not None else 0
    positions = _positions_for(cfg, batch, B, T, offset)

    def unit_fn(p, h, cache):
        return _apply_unit(p, h, cfg, rules, positions,
                           shared_attn=params.get("shared_attn"),
                           cache=cache, cache_index=cache_index, decode=decode)

    unit_fn_r = _remat(unit_fn, cfg) if caches is None else unit_fn
    block_caches = caches["blocks"] if caches is not None else None

    if block_caches is None:
        def body(h, p_i):
            h, _, aux = unit_fn_r(p_i, h, None)
            return h, aux
        h, auxs = lax.scan(body, h, params["blocks"])
        new_caches = None
    else:
        def body(h, xs):
            p_i, c_i = xs
            h, nc, aux = unit_fn_r(p_i, h, c_i)
            return h, (nc, aux)
        h, (new_blocks, auxs) = lax.scan(body, h, (params["blocks"], block_caches))
        new_caches = {"blocks": new_blocks}
    aux_total = jnp.sum(auxs)

    # hybrid tail (layers not covered by a full super-unit)
    if cfg.family == "hybrid" and hybrid_tail_layers(cfg):
        tail_caches = caches.get("tail") if caches is not None else None

        def tail_fn(p_i, h, c_i):
            return _apply_ssm_layer(p_i, h, cfg, rules, c_i, cache_index, decode)
        tail_fn_r = _remat(tail_fn, cfg) if caches is None else tail_fn
        if tail_caches is None:
            def tbody(h, p_i):
                h, _ = tail_fn_r(p_i, h, None)
                return h, None
            h, _ = lax.scan(tbody, h, params["tail_blocks"])
        else:
            def tbody(h, xs):
                p_i, c_i = xs
                h, nc = tail_fn_r(p_i, h, c_i)
                return h, nc
            h, new_tail = lax.scan(tbody, h, (params["tail_blocks"], tail_caches))
            new_caches["tail"] = new_tail

    h = apply_norm(params["ln_f"], h, cfg.norm)
    return h, new_caches, aux_total


# ---------------------------------------------------------------------------
# Chunked cross-entropy
# ---------------------------------------------------------------------------
def unembed_matrix(params, cfg: ModelConfig):
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["unembed"]


def chunked_xent(h, w_out, labels, mask, cfg: ModelConfig, rules: AxisRules):
    """h: (B, T, d) -> mean masked token xent (fp32).  Logits exist one
    chunk at a time, vocab sharded over "model"."""
    B, T, d = h.shape
    C = min(cfg.xent_chunk, T)
    n_chunks = -(-T // C)
    pad = n_chunks * C - T
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    h = h.astype(cfg.dtype)   # gathers to vocab-parallel regions stay bf16
    hc = jnp.moveaxis(h.reshape(B, n_chunks, C, d), 1, 0)
    lc = jnp.moveaxis(labels.reshape(B, n_chunks, C), 1, 0)
    mc = jnp.moveaxis(mask.reshape(B, n_chunks, C), 1, 0)
    w = w_out.astype(cfg.dtype)

    def body(acc, xs):
        hx, lx, mx = xs
        logits = jnp.einsum("bcd,dv->bcv", hx.astype(cfg.dtype), w,
                            preferred_element_type=jnp.float32)
        logits = logical_constraint(logits, rules, "batch", None, "act_vocab")
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.sum(jax.nn.one_hot(lx, logits.shape[-1], dtype=jnp.float32)
                     * logits, axis=-1)
        loss = (lse - ll) * mx
        return (acc[0] + jnp.sum(loss), acc[1] + jnp.sum(mx)), None

    body = jax.checkpoint(body) if cfg.remat != "none" else body
    (tot, cnt), _ = lax.scan(body, (jnp.zeros((), jnp.float32),
                                    jnp.zeros((), jnp.float32)), (hc, lc, mc))
    return tot / jnp.maximum(cnt, 1.0)


# ---------------------------------------------------------------------------
# Public model functions
# ---------------------------------------------------------------------------
def lm_loss(params, cfg: ModelConfig, batch: dict, rules: AxisRules):
    h, _, aux = trunk(params, cfg, batch, rules)
    labels = batch["labels"]
    mask = batch.get("mask", jnp.ones_like(labels, jnp.float32))
    if cfg.family == "vlm" and "vision_embeds" in batch:
        # loss only over the text region (appended after vision tokens)
        n_vis = batch["vision_embeds"].shape[1]
        h = h[:, n_vis:]
    loss = chunked_xent(h, unembed_matrix(params, cfg), labels,
                        mask.astype(jnp.float32), cfg, rules)
    return loss + AUX_LOSS_COEF * aux, {"xent": loss, "aux": aux}


def lm_prefill(params, cfg: ModelConfig, batch: dict, caches, rules: AxisRules):
    """Run the prompt through the trunk filling caches; returns last logits."""
    h, new_caches, _ = trunk(params, cfg, batch, rules, caches=caches,
                             cache_index=jnp.zeros((), jnp.int32))
    last = h[:, -1:]
    logits = jnp.einsum("btd,dv->btv", last.astype(cfg.dtype),
                        unembed_matrix(params, cfg).astype(cfg.dtype),
                        preferred_element_type=jnp.float32)
    return logits, new_caches


def lm_decode(params, cfg: ModelConfig, batch: dict, caches, cache_index,
              rules: AxisRules):
    """One decode step: batch["tokens"]: (B, 1)."""
    h, new_caches, _ = trunk(params, cfg, batch, rules, caches=caches,
                             cache_index=cache_index,
                             decode=cfg.family in ("ssm", "hybrid"))
    logits = jnp.einsum("btd,dv->btv", h.astype(cfg.dtype),
                        unembed_matrix(params, cfg).astype(cfg.dtype),
                        preferred_element_type=jnp.float32)
    logits = logical_constraint(logits, rules, "batch", None, "act_vocab")
    return logits, new_caches
