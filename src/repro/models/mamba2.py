"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) block.

Training/prefill uses the chunked SSD algorithm: within a chunk the
quadratic "attention-like" form runs on the MXU; across chunks a linear
recurrence carries the (H, P, N) state.  We scan over chunks so the
(L, L, H) intra-chunk score tensor exists for one chunk at a time.

Decode is the O(1) recurrent update with a rolling depthwise-conv buffer.
This is the XLA-path twin of ``repro.kernels.ssd``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .common import ModelConfig, ParamDef


def mamba2_def(cfg: ModelConfig) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    H = s.n_ssm_heads(d)
    conv_ch = di + 2 * s.n_groups * s.d_state
    proj_out = 2 * di + 2 * s.n_groups * s.d_state + H   # z, x, B, C, dt
    return {
        "in_proj": ParamDef((d, proj_out), ("embed", "ssm_inner"), dtype=cfg.param_dtype),
        "conv_w": ParamDef((s.d_conv, conv_ch), (None, "conv_dim"), scale=0.5, dtype=cfg.param_dtype),
        "conv_b": ParamDef((conv_ch,), ("conv_dim",), init="zeros", dtype=cfg.param_dtype),
        "A_log": ParamDef((H,), ("ssm_heads",), init="zeros", dtype=jnp.float32),
        "D": ParamDef((H,), ("ssm_heads",), init="ones", dtype=jnp.float32),
        "dt_bias": ParamDef((H,), ("ssm_heads",), init="zeros", dtype=jnp.float32),
        "norm_w": ParamDef((di,), ("ssm_inner",), init="ones", dtype=cfg.param_dtype),
        "out_proj": ParamDef((di, d), ("ssm_inner", "embed"), dtype=cfg.param_dtype),
    }


def _split_proj(proj: jnp.ndarray, cfg: ModelConfig):
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    gn = s.n_groups * s.d_state
    H = s.n_ssm_heads(cfg.d_model)
    z, xc, B_, C_, dt = jnp.split(proj, [di, 2 * di, 2 * di + gn, 2 * di + 2 * gn], axis=-1)
    return z, xc, B_, C_, dt


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv. x: (B, T, C); w: (k, C)."""
    k, C = w.shape
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = lax.conv_general_dilated(
        xp, w[:, None, :].astype(x.dtype),
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=C)
    return out + b.astype(x.dtype)


def ssd_chunked(x: jnp.ndarray, dt: jnp.ndarray, a: jnp.ndarray,
                B_: jnp.ndarray, C_: jnp.ndarray, chunk: int,
                state0: jnp.ndarray | None = None):
    """SSD scan.  x: (B, T, H, P); dt: (B, T, H); a: (H,) negative reals;
    B_, C_: (B, T, G, N).  Returns (y: (B, T, H, P), final_state: (B,H,P,N)).
    """
    Bb, T, H, Pd = x.shape
    G, N = B_.shape[2], B_.shape[3]
    L = min(chunk, T)
    n_chunks = -(-T // L)
    pad = n_chunks * L - T
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))   # dt=0 -> identity decay, no input
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C_ = jnp.pad(C_, ((0, 0), (0, pad), (0, 0), (0, 0)))
    rep = H // G

    def to_chunks(t):  # (B, T, ...) -> (nc, B, L, ...)
        return jnp.moveaxis(t.reshape((Bb, n_chunks, L) + t.shape[2:]), 1, 0)

    xs = (to_chunks(x), to_chunks(dt), to_chunks(B_), to_chunks(C_))
    f32 = jnp.float32

    def body(state, xs_c):
        xc, dtc, Bc, Cc = xs_c
        xc, dtc = xc.astype(f32), dtc.astype(f32)
        Bc, Cc = Bc.astype(f32), Cc.astype(f32)
        da = dtc * a                                       # (B, L, H)
        css = jnp.cumsum(da, axis=1)                       # inclusive
        seg_end = css[:, -1, :]                            # (B, H)
        # head -> group mapping by repetition
        Bh = jnp.repeat(Bc, rep, axis=2)                   # (B, L, H, N)
        Ch = jnp.repeat(Cc, rep, axis=2)
        # ---- inter-chunk: contribution of carried state --------------------
        y_inter = jnp.einsum("blhn,bhpn->blhp", Ch * jnp.exp(css)[..., None], state)
        # ---- intra-chunk quadratic form ------------------------------------
        scores = jnp.einsum("blhn,bmhn->blmh", Ch, Bh)     # (B, L, L, H)
        decay = jnp.exp(css[:, :, None, :] - css[:, None, :, :])  # l,m
        mask = jnp.tril(jnp.ones((L, L), bool))[None, :, :, None]
        att = jnp.where(mask, scores * decay, 0.0) * dtc[:, None, :, :]
        y_intra = jnp.einsum("blmh,bmhp->blhp", att, xc)
        # ---- state update ---------------------------------------------------
        sdecay = jnp.exp(seg_end[:, None, :] - css)        # (B, L, H): decay to chunk end
        chunk_state = jnp.einsum("blhn,blhp->bhpn", Bh * sdecay[..., None],
                                 xc * dtc[..., None])
        state_new = state * jnp.exp(seg_end)[..., None, None] + chunk_state
        return state_new, y_inter + y_intra

    state0 = (jnp.zeros((Bb, H, Pd, N), f32) if state0 is None
              else state0.astype(f32))
    final_state, ys = lax.scan(body, state0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(Bb, n_chunks * L, H, Pd)
    if pad:
        y = y[:, :T]
    return y, final_state


def _gated_rmsnorm(y: jnp.ndarray, z: jnp.ndarray, w: jnp.ndarray,
                   eps: float = 1e-6) -> jnp.ndarray:
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    ms = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    return (yf * lax.rsqrt(ms + eps) * w.astype(jnp.float32)).astype(y.dtype)


def apply_mamba2(params: dict, u: jnp.ndarray, cfg: ModelConfig,
                 cache: dict | None = None, cache_index=None):
    """u: (B, T, d_model).  Train/prefill path (chunked SSD over T).

    With ``cache`` ({"conv": (B, k-1, conv_ch), "state": (B,H,P,N)}) given,
    the final conv window and SSD state are written back (prefill).
    Returns (out, new_cache | None).
    """
    s = cfg.ssm
    dt_ = cfg.dtype
    di = s.d_inner(cfg.d_model)
    H = s.n_ssm_heads(cfg.d_model)
    Bb, T, _ = u.shape

    proj = jnp.einsum("btd,dp->btp", u, params["in_proj"].astype(dt_))
    z, xc, B_, C_, dtr = _split_proj(proj, cfg)
    xBC = jnp.concatenate([xc, B_, C_], axis=-1)
    if cache is not None:
        # prepend cached conv window for seamless continuation
        xBC_in = jnp.concatenate([cache["conv"].astype(dt_), xBC], axis=1)
        conv_out = _causal_conv(xBC_in, params["conv_w"], params["conv_b"])[:, -T:]
        new_conv = xBC_in[:, -(s.d_conv - 1):]
    else:
        conv_out = _causal_conv(xBC, params["conv_w"], params["conv_b"])
        new_conv = None
    conv_out = jax.nn.silu(conv_out)
    gn = s.n_groups * s.d_state
    xc = conv_out[..., :di]
    B_ = conv_out[..., di:di + gn].reshape(Bb, T, s.n_groups, s.d_state)
    C_ = conv_out[..., di + gn:].reshape(Bb, T, s.n_groups, s.d_state)

    dt_act = jax.nn.softplus(dtr.astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["A_log"])                              # (H,) negative
    xh = xc.reshape(Bb, T, H, s.head_dim)
    state0 = cache["state"] if cache is not None else None
    y, state = ssd_chunked(xh, dt_act, a, B_, C_, s.chunk, state0=state0)
    y = y + params["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.astype(dt_).reshape(Bb, T, di)
    y = _gated_rmsnorm(y, z, params["norm_w"])
    out = jnp.einsum("bti,id->btd", y, params["out_proj"].astype(dt_))
    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv.astype(cache["conv"].dtype),
                     "state": state.astype(cache["state"].dtype)}
    return out, new_cache


def decode_mamba2(params: dict, u: jnp.ndarray, cfg: ModelConfig, cache: dict):
    """Single-token decode. u: (B, 1, d_model); O(1) state update."""
    s = cfg.ssm
    dt_ = cfg.dtype
    di = s.d_inner(cfg.d_model)
    H = s.n_ssm_heads(cfg.d_model)
    Bb = u.shape[0]

    proj = jnp.einsum("btd,dp->btp", u, params["in_proj"].astype(dt_))
    z, xc, B_, C_, dtr = _split_proj(proj, cfg)
    xBC = jnp.concatenate([xc, B_, C_], axis=-1)[:, 0]         # (B, conv_ch)
    window = jnp.concatenate([cache["conv"].astype(dt_), xBC[:, None, :]], axis=1)
    # Run the SAME depthwise-conv op as the prefill path (same dtype, same
    # XLA kernel) and take the last position: an fp32 einsum here is more
    # precise but *different* — the unquantised conv output drifts from the
    # prefill's bf16 one by an ulp per layer, and the hybrid (zamba2)
    # attention blocks amplify that past decode-consistency tolerance.
    conv_out = _causal_conv(window, params["conv_w"], params["conv_b"])[:, -1]
    conv_out = jax.nn.silu(conv_out)
    gn = s.n_groups * s.d_state
    xc1 = conv_out[:, :di]
    B1 = conv_out[:, di:di + gn].reshape(Bb, s.n_groups, s.d_state)
    C1 = conv_out[:, di + gn:].reshape(Bb, s.n_groups, s.d_state)
    rep = H // s.n_groups
    Bh = jnp.repeat(B1, rep, axis=1)                           # (B, H, N)
    Ch = jnp.repeat(C1, rep, axis=1)

    dt1 = jax.nn.softplus(dtr.astype(jnp.float32)[:, 0] + params["dt_bias"])  # (B,H)
    a = -jnp.exp(params["A_log"])
    decay = jnp.exp(dt1 * a)                                   # (B, H)
    xh = xc1.reshape(Bb, H, s.head_dim).astype(jnp.float32)
    state = cache["state"].astype(jnp.float32)
    state = (state * decay[..., None, None]
             + jnp.einsum("bhn,bhp->bhpn", Bh, xh * dt1[..., None]))
    y = jnp.einsum("bhn,bhpn->bhp", Ch, state)
    y = y + params["D"][None, :, None] * xh
    y = y.reshape(Bb, 1, di).astype(dt_)
    y = _gated_rmsnorm(y, z, params["norm_w"])
    out = jnp.einsum("bti,id->btd", y, params["out_proj"].astype(dt_))
    new_cache = {"conv": window[:, 1:].astype(cache["conv"].dtype),
                 "state": state.astype(cache["state"].dtype)}
    return out, new_cache
