"""Core layers: norms, RoPE / M-RoPE, GQA chunked (flash-style) attention, MLPs.

Attention never materialises a (Tq, Tk) score tensor: it scans over KV
blocks with a running-softmax accumulator (the XLA-path twin of
``repro.kernels.flash_attention``), so 32k prefill compiles and fits on a
16 GB/chip mesh.  All reductions accumulate in fp32.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from .common import ModelConfig, ParamDef

NEG_INF = -1.0e30


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def rmsnorm_def(dim: int) -> dict:
    return {"scale": ParamDef((dim,), ("embed_noshard",), init="ones")}


def layernorm_def(dim: int) -> dict:
    return {"scale": ParamDef((dim,), ("embed_noshard",), init="ones"),
            "bias": ParamDef((dim,), ("embed_noshard",), init="zeros")}


def apply_norm(params: dict, x: jnp.ndarray, kind: str, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * lax.rsqrt(var + eps)
        y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    else:
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * lax.rsqrt(ms + eps) * params["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------
def _rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return theta ** (-jnp.arange(half, dtype=jnp.float32) / half)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, T, H, D); positions: (B, T) int32."""
    freqs = _rope_freqs(x.shape[-1], theta)
    ang = positions.astype(jnp.float32)[..., None] * freqs      # (B, T, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions3: jnp.ndarray, theta: float,
                sections: tuple[int, ...] | None = None) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE. positions3: (B, T, 3) [temporal, h, w]."""
    half = x.shape[-1] // 2
    if sections is None:
        # qwen2-vl ratio (16, 24, 24) generalised to any head_dim
        a = half // 4
        b = (half - a) // 2
        sections = (a, b, half - a - b)
    assert sum(sections) == half, (sections, half)
    freqs = _rope_freqs(x.shape[-1], theta)                      # (half,)
    comp = jnp.concatenate([jnp.full((s,), i, dtype=jnp.int32)
                            for i, s in enumerate(sections)])    # (half,)
    pos = jnp.take_along_axis(
        positions3.astype(jnp.float32),
        jnp.broadcast_to(comp[None, None, :], positions3.shape[:2] + (half,)),
        axis=-1)                                                 # (B, T, half)
    ang = pos * freqs
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA chunked flash-style attention (XLA path)
# ---------------------------------------------------------------------------
def chunked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                      causal: bool, chunk: int, q_offset=0,
                      kv_len: jnp.ndarray | None = None) -> jnp.ndarray:
    """q: (B, Tq, Hq, D); k, v: (B, Tk, Hkv, D) with Hq % Hkv == 0.

    ``q_offset``: absolute position of q[:, 0] (decode: cache length so far).
    ``kv_len``: optional scalar/(B,) valid KV length (padded caches).
    Returns (B, Tq, Hq, D) in q.dtype; softmax/accumulation in fp32.
    """
    B, Tq, Hq, D = q.shape
    Tk, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Tq, Hkv, G, D)
    scale = 1.0 / math.sqrt(D)

    chunk = min(chunk, Tk)
    n_chunks = -(-Tk // chunk)
    pad = n_chunks * chunk - Tk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    # (n_chunks, B, C, Hkv, D)
    kc = jnp.moveaxis(k.reshape(B, n_chunks, chunk, Hkv, D), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, n_chunks, chunk, Hkv, D), 1, 0)
    starts = jnp.arange(n_chunks, dtype=jnp.int32) * chunk

    q_pos = (jnp.asarray(q_offset, jnp.int32)[..., None]
             if jnp.ndim(q_offset) else jnp.asarray(q_offset, jnp.int32))
    q_pos = q_pos + jnp.arange(Tq, dtype=jnp.int32)              # (Tq,) or (B,Tq)
    if q_pos.ndim == 1:
        q_pos = jnp.broadcast_to(q_pos[None], (B, Tq))

    limit = jnp.asarray(Tk if kv_len is None else kv_len, jnp.int32)
    limit = jnp.broadcast_to(jnp.atleast_1d(limit), (B,))        # (B,)

    def body(carry, xs):
        m, l, acc = carry
        kb, vb, start = xs
        # bf16 inputs, fp32 accumulation: MXU-native mixed precision
        s = jnp.einsum("bthgd,bchd->bthgc", qg, kb,
                       preferred_element_type=jnp.float32) * scale  # (B,Tq,Hkv,G,C)
        k_pos = start + jnp.arange(chunk, dtype=jnp.int32)       # (C,)
        valid = k_pos[None, None, :] < limit[:, None, None]      # (B,1,C)
        if causal:
            valid = valid & (k_pos[None, None, :] <= q_pos[:, :, None])
        valid = valid[:, :, None, None, :]                       # (B,Tq,1,1,C)
        s = jnp.where(valid, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.where(valid, jnp.exp(s - m_new[..., None]), 0.0)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bthgc,bchd->bthgd", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Tq, Hkv, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Tq, Hkv, G), jnp.float32)
    a0 = jnp.zeros((B, Tq, Hkv, G, D), jnp.float32)
    (m, l, acc), _ = lax.scan(body, (m0, l0, a0), (kc, vc, starts))
    out = acc / jnp.where(l == 0.0, 1.0, l)[..., None]
    return out.reshape(B, Tq, Hq, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# Attention block (projections + rope + cache handling)
# ---------------------------------------------------------------------------
def attention_def(cfg: ModelConfig) -> dict:
    hd = cfg.resolved_head_dim()
    d = {
        "wq": ParamDef((cfg.d_model, cfg.n_heads, hd), ("embed", "heads", "head_dim"), dtype=cfg.param_dtype),
        "wk": ParamDef((cfg.d_model, cfg.n_kv_heads, hd), ("embed", "kv_heads", "head_dim"), dtype=cfg.param_dtype),
        "wv": ParamDef((cfg.d_model, cfg.n_kv_heads, hd), ("embed", "kv_heads", "head_dim"), dtype=cfg.param_dtype),
        "wo": ParamDef((cfg.n_heads, hd, cfg.d_model), ("heads", "head_dim", "embed"), dtype=cfg.param_dtype),
    }
    if cfg.qkv_bias:
        d["bq"] = ParamDef((cfg.n_heads, hd), ("heads", "head_dim"), init="zeros", dtype=cfg.param_dtype)
        d["bk"] = ParamDef((cfg.n_kv_heads, hd), ("kv_heads", "head_dim"), init="zeros", dtype=cfg.param_dtype)
        d["bv"] = ParamDef((cfg.n_kv_heads, hd), ("kv_heads", "head_dim"), init="zeros", dtype=cfg.param_dtype)
    return d


def attention_qkv(params: dict, x: jnp.ndarray, cfg: ModelConfig):
    dt = cfg.dtype
    q = jnp.einsum("btd,dhk->bthk", x, params["wq"].astype(dt))
    k = jnp.einsum("btd,dhk->bthk", x, params["wk"].astype(dt))
    v = jnp.einsum("btd,dhk->bthk", x, params["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(dt)
        k = k + params["bk"].astype(dt)
        v = v + params["bv"].astype(dt)
    return q, k, v


def attention_out(params: dict, o: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    return jnp.einsum("bthk,hkd->btd", o, params["wo"].astype(cfg.dtype))


def self_attention(params: dict, x: jnp.ndarray, cfg: ModelConfig, *,
                   causal: bool, positions: jnp.ndarray,
                   cache: dict | None = None, cache_index=None,
                   rules=None):
    """Full self-attention block.

    ``cache``: {"k": (B, Tmax, Hkv, D), "v": ...} — when given with
    ``cache_index`` (scalar int32: tokens already in cache), the new K/V are
    written at that offset and attention runs over the whole (masked) cache.
    Returns (out, new_cache).
    """
    q, k, v = attention_qkv(params, x, cfg)
    if rules is not None:
        # Pin the attention layout so GSPMD does one resharding at entry
        # instead of per-KV-chunk collectives.  Two regimes:
        #  * heads divide TP: heads sharded, seq full (Megatron-TP);
        #  * heads don't divide TP (e.g. 28 heads @ tp16): shard the QUERY
        #    sequence instead and replicate the (small, GQA) K/V — a
        #    Megatron-SP/context-parallel layout with KV-only gathers.
        from .common import logical_constraint
        heads_spec = rules.resolve("batch", None, "act_heads", None,
                                   dims=q.shape)
        if len(heads_spec) > 2 and heads_spec[2] is not None:
            q = logical_constraint(q, rules, "batch", None, "act_heads", None)
            k = logical_constraint(k, rules, "batch", None, "act_kv_heads", None)
            v = logical_constraint(v, rules, "batch", None, "act_kv_heads", None)
        elif q.shape[1] > 1:
            q = logical_constraint(q, rules, "batch", "act_seq", None, None)
            k = logical_constraint(k, rules, "batch", None, None, None)
            v = logical_constraint(v, rules, "batch", None, None, None)
    if cfg.mrope:
        q = apply_mrope(q, positions, cfg.rope_theta)
        k = apply_mrope(k, positions, cfg.rope_theta)
        q_pos_1d = positions[..., 0]
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        q_pos_1d = positions
    new_cache = None
    if cache is not None:
        ck = lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), cache_index, axis=1)
        cv = lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), cache_index, axis=1)
        new_cache = {"k": ck, "v": cv}
        kv_len = cache_index + x.shape[1]
        out = chunked_attention(q, ck.astype(cfg.dtype), cv.astype(cfg.dtype),
                                causal=causal, chunk=cfg.attn_chunk,
                                q_offset=q_pos_1d[:, 0], kv_len=kv_len)
    else:
        out = chunked_attention(q, k, v, causal=causal, chunk=cfg.attn_chunk,
                                q_offset=0)
    return attention_out(params, out, cfg), new_cache


def cross_attention_def(cfg: ModelConfig) -> dict:
    return attention_def(cfg.with_(qkv_bias=False))


def cross_attention(params: dict, x: jnp.ndarray, kv_src: jnp.ndarray,
                    cfg: ModelConfig, kv_cache: dict | None = None):
    """Decoder cross-attention. kv_src: encoder output (B, Ts, d).

    With ``kv_cache`` given ({"k","v"} precomputed), kv_src is ignored.
    """
    dt = cfg.dtype
    q = jnp.einsum("btd,dhk->bthk", x, params["wq"].astype(dt))
    if kv_cache is None:
        k = jnp.einsum("btd,dhk->bthk", kv_src, params["wk"].astype(dt))
        v = jnp.einsum("btd,dhk->bthk", kv_src, params["wv"].astype(dt))
        kv_cache = {"k": k, "v": v}
    out = chunked_attention(q, kv_cache["k"].astype(dt), kv_cache["v"].astype(dt),
                            causal=False, chunk=cfg.attn_chunk)
    return attention_out(params, out, cfg), kv_cache


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------
def mlp_def(cfg: ModelConfig, d_ff: int | None = None) -> dict:
    f = d_ff or cfg.d_ff
    if cfg.act == "swiglu":
        return {"wg": ParamDef((cfg.d_model, f), ("embed", "mlp"), dtype=cfg.param_dtype),
                "wu": ParamDef((cfg.d_model, f), ("embed", "mlp"), dtype=cfg.param_dtype),
                "wd": ParamDef((f, cfg.d_model), ("mlp", "embed"), dtype=cfg.param_dtype)}
    return {"w1": ParamDef((cfg.d_model, f), ("embed", "mlp"), dtype=cfg.param_dtype),
            "b1": ParamDef((f,), ("mlp",), init="zeros", dtype=cfg.param_dtype),
            "w2": ParamDef((f, cfg.d_model), ("mlp", "embed"), dtype=cfg.param_dtype),
            "b2": ParamDef((cfg.d_model,), ("embed_noshard",), init="zeros", dtype=cfg.param_dtype)}


def apply_mlp(params: dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    dt = cfg.dtype
    if cfg.act == "swiglu":
        g = jnp.einsum("btd,df->btf", x, params["wg"].astype(dt))
        u = jnp.einsum("btd,df->btf", x, params["wu"].astype(dt))
        h = jax.nn.silu(g) * u
        return jnp.einsum("btf,fd->btd", h, params["wd"].astype(dt))
    h = jnp.einsum("btd,df->btf", x, params["w1"].astype(dt)) + params["b1"].astype(dt)
    h = jax.nn.gelu(h)
    return jnp.einsum("btf,fd->btd", h, params["w2"].astype(dt)) + params["b2"].astype(dt)
