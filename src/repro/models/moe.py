"""Mixture-of-Experts with capacity-based, group-local dispatch.

GShard/Switch-style: tokens are viewed as (groups, S, d) with groups mapped
to the data-parallel axes and experts to the "model" axis (expert
parallelism).  Dispatch is *scatter/gather based* — we never materialise the
(S, E, C) one-hot dispatch tensor (at 1M tokens × 128 experts that would be
O(10^13) elements).  Capacity overflow tokens are dropped (standard
capacity-factor semantics); the router returns an aux load-balancing loss.

The data→expert resharding boundary of the (G, E, C, d) buffer is where the
all-to-all appears in the lowered HLO.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import AxisRules, ModelConfig, ParamDef, logical_constraint
from .layers import apply_mlp, mlp_def


def moe_def(cfg: ModelConfig) -> dict:
    m = cfg.moe
    d = {
        "router": ParamDef((cfg.d_model, m.n_experts), ("embed", "experts"),
                           scale=0.1, dtype=cfg.param_dtype),
        "wg": ParamDef((m.n_experts, cfg.d_model, m.d_ff_expert),
                       ("experts", "embed", "expert_mlp"), dtype=cfg.param_dtype),
        "wu": ParamDef((m.n_experts, cfg.d_model, m.d_ff_expert),
                       ("experts", "embed", "expert_mlp"), dtype=cfg.param_dtype),
        "wd": ParamDef((m.n_experts, m.d_ff_expert, cfg.d_model),
                       ("experts", "expert_mlp", "embed"), dtype=cfg.param_dtype),
    }
    if m.shared_expert:
        d["shared"] = mlp_def(cfg, d_ff=m.d_ff_expert)
    return d


def _capacity(s_per_group: int, cfg: ModelConfig) -> int:
    m = cfg.moe
    c = int(s_per_group * m.top_k * m.capacity_factor / m.n_experts)
    return max(8, -(-c // 8) * 8)  # >=8, 8-aligned


def apply_moe(params: dict, x: jnp.ndarray, cfg: ModelConfig,
              rules: AxisRules | None = None) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, T, d) -> (out, aux_loss)."""
    rules = rules or AxisRules(fsdp_axes=(), dp_axes=())
    m = cfg.moe
    B, T, d = x.shape
    n_tok = B * T
    G = cfg.moe_groups or 1
    if n_tok % G or (n_tok // G) < m.n_experts // m.top_k:
        G = 1  # degenerate/smoke shapes: single group
    S = n_tok // G
    E, K = m.n_experts, m.top_k
    C = _capacity(S, cfg)

    xg = x.reshape(G, S, d)
    xg = logical_constraint(xg, rules, "groups", None, None)

    # --- router (fp32) ----------------------------------------------------
    logits = jnp.einsum("gsd,de->gse", xg.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                    # (G,S,E)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)            # (G,S,K)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # aux load-balance loss (Switch): E * sum_e f_e * P_e
    me = jnp.mean(probs, axis=(0, 1))                          # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(expert_idx, E, dtype=jnp.float32), axis=2),
        axis=(0, 1))                                           # (E,)
    aux = E * jnp.sum(me * ce)

    # --- dispatch slots: rank of each (s,k) within its expert -------------
    flat_e = expert_idx.reshape(G, S * K)                      # token-major
    oh = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)            # (G, S*K, E)
    pos_in_e = jnp.cumsum(oh, axis=1) - oh
    slot = jnp.sum(pos_in_e * oh, axis=-1)                     # (G, S*K)
    keep = slot < C
    slot_c = jnp.minimum(slot, C - 1)

    # --- scatter token *indices* into the (E, C) routing table -------------
    s_of = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, :, None],
                            (G, S, K)).reshape(G, S * K)
    sentinel = S                                               # maps to zero row
    g_of = jnp.broadcast_to(jnp.arange(G)[:, None], (G, S * K))
    buf_idx = jnp.full((G, E, C), sentinel, jnp.int32)
    buf_idx = buf_idx.at[
        g_of.reshape(-1),
        jnp.where(keep, flat_e, 0).reshape(-1),
        slot_c.reshape(-1),
    ].set(jnp.where(keep, s_of, sentinel).reshape(-1), mode="drop")

    # --- gather values into the dispatch buffer (G, E, C, d) ---------------
    xg_pad = jnp.concatenate([xg, jnp.zeros((G, 1, d), xg.dtype)], axis=1)
    dispatched = jnp.take_along_axis(
        xg_pad, buf_idx.reshape(G, E * C)[..., None], axis=1)
    dispatched = dispatched.reshape(G, E, C, d)
    dispatched = logical_constraint(dispatched, rules, "groups", "experts", None, None)

    # --- expert computation (EP over "model") ------------------------------
    dt = cfg.dtype
    g_h = jnp.einsum("gecd,edf->gecf", dispatched.astype(dt), params["wg"].astype(dt))
    u_h = jnp.einsum("gecd,edf->gecf", dispatched.astype(dt), params["wu"].astype(dt))
    h = jax.nn.silu(g_h) * u_h
    y_buf = jnp.einsum("gecf,efd->gecd", h, params["wd"].astype(dt))
    y_buf = logical_constraint(y_buf, rules, "groups", "experts", None, None)

    # --- combine: gather each token's K expert outputs, weight by gates ----
    flat_addr = jnp.where(keep, flat_e * C + slot_c, E * C)    # (G, S*K)
    y_flat = y_buf.reshape(G, E * C, d)
    y_flat = jnp.concatenate([y_flat, jnp.zeros((G, 1, d), y_flat.dtype)], axis=1)
    gathered = jnp.take_along_axis(y_flat, flat_addr[..., None], axis=1)
    gathered = gathered.reshape(G, S, K, d)
    out = jnp.sum(gathered.astype(jnp.float32)
                  * gate_vals[..., None].astype(jnp.float32), axis=2)
    out = out.astype(x.dtype).reshape(B, T, d)

    if m.shared_expert:
        out = out + apply_mlp(params["shared"], x, cfg)
    return out, aux.astype(jnp.float32)
