"""int8 KV-cache quantization (serving memory feature, beyond paper).

Per-(position, head) absmax scales: K/V rows quantize independently so
decode appends stay O(1).  Halving-to-quarter the 32k-cache footprint of
the decode cells (e.g. qwen2 decode_32k: 469 MB -> 118 MB per device)
directly moves their memory-roofline term, which is what those cells are
bound by (§Roofline).

Attention over a quantized cache dequantizes blockwise inside the chunked
scan — the same streaming structure the Pallas kernel uses, so on TPU the
dequant fuses into the K/V loads.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import chunked_attention


def quantize_kv(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: (..., D) -> (int8 codes, fp16-ish scales broadcastable to x)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.float32)


def dequantize_kv(q: jnp.ndarray, scale: jnp.ndarray,
                  dtype=jnp.bfloat16) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def init_quant_cache(batch: int, max_len: int, n_kv_heads: int,
                     head_dim: int) -> dict:
    return {
        "k_q": jnp.zeros((batch, max_len, n_kv_heads, head_dim), jnp.int8),
        "k_s": jnp.ones((batch, max_len, n_kv_heads, 1), jnp.float32),
        "v_q": jnp.zeros((batch, max_len, n_kv_heads, head_dim), jnp.int8),
        "v_s": jnp.ones((batch, max_len, n_kv_heads, 1), jnp.float32),
    }


def append_quant_cache(cache: dict, k_new: jnp.ndarray, v_new: jnp.ndarray,
                       index) -> dict:
    """Write new K/V rows (B, T_new, H, D) at position ``index``."""
    kq, ks = quantize_kv(k_new)
    vq, vs = quantize_kv(v_new)
    upd = lambda buf, val: jax.lax.dynamic_update_slice_in_dim(
        buf, val.astype(buf.dtype), index, axis=1)
    return {"k_q": upd(cache["k_q"], kq), "k_s": upd(cache["k_s"], ks),
            "v_q": upd(cache["v_q"], vq), "v_s": upd(cache["v_s"], vs)}


def attention_over_quant_cache(q: jnp.ndarray, cache: dict, *, kv_len,
                               causal: bool = False, chunk: int = 512,
                               q_offset=0) -> jnp.ndarray:
    """q: (B, Tq, Hq, D) against an int8 cache; returns (B, Tq, Hq, D)."""
    k = dequantize_kv(cache["k_q"], cache["k_s"], q.dtype)
    v = dequantize_kv(cache["v_q"], cache["v_s"], q.dtype)
    return chunked_attention(q, k, v, causal=causal, chunk=chunk,
                             q_offset=q_offset, kv_len=kv_len)
