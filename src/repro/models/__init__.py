from .common import AxisRules, ModelConfig, MoEConfig, SSMConfig, rules_for_mesh
from .model import Model, build_model

__all__ = ["AxisRules", "ModelConfig", "MoEConfig", "SSMConfig",
           "rules_for_mesh", "Model", "build_model"]
