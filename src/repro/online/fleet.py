"""Multi-workflow fleet: a leading ``(W,)`` axis over ``EstimatorState``.

The fused tick (``repro.core.tick``) made one estimator's whole
observe → update → bias scatter → re-predict sequence a single jitted
dispatch over an ``EstimatorState`` pytree.  This module lifts that to a
fleet of W concurrent workflows: per-workflow states are padded to a
common ``(T, N)`` envelope, stacked leaf-wise into one ``FleetState``
whose every array leaf carries a leading workflow axis, and advanced by
``fleet_tick_step`` — ``jax.vmap`` of the SAME ``_tick_core`` the
single-workflow path jits, so the fleet semantics are the per-workflow
semantics by construction (property-tested in ``tests/test_fleet.py``).

Sharding: ``repro.launch.mesh.make_fleet_mesh`` builds a ``("wf",
"task")`` mesh and ``shard_fleet`` lays the stacked leaves out with
``jax.sharding.NamedSharding`` — workflows over the "wf" axis, task rows
over "task".  On a single device the mesh is (1, 1) and every spec is
fully replicated: the layout degrades to exactly today's single-state
arrays, with no resharding and no layout change.

Padding values are chosen inert, not just ignored: padded observation
rows carry ``valid = 0`` (the masked scan keeps the model bitwise
unchanged), padded task rows get an identity posterior whose fold output
is finite, and padded node columns sit outside the bias universe
(``node_cols = -1``).  Consumers slice real cells back out with
``fleet_slice``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax
import numpy as np
from jax import numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro.core.blr import (BatchedTaskModel, BLRPosterior, OnlineStats,
                            _default_dtype)
from repro.core.state import EstimatorState, StateMeta
from repro.core.tick import _predict_state_core, _tick_core

#: columns of one packed observation row (see ``core.tick._tick_core``)
OBS_WIDTH = 8


@dataclass(frozen=True)
class FleetState:
    """W stacked estimator states plus their real (unpadded) extents.

    ``state``'s array leaves all carry a leading ``(W,)`` axis;
    ``t_count`` / ``n_count`` record how many task rows / node columns of
    each workflow's padded envelope are real.
    """
    state: EstimatorState
    t_count: jnp.ndarray     # (W,) int32 real task rows per workflow
    n_count: jnp.ndarray     # (W,) int32 real node columns per workflow


jax.tree_util.register_dataclass(
    FleetState, data_fields=["state", "t_count", "n_count"], meta_fields=[])


def pad_state(state: EstimatorState, t_pad: int, n_pad: int,
              nb_pad: int | None = None) -> EstimatorState:
    """Grow a state's envelope to ``(t_pad, n_pad)`` task/node extents
    (and ``nb_pad`` bias columns, default ``n_pad``) with inert filler:
    padded rows are uncorrelated identity posteriors with zero
    median/moments, padded factors are 1, padded node columns map to no
    bias column.  Real cells are byte-identical to the input."""
    model = state.model
    t0 = int(model.median.shape[-1])
    n0 = int(state.factors.shape[-1])
    nb0 = int(state.bias_counts.shape[-1])
    nb_pad = n_pad if nb_pad is None else nb_pad
    if t_pad < t0 or n_pad < n0 or nb_pad < nb0:
        raise ValueError(
            f"pad_state cannot shrink: have (T={t0}, N={n0}, Nb={nb0}), "
            f"asked for (T={t_pad}, N={n_pad}, Nb={nb_pad})")
    dt = state.factors.dtype
    te = t_pad - t0

    def row_pad(x, value=0.0):
        """Pad the leading task axis of a (T, ...) leaf with ``value``."""
        widths = [(0, te)] + [(0, 0)] * (x.ndim - 1)
        return jnp.pad(x, widths, constant_values=value)

    p = model.post
    eye = jnp.broadcast_to(jnp.eye(2, dtype=dt), (te, 2, 2))
    post = BLRPosterior(
        mu=row_pad(p.mu), V=jnp.concatenate([p.V, eye], axis=0),
        a=row_pad(p.a, 1.5), b=row_pad(p.b, 1.0),
        x_scale=row_pad(p.x_scale, 1.0), y_scale=row_pad(p.y_scale, 1.0))
    stats = (None if model.stats is None else
             OnlineStats(moments=row_pad(model.stats.moments), log=None))
    padded_model = BatchedTaskModel(
        correlated=row_pad(model.correlated, False), post=post,
        median=row_pad(model.median), spread=row_pad(model.spread),
        stats=stats)

    def grid_pad(x, value=0.0):
        return jnp.pad(x, [(0, te), (0, nb_pad - nb0)],
                       constant_values=value)

    factors = jnp.pad(state.factors, [(0, te), (0, n_pad - n0)],
                      constant_values=1.0)
    node_cols = jnp.pad(state.node_cols, (0, n_pad - n0),
                        constant_values=-1)
    return EstimatorState(
        model=padded_model, factors=factors, node_cols=node_cols,
        bias_counts=grid_pad(state.bias_counts),
        bias_log_sum=grid_pad(state.bias_log_sum),
        bias_log_sq=grid_pad(state.bias_log_sq),
        rel_succ=state.rel_succ, rel_fail=state.rel_fail, meta=state.meta)


def stack_states(states) -> FleetState:
    """Pad each workflow's state to the common envelope and stack every
    array leaf along a new leading ``(W,)`` axis.

    All states must share one ``StateMeta`` (the hyperparameters are the
    compiled tick's specialisation key — workflows with different bias
    decay cannot ride one vmap) and one reliability slot count.
    """
    states = list(states)
    if not states:
        raise ValueError("stack_states needs at least one state")
    meta = states[0].meta
    for s in states[1:]:
        if s.meta != meta:
            raise ValueError(
                "fleet states must share StateMeta hyperparameters: "
                f"{s.meta} != {meta}")
    r_counts = {int(s.rel_succ.shape[0]) for s in states}
    if len(r_counts) > 1:
        raise ValueError(
            f"fleet states must share the reliability slot count, "
            f"got {sorted(r_counts)}")
    t_pad = max(int(s.model.median.shape[-1]) for s in states)
    n_pad = max(int(s.factors.shape[-1]) for s in states)
    nb_pad = max(max((int(s.bias_counts.shape[-1]) for s in states),
                     default=0), n_pad)
    t_count = jnp.asarray([int(s.model.median.shape[-1]) for s in states],
                          jnp.int32)
    n_count = jnp.asarray([int(s.factors.shape[-1]) for s in states],
                          jnp.int32)
    padded = []
    for s in states:
        s = pad_state(s, t_pad, n_pad, nb_pad)
        if s.model.stats is not None and s.model.stats.log is not None:
            # the host-side raw-sample log is pytree meta: stacked states
            # must agree on it, and the fleet never reads it — strip it
            s = dataclasses.replace(
                s, model=dataclasses.replace(
                    s.model, stats=OnlineStats(
                        moments=s.model.stats.moments, log=None)))
        padded.append(s)
    stacked = jax.tree_util.tree_map(lambda *ls: jnp.stack(ls), *padded)
    return FleetState(state=stacked, t_count=t_count, n_count=n_count)


def _fleet_tick_core(fleet: FleetState, obs, sizes):
    """One fused tick for every workflow at once.

    ``obs`` is (W, B, 8) packed observation rows — pad a workflow's short
    tick with ``valid = 0`` rows (``pad_obs``); ``sizes`` is the (W,)
    per-workflow prediction input size.  Returns ``(fleet', mean, std)``
    with (W, T, N) estimate matrices.
    """
    step = jax.vmap(
        lambda s, o, z: _tick_core(s, o, z, host_deadjust=False))
    new_state, mean, std, _y = step(fleet.state, obs, sizes)
    return (FleetState(state=new_state, t_count=fleet.t_count,
                       n_count=fleet.n_count), mean, std)


def _fleet_predict_core(fleet: FleetState, sizes):
    mean, std = jax.vmap(_predict_state_core)(fleet.state, sizes)
    return mean, std


#: the fleet tick / predict entry points — the donated fleet buffers are
#: consumed in place, one compile per (W, B, T, N) envelope
fleet_tick_step = jax.jit(_fleet_tick_core, donate_argnums=(0,))
fleet_predict = jax.jit(_fleet_predict_core)


def pad_obs(obs_rows, batch: int):
    """Pack one workflow's tick observations (each an 8-wide row, see
    ``core.tick``) into a fixed (batch, 8) block, padding with
    ``valid = 0`` rows that the masked scan ignores."""
    dt = _default_dtype()
    out = np.zeros((batch, OBS_WIDTH), np.float64)
    rows = np.asarray(obs_rows, np.float64)
    if rows.size:
        if rows.shape[0] > batch:
            raise ValueError(
                f"tick has {rows.shape[0]} observations, envelope is "
                f"{batch} — raise the fleet batch size")
        out[:rows.shape[0]] = rows
    return jnp.asarray(out, dt)


def fleet_slice(arr, fleet: FleetState, w: int) -> np.ndarray:
    """Workflow ``w``'s real (unpadded) cells of a (W, T, N) fleet
    output, as a host array."""
    t = int(fleet.t_count[w])
    n = int(fleet.n_count[w])
    return np.asarray(arr[w])[:t, :n]


def fleet_pspecs(fleet: FleetState, mesh) -> FleetState:
    """Partition specs for every leaf of a ``FleetState``: workflows over
    the mesh's "wf" axis, task rows over "task" where a leaf has a task
    axis, everything else replicated.  Built structurally (field by
    field), not by shape sniffing — T and N extents can coincide."""
    names = mesh.axis_names
    wf = PartitionSpec("wf") if "wf" in names else PartitionSpec()
    wt = (PartitionSpec("wf", "task") if "wf" in names and "task" in names
          else wf)
    st = fleet.state
    post = BLRPosterior(mu=wt, V=wt, a=wt, b=wt, x_scale=wt, y_scale=wt)
    stats = (None if st.model.stats is None
             else OnlineStats(moments=wt, log=None))
    model = BatchedTaskModel(correlated=wt, post=post, median=wt,
                             spread=wt, stats=stats)
    state = EstimatorState(
        model=model, factors=wt, node_cols=wf, bias_counts=wt,
        bias_log_sum=wt, bias_log_sq=wt, rel_succ=wf, rel_fail=wf,
        meta=st.meta)
    return FleetState(state=state, t_count=wf, n_count=wf)


def shard_fleet(fleet: FleetState, mesh) -> FleetState:
    """Lay a stacked fleet out over ``mesh`` with ``NamedSharding``.

    Axis extents must divide the mesh ("wf" | W, "task" | T) — raises
    with the offending extents otherwise.  A (1, 1) mesh (single device)
    replicates everything: bit-identical to the unsharded layout.
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    w = int(fleet.t_count.shape[0])
    t = int(fleet.state.model.median.shape[-1])
    if "wf" in sizes and w % sizes["wf"] != 0:
        raise ValueError(f"fleet W={w} not divisible by mesh wf axis "
                         f"({sizes['wf']})")
    if "task" in sizes and t % sizes["task"] != 0:
        raise ValueError(f"fleet T={t} not divisible by mesh task axis "
                         f"({sizes['task']})")
    specs = fleet_pspecs(fleet, mesh)
    return jax.tree_util.tree_map(
        lambda leaf, spec: jax.device_put(leaf, NamedSharding(mesh, spec)),
        fleet, specs)
