"""Observation stream for online estimation.

An ``ObservationBuffer`` is the append-only log of realised task runtimes
that the execution engine feeds back into the estimator: each entry keeps
both the runtime as measured on the target node and its de-adjusted
local-machine equivalent (what actually entered the model), so the stream
can be replayed — ``update_task_batch_stream`` over ``arrays()`` rebuilds
the estimator state reached online.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass

import numpy as np


@dataclass(frozen=True)
class Observation:
    task: str             # abstract task name (the estimator's row)
    node: str             # node (type) the runtime was measured on
    size: float           # input size / token count
    runtime: float        # as measured on `node`
    local_runtime: float  # de-adjusted by the node factor (model units)
    time: float = 0.0     # simulation time of the completion


class ObservationBuffer:
    """Append-only stream of ``Observation``s with replay helpers."""

    #: default tick-grouping tolerance — ``add`` maintains the incremental
    #: tick index at exactly this atol, so the common ``by_tick()`` call
    #: never has to re-group the whole stream
    TICK_ATOL = 1e-12

    def __init__(self):
        self._obs: list[Observation] = []
        self._ticks: list[tuple[float, list[Observation]]] = []

    def add(self, obs: Observation) -> None:
        self._obs.append(obs)
        # grouping is against the FIRST time of the open group (not the
        # previous observation), matching the legacy one-shot scan exactly
        if self._ticks and abs(obs.time - self._ticks[-1][0]) <= \
                self.TICK_ATOL:
            self._ticks[-1][1].append(obs)
        else:
            self._ticks.append((obs.time, [obs]))

    def record(self, task: str, node: str, size: float, runtime: float,
               local_runtime: float, time: float = 0.0) -> Observation:
        obs = Observation(task=task, node=node, size=size, runtime=runtime,
                          local_runtime=local_runtime, time=time)
        self.add(obs)
        return obs

    def __len__(self) -> int:
        return len(self._obs)

    def __iter__(self):
        return iter(self._obs)

    def __getitem__(self, i):
        return self._obs[i]

    def count(self, task: str) -> int:
        return sum(1 for o in self._obs if o.task == task)

    def per_task(self) -> dict[str, list[Observation]]:
        out: dict[str, list[Observation]] = {}
        for o in self._obs:
            out.setdefault(o.task, []).append(o)
        return out

    def arrays(self, task_index: dict[str, int]):
        """(task_idx, sizes, local_runtimes) arrays in stream order — the
        exact input ``update_task_batch_stream`` needs to replay the
        stream onto a freshly fitted ``BatchedTaskModel``.

        Raises ``ValueError`` naming the offending task when an
        observation's task is missing from ``task_index`` (a replay onto
        a model fitted for a different task set would otherwise die with
        a bare ``KeyError`` deep in the comprehension)."""
        missing = sorted({o.task for o in self._obs
                          if o.task not in task_index})
        if missing:
            raise ValueError(
                f"observation task(s) {missing} not in task_index "
                f"(known: {sorted(task_index)}) — the buffer was recorded "
                "against a different task set than the model being replayed")
        idx = np.array([task_index[o.task] for o in self._obs], np.int64)
        sizes = np.array([o.size for o in self._obs], np.float64)
        local = np.array([o.local_runtime for o in self._obs], np.float64)
        return idx, sizes, local

    def to_dict(self) -> dict:
        """JSON-ready dict of the stream (order-preserving) — the
        observation half of ``ExecutionTrace.to_dict``."""
        return {"observations": [asdict(o) for o in self._obs]}

    @classmethod
    def from_dict(cls, d: dict) -> "ObservationBuffer":
        buf = cls()
        for o in d["observations"]:
            buf.add(Observation(task=str(o["task"]), node=str(o["node"]),
                                size=float(o["size"]),
                                runtime=float(o["runtime"]),
                                local_runtime=float(o["local_runtime"]),
                                time=float(o.get("time", 0.0))))
        return buf

    def by_tick(self, atol: float = TICK_ATOL) -> list[tuple[float,
                                                         list[Observation]]]:
        """Group the stream by completion time (within ``atol``): the
        same-tick batches the executor fed through ``observe_batch`` —
        replaying tick by tick reproduces the online update sequence.

        The default-``atol`` grouping is served from the index ``add``
        maintains incrementally, so calling this after every completion
        (the replay-while-running pattern) no longer re-scans the whole
        stream each time; a non-default ``atol`` falls back to the
        one-shot scan.  Returned group lists are fresh copies either way.
        """
        if atol == self.TICK_ATOL:
            return [(t, list(g)) for t, g in self._ticks]
        out: list[tuple[float, list[Observation]]] = []
        for o in self._obs:
            if out and abs(o.time - out[-1][0]) <= atol:
                out[-1][1].append(o)
            else:
                out.append((o.time, [o]))
        return out
