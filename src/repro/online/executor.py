"""Event-driven online execution engine (run → observe → re-predict →
re-schedule).

The closed loop the paper motivates but never builds: a HEFT plan from the
locally-fitted estimates is executed on grid-engine-style nodes; every
finished task's realised runtime is fed back through
``LotaruEstimator.observe`` (incremental conjugate update, O(d²)); and when
a runtime falls outside its predictive interval — the model was *surprised*
— the not-yet-started frontier is re-planned with ``heft_schedule_array``
over the refreshed estimate matrix, with node/task availability floors so
running work is never disturbed.

The same loop with ``online=False`` executes the static plan with frozen
predictions, which is the baseline every benchmark compares against.

Risk-aware mode (``risk_k > 0``) closes the paper's last open loop: the
"robust uncertainty estimates" its Bayesian predictor produces actually
*drive placement*.  Every plan and re-plan schedules on the effective
cost ``mean + risk_k * sigma`` where sigma is the bias-widened predictive
std, and speculative-copy admission can be gated on the bias posterior's
tail mass (``spec_tail``) instead of its point estimate.
"""
from __future__ import annotations

import heapq
from dataclasses import asdict, dataclass, field

import numpy as np

from repro.obs.calibration import running_median
from repro.obs.trace import NULL_TRACER
from repro.sched.heft import (CommCosts, SchedTask, _topo_order,
                              heft_schedule_array, upward_rank_array,
                              upward_rank_incremental)
from repro.sched.simulator import GridEngine

from .buffer import ObservationBuffer

#: ExecutionTrace.to_dict / from_dict on-disk format
TRACE_SCHEMA_VERSION = 1


@dataclass(frozen=True)
class TaskRun:
    """One completed task instance with the prediction it was dispatched
    under (the dispatch-time belief, not hindsight)."""
    id: str
    name: str             # abstract task name (estimator row)
    node: str             # node instance ("type/i")
    node_type: str
    start: float
    end: float
    runtime: float
    pred_mean: float
    pred_std: float

    @property
    def error(self) -> float:
        """Paper eq. 7: |predicted - actual| / actual."""
        return abs(self.pred_mean - self.runtime) / max(self.runtime, 1e-12)


@dataclass(frozen=True)
class CensoredRun:
    """A killed or crashed attempt: the task did NOT finish, so its
    elapsed time is only a *lower bound* on the true runtime — it is
    kept out of the runtime posterior (a censored observation would bias
    it low) but logged here and fed to the reliability model as a failed
    attempt."""
    id: str
    name: str             # abstract task name (estimator row)
    node: str             # node instance the attempt died on
    node_type: str
    start: float
    lost_at: float        # when the failure manifested / the node died
    reason: str           # "attempt" (task-level failure) | "node" (crash)

    @property
    def elapsed(self) -> float:
        """Runtime lower bound: how long the attempt ran before dying."""
        return self.lost_at - self.start


@dataclass
class ExecutionTrace:
    records: list[TaskRun] = field(default_factory=list)
    makespan: float = 0.0
    replans: int = 0
    surprises: int = 0
    speculations: int = 0      # straggler copies launched (bias coupling)
    spec_wins: int = 0         # copies that finished before the original
    failures: int = 0          # attempts lost to faults (task- or node-level)
    retries: int = 0           # re-queued attempts (after backoff)
    lost_nodes: int = 0        # node-down events (crashes + outage starts)
    stranded: int = 0          # tasks abandoned (non-strict mode only)
    completed: int = 0         # tasks that finished
    total: int = 0             # tasks in the DAG
    censored: list[CensoredRun] = field(default_factory=list)
    observations: ObservationBuffer = field(default_factory=ObservationBuffer)

    def completed_fraction(self) -> float:
        """Fraction of DAG tasks that actually finished (1.0 in strict
        mode, which raises rather than strand work)."""
        return self.completed / self.total if self.total else 1.0

    def errors(self) -> np.ndarray:
        """Per-task prediction errors in completion order."""
        return np.array([r.error for r in self.records])

    def cumulative_mpe(self) -> np.ndarray:
        """Running median prediction error after each completion — the
        online trajectory (should fall as observations stream in).
        Incremental two-heap running median: O(n log n) total where the
        prefix re-median was O(n²) — equivalence with the naive form is
        property-tested."""
        return running_median(r.error for r in self.records)

    def final_mpe(self) -> float:
        errs = self.errors()
        return float(np.median(errs)) if len(errs) else float("nan")

    # ---- versioned machine-readable form ----------------------------------
    def to_dict(self) -> dict:
        """JSON-ready dict of the full trace (schema
        ``TRACE_SCHEMA_VERSION``): every counter, every completed
        ``TaskRun``, every ``CensoredRun``, and the observation stream.
        ``from_dict`` round-trips bit-exactly, so bench artifacts and CI
        uploads are machine-readable instead of ad-hoc prints."""
        return {
            "version": TRACE_SCHEMA_VERSION,
            "makespan": self.makespan,
            "replans": self.replans,
            "surprises": self.surprises,
            "speculations": self.speculations,
            "spec_wins": self.spec_wins,
            "failures": self.failures,
            "retries": self.retries,
            "lost_nodes": self.lost_nodes,
            "stranded": self.stranded,
            "completed": self.completed,
            "total": self.total,
            "records": [asdict(r) for r in self.records],
            "censored": [asdict(c) for c in self.censored],
            "observations": self.observations.to_dict(),
        }

    @classmethod
    def from_dict(cls, d: dict) -> "ExecutionTrace":
        version = d.get("version", 1)
        if version > TRACE_SCHEMA_VERSION:
            raise ValueError(
                f"trace schema v{version} is newer than this reader "
                f"(v{TRACE_SCHEMA_VERSION})")
        return cls(
            records=[TaskRun(**r) for r in d["records"]],
            makespan=float(d["makespan"]),
            replans=int(d["replans"]),
            surprises=int(d["surprises"]),
            speculations=int(d["speculations"]),
            spec_wins=int(d["spec_wins"]),
            failures=int(d.get("failures", 0)),
            retries=int(d.get("retries", 0)),
            lost_nodes=int(d.get("lost_nodes", 0)),
            stranded=int(d.get("stranded", 0)),
            completed=int(d.get("completed", 0)),
            total=int(d.get("total", 0)),
            censored=[CensoredRun(**c) for c in d.get("censored", [])],
            observations=ObservationBuffer.from_dict(d["observations"]),
        )


class OnlineExecutor:
    """Discrete-event loop interleaving execution with estimation.

    Parameters
    ----------
    estimator : LotaruEstimator-like (``predict_matrix``, ``observe``,
        ``predict_interval_node``, ``task_names``)
    tasks : dict[str, SchedTask] — instance-level DAG
    task_name : dict[str, str] — instance id → abstract estimator task
    size : float — the workflow's input size (shared by all instances)
    grid : GridEngine — concrete node instances of heterogeneous types
    runtime_fn : (task_id, node_name) → float — ground-truth runtime
    online : False freezes the initial predictions (static baseline)
    confidence : predictive-interval mass for the surprise gate
    risk_k : uncertainty-aware HEFT knob — every (re-)plan schedules on
        the effective cost ``mean + risk_k·sigma``, where sigma is the
        estimator's *bias-widened* predictive std (``predict_matrix``
        with ``with_std=True``), end to end: the upward rank, the EFT
        placement, and the speculative alternate-node pick all consume
        it.  Because ``observe`` feeds the bias posterior, every
        re-plan after a surprise prices placements by the *current*
        posterior widths — pairs whose bias is still unsettled look
        expensive until evidence narrows them.
    replan_cooldown : minimum completions between two re-plans
    speculate : couple the bias posterior to straggler mitigation — a
        still-running task that has outrun its dispatch-time envelope
        (mean + spec_k·sigma) on a node whose learned (task, node) bias
        has drifted past ``bias_drift`` gets a speculative copy on the
        best idle node; whichever attempt finishes first wins, the loser
        is killed and its node freed at that moment
    spec_k : envelope multiplier for the overdue check
    bias_drift : bias drift threshold that marks a node as systematically
        slow for the task (pairs look undrifted until observed)
    spec_tail : admission statistic for the drift check.  ``None``
        (default) compares the bias *point estimate* against
        ``bias_drift`` (the PR 3 behaviour, needs ``bias_point``); a
        float in (0, 1) instead requires the bias posterior's tail mass
        ``P(bias > bias_drift)`` to reach it (needs ``bias_tail_mass``).
        Values above 0.5 are strictly more conservative than the point
        estimate — a single noisy residual can move the posterior mean
        across the drift line, but not drag most of its mass across —
        so tail-mass admission launches fewer, better-justified copies.
    faults : ``FaultInjector`` describing node crashes, transient
        outages and per-attempt failure probabilities — or ``None``
        (default), which keeps the fault-free loop bit-exact.  With an
        injector attached the loop becomes fault-tolerant: lost running
        attempts are detected the moment their node dies (or their
        deterministic failure time fires), recorded as *censored*
        observations (elapsed time is a runtime lower bound — logged in
        ``trace.censored`` and fed to the reliability posterior, never
        to the runtime posterior), and re-queued with capped exponential
        backoff under a per-task attempt budget; orphaned queue entries
        on a dead node trigger a frontier re-plan, as does a node
        rejoining after an outage.
    max_attempts : per-task attempt budget.  A task whose every attempt
        fails raises a ``RuntimeError`` naming the task once the budget
        is exhausted (strict mode) or is stranded (``strict=False``).
    backoff_base / backoff_cap : retry delay after the k-th failure is
        ``min(backoff_base * 2**(k-1), backoff_cap)`` — capped
        exponential backoff, so a flapping task neither hammers the
        cluster nor waits unboundedly.
    rel_k : reliability-aware placement knob (``None`` = off, bit-exact
        with PR 4).  Every (re-)plan multiplies each node's column of
        the effective cost by the estimator's per-node reliability
        factor ``1 / (E[p_success] - rel_k·sd)`` — the Beta–Binomial
        expected time-to-success, uncertainty-widened exactly like
        ``risk_k`` widens runtimes — so flaky nodes price out of HEFT
        placements as attempt failures accrue.  Completions and
        failures feed the posterior via ``estimator.record_attempt``
        (reliability is also tracked whenever ``faults`` is set, even
        with pricing off, so the evidence is there when pricing turns
        on).
    strict : ``True`` (default) raises on exhausted attempt budgets and
        execution stalls; ``False`` strands the affected tasks (and,
        transitively, their dependents) and returns a partial trace —
        ``trace.stranded`` / ``trace.completed_fraction()`` quantify the
        damage.  The static-plan-under-faults baseline runs non-strict:
        stranding work is exactly the failure mode the fault-tolerant
        loop exists to prevent.
    edge_gb : ``(producer_id, consumer_id) -> GB`` per-edge data volumes
        over the instance DAG (e.g. ``repro.sched.workflows.dag_edge_gb``)
        or ``None`` (default — the data-free loop, bit-exact with
        pre-comm behaviour).  With volumes attached AND a grid topology,
        execution becomes data-aware end to end: every launch is delayed
        by the realized staging time of inputs still in flight from
        other nodes (compute ``runtime`` stays pure — the estimator's
        runtime posterior never sees transfer time), and every (re-)plan
        prices transfers via ``CommCosts`` built from the grid's LIVE
        ``secs_per_gb`` matrix — so dead nodes are masked as data
        sources and rejoining nodes re-enter comm pricing, tick by tick.
    comm_aware : ``False`` keeps the realized staging delays (the
        cluster still pays for copies) but plans comm-blind — the
        ablation arm the data-locality bench compares against.
    tracer : a ``repro.obs`` tracer (e.g. ``EventLog``) or ``None``
        (default, the zero-cost no-op path).  With a live tracer the
        whole tick becomes observable: typed events (tick, plan,
        dispatch, finish, observe — with interval coverage and PIT —
        predict, surprise, speculation, fault, retry, backoff,
        node_down/up, stranded) with sim- and wall-time stamps, plus
        wall-clock spans around the HEFT (re-)plan and the estimator's
        jitted predict/update dispatches (the tracer is attached to the
        grid and the estimator too).  Tracing is strictly read-only:
        ``run()`` output is bit-identical with and without it
        (test-enforced, same pattern as the ``faults=None`` proof).
    """

    def __init__(self, estimator, tasks: dict[str, SchedTask],
                 task_name: dict[str, str], size: float, grid: GridEngine,
                 runtime_fn, *, online: bool = True,
                 confidence: float = 0.9, risk_k: float = 0.0,
                 replan_cooldown: int = 0, speculate: bool = True,
                 spec_k: float = 2.0, bias_drift: float = 1.15,
                 spec_tail: float | None = None,
                 faults=None, max_attempts: int = 4,
                 backoff_base: float = 1.0, backoff_cap: float = 30.0,
                 rel_k: float | None = None, strict: bool = True,
                 tracer=None, fused: bool = False,
                 incremental_replan: bool | None = None,
                 edge_gb: dict[tuple[str, str], float] | None = None,
                 comm_aware: bool = True):
        if spec_tail is not None and not 0.0 < spec_tail < 1.0:
            raise ValueError(f"spec_tail must be in (0, 1), got {spec_tail}")
        if max_attempts < 1:
            raise ValueError(f"max_attempts must be >= 1, got {max_attempts}")
        if backoff_base < 0 or backoff_cap < 0:
            raise ValueError("backoff_base/backoff_cap must be >= 0, got "
                             f"{backoff_base}/{backoff_cap}")
        self.est = estimator
        self.tasks = tasks
        self.task_name = task_name
        self.size = float(size)
        self.grid = grid
        self.runtime_fn = runtime_fn
        self.online = online
        self.confidence = confidence
        self.risk_k = risk_k
        self.replan_cooldown = replan_cooldown
        self.speculate = speculate
        self.spec_k = spec_k
        self.bias_drift = bias_drift
        self.spec_tail = spec_tail
        self.faults = faults
        self.max_attempts = max_attempts
        self.backoff_base = float(backoff_base)
        self.backoff_cap = float(backoff_cap)
        self.rel_k = rel_k
        self.strict = strict
        self.tracer = tracer if tracer is not None else NULL_TRACER
        if tracer is not None:
            # one log observes the whole stack: grid membership churn and
            # the estimator's predict/update spans land in the same trace
            grid.tracer = self.tracer
            if hasattr(estimator, "set_tracer"):
                estimator.set_tracer(self.tracer)
        # track attempt outcomes in the reliability posterior whenever a
        # fault process exists or reliability pricing is on (and the
        # estimator has the availability plane at all)
        self._track_rel = ((faults is not None or rel_k is not None)
                           and hasattr(estimator, "record_attempt"))
        self.node_names = grid.names()
        # stable node-type column order for the estimate matrix
        seen: dict[str, None] = {}
        for n in self.node_names:
            seen.setdefault(grid.type_of(n).name)
        self.type_names = list(seen)
        self._type_idx = {t: j for j, t in enumerate(self.type_names)}
        self._col = np.array([self._type_idx[grid.type_of(n).name]
                              for n in self.node_names])
        self._row = {}   # instance id -> estimator row
        task_rows = {nm: i for i, nm in enumerate(estimator.task_names())}
        for tid, nm in task_name.items():
            self._row[tid] = task_rows[nm]
        # fused mode: the per-tick estimator surface is served by a
        # TickEngine (one jitted tick_step per completion batch) instead
        # of the estimator's host-orchestrated observe/predict sequence;
        # the final state is written back into the estimator at run end
        self._engine = None
        if fused and online:
            from repro.core.tick import TickEngine
            self._engine = TickEngine(estimator, self.type_names,
                                      size=self.size, tracer=self.tracer)
        self._api = self._engine if self._engine is not None else estimator
        # incremental re-planning (defaults on with the fused tick):
        # upward ranks over the FULL instance graph are cached and only
        # the dirty ancestor chains re-ranked per re-plan — bitwise equal
        # to the from-scratch rank (oracle-tested), because a successor
        # of an unstarted task is always itself unstarted
        self._incremental = ((fused if incremental_replan is None
                              else incremental_replan) and online)
        self._ids = list(tasks)
        self._id_idx = {tid: i for i, tid in enumerate(self._ids)}
        # edges to ids outside the instance set (external/unsatisfiable
        # deps) are dropped, exactly like _plan's subgraph build
        self._succ_full = [[self._id_idx[s] for s in tasks[tid].succ
                            if s in self._id_idx] for tid in self._ids]
        self._pred_full = [[self._id_idx[p] for p in tasks[tid].pred
                            if p in self._id_idx] for tid in self._ids]
        self._rows_full = np.array([self._row[tid] for tid in self._ids])
        self._topo_full: list[int] | None = None
        self._rank_cache: tuple[np.ndarray, np.ndarray] | None = None
        # data-aware execution: staging delays always apply once edge
        # volumes + a topology exist; comm_aware additionally routes the
        # transfer term into planning.  _node_of tracks where each
        # started/finished task's output lives (the winning attempt's
        # node), _node_idx maps node name -> column for the 2-D floors.
        self.edge_gb = dict(edge_gb) if edge_gb is not None else None
        self._has_comm = (self.edge_gb is not None
                          and grid.topology is not None)
        self.comm_aware = comm_aware and self._has_comm
        self._node_of: dict[str, str] = {}
        self._node_idx = {n: j for j, n in enumerate(self.node_names)}
        self._edge_gb_full: dict[tuple[int, int], float] = {}
        if self.edge_gb is not None:
            for (p, s), g in self.edge_gb.items():
                if p in self._id_idx and s in self._id_idx:
                    self._edge_gb_full[(self._id_idx[p],
                                        self._id_idx[s])] = float(g)
        # the incremental rank cache is additionally keyed on the live
        # transfer matrix: membership churn re-prices the mean transfer
        # rate, which is part of the comm-aware rank, so a changed matrix
        # invalidates prev_rank wholesale (see upward_rank_incremental)
        self._rank_spg_key: bytes | None = None

    def _backoff(self, n_failures: int) -> float:
        """Retry delay after the ``n_failures``-th failure of a task:
        capped exponential, ``min(base * 2**(n-1), cap)``."""
        return min(self.backoff_base * 2.0 ** (max(n_failures, 1) - 1),
                   self.backoff_cap)

    def _rel_factors(self) -> np.ndarray:
        """(N,) per-node-instance reliability price multipliers (all-ones
        when the estimator has no availability plane)."""
        if hasattr(self._api, "reliability_factors"):
            return np.asarray(self._api.reliability_factors(
                self.node_names, self.rel_k), np.float64)
        return np.ones(len(self.node_names), np.float64)

    # ---- planning ---------------------------------------------------------
    def _estimates(self, with_std: bool = True):
        """Current (abstract-task × node-type) mean/std matrices.  After an
        ``observe`` only the dirty row is recomputed (matrix row cache).
        ``with_std=False`` returns ``(mean, None)`` and skips the bias
        widening — the mean-only fast path a risk-neutral plan takes."""
        return self._api.predict_matrix(self.type_names, self.size,
                                        with_std=with_std)

    def _incremental_rank(self, unstarted: list[str], mean, std,
                          rf, spg: np.ndarray | None = None) -> np.ndarray:
        """Upward ranks for the unstarted subgraph, refreshed from the
        cached full-instance-graph ranks instead of recomputed.

        Bitwise equal to the rank ``heft_schedule_array`` would build
        itself: a task can only start once every predecessor is done, so
        successors of unstarted tasks are themselves unstarted — the
        full-graph rank restricted to the frontier IS the subgraph rank
        (edges into the frontier never enter an *upward* rank, so this
        holds with the comm term too).  Only instances whose effective
        mean cost changed since the last plan (plus their ancestor
        chains) are re-ranked; a changed transfer matrix (membership
        churn re-pricing the mean rate) drops the cache wholesale."""
        eff_abs = mean[:, self._col]
        if rf is not None:
            eff_abs = eff_abs * rf[None, :]
        if self.risk_k > 0:
            unc_abs = std[:, self._col]
            if rf is not None:
                unc_abs = unc_abs * rf[None, :]
            eff_abs = eff_abs + self.risk_k * unc_abs
        inst_cost = eff_abs.mean(axis=1)[self._rows_full]
        edge_comm = None
        if spg is not None:
            key = spg.tobytes()
            if key != self._rank_spg_key:
                self._rank_cache = None
                self._rank_spg_key = key
            mean_spg = float(spg.mean())
            edge_comm = [[self._edge_gb_full.get((t, s), 0.0) * mean_spg
                          for s in ss]
                         for t, ss in enumerate(self._succ_full)]
        if self._rank_cache is None:
            if self._topo_full is None:
                self._topo_full = _topo_order(self._succ_full,
                                              self._pred_full)
            rank_full = upward_rank_array(self._succ_full,
                                          self._pred_full, inst_cost,
                                          edge_comm=edge_comm)
        else:
            prev_cost, prev_rank = self._rank_cache
            dirty = np.nonzero(inst_cost != prev_cost)[0]
            rank_full = upward_rank_incremental(
                self._succ_full, self._pred_full, inst_cost, prev_rank,
                dirty, topo=self._topo_full, edge_comm=edge_comm)
        self._rank_cache = (inst_cost, rank_full)
        return rank_full[[self._id_idx[tid] for tid in unstarted]]

    def _plan(self, unstarted: list[str], t_now: float,
              ext_finish: dict[str, float],
              frontier_exact: bool = True) -> dict[str, list[str]]:
        """(Re-)plan the not-yet-started frontier; returns per-node queues.

        ``ext_finish`` maps done/running predecessors to their (actual or
        expected) finish times — they become ``task_ready`` floors, and the
        grid's busy-until times become ``node_ready`` floors, so the plan
        never assumes a busy node or an unfinished input.
        ``frontier_exact`` asserts ``unstarted`` is the complete
        never-started remainder of the DAG (no stranded holes) — the
        precondition for the incremental rank reuse; callers that dropped
        stranded tasks pass False and take the from-scratch rank."""
        if not unstarted:
            return {n: [] for n in self.node_names}
        # risk-neutral plans consume only the means: skip the bias-widened
        # std entirely (with_std=False) instead of computing and dropping it
        mean, std = self._estimates(with_std=self.risk_k > 0)
        idx = {tid: i for i, tid in enumerate(unstarted)}
        succ = [[idx[s] for s in self.tasks[tid].succ if s in idx]
                for tid in unstarted]
        pred = [[idx[p] for p in self.tasks[tid].pred if p in idx]
                for tid in unstarted]
        rows = np.array([self._row[tid] for tid in unstarted])
        cost = mean[rows][:, self._col]
        unc = std[rows][:, self._col] if self.risk_k > 0 else None
        rf = self._rel_factors() if self.rel_k is not None else None
        if rf is not None:
            # availability pricing: each node-instance column is scaled
            # by its expected time-to-success multiplier, so the same
            # mean runtime on a flaky node costs more end to end (rank
            # AND placement, like risk_k)
            cost = cost * rf[None, :]
            if unc is not None:
                unc = unc * rf[None, :]
        comm = None
        spg = None
        if self.comm_aware:
            # live transfer matrix: dead nodes are re-priced as data
            # sources every plan (stateless), rejoins restore real rates
            spg = self.grid.secs_per_gb()
        if spg is not None:
            comm = CommCosts(
                pred,
                {(idx[p], idx[s]): g for (p, s), g in self.edge_gb.items()
                 if p in idx and s in idx},
                spg)
        rank = (self._incremental_rank(unstarted, mean, std, rf, spg)
                if self._incremental and frontier_exact else None)
        if comm is None:
            task_ready = np.array([
                max((ext_finish.get(p, t_now)
                     for p in self.tasks[tid].pred if p not in idx),
                    default=t_now)
                for tid in unstarted])
            task_ready = np.maximum(task_ready, t_now)
        else:
            # (T, N) floors: an external (done/running) predecessor's
            # output still has to be COPIED from where it ran to wherever
            # the frontier task lands, so its floor is node-dependent
            task_ready = np.full((len(unstarted), len(self.node_names)),
                                 t_now)
            for i, tid in enumerate(unstarted):
                for p in self.tasks[tid].pred:
                    if p in idx:
                        continue
                    base = max(ext_finish.get(p, t_now), t_now)
                    gb = self.edge_gb.get((p, tid), 0.0)
                    src = self._node_idx.get(self._node_of.get(p))
                    if src is None or gb <= 0:
                        task_ready[i] = np.maximum(task_ready[i], base)
                    else:
                        task_ready[i] = np.maximum(
                            task_ready[i], base + gb * spg[src])
        if self.tracer.enabled:
            self.tracer.emit("plan", t_sim=t_now, n_tasks=len(unstarted),
                             risk=self.risk_k > 0)
        with self.tracer.span("plan", t_sim=t_now, n_tasks=len(unstarted)):
            sched = heft_schedule_array(
                succ, pred, cost, unc, self.risk_k,
                node_ready=self.grid.ready_vector(t_now),
                task_ready=task_ready, rank=rank, comm=comm)
        queues: dict[str, list[str]] = {n: [] for n in self.node_names}
        for i in sched["order"]:
            queues[self.node_names[sched["assignment"][i]]].append(
                unstarted[int(i)])
        return queues

    # ---- the loop ---------------------------------------------------------
    def run(self) -> ExecutionTrace:
        tr = self.tracer
        if tr.enabled:
            tr.emit("run_start", t_sim=0.0, tasks=len(self.tasks),
                    nodes=len(self.node_names), online=self.online,
                    confidence=self.confidence, risk_k=self.risk_k,
                    rel_k=self.rel_k, spec_tail=self.spec_tail,
                    speculate=self.speculate,
                    faults=self.faults is not None, strict=self.strict)
        trace = ExecutionTrace()
        trace.total = len(self.tasks)
        done: dict[str, float] = {}
        expected_finish: dict[str, float] = {}
        started: set[str] = set()
        stranded: set[str] = set()         # abandoned tasks (strict=False)
        # heap entries: (time, seq, kind, a, b).  "finish"/"fail" carry
        # (task id, node) and their push seq doubles as the attempt id;
        # "down"/"up" carry (node, None); "retry" carries (task id, None).
        # Ordering is (time, seq), identical to the fault-free loop.
        heap: list[tuple[float, int, str, str, str | None]] = []
        seq = 0
        t = 0.0
        cooldown = 0
        attempt_no: dict[str, int] = {}    # attempts dispatched per task
        fail_count: dict[str, int] = {}    # attempts lost per task
        retry_at: dict[str, float] = {}    # backoff floor per task
        dead_attempts: set[int] = set()    # attempt seqs killed by churn
        if self.faults is not None:
            for ev_t, ev_node, ev_kind in self.faults.node_events():
                if ev_node in self.grid.nodes:
                    heapq.heappush(heap, (float(ev_t), seq, ev_kind,
                                          ev_node, None))
                    seq += 1
        queues = self._plan(list(self.tasks), t, {})
        mean, std = self._estimates()
        rec_idx: dict[str, int] = {}            # task id -> trace.records slot
        # active attempts: tid -> [(node, event time, attempt seq, start)]
        running: dict[str, list[tuple[str, float, int, float]]] = {}
        spec_run: dict[str, TaskRun] = {}       # pending copy's TaskRun
        speculated: set[str] = set()

        def launch(tid: str, node: str, t_now: float) -> tuple[float, float]:
            """Draw the attempt's fate and book it: a successful attempt
            finishes at start + staging + dur; a doomed one (``faults``
            decided) dies at its deterministic failure fraction of the
            runtime.  Returns ``(duration, staging wait)`` — with edge
            volumes + a topology, inputs produced on OTHER nodes must
            first be copied over (same-node inputs are free), and the
            attempt computes only after the last one lands.  The wait is
            charged to the cluster whether or not planning was comm-aware
            (that is the bench's whole comparison) but never to the
            compute ``runtime`` the estimator observes."""
            nonlocal seq
            dur = float(self.runtime_fn(tid, node))
            wait = 0.0
            if self._has_comm:
                topo = self.grid.topology
                for p in self.tasks[tid].pred:
                    gb = self.edge_gb.get((p, tid), 0.0)
                    src = self._node_of.get(p)
                    if gb <= 0 or src is None or src == node:
                        continue
                    arr = done.get(p, t_now) + gb * topo.pair_secs_per_gb(
                        src, node)
                    if arr - t_now > wait:
                        wait = arr - t_now
            k = attempt_no.get(tid, 0)
            attempt_no[tid] = k + 1
            frac = (self.faults.attempt_outcome(tid, node, k)
                    if self.faults is not None else None)
            if frac is None:
                end, kind = t_now + wait + dur, "finish"
            else:
                end, kind = t_now + wait + frac * dur, "fail"
            self.grid.occupy(node, end)
            heapq.heappush(heap, (end, seq, kind, tid, node))
            running.setdefault(tid, []).append((node, end, seq, t_now))
            seq += 1
            self._node_of[tid] = node
            return dur, wait

        def dispatch(t_now: float) -> bool:
            progressed = False
            for node in self.grid.idle(t_now):
                q = queues[node]
                pick = next(
                    (tid for tid in q
                     if all(p in done for p in self.tasks[tid].pred)
                     and retry_at.get(tid, 0.0) <= t_now + 1e-12), None)
                if pick is None:
                    continue
                q.remove(pick)
                started.add(pick)
                if tr.enabled:
                    tr.emit("dispatch", t_sim=t_now, task=pick, node=node,
                            attempt=attempt_no.get(pick, 0))
                dur, wait = launch(pick, node, t_now)
                r, c = self._row[pick], self._type_idx[
                    self.grid.type_of(node).name]
                expected_finish[pick] = t_now + wait + float(mean[r, c])
                run_rec = TaskRun(
                    id=pick, name=self.task_name[pick], node=node,
                    node_type=self.grid.type_of(node).name,
                    start=t_now, end=t_now + wait + dur, runtime=dur,
                    pred_mean=float(mean[r, c]), pred_std=float(std[r, c]))
                if pick in rec_idx:      # retry: replace the lost attempt
                    trace.records[rec_idx[pick]] = run_rec
                else:
                    rec_idx[pick] = len(trace.records)
                    trace.records.append(run_rec)
                progressed = True
            return progressed

        # ---- failure machinery (inert while faults is None) ----------
        def record_censored(tid: str, node: str, start: float,
                            t_now: float, reason: str) -> None:
            """A lost attempt's elapsed time is a censored runtime
            observation: a lower bound, never fed to the runtime
            posterior — logged for the trace and counted against the
            node's reliability posterior."""
            trace.failures += 1
            trace.censored.append(CensoredRun(
                id=tid, name=self.task_name[tid], node=node,
                node_type=self.grid.type_of(node).name,
                start=start, lost_at=t_now, reason=reason))
            if tr.enabled:
                tr.emit("fault", t_sim=t_now, task=tid, node=node,
                        reason=reason, elapsed=t_now - start)
            if self._track_rel:
                self._api.record_attempt(node, False)

        def lose_attempt(tid: str, att_seq: int, t_now: float,
                         reason: str) -> bool:
            """Kill one live attempt; True when the task has no attempts
            left and needs a retry (or stranding)."""
            atts = running.get(tid, [])
            entry = next((a for a in atts if a[2] == att_seq), None)
            if entry is None:
                return False
            atts.remove(entry)
            node = entry[0]
            record_censored(tid, node, entry[3], t_now, reason)
            sr = spec_run.get(tid)
            if sr is not None and sr.node == node:
                spec_run.pop(tid)        # the speculative copy itself died
            if atts:
                return False             # a twin attempt is still live
            running.pop(tid, None)
            started.discard(tid)         # back to the unstarted frontier
            speculated.discard(tid)      # a retry may speculate again
            return True

        def schedule_retry(tid: str, node: str, t_now: float) -> None:
            """Capped exponential backoff under the attempt budget, for a
            task whose every live attempt has been lost."""
            nonlocal seq
            fail_count[tid] = fail_count.get(tid, 0) + 1
            if attempt_no.get(tid, 0) >= self.max_attempts:
                if self.strict:
                    raise RuntimeError(
                        f"task {tid!r} exhausted its attempt budget: "
                        f"{attempt_no[tid]} attempts, {fail_count[tid]} "
                        f"lost (last on {node!r} at t={t_now:.2f}) — "
                        "raise max_attempts or fix the fault source")
                stranded.add(tid)
                if tr.enabled:
                    tr.emit("stranded", t_sim=t_now, task=tid, node=node,
                            reason="attempt budget exhausted")
                return
            delay = self._backoff(fail_count[tid])
            retry_at[tid] = t_now + delay
            heapq.heappush(heap, (t_now + delay, seq, "retry", tid, None))
            seq += 1
            trace.retries += 1
            if tr.enabled:
                tr.emit("retry", t_sim=t_now, task=tid, node=node,
                        delay=delay, fails=fail_count[tid],
                        attempts=attempt_no.get(tid, 0))
            if not self.online:
                # a static plan cannot re-plan: the retry goes back to
                # its frozen node's queue if that node is still alive —
                # otherwise the work is stranded with the node, which is
                # exactly how static plans fail under churn
                if self.grid.nodes[node].alive:
                    queues[node].append(tid)
                elif self.strict:
                    raise RuntimeError(
                        f"task {tid!r} was running on dead node {node!r} "
                        "and the static plan (online=False) cannot "
                        "re-assign it")
                else:
                    stranded.add(tid)
                    if tr.enabled:
                        tr.emit("stranded", t_sim=t_now, task=tid,
                                node=node, reason="static plan, dead node")

        def replan_frontier(t_now: float) -> None:
            """Re-plan the unstarted frontier (membership changed or a
            retry re-entered it) with fresh availability floors."""
            nonlocal queues
            if not self.online:
                return
            unstarted = [x for x in self.tasks
                         if x not in started and x not in done
                         and x not in stranded]
            if not unstarted:
                return
            ext = {**done, **{k: max(v, t_now)
                              for k, v in expected_finish.items()
                              if k not in done}}
            queues = self._plan(unstarted, t_now, ext,
                                frontier_exact=not stranded)
            trace.replans += 1

        def node_down(node: str, t_now: float) -> None:
            """A crash or outage start: mask the node, kill its running
            attempts (censored + retry), rescue orphaned queue entries
            via a frontier re-plan."""
            self.grid.fail(node, t_now)
            trace.lost_nodes += 1
            orphaned = bool(queues.get(node))
            needs_retry = []
            for tid, atts in list(running.items()):
                for entry in [a for a in atts if a[0] == node]:
                    dead_attempts.add(entry[2])
                    if lose_attempt(tid, entry[2], t_now, "node"):
                        needs_retry.append(tid)
            for tid in needs_retry:
                schedule_retry(tid, node, t_now)
            if self.online and (orphaned or needs_retry):
                replan_frontier(t_now)
            elif not self.online and orphaned and self.strict:
                raise RuntimeError(
                    f"node {node!r} died at t={t_now:.2f} with "
                    f"{len(queues[node])} queued tasks "
                    f"({', '.join(queues[node][:6])}) and the static plan "
                    "(online=False) cannot re-assign them")

        def node_up(node: str, t_now: float) -> None:
            """An outage ends: revive the node and re-plan so the
            frontier can use the recovered capacity."""
            self.grid.join(node, t_now)
            replan_frontier(t_now)

        def speculate_stragglers(t_now: float) -> None:
            """Bias-coupled straggler mitigation: the surprise gate already
            told us a node is systematically slow for a task (its bias
            posterior drifted high) — so a still-running instance of that
            pair that has outrun its dispatch-time envelope gets a copy on
            the best idle node, instead of only re-planning work that has
            not started yet.  First finish wins; the loser is killed and
            its node freed at that moment.

            Admission: the point-estimate drift check by default, or —
            when ``spec_tail`` is set — the posterior tail mass
            ``P(bias > bias_drift) >= spec_tail``, which no single noisy
            residual can satisfy."""
            bias_point = getattr(self._api, "bias_point", None)
            tail_mass = getattr(self._api, "bias_tail_mass", None)
            if self.spec_tail is not None:
                if tail_mass is None:
                    return
            elif bias_point is None:
                return
            for tid, attempts in list(running.items()):
                if tid in done or tid in speculated or len(attempts) != 1:
                    continue
                rec = trace.records[rec_idx[tid]]
                envelope = rec.pred_mean + self.spec_k * max(
                    rec.pred_std, 1e-9)
                if t_now < rec.start + envelope:
                    continue                      # not overdue yet
                if self.spec_tail is not None:
                    if tail_mass(rec.name, rec.node_type,
                                 self.bias_drift) < self.spec_tail:
                        continue    # posterior mass not behind the drift
                elif bias_point(rec.name, rec.node_type) < self.bias_drift:
                    continue                      # node not drifted for it
                node = attempts[0][0]
                idle = [n for n in self.grid.idle(t_now) if n != node]
                if not idle:
                    continue
                r = self._row[tid]
                # the copy's landing spot is priced with the same risk
                # aversion as the plan: a low-mean but still-uncertain
                # node is a bad place to park a rescue attempt
                alt = min(idle, key=lambda n: mean[
                    r, self._type_idx[self.grid.type_of(n).name]]
                    + self.risk_k * std[
                        r, self._type_idx[self.grid.type_of(n).name]])
                dur, wait = launch(tid, alt, t_now)
                end = t_now + wait + dur
                speculated.add(tid)
                c = self._type_idx[self.grid.type_of(alt).name]
                spec_run[tid] = TaskRun(
                    id=tid, name=self.task_name[tid], node=alt,
                    node_type=self.grid.type_of(alt).name,
                    start=t_now, end=end, runtime=dur,
                    pred_mean=float(mean[r, c]), pred_std=float(std[r, c]))
                expected_finish[tid] = min(expected_finish[tid],
                                           t_now + float(mean[r, c]))
                trace.speculations += 1
                if tr.enabled:
                    tr.emit("speculation", t_sim=t_now, task=tid,
                            node=node, alt=alt,
                            overdue=t_now - (rec.start + envelope))

        while len(done) + len(stranded) < len(self.tasks):
            while dispatch(t):
                pass
            if not heap:
                missing = sorted(tid for tid in self.tasks
                                 if tid not in done and tid not in stranded)
                if not self.strict:
                    stranded.update(missing)
                    if tr.enabled:
                        for mtid in missing:
                            tr.emit("stranded", t_sim=t, task=mtid,
                                    node=None, reason="execution stalled")
                    break
                details = []
                for btid in missing[:8]:
                    blockers = [p for p in self.tasks[btid].pred
                                if p not in done]
                    details.append(
                        f"{btid} <- waiting on {', '.join(sorted(blockers))}"
                        if blockers else
                        f"{btid} (ready but not dispatchable — queued on a "
                        "dead node, or no live nodes left?)")
                more = (f"\n  ... and {len(missing) - 8} more"
                        if len(missing) > 8 else "")
                raise RuntimeError(
                    f"execution stalled with {len(missing)} tasks blocked:"
                    "\n  " + "\n  ".join(details) + more)
            end, ev_seq, kind, a, b = heapq.heappop(heap)
            if tr.enabled:
                tr.emit("tick", t_sim=end, event=kind, seq=ev_seq)
            if kind == "retry":
                t = max(t, end)          # backoff expired: just dispatch
                if tr.enabled:
                    tr.emit("backoff", t_sim=t, task=a)
                continue
            if kind == "down":
                t = max(t, end)
                if self.grid.nodes[a].alive:
                    node_down(a, t)
                continue
            if kind == "up":
                t = max(t, end)
                node_up(a, t)
                continue
            tid, node = a, b
            if tid in done or ev_seq in dead_attempts:
                continue                 # stale event of a killed attempt
            t = end
            if kind == "fail":
                if lose_attempt(tid, ev_seq, t, "attempt"):
                    schedule_retry(tid, node, t)
                    replan_frontier(t)
                continue
            # batch every completion landing on this tick: multi-node
            # observations arriving together are absorbed by ONE scanned
            # estimator update instead of per-observation calls
            completions = [(tid, node, end)]
            seen = {tid}
            while (heap and heap[0][0] <= t + 1e-12
                   and heap[0][2] == "finish"):
                e2, s2, _, tid2, node2 = heapq.heappop(heap)
                if tid2 in done or tid2 in seen or s2 in dead_attempts:
                    continue             # stale, or a same-tick lost twin
                completions.append((tid2, node2, e2))
                seen.add(tid2)
            for ctid, cnode, cend in completions:
                done[ctid] = cend
                self._node_of[ctid] = cnode  # winner holds the output
                # resolve the speculative race: kill the other attempts,
                # free their nodes NOW, and let the winning run's record
                # stand (predictions are the dispatch-time belief of the
                # attempt that actually finished).  A scheduler-ordered
                # kill is NOT a node failure: it never touches the
                # reliability posterior.
                for n2, e2, s2, _ in running.pop(ctid, []):
                    if n2 != cnode:
                        self.grid.release(n2, cend)
                        dead_attempts.add(s2)
                sr = spec_run.pop(ctid, None)
                if sr is not None and sr.node == cnode:
                    trace.records[rec_idx[ctid]] = sr
                    trace.spec_wins += 1
                if self._track_rel:
                    self._api.record_attempt(cnode, True)
                if tr.enabled:
                    crec = trace.records[rec_idx[ctid]]
                    tr.emit("finish", t_sim=cend, task=ctid,
                            node=crec.node, start=crec.start,
                            runtime=crec.runtime,
                            spec_win=sr is not None and sr.node == cnode)
            cooldown = max(0, cooldown - len(completions))
            if self.online:
                # surprise gates BEFORE the update: was each realised
                # runtime outside what the dispatch-time posterior (the
                # tick-start belief) considered likely?
                batch = []
                gates = []
                pit_of = getattr(self._api, "predict_pit_node", None)
                for ctid, cnode, _ in completions:
                    run = trace.records[rec_idx[ctid]]
                    name = self.task_name[ctid]
                    ntype = self.grid.type_of(cnode).name
                    lo, hi = self._api.predict_interval_node(
                        name, ntype, self.size, self.confidence)
                    gate = not (lo <= run.runtime <= hi)
                    gates.append(gate)
                    batch.append((name, ntype, self.size, run.runtime))
                    if tr.enabled:
                        # the tick-start belief, read-only: the same
                        # interval the surprise gate consumed, plus the
                        # PIT of the realised runtime under it
                        pit = (pit_of(name, ntype, self.size, run.runtime)
                               if pit_of is not None else None)
                        tr.emit("observe", t_sim=t, task=ctid, name=name,
                                node=run.node, node_type=ntype,
                                runtime=run.runtime,
                                pred_mean=run.pred_mean,
                                pred_std=run.pred_std,
                                lo=lo, hi=hi, covered=not gate, pit=pit)
                        if gate:
                            tr.emit("surprise", t_sim=t, task=ctid,
                                    name=name, node_type=ntype,
                                    runtime=run.runtime, lo=lo, hi=hi)
                local_rts = self._api.observe_batch(batch)
                for (name, ntype, _, runtime), local_rt in zip(batch,
                                                               local_rts):
                    trace.observations.record(name, ntype, self.size,
                                              runtime, local_rt, time=t)
                mean, std = self._estimates()     # dirty-row refresh only
                trace.surprises += sum(gates)
                if tr.enabled:
                    tr.emit("predict", t_sim=t, n_obs=len(batch),
                            surprises=sum(gates))
                unstarted = [x for x in self.tasks
                             if x not in started and x not in done
                             and x not in stranded]
                if any(gates) and unstarted and cooldown == 0:
                    ext = {**done, **{k: max(v, t)
                                      for k, v in expected_finish.items()
                                      if k not in done}}
                    queues = self._plan(unstarted, t, ext,
                                        frontier_exact=not stranded)
                    trace.replans += 1
                    cooldown = self.replan_cooldown
                if self.speculate:
                    speculate_stragglers(t)
        trace.makespan = max(done.values()) if done else 0.0
        trace.completed = len(done)
        trace.stranded = len(stranded)
        if stranded:
            # placeholder records of attempts that never completed would
            # read as finished runs — keep only what actually ran to end
            trace.records = [r for r in trace.records if r.id in done]
        if tr.enabled:
            tr.emit("run_end", t_sim=trace.makespan,
                    makespan=trace.makespan, completed=trace.completed,
                    stranded=trace.stranded, replans=trace.replans,
                    surprises=trace.surprises,
                    speculations=trace.speculations,
                    spec_wins=trace.spec_wins, failures=trace.failures,
                    retries=trace.retries, mpe=trace.final_mpe())
        if self._engine is not None:
            # fold the device-resident state back into the estimator so
            # the OO surface (scalar predicts, save/load) picks up from
            # exactly where the fused ticks left off
            self._engine.finalize()
        return trace


def fanout_chain_dag(chain: list[str], n_samples: int
                     ) -> tuple[dict[str, SchedTask], dict[str, str]]:
    """Physical workflow: ``n_samples`` inputs each flowing through the
    abstract task ``chain`` (parallel across samples, sequential within).
    Returns (instance DAG, instance id → abstract task name) — the two
    structures ``OnlineExecutor`` consumes.  Instance ids are
    ``s<sample>.<task>``."""
    tasks: dict[str, SchedTask] = {}
    task_name: dict[str, str] = {}
    for s in range(n_samples):
        prev = None
        for nm in chain:
            tid = f"s{s}.{nm}"
            tasks[tid] = SchedTask(id=tid)
            task_name[tid] = nm
            if prev is not None:
                tasks[tid].pred.append(prev)
                tasks[prev].succ.append(tid)
            prev = tid
    return tasks, task_name


def run_static_and_online(make_executor) -> tuple[ExecutionTrace,
                                                  ExecutionTrace]:
    """Convenience: run the same scenario twice — frozen initial plan vs
    the full observe/re-plan loop.  ``make_executor(online)`` must build a
    fresh executor (estimator state is mutated by the online run)."""
    static = make_executor(online=False).run()
    online = make_executor(online=True).run()
    return static, online
