"""Event-driven online execution engine (run → observe → re-predict →
re-schedule).

The closed loop the paper motivates but never builds: a HEFT plan from the
locally-fitted estimates is executed on grid-engine-style nodes; every
finished task's realised runtime is fed back through
``LotaruEstimator.observe`` (incremental conjugate update, O(d²)); and when
a runtime falls outside its predictive interval — the model was *surprised*
— the not-yet-started frontier is re-planned with ``heft_schedule_array``
over the refreshed estimate matrix, with node/task availability floors so
running work is never disturbed.

The same loop with ``online=False`` executes the static plan with frozen
predictions, which is the baseline every benchmark compares against.

Risk-aware mode (``risk_k > 0``) closes the paper's last open loop: the
"robust uncertainty estimates" its Bayesian predictor produces actually
*drive placement*.  Every plan and re-plan schedules on the effective
cost ``mean + risk_k * sigma`` where sigma is the bias-widened predictive
std, and speculative-copy admission can be gated on the bias posterior's
tail mass (``spec_tail``) instead of its point estimate.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field

import numpy as np

from repro.sched.heft import SchedTask, heft_schedule_array
from repro.sched.simulator import GridEngine

from .buffer import ObservationBuffer


@dataclass(frozen=True)
class TaskRun:
    """One completed task instance with the prediction it was dispatched
    under (the dispatch-time belief, not hindsight)."""
    id: str
    name: str             # abstract task name (estimator row)
    node: str             # node instance ("type/i")
    node_type: str
    start: float
    end: float
    runtime: float
    pred_mean: float
    pred_std: float

    @property
    def error(self) -> float:
        """Paper eq. 7: |predicted - actual| / actual."""
        return abs(self.pred_mean - self.runtime) / max(self.runtime, 1e-12)


@dataclass
class ExecutionTrace:
    records: list[TaskRun] = field(default_factory=list)
    makespan: float = 0.0
    replans: int = 0
    surprises: int = 0
    speculations: int = 0      # straggler copies launched (bias coupling)
    spec_wins: int = 0         # copies that finished before the original
    observations: ObservationBuffer = field(default_factory=ObservationBuffer)

    def errors(self) -> np.ndarray:
        """Per-task prediction errors in completion order."""
        return np.array([r.error for r in self.records])

    def cumulative_mpe(self) -> np.ndarray:
        """Running median prediction error after each completion — the
        online trajectory (should fall as observations stream in)."""
        errs = self.errors()
        return np.array([np.median(errs[:k + 1]) for k in range(len(errs))])

    def final_mpe(self) -> float:
        errs = self.errors()
        return float(np.median(errs)) if len(errs) else float("nan")


class OnlineExecutor:
    """Discrete-event loop interleaving execution with estimation.

    Parameters
    ----------
    estimator : LotaruEstimator-like (``predict_matrix``, ``observe``,
        ``predict_interval_node``, ``task_names``)
    tasks : dict[str, SchedTask] — instance-level DAG
    task_name : dict[str, str] — instance id → abstract estimator task
    size : float — the workflow's input size (shared by all instances)
    grid : GridEngine — concrete node instances of heterogeneous types
    runtime_fn : (task_id, node_name) → float — ground-truth runtime
    online : False freezes the initial predictions (static baseline)
    confidence : predictive-interval mass for the surprise gate
    risk_k : uncertainty-aware HEFT knob — every (re-)plan schedules on
        the effective cost ``mean + risk_k·sigma``, where sigma is the
        estimator's *bias-widened* predictive std (``predict_matrix``
        with ``with_std=True``), end to end: the upward rank, the EFT
        placement, and the speculative alternate-node pick all consume
        it.  Because ``observe`` feeds the bias posterior, every
        re-plan after a surprise prices placements by the *current*
        posterior widths — pairs whose bias is still unsettled look
        expensive until evidence narrows them.
    replan_cooldown : minimum completions between two re-plans
    speculate : couple the bias posterior to straggler mitigation — a
        still-running task that has outrun its dispatch-time envelope
        (mean + spec_k·sigma) on a node whose learned (task, node) bias
        has drifted past ``bias_drift`` gets a speculative copy on the
        best idle node; whichever attempt finishes first wins, the loser
        is killed and its node freed at that moment
    spec_k : envelope multiplier for the overdue check
    bias_drift : bias drift threshold that marks a node as systematically
        slow for the task (pairs look undrifted until observed)
    spec_tail : admission statistic for the drift check.  ``None``
        (default) compares the bias *point estimate* against
        ``bias_drift`` (the PR 3 behaviour, needs ``bias_point``); a
        float in (0, 1) instead requires the bias posterior's tail mass
        ``P(bias > bias_drift)`` to reach it (needs ``bias_tail_mass``).
        Values above 0.5 are strictly more conservative than the point
        estimate — a single noisy residual can move the posterior mean
        across the drift line, but not drag most of its mass across —
        so tail-mass admission launches fewer, better-justified copies.
    """

    def __init__(self, estimator, tasks: dict[str, SchedTask],
                 task_name: dict[str, str], size: float, grid: GridEngine,
                 runtime_fn, *, online: bool = True,
                 confidence: float = 0.9, risk_k: float = 0.0,
                 replan_cooldown: int = 0, speculate: bool = True,
                 spec_k: float = 2.0, bias_drift: float = 1.15,
                 spec_tail: float | None = None):
        if spec_tail is not None and not 0.0 < spec_tail < 1.0:
            raise ValueError(f"spec_tail must be in (0, 1), got {spec_tail}")
        self.est = estimator
        self.tasks = tasks
        self.task_name = task_name
        self.size = float(size)
        self.grid = grid
        self.runtime_fn = runtime_fn
        self.online = online
        self.confidence = confidence
        self.risk_k = risk_k
        self.replan_cooldown = replan_cooldown
        self.speculate = speculate
        self.spec_k = spec_k
        self.bias_drift = bias_drift
        self.spec_tail = spec_tail
        self.node_names = grid.names()
        # stable node-type column order for the estimate matrix
        seen: dict[str, None] = {}
        for n in self.node_names:
            seen.setdefault(grid.type_of(n).name)
        self.type_names = list(seen)
        self._type_idx = {t: j for j, t in enumerate(self.type_names)}
        self._col = np.array([self._type_idx[grid.type_of(n).name]
                              for n in self.node_names])
        self._row = {}   # instance id -> estimator row
        task_rows = {nm: i for i, nm in enumerate(estimator.task_names())}
        for tid, nm in task_name.items():
            self._row[tid] = task_rows[nm]

    # ---- planning ---------------------------------------------------------
    def _estimates(self, with_std: bool = True):
        """Current (abstract-task × node-type) mean/std matrices.  After an
        ``observe`` only the dirty row is recomputed (matrix row cache).
        ``with_std=False`` returns ``(mean, None)`` and skips the bias
        widening — the mean-only fast path a risk-neutral plan takes."""
        return self.est.predict_matrix(self.type_names, self.size,
                                       with_std=with_std)

    def _plan(self, unstarted: list[str], t_now: float,
              ext_finish: dict[str, float]) -> dict[str, list[str]]:
        """(Re-)plan the not-yet-started frontier; returns per-node queues.

        ``ext_finish`` maps done/running predecessors to their (actual or
        expected) finish times — they become ``task_ready`` floors, and the
        grid's busy-until times become ``node_ready`` floors, so the plan
        never assumes a busy node or an unfinished input."""
        if not unstarted:
            return {n: [] for n in self.node_names}
        # risk-neutral plans consume only the means: skip the bias-widened
        # std entirely (with_std=False) instead of computing and dropping it
        mean, std = self._estimates(with_std=self.risk_k > 0)
        idx = {tid: i for i, tid in enumerate(unstarted)}
        succ = [[idx[s] for s in self.tasks[tid].succ if s in idx]
                for tid in unstarted]
        pred = [[idx[p] for p in self.tasks[tid].pred if p in idx]
                for tid in unstarted]
        rows = np.array([self._row[tid] for tid in unstarted])
        cost = mean[rows][:, self._col]
        unc = std[rows][:, self._col] if self.risk_k > 0 else None
        task_ready = np.array([
            max((ext_finish.get(p, t_now)
                 for p in self.tasks[tid].pred if p not in idx),
                default=t_now)
            for tid in unstarted])
        task_ready = np.maximum(task_ready, t_now)
        sched = heft_schedule_array(
            succ, pred, cost, unc, self.risk_k,
            node_ready=self.grid.ready_vector(t_now),
            task_ready=task_ready)
        queues: dict[str, list[str]] = {n: [] for n in self.node_names}
        for i in sched["order"]:
            queues[self.node_names[sched["assignment"][i]]].append(
                unstarted[int(i)])
        return queues

    # ---- the loop ---------------------------------------------------------
    def run(self) -> ExecutionTrace:
        trace = ExecutionTrace()
        done: dict[str, float] = {}
        expected_finish: dict[str, float] = {}
        started: set[str] = set()
        heap: list[tuple[float, int, str, str]] = []
        seq = 0
        t = 0.0
        cooldown = 0
        queues = self._plan(list(self.tasks), t, {})
        mean, std = self._estimates()
        rec_idx: dict[str, int] = {}            # task id -> trace.records slot
        running: dict[str, list[tuple[str, float]]] = {}   # active attempts
        spec_run: dict[str, TaskRun] = {}       # pending copy's TaskRun
        speculated: set[str] = set()

        def dispatch(t_now: float) -> bool:
            nonlocal seq
            progressed = False
            for node in self.grid.idle(t_now):
                q = queues[node]
                pick = next((tid for tid in q
                             if all(p in done
                                    for p in self.tasks[tid].pred)), None)
                if pick is None:
                    continue
                q.remove(pick)
                started.add(pick)
                dur = float(self.runtime_fn(pick, node))
                end = t_now + dur
                self.grid.occupy(node, end)
                heapq.heappush(heap, (end, seq, pick, node))
                seq += 1
                running[pick] = [(node, end)]
                r, c = self._row[pick], self._type_idx[
                    self.grid.type_of(node).name]
                expected_finish[pick] = t_now + float(mean[r, c])
                rec_idx[pick] = len(trace.records)
                trace.records.append(TaskRun(
                    id=pick, name=self.task_name[pick], node=node,
                    node_type=self.grid.type_of(node).name,
                    start=t_now, end=end, runtime=dur,
                    pred_mean=float(mean[r, c]), pred_std=float(std[r, c])))
                progressed = True
            return progressed

        def speculate_stragglers(t_now: float) -> None:
            """Bias-coupled straggler mitigation: the surprise gate already
            told us a node is systematically slow for a task (its bias
            posterior drifted high) — so a still-running instance of that
            pair that has outrun its dispatch-time envelope gets a copy on
            the best idle node, instead of only re-planning work that has
            not started yet.  First finish wins; the loser is killed and
            its node freed at that moment.

            Admission: the point-estimate drift check by default, or —
            when ``spec_tail`` is set — the posterior tail mass
            ``P(bias > bias_drift) >= spec_tail``, which no single noisy
            residual can satisfy."""
            bias_point = getattr(self.est, "bias_point", None)
            tail_mass = getattr(self.est, "bias_tail_mass", None)
            if self.spec_tail is not None:
                if tail_mass is None:
                    return
            elif bias_point is None:
                return
            nonlocal seq
            for tid, attempts in list(running.items()):
                if tid in done or tid in speculated or len(attempts) != 1:
                    continue
                rec = trace.records[rec_idx[tid]]
                envelope = rec.pred_mean + self.spec_k * max(
                    rec.pred_std, 1e-9)
                if t_now < rec.start + envelope:
                    continue                      # not overdue yet
                if self.spec_tail is not None:
                    if tail_mass(rec.name, rec.node_type,
                                 self.bias_drift) < self.spec_tail:
                        continue    # posterior mass not behind the drift
                elif bias_point(rec.name, rec.node_type) < self.bias_drift:
                    continue                      # node not drifted for it
                node = attempts[0][0]
                idle = [n for n in self.grid.idle(t_now) if n != node]
                if not idle:
                    continue
                r = self._row[tid]
                # the copy's landing spot is priced with the same risk
                # aversion as the plan: a low-mean but still-uncertain
                # node is a bad place to park a rescue attempt
                alt = min(idle, key=lambda n: mean[
                    r, self._type_idx[self.grid.type_of(n).name]]
                    + self.risk_k * std[
                        r, self._type_idx[self.grid.type_of(n).name]])
                dur = float(self.runtime_fn(tid, alt))
                end = t_now + dur
                self.grid.occupy(alt, end)
                heapq.heappush(heap, (end, seq, tid, alt))
                seq += 1
                running[tid].append((alt, end))
                speculated.add(tid)
                c = self._type_idx[self.grid.type_of(alt).name]
                spec_run[tid] = TaskRun(
                    id=tid, name=self.task_name[tid], node=alt,
                    node_type=self.grid.type_of(alt).name,
                    start=t_now, end=end, runtime=dur,
                    pred_mean=float(mean[r, c]), pred_std=float(std[r, c]))
                expected_finish[tid] = min(expected_finish[tid],
                                           t_now + float(mean[r, c]))
                trace.speculations += 1

        while len(done) < len(self.tasks):
            while dispatch(t):
                pass
            if not heap:
                missing = [tid for tid in self.tasks if tid not in done]
                raise RuntimeError(
                    f"execution stalled with {len(missing)} tasks blocked "
                    "(cyclic dependencies or unassigned tasks?)")
            end, _, tid, node = heapq.heappop(heap)
            if tid in done:
                continue                 # stale event of a killed attempt
            t = end
            # batch every completion landing on this tick: multi-node
            # observations arriving together are absorbed by ONE scanned
            # estimator update instead of per-observation calls
            completions = [(tid, node, end)]
            seen = {tid}
            while heap and heap[0][0] <= t + 1e-12:
                e2, _, tid2, node2 = heapq.heappop(heap)
                if tid2 in done or tid2 in seen:
                    continue             # stale, or a same-tick lost twin
                completions.append((tid2, node2, e2))
                seen.add(tid2)
            for ctid, cnode, cend in completions:
                done[ctid] = cend
                # resolve the speculative race: kill the other attempts,
                # free their nodes NOW, and let the winning run's record
                # stand (predictions are the dispatch-time belief of the
                # attempt that actually finished)
                for n2, e2 in running.pop(ctid, []):
                    if n2 != cnode:
                        self.grid.release(n2, cend)
                sr = spec_run.pop(ctid, None)
                if sr is not None and sr.node == cnode:
                    trace.records[rec_idx[ctid]] = sr
                    trace.spec_wins += 1
            cooldown = max(0, cooldown - len(completions))
            if self.online:
                # surprise gates BEFORE the update: was each realised
                # runtime outside what the dispatch-time posterior (the
                # tick-start belief) considered likely?
                batch = []
                gates = []
                for ctid, cnode, _ in completions:
                    run = trace.records[rec_idx[ctid]]
                    name = self.task_name[ctid]
                    ntype = self.grid.type_of(cnode).name
                    lo, hi = self.est.predict_interval_node(
                        name, ntype, self.size, self.confidence)
                    gates.append(not (lo <= run.runtime <= hi))
                    batch.append((name, ntype, self.size, run.runtime))
                local_rts = self.est.observe_batch(batch)
                for (name, ntype, _, runtime), local_rt in zip(batch,
                                                               local_rts):
                    trace.observations.record(name, ntype, self.size,
                                              runtime, local_rt, time=t)
                mean, std = self._estimates()     # dirty-row refresh only
                trace.surprises += sum(gates)
                unstarted = [x for x in self.tasks
                             if x not in started and x not in done]
                if any(gates) and unstarted and cooldown == 0:
                    ext = {**done, **{k: max(v, t)
                                      for k, v in expected_finish.items()
                                      if k not in done}}
                    queues = self._plan(unstarted, t, ext)
                    trace.replans += 1
                    cooldown = self.replan_cooldown
                if self.speculate:
                    speculate_stragglers(t)
        trace.makespan = max(done.values()) if done else 0.0
        return trace


def fanout_chain_dag(chain: list[str], n_samples: int
                     ) -> tuple[dict[str, SchedTask], dict[str, str]]:
    """Physical workflow: ``n_samples`` inputs each flowing through the
    abstract task ``chain`` (parallel across samples, sequential within).
    Returns (instance DAG, instance id → abstract task name) — the two
    structures ``OnlineExecutor`` consumes.  Instance ids are
    ``s<sample>.<task>``."""
    tasks: dict[str, SchedTask] = {}
    task_name: dict[str, str] = {}
    for s in range(n_samples):
        prev = None
        for nm in chain:
            tid = f"s{s}.{nm}"
            tasks[tid] = SchedTask(id=tid)
            task_name[tid] = nm
            if prev is not None:
                tasks[tid].pred.append(prev)
                tasks[prev].succ.append(tid)
            prev = tid
    return tasks, task_name


def run_static_and_online(make_executor) -> tuple[ExecutionTrace,
                                                  ExecutionTrace]:
    """Convenience: run the same scenario twice — frozen initial plan vs
    the full observe/re-plan loop.  ``make_executor(online)`` must build a
    fresh executor (estimator state is mutated by the online run)."""
    static = make_executor(online=False).run()
    online = make_executor(online=True).run()
    return static, online
