"""Online estimation subsystem (beyond-paper phase 5).

Closes the loop the paper leaves open: incremental conjugate posterior
updates (``repro.core.blr.update_task_batch``), an observation stream fed
back through ``LotaruEstimator.observe`` / ``LotaruML.observe``, and an
event-driven execution engine that interleaves run → observe → re-predict
→ re-schedule over grid-engine-style heterogeneous nodes.
"""
from .buffer import Observation, ObservationBuffer
from .executor import (CensoredRun, ExecutionTrace, OnlineExecutor, TaskRun,
                       fanout_chain_dag, run_static_and_online)
from .fleet import (FleetState, fleet_predict, fleet_slice, fleet_tick_step,
                    pad_obs, pad_state, shard_fleet, stack_states)

__all__ = ["Observation", "ObservationBuffer", "CensoredRun",
           "ExecutionTrace", "OnlineExecutor", "TaskRun",
           "fanout_chain_dag", "run_static_and_online", "FleetState",
           "fleet_predict", "fleet_slice", "fleet_tick_step", "pad_obs",
           "pad_state", "shard_fleet", "stack_states"]
