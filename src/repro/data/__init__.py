from .synthetic import (DAG_SCHEMA_VERSION, SyntheticDAG, SyntheticLMData,
                        synthetic_dag)

__all__ = ["DAG_SCHEMA_VERSION", "SyntheticDAG", "SyntheticLMData",
           "synthetic_dag"]
