from .synthetic import SyntheticLMData

__all__ = ["SyntheticLMData"]
