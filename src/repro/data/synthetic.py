"""Deterministic synthetic data: LM token pipeline + workflow DAGs.

Batches are a pure function of (seed, step): restart-safe (a restored run
at step N sees exactly the token stream an uninterrupted run would have),
host-shardable (each host materialises only its batch rows — the
``host_slice`` arguments model per-host sharding even though this container
is single-process), and family-aware (vision/audio stubs for the VLM and
enc-dec archs).

The "dataset downsampling" used by Lotaru's local phase is just a smaller
(seq, batch) request — token streams have no file-format coupling.

``synthetic_dag`` is the scheduler-side counterpart: a WfCommons-style
layered workflow generator (seeded; width/depth/fan-out/data-size
distributions) that scales past 10k tasks — the stress harness for
data-aware HEFT and the sample source for the hypothesis oracle suite.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ModelConfig


@dataclass(frozen=True)
class SyntheticLMData:
    cfg: ModelConfig
    seq: int
    global_batch: int
    seed: int = 0

    def batch(self, step: int, host_index: int = 0, host_count: int = 1) -> dict:
        b = self.global_batch // host_count
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, host_index]))
        tokens = rng.integers(0, self.cfg.vocab, (b, self.seq),
                              dtype=np.int32)
        # next-token labels over a repeating-pattern stream: learnable signal
        pattern = (np.arange(self.seq, dtype=np.int32)[None, :]
                   + rng.integers(0, 97, (b, 1), dtype=np.int32)) % 97
        tokens = (tokens % 7) * 97 // 7 + pattern % 7  # mixture, in-vocab
        tokens = tokens.astype(np.int32) % self.cfg.vocab
        labels = np.roll(tokens, -1, axis=1)
        out = {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)}
        if self.cfg.family == "vlm":
            nv = max(2, self.seq // 8)
            out["vision_embeds"] = jnp.asarray(
                rng.normal(0, 0.1, (b, nv, self.cfg.d_model)), jnp.bfloat16)
            T = self.seq + nv
            pos = np.broadcast_to(np.arange(T, dtype=np.int32)[None, :, None],
                                  (b, T, 3))
            out["positions"] = jnp.asarray(pos)
        if self.cfg.family == "encdec":
            out["src_embeds"] = jnp.asarray(
                rng.normal(0, 0.1, (b, self.seq, self.cfg.d_model)),
                jnp.float32)
        return out


# ---------------------------------------------------------------------------
# WfCommons-style synthetic workflow DAGs (scheduler stress + property tests)
# ---------------------------------------------------------------------------
DAG_SCHEMA_VERSION = 1


class SyntheticDAG:
    """An immutable task DAG with per-edge data volumes and per-task work.

    ``succ`` / ``pred`` are index-based adjacency lists (mirror-consistent
    by construction contract — validated), ``data_gb[t]`` is aligned with
    ``pred[t]`` (GB arriving along each in-edge), ``work[t]`` the task's
    abstract compute demand in reference-seconds.  The layout matches what
    ``repro.sched.heft.CommCosts`` and ``heft_schedule_array`` consume
    directly, so a 10k-task instance never materialises a (T, T) matrix.
    """

    def __init__(self, succ: list[list[int]], pred: list[list[int]],
                 data_gb: list[list[float]], work,
                 params: dict | None = None):
        T = len(succ)
        if len(pred) != T:
            raise ValueError(f"succ has {T} tasks but pred has {len(pred)}")
        if len(data_gb) != T:
            raise ValueError(f"data_gb has {len(data_gb)} rows for {T} tasks")
        for t in range(T):
            if len(data_gb[t]) != len(pred[t]):
                raise ValueError(
                    f"data_gb[{t}] has {len(data_gb[t])} entries but task "
                    f"{t} has {len(pred[t])} predecessors")
            for g in data_gb[t]:
                if g < 0:
                    raise ValueError(f"data_gb: negative data size {g} on "
                                     f"an edge into task {t}")
        # mirror consistency: (p -> t) in succ[p] iff p in pred[t]
        fwd = {(p, t) for t in range(T) for p in pred[t]}
        bwd = {(t, s) for t in range(T) for s in succ[t]}
        if fwd != bwd:
            bad = sorted(fwd.symmetric_difference(bwd))[:3]
            raise ValueError(f"succ/pred adjacency is not mirror-consistent "
                             f"(first mismatches: {bad})")
        for t in range(T):
            for s in succ[t]:
                if not 0 <= s < T:
                    raise ValueError(f"edge ({t}, {s}) references a task "
                                     f"outside 0..{T - 1}")
        # cycle check (raises ValueError naming the cycle) — reuse the
        # scheduler's Kahn pass so "valid DAG" means the same thing in
        # both layers
        from repro.sched.heft import _topo_order
        _topo_order(succ, pred)
        w = np.asarray(work, np.float64)
        if w.shape != (T,):
            raise ValueError(f"work must be shape ({T},), got {w.shape}")
        if (w < 0).any():
            raise ValueError("work has negative entries")
        self.succ = succ
        self.pred = pred
        self.data_gb = data_gb
        self.work = w
        self.params = dict(params or {})

    @property
    def n_tasks(self) -> int:
        return len(self.succ)

    @property
    def n_edges(self) -> int:
        return sum(len(s) for s in self.succ)

    def edge_dict(self) -> dict[tuple[int, int], float]:
        """``(producer, consumer) -> GB`` — the sparse ``CommCosts``
        input form."""
        return {(p, t): float(g)
                for t in range(self.n_tasks)
                for p, g in zip(self.pred[t], self.data_gb[t])}

    def cost_matrix(self, speeds) -> np.ndarray:
        """(T, N) runtime estimates: ``work[t] / speeds[n]`` — the
        minimal heterogeneous-cluster cost model for scheduler benches
        (``speeds`` in reference-machine multiples, all > 0)."""
        sp = np.asarray(speeds, np.float64)
        if sp.ndim != 1 or (sp <= 0).any():
            raise ValueError("speeds must be a 1-D vector of positive "
                             "node speed multipliers")
        return self.work[:, None] / sp[None, :]

    def to_dict(self) -> dict:
        """JSON-safe serialisation: edges as flat ``[producer, consumer,
        gb]`` triples (10k-task DAGs stay linear in E, never (T, T))."""
        return {"version": DAG_SCHEMA_VERSION,
                "params": dict(self.params),
                "n_tasks": self.n_tasks,
                "edges": [[p, t, float(g)]
                          for t in range(self.n_tasks)
                          for p, g in zip(self.pred[t], self.data_gb[t])],
                "work": [float(w) for w in self.work]}

    @classmethod
    def from_dict(cls, d: dict) -> "SyntheticDAG":
        if d.get("version", 0) >= 1:
            T = int(d["n_tasks"])
            succ: list[list[int]] = [[] for _ in range(T)]
            pred: list[list[int]] = [[] for _ in range(T)]
            data_gb: list[list[float]] = [[] for _ in range(T)]
            for p, t, g in d["edges"]:
                succ[int(p)].append(int(t))
                pred[int(t)].append(int(p))
                data_gb[int(t)].append(float(g))
            return cls(succ, pred, data_gb, d["work"],
                       params=d.get("params"))
        raise ValueError(f"unknown SyntheticDAG schema version "
                         f"{d.get('version')!r}")


def synthetic_dag(width: int = 8, depth: int = 10, fanout: float = 2.0,
                  data_gb_mean: float = 1.0, data_gb_sigma: float = 0.75,
                  work_mean: float = 60.0, work_sigma: float = 0.6,
                  seed: int = 0) -> SyntheticDAG:
    """Generate a layered WfCommons-style workflow DAG.

    ``depth`` layers of ~``width`` tasks each (layer sizes jitter in
    [ceil(width/2), width]); every non-root task draws ``k ~ 1 +
    Poisson(fanout - 1)`` predecessors from the previous layer, so
    ``fanout`` is the mean in-degree and E stays O(T · fanout) — the
    bounded-degree regime where the comm-aware EFT loop is O(T·N).
    Per-edge volumes are lognormal(ln ``data_gb_mean``,
    ``data_gb_sigma``) — heavy-tailed like real intermediate files —
    and per-task work lognormal(ln ``work_mean``, ``work_sigma``).

    Same (seed, params) → bit-identical DAG (structure, sizes, work):
    draws come from one ``np.random.default_rng(seed)`` stream in a
    fixed order.  Degenerate parameters raise ``ValueError`` naming the
    offending parameter.
    """
    if width < 1:
        raise ValueError(f"width must be >= 1, got {width}")
    if depth < 1:
        raise ValueError(f"depth must be >= 1, got {depth}")
    if fanout < 1.0:
        raise ValueError(f"fanout must be >= 1.0 (mean in-degree), "
                         f"got {fanout}")
    if data_gb_mean <= 0:
        raise ValueError(f"data_gb_mean must be > 0, got {data_gb_mean}")
    if data_gb_sigma < 0:
        raise ValueError(f"data_gb_sigma must be >= 0, got {data_gb_sigma}")
    if work_mean <= 0:
        raise ValueError(f"work_mean must be > 0, got {work_mean}")
    if work_sigma < 0:
        raise ValueError(f"work_sigma must be >= 0, got {work_sigma}")
    rng = np.random.default_rng(seed)
    lo = (width + 1) // 2
    sizes = [int(rng.integers(lo, width + 1)) for _ in range(depth)]
    layers: list[list[int]] = []
    nxt = 0
    for sz in sizes:
        layers.append(list(range(nxt, nxt + sz)))
        nxt += sz
    T = nxt
    succ: list[list[int]] = [[] for _ in range(T)]
    pred: list[list[int]] = [[] for _ in range(T)]
    data_gb: list[list[float]] = [[] for _ in range(T)]
    for li in range(1, depth):
        prev = layers[li - 1]
        for t in layers[li]:
            k = min(len(prev), 1 + int(rng.poisson(fanout - 1.0)))
            ps = sorted(int(p) for p in
                        rng.choice(prev, size=k, replace=False))
            for p in ps:
                succ[p].append(t)
                pred[t].append(p)
                data_gb[t].append(float(rng.lognormal(
                    np.log(data_gb_mean), data_gb_sigma)))
    work = rng.lognormal(np.log(work_mean), work_sigma, size=T)
    params = {"width": width, "depth": depth, "fanout": fanout,
              "data_gb_mean": data_gb_mean, "data_gb_sigma": data_gb_sigma,
              "work_mean": work_mean, "work_sigma": work_sigma, "seed": seed}
    return SyntheticDAG(succ, pred, data_gb, work, params=params)
