"""Deterministic synthetic LM data pipeline.

Batches are a pure function of (seed, step): restart-safe (a restored run
at step N sees exactly the token stream an uninterrupted run would have),
host-shardable (each host materialises only its batch rows — the
``host_slice`` arguments model per-host sharding even though this container
is single-process), and family-aware (vision/audio stubs for the VLM and
enc-dec archs).

The "dataset downsampling" used by Lotaru's local phase is just a smaller
(seq, batch) request — token streams have no file-format coupling.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ModelConfig


@dataclass(frozen=True)
class SyntheticLMData:
    cfg: ModelConfig
    seq: int
    global_batch: int
    seed: int = 0

    def batch(self, step: int, host_index: int = 0, host_count: int = 1) -> dict:
        b = self.global_batch // host_count
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, host_index]))
        tokens = rng.integers(0, self.cfg.vocab, (b, self.seq),
                              dtype=np.int32)
        # next-token labels over a repeating-pattern stream: learnable signal
        pattern = (np.arange(self.seq, dtype=np.int32)[None, :]
                   + rng.integers(0, 97, (b, 1), dtype=np.int32)) % 97
        tokens = (tokens % 7) * 97 // 7 + pattern % 7  # mixture, in-vocab
        tokens = tokens.astype(np.int32) % self.cfg.vocab
        labels = np.roll(tokens, -1, axis=1)
        out = {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)}
        if self.cfg.family == "vlm":
            nv = max(2, self.seq // 8)
            out["vision_embeds"] = jnp.asarray(
                rng.normal(0, 0.1, (b, nv, self.cfg.d_model)), jnp.bfloat16)
            T = self.seq + nv
            pos = np.broadcast_to(np.arange(T, dtype=np.int32)[None, :, None],
                                  (b, T, 3))
            out["positions"] = jnp.asarray(pos)
        if self.cfg.family == "encdec":
            out["src_embeds"] = jnp.asarray(
                rng.normal(0, 0.1, (b, self.seq, self.cfg.d_model)),
                jnp.float32)
        return out
