"""Human-readable rendering of a saved trace.

``render_report`` turns an event stream (live ``EventLog`` or events
loaded from a JSONL trace) into the summary a person actually wants when
a run looks wrong: what happened (counters), whether the uncertainty can
be trusted (calibration table + PIT histogram), where the wall time went
(per-phase latency, compile vs steady state, slowest ticks), and the
chronological fault/retry narrative.  ``scripts/report_trace.py`` is the
CLI wrapper; ``report_dict`` is the machine-readable twin CI archives
next to the trace.
"""
from __future__ import annotations

import math

from .calibration import calibration_summary
from .profiling import phase_breakdown, slowest_spans, tick_latency_summary
from .registry import MetricsRegistry


def _fmt(v, unit: str = "", prec: int = 3) -> str:
    if v is None or (isinstance(v, float) and not math.isfinite(v)):
        return "-"
    if isinstance(v, float):
        return f"{v:.{prec}g}{unit}"
    return f"{v}{unit}"


def _fmt_s(v) -> str:
    """Engineering-format seconds (ms/us below 1s)."""
    if v is None or not math.isfinite(v):
        return "-"
    if v >= 1.0:
        return f"{v:.2f}s"
    if v >= 1e-3:
        return f"{v * 1e3:.2f}ms"
    return f"{v * 1e6:.0f}us"


def _pit_bar(counts: list[int], width: int = 30) -> list[str]:
    total = sum(counts) or 1
    peak = max(counts) or 1
    return [f"{'#' * max(1, round(width * c / peak)) if c else '':<{width}}"
            f" {c:4d} ({c / total:5.1%})" for c in counts]


def report_dict(events, min_obs: int = 20) -> dict:
    """Machine-readable report: metrics roll-up, calibration summary,
    latency breakdown, slowest spans, fault narrative."""
    narrative = []
    for e in events:
        kind = e.kind if hasattr(e, "kind") else e.get("kind")
        if kind in ("fault", "retry", "node_down", "node_up", "stranded"):
            d = dict(e.data) if hasattr(e, "data") else dict(e)
            d.pop("t_wall", None)
            narrative.append({"t_sim": getattr(e, "t_sim", d.pop("t_sim", 0.0)),
                              "kind": kind, **d})
    return {
        "metrics": MetricsRegistry.from_events(events).to_dict(),
        "calibration": calibration_summary(events, min_obs=min_obs),
        "latency": tick_latency_summary(events),
        "slowest_spans": slowest_spans(events),
        "fault_narrative": narrative,
    }


def render_report(events, min_obs: int = 20) -> str:
    """The human-readable report (one plain-text block)."""
    events = list(events)
    lines: list[str] = []
    reg = MetricsRegistry.from_events(events).to_dict()

    # ---- header: run configuration ---------------------------------------
    start = next((e for e in events
                  if (e.kind if hasattr(e, "kind") else e.get("kind"))
                  == "run_start"), None)
    lines.append("=" * 64)
    lines.append("TRACE REPORT")
    lines.append("=" * 64)
    if start is not None:
        d = start.data if hasattr(start, "data") else start
        cfg = ", ".join(f"{k}={v}" for k, v in sorted(d.items()))
        lines.append(f"run config: {cfg}")
    final = reg["gauges"]
    if final:
        lines.append("final state: " + ", ".join(
            f"{k.removeprefix('final.')}={_fmt(v)}"
            for k, v in final.items()))
    lines.append("")

    # ---- counters ----------------------------------------------------------
    lines.append("-- event counters " + "-" * 46)
    counters = {k.removeprefix("events."): v
                for k, v in reg["counters"].items()}
    for k in sorted(counters):
        lines.append(f"  {k:<14s} {counters[k]:6d}")
    lines.append("")

    # ---- calibration -------------------------------------------------------
    cal = calibration_summary(events, min_obs=min_obs)
    lines.append("-- calibration (predictive intervals) " + "-" * 26)
    if cal["n_obs"] == 0:
        lines.append("  no observe events in this trace")
    else:
        lines.append(
            f"  observations: {cal['n_obs']} "
            f"({cal['n_post_warmup']} after the {cal['min_obs']}-obs "
            "warm-up)")
        lines.append(
            f"  coverage      post-warmup {_fmt(cal['coverage'], prec=4)}"
            f"   all {_fmt(cal['coverage_all'], prec=4)}")
        lines.append(
            f"  sharpness     post-warmup {_fmt(cal['sharpness'])}s"
            f"   relative {_fmt(cal['sharpness_rel'])}")
        lines.append(
            f"  PIT dist-from-uniform (TV): {_fmt(cal['pit_tv'])}")
        cov0, cov1 = cal["coverage_timeline_first_last"]
        mpe0, mpe1 = cal["mpe_timeline_first_last"]
        lines.append(f"  coverage timeline {_fmt(cov0, prec=4)} -> "
                     f"{_fmt(cov1, prec=4)}   cumulative MPE "
                     f"{_fmt(mpe0)} -> {_fmt(mpe1)}")
        if cal["n_post_warmup"]:
            lines.append("  PIT histogram (post-warm-up, 10 bins over "
                         "[0, 1]):")
            for i, bar in enumerate(_pit_bar(cal["pit_hist"])):
                lo, hi = cal["pit_edges"][i], cal["pit_edges"][i + 1]
                lines.append(f"    [{lo:.1f},{hi:.1f}) {bar}")
    lines.append("")

    # ---- latency -----------------------------------------------------------
    lines.append("-- latency (wall clock, compile vs steady state) "
                 + "-" * 15)
    phases = phase_breakdown(events)
    if not phases:
        lines.append("  no span events in this trace")
    else:
        lines.append(f"  {'phase':<16s} {'count':>5s} {'first':>9s} "
                     f"{'steady p50':>10s} {'steady max':>10s} "
                     f"{'total':>9s}")
        for phase in sorted(phases, key=lambda p: -phases[p]["total_s"]):
            p = phases[phase]
            lines.append(
                f"  {phase:<16s} {p['count']:>5d} {_fmt_s(p['first_s']):>9s} "
                f"{_fmt_s(p['steady_p50_s']):>10s} "
                f"{_fmt_s(p['steady_max_s']):>10s} "
                f"{_fmt_s(p['total_s']):>9s}")
        summ = tick_latency_summary(events)
        lines.append(
            f"  compile share {_fmt(summ['compile_frac'], prec=3)} of "
            f"{_fmt_s(summ['traced_total_s'])} traced; steady-state tick "
            f"~{_fmt_s(summ['steady_tick_s'])}")
        slow = slowest_spans(events, 5)
        if slow:
            lines.append("  slowest spans:")
            for s in slow:
                extra = {k: v for k, v in s.items()
                         if k not in ("phase", "dur_s", "t_sim", "t_wall")}
                extra_s = f"  {extra}" if extra else ""
                lines.append(
                    f"    {_fmt_s(s['dur_s']):>9s}  {s['phase']:<16s}"
                    f"t_sim={_fmt(s.get('t_sim', 0.0), prec=5)}{extra_s}")
    lines.append("")

    # ---- fault narrative ---------------------------------------------------
    churn = [e for e in events
             if (e.kind if hasattr(e, "kind") else e.get("kind"))
             in ("fault", "retry", "node_down", "node_up", "stranded")]
    lines.append("-- fault / retry narrative " + "-" * 37)
    if not churn:
        lines.append("  clean run: no faults, retries or node churn")
    else:
        for e in churn:
            kind = e.kind if hasattr(e, "kind") else e.get("kind")
            d = dict(e.data) if hasattr(e, "data") else dict(e)
            t = getattr(e, "t_sim", d.pop("t_sim", 0.0))
            detail = ", ".join(f"{k}={_fmt(v, prec=4)}"
                               for k, v in sorted(d.items()))
            lines.append(f"  t={t:10.2f}  {kind:<10s} {detail}")
    lines.append("=" * 64)
    return "\n".join(lines)
