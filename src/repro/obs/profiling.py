"""Latency profiling over the span events of a trace.

The online tick's wall-clock cost hides three very different phases — the
jitted ``predict_matrix`` dispatch, the conjugate update stream, and the
``heft_schedule_array`` re-plan — and each pays a large one-off XLA
compile on its first call.  Averaging compile into steady state makes
every latency number a lie, so the breakdown here splits them: per phase,
the first span is reported as ``first_s`` (compile + execute) and the
rest as steady-state statistics.  ``bench_online`` records this breakdown
into ``BENCH_online.json``; ROADMAP item 1 (tick latency at the ~1M-cell
scale) gates on it.
"""
from __future__ import annotations

import numpy as np


def _span_payloads(events, phase: str | None = None) -> list[dict]:
    out = []
    for e in events:
        kind = e.kind if hasattr(e, "kind") else e.get("kind")
        if kind != "span":
            continue
        d = dict(e.data) if hasattr(e, "data") else dict(e)
        if phase is None or d.get("phase") == phase:
            if hasattr(e, "t_sim"):
                d.setdefault("t_sim", e.t_sim)
                d.setdefault("t_wall", e.t_wall)
            out.append(d)
    return out


def phase_breakdown(events) -> dict[str, dict]:
    """Per-phase wall-time statistics with the compile call split out.

    Returns ``{phase: {count, first_s, steady_mean_s, steady_p50_s,
    steady_max_s, steady_total_s, total_s}}``.  ``first_s`` is the
    phase's first span (jit compile + execute for the jitted phases);
    the ``steady_*`` statistics cover every later span — NaN when the
    phase ran only once.  Spans are grouped in stream order, which is
    wall-clock order for a single-threaded loop.
    """
    by_phase: dict[str, list[float]] = {}
    for d in _span_payloads(events):
        by_phase.setdefault(str(d.get("phase", "?")), []).append(
            float(d.get("dur_s", 0.0)))
    out: dict[str, dict] = {}
    for phase, durs in by_phase.items():
        steady = np.array(durs[1:], np.float64)
        out[phase] = {
            "count": len(durs),
            "first_s": durs[0],
            "steady_mean_s": float(steady.mean()) if steady.size else
            float("nan"),
            "steady_p50_s": float(np.median(steady)) if steady.size else
            float("nan"),
            "steady_max_s": float(steady.max()) if steady.size else
            float("nan"),
            "steady_total_s": float(steady.sum()),
            "total_s": float(sum(durs)),
        }
    return out


def slowest_spans(events, n: int = 5) -> list[dict]:
    """The ``n`` slowest spans of the trace (phase, dur_s, t_sim, extra
    payload), slowest first — the "which tick hurt" view."""
    spans = _span_payloads(events)
    spans.sort(key=lambda d: -float(d.get("dur_s", 0.0)))
    return spans[:n]


def tick_latency_summary(events) -> dict:
    """One roll-up for benchmarks: the per-phase breakdown plus the
    total traced wall time, the compile share, and the steady-state
    per-tick cost (sum of every phase's steady mean — the cost of one
    fully-instrumented observe → re-predict → re-plan tick once all
    executables are compiled)."""
    phases = phase_breakdown(events)
    total = sum(p["total_s"] for p in phases.values())
    first = sum(p["first_s"] for p in phases.values())
    steady_tick = sum(p["steady_mean_s"] for p in phases.values()
                     if np.isfinite(p["steady_mean_s"]))
    return {
        "phases": phases,
        "traced_total_s": total,
        "compile_total_s": first,
        "compile_frac": first / total if total > 0 else float("nan"),
        "steady_tick_s": steady_tick,
    }
