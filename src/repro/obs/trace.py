"""Structured event tracing for the online estimator loop.

The observe → re-predict → re-plan tick was a black box beyond a dozen
integer counters on ``ExecutionTrace``; this module makes it inspectable.
``OnlineExecutor``, ``GridEngine`` and both estimator planes emit typed
events through a ``Tracer`` — a two-method protocol (``emit`` for instant
events, ``span`` for wall-clock-timed regions) — and the concrete
``EventLog`` collects them append-only with both clocks attached: the
simulation time the event refers to and the wall time it was recorded at.

Tracing is strictly read-only: an attached tracer observes the loop, it
never perturbs it (``tests/test_obs.py`` proves the executor's output is
bit-identical with and without one).  With no tracer attached every site
goes through the shared ``NULL_TRACER`` singleton, whose ``emit`` is a
bare ``pass`` and whose ``span`` hands back one reusable no-op context
manager — the disabled path costs attribute lookups, nothing else.

Export formats:

* ``to_jsonl`` / ``load_jsonl`` — one JSON object per line, the stable
  machine-readable substrate every diagnostic in ``repro.obs`` consumes;
* ``to_chrome`` — Chrome ``trace_event`` JSON: open it in Perfetto
  (https://ui.perfetto.dev) or ``chrome://tracing`` and the run renders
  as two process tracks — host wall-clock spans (plan / predict /
  update), and the simulation clock with one thread lane per node
  showing every task attempt as a duration slice, with faults, retries
  and speculations as instant markers.
"""
from __future__ import annotations

import json
import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from pathlib import Path
from typing import Iterable, Iterator, Protocol, runtime_checkable

#: trace file format version, stamped into every JSONL export
TRACE_FORMAT_VERSION = 1

#: the closed event taxonomy (see docs/architecture.md for field maps).
#: ``emit`` warns on anything else rather than raising — a trace with an
#: unknown event is more useful than an execution killed by telemetry.
EVENT_KINDS = frozenset({
    "run_start",    # loop config snapshot (tasks, nodes, knobs)
    "tick",         # one popped event-heap entry (the loop's heartbeat)
    "plan",         # a (re-)plan of the unstarted frontier
    "dispatch",     # an attempt starts on a node
    "finish",       # an attempt completes (start/end/runtime/prediction)
    "observe",      # a completion fed back to the estimator, with its
                    # dispatch-time interval, coverage flag and PIT
    "predict",      # an estimate-matrix refresh (dirty rows re-predicted)
    "surprise",     # a runtime fell outside its predictive interval
    "speculation",  # a straggler copy was launched
    "fault",        # an attempt was lost (censored observation)
    "retry",        # a lost task re-queued with its backoff delay
    "backoff",      # a backoff window expired (the retry becomes runnable)
    "node_down",    # a node crashed or entered an outage
    "node_up",      # a node rejoined after an outage
    "stranded",     # a task was abandoned (non-strict mode)
    "run_end",      # final counters (makespan, completions, ...)
    "span",         # a wall-clock-timed region (phase + dur_s)
})


@dataclass(frozen=True)
class Event:
    """One trace event: a kind from ``EVENT_KINDS``, the simulation time
    it refers to, the wall time it was recorded at (seconds since the
    log's creation), and a kind-specific payload dict."""
    kind: str
    t_sim: float
    t_wall: float
    data: dict = field(default_factory=dict)

    def to_json(self) -> dict:
        return {"kind": self.kind, "t_sim": self.t_sim,
                "t_wall": self.t_wall, **self.data}

    @classmethod
    def from_json(cls, d: dict) -> "Event":
        d = dict(d)
        return cls(kind=d.pop("kind"), t_sim=float(d.pop("t_sim")),
                   t_wall=float(d.pop("t_wall")), data=d)


class _NullSpan:
    """Reusable no-op context manager (one shared instance, no per-call
    allocation on the disabled path)."""
    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


@runtime_checkable
class Tracer(Protocol):
    """What an instrumented site needs: ``enabled`` to guard payload
    construction, ``emit`` for instant events, ``span`` for timed
    regions.  ``EventLog`` is the collecting implementation;
    ``NullTracer`` the zero-cost disabled one."""
    enabled: bool

    def emit(self, kind: str, t_sim: float = 0.0, **data) -> None: ...

    def span(self, phase: str, t_sim: float = 0.0, **data): ...


class NullTracer:
    """The disabled tracer: ``emit`` is a bare pass, ``span`` returns a
    shared no-op context manager.  All instrumentation sites default to
    the module-level ``NULL_TRACER`` singleton, so untraced execution
    pays only the attribute lookup."""
    enabled = False
    __slots__ = ()

    def emit(self, kind: str, t_sim: float = 0.0, **data) -> None:
        pass

    def span(self, phase: str, t_sim: float = 0.0, **data):
        return _NULL_SPAN


NULL_TRACER = NullTracer()


class EventLog:
    """Append-only typed event log (the concrete ``Tracer``).

    Wall times are seconds since construction (``perf_counter`` deltas),
    so exported traces are machine-relocatable.  The log never mutates
    anything it observes; it only appends."""
    enabled = True

    def __init__(self):
        self.events: list[Event] = []
        self._t0 = time.perf_counter()

    # ---- Tracer protocol ---------------------------------------------------
    def emit(self, kind: str, t_sim: float = 0.0, **data) -> None:
        if kind not in EVENT_KINDS:
            import warnings
            warnings.warn(f"unknown trace event kind {kind!r} (known: "
                          f"{sorted(EVENT_KINDS)})", stacklevel=2)
        self.events.append(Event(kind=kind, t_sim=float(t_sim),
                                 t_wall=time.perf_counter() - self._t0,
                                 data=data))

    @contextmanager
    def span(self, phase: str, t_sim: float = 0.0, **data):
        """Time a region: on exit one ``span`` event is emitted carrying
        ``phase``, the wall duration ``dur_s``, and any extra payload."""
        w0 = time.perf_counter()
        try:
            yield self
        finally:
            self.events.append(Event(
                kind="span", t_sim=float(t_sim),
                t_wall=w0 - self._t0,
                data={"phase": phase,
                      "dur_s": time.perf_counter() - w0, **data}))

    # ---- queries -----------------------------------------------------------
    def __len__(self) -> int:
        return len(self.events)

    def __iter__(self) -> Iterator[Event]:
        return iter(self.events)

    def filter(self, kind: str) -> list[Event]:
        return [e for e in self.events if e.kind == kind]

    def spans(self, phase: str | None = None) -> list[Event]:
        return [e for e in self.events if e.kind == "span"
                and (phase is None or e.data.get("phase") == phase)]

    def counters(self) -> dict[str, int]:
        """Event count per kind (span events additionally broken out per
        phase as ``span:<phase>``)."""
        out: dict[str, int] = {}
        for e in self.events:
            out[e.kind] = out.get(e.kind, 0) + 1
            if e.kind == "span":
                k = f"span:{e.data.get('phase', '?')}"
                out[k] = out.get(k, 0) + 1
        return out

    # ---- export ------------------------------------------------------------
    def to_jsonl(self, path) -> Path:
        """One event per line; first line is a format header."""
        path = Path(path)
        with path.open("w") as f:
            f.write(json.dumps({"trace_format": TRACE_FORMAT_VERSION,
                                "events": len(self.events)}) + "\n")
            for e in self.events:
                f.write(json.dumps(e.to_json()) + "\n")
        return path

    def to_chrome(self, path) -> Path:
        """Chrome ``trace_event`` JSON (Perfetto-loadable) — see the
        module docstring for the track layout."""
        path = Path(path)
        path.write_text(json.dumps(
            {"traceEvents": chrome_trace_events(self.events),
             "displayTimeUnit": "ms"}))
        return path


def load_jsonl(path) -> list[Event]:
    """Load a ``to_jsonl`` trace back into ``Event`` objects (the header
    line is validated and skipped; headerless files still load)."""
    events: list[Event] = []
    with Path(path).open() as f:
        for i, line in enumerate(f):
            line = line.strip()
            if not line:
                continue
            d = json.loads(line)
            if i == 0 and "trace_format" in d:
                if d["trace_format"] > TRACE_FORMAT_VERSION:
                    raise ValueError(
                        f"trace format {d['trace_format']} is newer than "
                        f"this reader (v{TRACE_FORMAT_VERSION})")
                continue
            events.append(Event.from_json(d))
    return events


def chrome_trace_events(events: Iterable[Event]) -> list[dict]:
    """Translate an event stream into Chrome ``trace_event`` dicts.

    Two processes: pid 1 is the host wall clock (every ``span`` as an
    ``X`` duration slice on one thread per phase), pid 2 is the
    simulation clock (every ``finish`` as a duration slice on its node's
    own thread lane; faults / retries / speculations / node churn as
    instant ``i`` markers).  All timestamps are microseconds, as the
    format requires.
    """
    out: list[dict] = []
    out.append({"ph": "M", "pid": 1, "name": "process_name",
                "args": {"name": "host (wall clock)"}})
    out.append({"ph": "M", "pid": 2, "name": "process_name",
                "args": {"name": "simulation (sim clock)"}})
    phase_tid: dict[str, int] = {}
    node_tid: dict[str, int] = {}

    def _phase_tid(phase: str) -> int:
        if phase not in phase_tid:
            phase_tid[phase] = len(phase_tid) + 1
            out.append({"ph": "M", "pid": 1, "tid": phase_tid[phase],
                        "name": "thread_name", "args": {"name": phase}})
        return phase_tid[phase]

    def _node_tid(node: str) -> int:
        if node not in node_tid:
            node_tid[node] = len(node_tid) + 1
            out.append({"ph": "M", "pid": 2, "tid": node_tid[node],
                        "name": "thread_name", "args": {"name": node}})
        return node_tid[node]

    for e in events:
        if e.kind == "span":
            phase = str(e.data.get("phase", "?"))
            args = {k: v for k, v in e.data.items()
                    if k not in ("phase", "dur_s")}
            out.append({"name": phase, "ph": "X", "pid": 1,
                        "tid": _phase_tid(phase),
                        "ts": e.t_wall * 1e6,
                        "dur": e.data.get("dur_s", 0.0) * 1e6,
                        "args": args})
        elif e.kind == "finish":
            node = str(e.data.get("node", "?"))
            start = float(e.data.get("start", e.t_sim))
            out.append({"name": str(e.data.get("task", "?")), "ph": "X",
                        "pid": 2, "tid": _node_tid(node),
                        "ts": start * 1e6,
                        "dur": (e.t_sim - start) * 1e6,
                        "args": {k: v for k, v in e.data.items()
                                 if k not in ("node", "start")}})
        elif e.kind in ("fault", "retry", "speculation", "surprise",
                        "node_down", "node_up", "stranded"):
            node = str(e.data.get("node", "?"))
            out.append({"name": e.kind, "ph": "i", "pid": 2,
                        "tid": _node_tid(node), "ts": e.t_sim * 1e6,
                        "s": "g", "args": dict(e.data)})
    return out
