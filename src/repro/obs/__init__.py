"""Observability plane for the online estimator loop.

Four layers over one substrate — the structured event trace:

* ``trace`` — the ``Tracer`` protocol (``NULL_TRACER`` when disabled),
  the append-only typed ``EventLog``, JSONL + Chrome ``trace_event``
  export (a tick timeline opens directly in Perfetto);
* ``calibration`` — empirical coverage, PIT histogram, sharpness and
  coverage/MPE timelines of the predictive intervals, computed from the
  trace's ``observe`` events (plus ``RunningMedian``, the O(log n)
  streaming median);
* ``profiling`` — per-phase wall-clock breakdown of the tick with the
  first-call (XLA compile) cost split from steady state;
* ``registry`` / ``report`` — the flat metrics roll-up and the
  human-readable report (``scripts/report_trace.py`` is the CLI).
"""
from .calibration import (RunningMedian, calibration_summary,
                          coverage_timeline, empirical_coverage,
                          observe_records, pit_histogram, pit_uniformity,
                          running_median, sharpness)
from .profiling import (phase_breakdown, slowest_spans,
                        tick_latency_summary)
from .registry import MetricsRegistry
from .report import render_report, report_dict
from .trace import (EVENT_KINDS, TRACE_FORMAT_VERSION, Event, EventLog,
                    NULL_TRACER, NullTracer, Tracer, chrome_trace_events,
                    load_jsonl)

__all__ = [
    "EVENT_KINDS", "TRACE_FORMAT_VERSION", "Event", "EventLog",
    "NULL_TRACER", "NullTracer", "Tracer", "chrome_trace_events",
    "load_jsonl",
    "RunningMedian", "calibration_summary", "coverage_timeline",
    "empirical_coverage", "observe_records", "pit_histogram",
    "pit_uniformity", "running_median", "sharpness",
    "phase_breakdown", "slowest_spans", "tick_latency_summary",
    "MetricsRegistry", "render_report", "report_dict",
]
