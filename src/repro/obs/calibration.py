"""Calibration diagnostics for the online predictive distribution.

The paper's headline is not only low point error but *robust uncertainty
estimates as an input for advanced scheduling* — yet a σ nobody checks is
a σ nobody should price risk with: miscalibrated intervals silently
corrupt ``risk_k`` pricing and tail-mass speculation admission.  This
module turns the ``observe`` events of a trace (each carries the realised
runtime, the dispatch-time predictive mean/std, the ``confidence``-level
interval and its coverage flag, and the PIT value) into the standard
diagnostics:

* **empirical coverage** — the fraction of realised runtimes that landed
  inside their predictive interval, overall and after a warm-up (the
  first observations stream in against near-prior posteriors, so the gate
  excludes them);
* **PIT histogram** — probability-integral-transform values
  ``F(runtime)`` under the predictive CDF; a calibrated predictor's PITs
  are uniform on [0, 1] (∪-shape ⇒ overconfident, ∩-shape ⇒
  underconfident);
* **sharpness** — mean predictive-interval width (absolute and relative
  to the realised runtime): calibration alone is cheap (predict ±∞), the
  pair (coverage ≈ nominal, width small) is the actual target;
* **timelines** — running coverage and running-median prediction error
  per observation index, the trajectories ROADMAP item 4's regret
  feedback will consume.

Also home to ``RunningMedian``, the O(log n)-per-push two-heap running
median that replaced the O(n²) prefix re-median in
``ExecutionTrace.cumulative_mpe``.
"""
from __future__ import annotations

import heapq
from typing import Iterable, Sequence

import numpy as np


class RunningMedian:
    """Streaming median via the classic two-heap construction: a
    max-heap of the lower half, a min-heap of the upper half, rebalanced
    so their sizes never differ by more than one.  ``push`` is
    O(log n); ``median`` is O(1) and matches ``np.median`` of the pushed
    prefix exactly (odd count → the middle element; even count → the
    mean of the two middles, the same ``(a + b) / 2`` float arithmetic).
    """
    __slots__ = ("_lo", "_hi")

    def __init__(self):
        self._lo: list[float] = []   # max-heap (negated) — lower half
        self._hi: list[float] = []   # min-heap — upper half

    def __len__(self) -> int:
        return len(self._lo) + len(self._hi)

    def push(self, x: float) -> None:
        x = float(x)
        if self._lo and x > -self._lo[0]:
            heapq.heappush(self._hi, x)
        else:
            heapq.heappush(self._lo, -x)
        # rebalance: |lo| - |hi| must stay in {0, 1}
        if len(self._lo) > len(self._hi) + 1:
            heapq.heappush(self._hi, -heapq.heappop(self._lo))
        elif len(self._hi) > len(self._lo):
            heapq.heappush(self._lo, -heapq.heappop(self._hi))

    def median(self) -> float:
        if not self._lo:
            raise ValueError("median of an empty stream")
        if len(self._lo) > len(self._hi):
            return -self._lo[0]
        return (-self._lo[0] + self._hi[0]) / 2.0


def running_median(values: Iterable[float]) -> np.ndarray:
    """Median of each prefix of ``values`` — O(n log n) total, equal to
    ``[np.median(v[:k+1]) for k in range(n)]``."""
    rm = RunningMedian()
    out = []
    for v in values:
        rm.push(v)
        out.append(rm.median())
    return np.array(out)


def empirical_coverage(covered: Sequence[bool]) -> float:
    """Fraction of observations whose realised runtime fell inside its
    predictive interval (NaN on an empty sequence)."""
    c = np.asarray(covered, bool)
    return float(c.mean()) if c.size else float("nan")


def coverage_timeline(covered: Sequence[bool]) -> np.ndarray:
    """Running empirical coverage after each observation."""
    c = np.asarray(covered, np.float64)
    if c.size == 0:
        return c
    return np.cumsum(c) / np.arange(1, c.size + 1)


def pit_histogram(pits: Sequence[float], bins: int = 10
                  ) -> tuple[np.ndarray, np.ndarray]:
    """Histogram of PIT values over [0, 1] (counts, bin edges)."""
    p = np.asarray(pits, np.float64)
    return np.histogram(p, bins=bins, range=(0.0, 1.0))


def pit_uniformity(pits: Sequence[float], bins: int = 10) -> float:
    """Total-variation distance of the PIT histogram from uniform, in
    [0, 1): 0 is perfectly calibrated, larger is worse.  A coarse single
    number for gates and tables — eyeball the histogram for the shape."""
    p = np.asarray(pits, np.float64)
    if p.size == 0:
        return float("nan")
    counts, _ = pit_histogram(p, bins)
    freq = counts / p.size
    return float(0.5 * np.abs(freq - 1.0 / bins).sum())


def sharpness(widths: Sequence[float]) -> float:
    """Mean predictive-interval width — the sharpness half of the
    calibration/sharpness trade-off (NaN on empty input)."""
    w = np.asarray(widths, np.float64)
    return float(w.mean()) if w.size else float("nan")


def observe_records(events) -> list[dict]:
    """The ``observe`` events of a trace as plain payload dicts, in
    stream order (accepts ``Event`` objects or raw dicts)."""
    out = []
    for e in events:
        kind = e.kind if hasattr(e, "kind") else e.get("kind")
        if kind != "observe":
            continue
        out.append(dict(e.data) if hasattr(e, "data") else dict(e))
    return out


def calibration_summary(events, min_obs: int = 20,
                        bins: int = 10) -> dict:
    """All calibration diagnostics of one trace in a JSON-ready dict.

    ``min_obs`` is the warm-up: ``coverage`` / ``sharpness_rel`` /
    ``pit_tv`` are computed over observations from index ``min_obs`` on
    (the stream's early intervals reflect the near-prior posterior, not
    the online estimator the gate is judging); the ``*_all`` twins cover
    the full stream.  Returns NaNs (and ``n_post_warmup = 0``) when the
    stream is shorter than the warm-up.
    """
    recs = observe_records(events)
    covered = np.array([bool(r["covered"]) for r in recs], bool)
    pits = np.array([float(r["pit"]) for r in recs
                     if r.get("pit") is not None], np.float64)
    widths = np.array([float(r["hi"]) - float(r["lo"]) for r in recs],
                      np.float64)
    rts = np.array([float(r["runtime"]) for r in recs], np.float64)
    rel_w = widths / np.maximum(rts, 1e-12)
    errs = np.array([abs(float(r["pred_mean"]) - float(r["runtime"]))
                     / max(float(r["runtime"]), 1e-12) for r in recs])
    post = slice(min_obs, None)
    n_post = max(len(recs) - min_obs, 0)
    counts, edges = pit_histogram(pits[post] if n_post else [], bins)
    return {
        "n_obs": len(recs),
        "min_obs": int(min_obs),
        "n_post_warmup": n_post,
        "coverage": empirical_coverage(covered[post]),
        "coverage_all": empirical_coverage(covered),
        "sharpness": sharpness(widths[post]),
        "sharpness_all": sharpness(widths),
        "sharpness_rel": sharpness(rel_w[post]),
        "pit_tv": pit_uniformity(pits[post] if n_post else [], bins),
        "pit_hist": counts.tolist(),
        "pit_edges": edges.tolist(),
        "coverage_timeline_first_last": (
            [float(coverage_timeline(covered)[0]),
             float(coverage_timeline(covered)[-1])]
            if len(recs) else [float("nan")] * 2),
        "mpe_timeline_first_last": (
            [float(running_median(errs)[0]), float(running_median(errs)[-1])]
            if len(recs) else [float("nan")] * 2),
    }
