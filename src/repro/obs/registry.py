"""Minimal metrics registry: named counters, gauges and histograms.

The trace is the ground truth; the registry is the roll-up — a flat,
JSON-ready bag of metrics that reports, benches and CI gates read without
re-walking the event stream.  ``MetricsRegistry.from_events`` builds the
standard set from a trace (event counts per kind, a histogram per span
phase, final-state gauges from ``run_end``); callers can also register
their own series by hand (``counter`` / ``gauge`` / ``histogram``).

Deliberately tiny — no labels, no time windows, no export protocol beyond
``to_dict``.  If this ever needs Prometheus semantics, replace it, don't
grow it.
"""
from __future__ import annotations

import numpy as np


class Counter:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, by: int = 1) -> None:
        self.value += by


class Gauge:
    __slots__ = ("value",)

    def __init__(self):
        self.value: float | None = None

    def set(self, v: float) -> None:
        self.value = v


class Histogram:
    """Append-only sample list with summary statistics on demand."""
    __slots__ = ("samples",)

    def __init__(self):
        self.samples: list[float] = []

    def observe(self, v: float) -> None:
        self.samples.append(float(v))

    def summary(self) -> dict:
        if not self.samples:
            return {"count": 0}
        a = np.asarray(self.samples, np.float64)
        return {"count": int(a.size), "sum": float(a.sum()),
                "mean": float(a.mean()), "p50": float(np.median(a)),
                "p95": float(np.percentile(a, 95)), "max": float(a.max())}


class MetricsRegistry:
    """Namespace of metrics; creation is idempotent per (kind, name)."""

    def __init__(self):
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._histograms: dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        return self._counters.setdefault(name, Counter())

    def gauge(self, name: str) -> Gauge:
        return self._gauges.setdefault(name, Gauge())

    def histogram(self, name: str) -> Histogram:
        return self._histograms.setdefault(name, Histogram())

    def to_dict(self) -> dict:
        return {
            "counters": {k: c.value
                         for k, c in sorted(self._counters.items())},
            "gauges": {k: g.value for k, g in sorted(self._gauges.items())},
            "histograms": {k: h.summary()
                           for k, h in sorted(self._histograms.items())},
        }

    @classmethod
    def from_events(cls, events) -> "MetricsRegistry":
        """The standard trace roll-up: ``events.<kind>`` counters, a
        ``span_s.<phase>`` histogram per span phase, and one gauge per
        numeric field of the final ``run_end`` event."""
        reg = cls()
        for e in events:
            kind = e.kind if hasattr(e, "kind") else e.get("kind")
            data = e.data if hasattr(e, "data") else {
                k: v for k, v in e.items()
                if k not in ("kind", "t_sim", "t_wall")}
            reg.counter(f"events.{kind}").inc()
            if kind == "span":
                reg.histogram(
                    f"span_s.{data.get('phase', '?')}").observe(
                    float(data.get("dur_s", 0.0)))
            elif kind == "run_end":
                for k, v in data.items():
                    if isinstance(v, (int, float)):
                        reg.gauge(f"final.{k}").set(float(v))
        return reg
