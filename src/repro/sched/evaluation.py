"""Evaluation protocol for the Lotaru reproduction (paper §5).

For each (workflow, dataset): downsample the input geometrically, run every
task locally (normal + CPU-throttled) in the simulator, fit Lotaru and the
three baselines on exactly the same local observations, then score
predictions of the *full-size* task runtimes:

  * homogeneous  (§5.2): target = the local machine type;
  * model adjustment (§5.3): estimated vs actual factor per task/node;
  * heterogeneous (§5.4): all five target node types.

err_t = |predicted - actual| / actual  (paper eq. 7); MPE = median err.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core import (BASELINES, LotaruEstimator, get_node, profile_cluster,
                        profile_node, target_nodes)
from repro.core.downsample import partition_sizes
from .simulator import ClusterSimulator
from .workflows import INPUTS, WORKFLOWS, TaskDef


@dataclass
class EvalResult:
    errors: dict          # approach -> workflow -> node -> [per-task err]

    def mpe(self, approach: str, workflow: str | None = None,
            node: str | None = None) -> float:
        errs = []
        for wf, nodes in self.errors[approach].items():
            if workflow and wf != workflow:
                continue
            for nd, es in nodes.items():
                if node and nd != node:
                    continue
                errs.extend(es)
        return float(np.median(errs)) if errs else float("nan")

    def all_errors(self, approach: str, workflow: str | None = None,
                   node: str | None = None) -> np.ndarray:
        errs = []
        for wf, nodes in self.errors[approach].items():
            if workflow and wf != workflow:
                continue
            for nd, es in nodes.items():
                if node and nd != node:
                    continue
                errs.extend(es)
        return np.asarray(errs)


APPROACHES = ("lotaru", "naive", "online_m", "online_p")


def run_evaluation(seed: int = 0, n_partitions: int = 10,
                   heterogeneous: bool = True,
                   workflows: dict | None = None,
                   inputs: dict | None = None) -> EvalResult:
    workflows = workflows or WORKFLOWS
    inputs = inputs or INPUTS
    sim = ClusterSimulator(seed=seed)
    truth_sim = ClusterSimulator(seed=seed + 1000)   # independent noise
    local = get_node("local-cpu")
    local_bench = profile_node(local, np.random.default_rng(seed + 7))
    targets = target_nodes() if heterogeneous else [local]
    tbenches = profile_cluster(target_nodes(), seed=seed + 13)

    errors: dict = {a: {} for a in APPROACHES}
    for (wf_name, ds), size in inputs.items():
        wf_key = f"{wf_name}-{ds}"
        tasks = workflows[wf_name]
        by_name = {t.name: t for t in tasks}

        est = LotaruEstimator(local_bench, tbenches)
        est.fit_tasks([t.name for t in tasks], size,
                      lambda name, s, cf: sim.run_task(by_name[name], local,
                                                       s, cpu_factor=cf),
                      n_partitions=n_partitions)

        # baselines see the identical local observations
        fitted_baselines = {}
        for bname, cls in BASELINES.items():
            fitted_baselines[bname] = {}
            for t in tasks:
                ft = est.tasks[t.name]
                fitted_baselines[bname][t.name] = cls().fit(ft.sizes,
                                                            ft.runtimes)

        for a in APPROACHES:
            errors[a].setdefault(wf_key, {})
        # one batched call for the full (task x node) Lotaru estimate matrix
        # (local node gets factor 1, matching predict_local)
        node_names = [n.name for n in targets]
        task_idx = {name: i for i, name in enumerate(est.task_names())}
        mean_mat, _ = est.predict_matrix(node_names, size)
        for nj, node in enumerate(targets):
            actual = {t.name: truth_sim.run_task(t, node, size)
                      for t in tasks}
            for a in APPROACHES:
                errs = []
                for t in tasks:
                    if a == "lotaru":
                        pred = mean_mat[task_idx[t.name], nj]
                    else:
                        pred = float(np.asarray(
                            fitted_baselines[a][t.name].predict(size)).reshape(-1)[0])
                    errs.append(abs(pred - actual[t.name]) / actual[t.name])
                errors[a][wf_key][node.name] = errs
    return EvalResult(errors=errors)


def factor_table(seed: int = 0, workflow: str = "eager", ds: int = 1):
    """Paper Tables 4+5: estimated vs actual adjustment factors."""
    sim = ClusterSimulator(seed=seed)
    local = get_node("local-cpu")
    local_bench = profile_node(local, np.random.default_rng(seed + 7))
    tbenches = profile_cluster(target_nodes(), seed=seed + 13)
    tasks = WORKFLOWS[workflow]
    by_name = {t.name: t for t in tasks}
    size = INPUTS[(workflow, ds)]

    est = LotaruEstimator(local_bench, tbenches)
    est.fit_tasks([t.name for t in tasks], size,
                  lambda name, s, cf: sim.run_task(by_name[name], local, s,
                                                   cpu_factor=cf))
    rows = []
    for t in tasks:
        row = {"task": t.name, "w": est.tasks[t.name].w}
        for node in target_nodes():
            est_f = est.factor(t.name, node.name)
            act_f = sim.actual_factor(t, local, node, size)
            row[node.name] = {"estimated": est_f, "actual": act_f,
                              "diff": abs(est_f - act_f)}
        rows.append(row)
    return rows
