"""Synthetic nf-core-like workflow suite (paper Table 3 analogue).

The published Lotaru traces are not available offline, so we generate a
workload suite with the same *structure*: 5 workflows with the paper's
abstract-task counts (Eager 13, Methylseq 8, Chipseq 14, Atacseq 14,
Bacass 5), two datasets each with the paper's uncompressed input sizes,
and per-task CPU/I-O mixes spanning the regimes the paper reports
(CPU-bound bwa, I/O-bound markduplicates, size-independent bcftools_stats
that exercises the median fallback, a non-linear samtools task, ...).

Each task's hidden ground truth on a node is
    t = [cpu_unit * size_gb * (ref_cpu / node.cpu_score) / cpu_factor
         + io_unit * size_gb * (ref_io / node.io_bw)] * noise
(reference machine = the paper's local workstation scores), which makes the
"actual runtime factor" between two nodes exactly the CPU/I-O-mix-weighted
ratio the paper's eq. 6 estimates.
"""
from __future__ import annotations

from dataclasses import dataclass

REF_CPU = 458.0     # local machine sysbench events/s (paper Table 2)
REF_IO = 415.0      # local machine fio MB/s


@dataclass(frozen=True)
class TaskDef:
    name: str
    workflow: str
    cpu_unit: float          # s per GB of input on the reference machine
    io_unit: float           # s per GB
    kind: str = "linear"     # linear | flat | sqrt
    base: float = 5.0        # constant seconds (dominates for kind="flat")
    out_unit: float = 0.25   # GB shipped downstream per effective input GB

    @property
    def cpu_share(self) -> float:
        return self.cpu_unit / max(self.cpu_unit + self.io_unit, 1e-9)


def _wf(workflow: str, specs: list[tuple]) -> list[TaskDef]:
    return [TaskDef(name=n, workflow=workflow, cpu_unit=c, io_unit=i,
                    kind=k, base=b) for (n, c, i, k, b) in specs]


WORKFLOWS: dict[str, list[TaskDef]] = {
    # name                      cpu_u   io_u   kind      base
    "eager": _wf("eager", [
        ("bwa",                  220.0,  14.0, "linear",  10.0),
        ("fastqc",                55.0,  28.0, "linear",   5.0),
        ("fastqc_after_clip",     52.0,  26.0, "linear",   5.0),
        ("adapter_removal",       80.0,  35.0, "linear",   8.0),
        ("samtools_flagstat",      6.0,  22.0, "linear",   2.0),
        ("samtools_filter",       18.0,  48.0, "linear",   4.0),
        ("samtools_f_a_f",         4.0,   9.0, "sqrt",     3.0),
        ("markduplicates",        25.0, 110.0, "linear",  10.0),
        ("damageprofiler",        60.0,  25.0, "linear",   6.0),
        ("preseq",                42.0,  18.0, "linear",   4.0),
        ("qualimap",              70.0,  45.0, "linear",   8.0),
        ("genotyping_hc",        180.0,  30.0, "linear",  15.0),
        ("bcftools_stats",         0.5,   0.5, "flat",    42.0),
    ]),
    "methylseq": _wf("methylseq", [
        ("fastqc",                55.0,  28.0, "linear",   5.0),
        ("trim_galore",           75.0,  40.0, "linear",   6.0),
        ("bismark_align",        260.0,  30.0, "linear",  12.0),
        ("bismark_deduplicate",   30.0,  95.0, "linear",   8.0),
        ("bismark_methxtract",    90.0,  40.0, "linear",   8.0),
        ("samtools_sort",         24.0,  60.0, "linear",   4.0),
        ("qualimap",              70.0,  45.0, "linear",   8.0),
        ("multiqc",                1.0,   1.0, "flat",    35.0),
    ]),
    "chipseq": _wf("chipseq", [
        ("fastqc",                55.0,  28.0, "linear",   5.0),
        ("trim_galore",           75.0,  40.0, "linear",   6.0),
        ("bwa_mem",              230.0,  18.0, "linear",  10.0),
        ("samtools_sort",         24.0,  60.0, "linear",   4.0),
        ("samtools_flagstat",      6.0,  22.0, "linear",   2.0),
        ("picard_markdup",        25.0, 105.0, "linear",  10.0),
        ("picard_collectmetrics", 40.0,  35.0, "linear",   6.0),
        ("preseq",                42.0,  18.0, "linear",   4.0),
        ("phantompeakqualtools", 120.0,  20.0, "linear",  10.0),
        ("deeptools_plotfpt",     35.0,  30.0, "linear",   5.0),
        ("macs2",                 90.0,  35.0, "linear",   8.0),
        ("homer_annotate",        50.0,  40.0, "linear",   6.0),
        ("subread_featurecounts", 30.0,  28.0, "sqrt",     5.0),
        ("multiqc",                1.0,   1.0, "flat",    35.0),
    ]),
    "atacseq": _wf("atacseq", [
        ("fastqc",                55.0,  28.0, "linear",   5.0),
        ("trim_galore",           75.0,  40.0, "linear",   6.0),
        ("bwa_mem",              230.0,  18.0, "linear",  10.0),
        ("samtools_sort",         24.0,  60.0, "linear",   4.0),
        ("samtools_flagstat",      6.0,  22.0, "linear",   2.0),
        ("picard_markdup",        25.0, 105.0, "linear",  10.0),
        ("picard_collectmetrics", 40.0,  35.0, "linear",   6.0),
        ("preseq",                42.0,  18.0, "linear",   4.0),
        ("deeptools_plotprofile", 35.0,  30.0, "linear",   5.0),
        ("macs2",                 90.0,  35.0, "linear",   8.0),
        ("homer_annotate",        50.0,  40.0, "linear",   6.0),
        ("subread_featurecounts", 30.0,  28.0, "sqrt",     5.0),
        ("ataqv",                 45.0,  25.0, "linear",   5.0),
        ("multiqc",                1.0,   1.0, "flat",    35.0),
    ]),
    "bacass": _wf("bacass", [
        ("fastqc",                55.0,  28.0, "linear",   5.0),
        ("skewer",                65.0,  38.0, "linear",   6.0),
        ("unicycler",            420.0,  45.0, "linear",  25.0),
        ("prokka",               150.0,  30.0, "linear",  12.0),
        ("quast",                  2.0,   2.0, "flat",    28.0),
    ]),
}

# (workflow, dataset) -> uncompressed input size in GB (paper Table 3)
INPUTS: dict[tuple[str, int], float] = {
    ("eager", 1): 8.33, ("eager", 2): 25.71,
    ("methylseq", 1): 17.03, ("methylseq", 2): 23.0,
    ("chipseq", 1): 4.81, ("chipseq", 2): 32.98,
    ("atacseq", 1): 14.09, ("atacseq", 2): 11.81,
    ("bacass", 1): 3.64, ("bacass", 2): 4.35,
}


def all_experiments() -> list[tuple[str, int, float]]:
    return [(wf, ds, size) for (wf, ds), size in INPUTS.items()]


def effective_size(task: TaskDef, size_gb: float) -> float:
    """Size transform by task kind: linear, sqrt (sub-linear tools), flat."""
    if task.kind == "flat":
        return 0.0
    if task.kind == "sqrt":
        return size_gb ** 0.5
    return size_gb


#: every edge ships at least this much (manifests, logs, QC reports) —
#: keeps flat tasks (effective size 0) from pretending their downstream
#: reads nothing at all
EDGE_BASE_GB = 0.02


def edge_gb(task: TaskDef, size_gb: float) -> float:
    """GB the task ships along EACH outgoing DAG edge for an input of
    ``size_gb``: its output volume ``out_unit * effective_size`` plus the
    ``EDGE_BASE_GB`` floor.  Output scales with the same kind-transformed
    size as runtime does — flat report tasks (multiqc, quast) ship only
    the floor, aligners ship the big BAMs — so data-aware placement
    faces the realistic mix of heavy and negligible edges."""
    return EDGE_BASE_GB + task.out_unit * effective_size(task, size_gb)


def dag_edge_gb(tasks, task_name: dict[str, str],
                by_name: dict[str, TaskDef],
                size_gb: float) -> dict[tuple[str, str], float]:
    """Per-edge data sizes for an instance DAG over this workflow.

    ``tasks`` is a ``{task_id: SchedTask}`` DAG (e.g. from
    ``fanout_chain_dag``), ``task_name`` maps instance id -> abstract
    task name, ``by_name`` maps name -> ``TaskDef``.  Returns the
    ``(producer_id, consumer_id) -> GB`` dict that ``heft_schedule`` /
    ``CommCosts`` consume; every edge out of a producer carries that
    producer's ``edge_gb`` volume."""
    out: dict[tuple[str, str], float] = {}
    for tid, t in tasks.items():
        gb = edge_gb(by_name[task_name[tid]], size_gb)
        for s in t.succ:
            out[(tid, s)] = gb
    return out
