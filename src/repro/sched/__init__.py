from .heft import (SchedTask, detect_stragglers, heft_schedule,
                   reschedule_elastic, round_robin_schedule,
                   simulate_with_stragglers)
from .simulator import (ClusterSimulator, EventSimulator, SimNode,
                        load_dryrun_cells)
from .workflows import INPUTS, WORKFLOWS, TaskDef, all_experiments

__all__ = ["SchedTask", "detect_stragglers", "heft_schedule",
           "reschedule_elastic", "round_robin_schedule",
           "simulate_with_stragglers", "ClusterSimulator", "EventSimulator",
           "SimNode", "load_dryrun_cells", "INPUTS", "WORKFLOWS", "TaskDef",
           "all_experiments"]
