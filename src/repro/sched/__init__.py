from .heft import (SchedTask, detect_stragglers, heft_schedule,
                   heft_schedule_array, heft_schedule_reference,
                   reschedule_elastic, round_robin_schedule,
                   simulate_with_stragglers, upward_rank_array)
from .simulator import (ClusterSimulator, EventSimulator, FaultInjector,
                        GridEngine, SimNode, load_dryrun_cells)
from .workflows import INPUTS, WORKFLOWS, TaskDef, all_experiments

__all__ = ["SchedTask", "detect_stragglers", "heft_schedule",
           "heft_schedule_array", "heft_schedule_reference",
           "reschedule_elastic", "round_robin_schedule",
           "simulate_with_stragglers", "upward_rank_array",
           "ClusterSimulator", "EventSimulator", "FaultInjector",
           "GridEngine", "SimNode", "load_dryrun_cells", "INPUTS",
           "WORKFLOWS", "TaskDef", "all_experiments"]
