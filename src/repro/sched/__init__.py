from .heft import (CommCosts, SchedTask, detect_stragglers, heft_schedule,
                   heft_schedule_array, heft_schedule_reference,
                   realized_makespan, reschedule_elastic,
                   round_robin_schedule, simulate_with_stragglers,
                   upward_rank_array)
from .simulator import (ClusterSimulator, EventSimulator, FaultInjector,
                        GridEngine, SimNode, Topology, load_dryrun_cells)
from .workflows import (INPUTS, WORKFLOWS, TaskDef, all_experiments,
                        dag_edge_gb, edge_gb)

__all__ = ["CommCosts", "SchedTask", "detect_stragglers", "heft_schedule",
           "heft_schedule_array", "heft_schedule_reference",
           "realized_makespan", "reschedule_elastic",
           "round_robin_schedule", "simulate_with_stragglers",
           "upward_rank_array", "ClusterSimulator", "EventSimulator",
           "FaultInjector", "GridEngine", "SimNode", "Topology",
           "load_dryrun_cells", "INPUTS", "WORKFLOWS", "TaskDef",
           "all_experiments", "dag_edge_gb", "edge_gb"]
