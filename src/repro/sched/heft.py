"""HEFT (Topcuoglu et al.) + Lotaru-informed variants.

The paper's motivation (§2.2): HEFT-class schedulers need runtime estimates
for every (task, node) pair, which Lotaru supplies online.  We implement:

  * ``heft_schedule``     — classic HEFT over a (task x node) estimate matrix
  * uncertainty-aware variant: ranks use mean + k*sigma (Bayesian predictive
    std from Lotaru), penalising placements whose runtime is *uncertain* —
    the paper's "advanced scheduling methods" consumer.
  * data-aware variant — per-edge data volumes priced by a per-node-pair
    transfer matrix (``CommCosts``): the canonical algorithm's compute
    PLUS communication ranking/placement.  The transfer term vanishes on
    same-node placement and is discounted within a zone (the matrix comes
    from ``repro.sched.simulator.Topology``); ``comm=None`` is bit-exact
    with the compute-only schedule.
  * straggler mitigation — runtime > mean + k*sigma triggers speculative
    re-execution on the fastest idle node.
  * elastic rescheduling — on node loss/join, unfinished tasks re-ranked.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field

import numpy as np


@dataclass
class SchedTask:
    id: str
    succ: list[str] = field(default_factory=list)
    pred: list[str] = field(default_factory=list)


class CommCosts:
    """Per-edge data volumes priced by a per-node-pair transfer matrix.

    ``edge_gb`` maps index edges ``(p, s)`` (or is a dense (T, T) array,
    ``data[p, s]``) to the data volume task ``p`` ships to ``s``;
    ``secs_per_gb`` is the (N, N) node-pair transfer price in seconds
    per GB with an all-zero diagonal — moving data to yourself is free,
    which is exactly how the transfer term vanishes on same-node
    placement (a ``Topology`` additionally discounts same-zone pairs).

    The EFT inner loop charges the *placement-dependent* term
    ``finish[p] + gb * secs_per_gb[node(p), n]`` per candidate node
    ``n``, vectorised over the node axis (O(E·N) total — the schedule
    stays O(T·N) for the bounded-degree DAGs the generator emits).  The
    upward rank uses the classic placement-free average,
    ``gb * mean(secs_per_gb)``.
    """

    def __init__(self, pred: list[list[int]], edge_gb,
                 secs_per_gb: np.ndarray):
        spg = np.asarray(secs_per_gb, np.float64)
        if spg.ndim != 2 or spg.shape[0] != spg.shape[1]:
            raise ValueError(f"secs_per_gb must be square (N, N), got "
                             f"shape {spg.shape}")
        if (spg < 0).any():
            raise ValueError("secs_per_gb has negative entries")
        if np.diag(spg).any():
            raise ValueError("secs_per_gb diagonal must be zero: same-node "
                             "transfers are free by definition")
        self.secs_per_gb = spg
        self.mean_secs_per_gb = float(spg.mean())
        T = len(pred)
        dense = None
        if isinstance(edge_gb, np.ndarray):
            dense = np.asarray(edge_gb, np.float64)
            if dense.shape != (T, T):
                raise ValueError(f"dense edge_gb must be (T, T) = ({T}, "
                                 f"{T}), got {dense.shape}")
        self.pred_idx: list[np.ndarray] = []
        self.pred_gb: list[np.ndarray] = []
        for t in range(T):
            pi = np.asarray(pred[t], np.int64)
            if dense is not None:
                gb = dense[pi, t] if len(pi) else np.zeros(0)
            else:
                gb = np.array([float(edge_gb.get((int(p), t), 0.0))
                               for p in pi])
            if (gb < 0).any():
                raise ValueError(f"edge data size is negative on an edge "
                                 f"into task {t}")
            self.pred_idx.append(pi)
            self.pred_gb.append(np.asarray(gb, np.float64))

    def edge_comm(self, succ: list[list[int]]) -> list[list[float]]:
        """Average (placement-free) comm cost per edge, aligned with
        ``succ`` — what the upward rank consumes."""
        gb_in: dict[tuple[int, int], float] = {}
        for t, (pi, gb) in enumerate(zip(self.pred_idx, self.pred_gb)):
            for p, g in zip(pi, gb):
                gb_in[(int(p), t)] = float(g)
        return [[gb_in.get((t, s), 0.0) * self.mean_secs_per_gb
                 for s in succ[t]] for t in range(len(succ))]

    def ready_floor(self, t: int, finish: np.ndarray,
                    assignment: np.ndarray) -> np.ndarray | None:
        """(N,) data-arrival floor of task ``t`` over candidate nodes,
        given its already-placed predecessors; None for a root."""
        pi = self.pred_idx[t]
        if not len(pi):
            return None
        arr = (finish[pi][:, None]
               + self.pred_gb[t][:, None] * self.secs_per_gb[assignment[pi]])
        return arr.max(axis=0)


def _upward_rank(tasks: dict[str, SchedTask], cost: dict[str, dict[str, float]],
                 comm: float = 0.0,
                 edge_comm: dict[tuple[str, str], float] | None = None
                 ) -> dict[str, float]:
    mean_cost = {t: float(np.mean(list(cost[t].values()))) for t in tasks}
    rank: dict[str, float] = {}

    def rec(tid: str) -> float:
        if tid in rank:
            return rank[tid]
        t = tasks[tid]
        if edge_comm is None:
            best_succ = max((comm + rec(s) for s in t.succ), default=0.0)
        else:
            best_succ = max((comm + edge_comm.get((tid, s), 0.0) + rec(s)
                             for s in t.succ), default=0.0)
        rank[tid] = mean_cost[tid] + best_succ
        return rank[tid]

    for tid in tasks:
        rec(tid)
    return rank


def _topo_order(succ: list[list[int]], pred: list[list[int]]) -> list[int]:
    """Kahn's algorithm; iterative, so 10k-deep chains don't blow the
    Python recursion limit like the recursive reference rank does."""
    indeg = [len(p) for p in pred]
    queue = [i for i, d in enumerate(indeg) if d == 0]
    topo: list[int] = []
    head = 0
    while head < len(queue):
        t = queue[head]
        head += 1
        topo.append(t)
        for s in succ[t]:
            indeg[s] -= 1
            if indeg[s] == 0:
                queue.append(s)
    if len(topo) != len(succ):
        raise ValueError("task graph contains a cycle")
    return topo


def upward_rank_array(succ: list[list[int]], pred: list[list[int]],
                      mean_cost: np.ndarray, comm: float = 0.0,
                      edge_comm: list[list[float]] | None = None
                      ) -> np.ndarray:
    """Iterative upward rank over index-based adjacency; (T,) array.

    ``edge_comm`` (aligned with ``succ``) adds a per-edge average
    communication cost on top of the uniform ``comm`` scalar — the
    classic HEFT rank's ``mean_cost + max(c̄(t, s) + rank(s))`` with
    ``c̄`` the placement-free mean transfer price (see
    ``CommCosts.edge_comm``).  ``edge_comm=None`` is bit-exact with the
    compute-only rank."""
    topo = _topo_order(succ, pred)
    rank = np.zeros(len(succ))
    for t in reversed(topo):
        best = 0.0
        if edge_comm is None:
            for s in succ[t]:
                best = max(best, comm + rank[s])
        else:
            for c, s in zip(edge_comm[t], succ[t]):
                best = max(best, comm + c + rank[s])
        rank[t] = mean_cost[t] + best
    return rank


def upward_rank_incremental(succ: list[list[int]], pred: list[list[int]],
                            mean_cost: np.ndarray, prev_rank: np.ndarray,
                            dirty, comm: float = 0.0,
                            topo: list[int] | None = None,
                            edge_comm: list[list[float]] | None = None
                            ) -> np.ndarray:
    """Refresh an upward rank after a sparse cost change — bitwise equal
    to recomputing ``upward_rank_array`` from scratch (test-enforced
    oracle, see ``tests/test_scheduler.py``).

    ``dirty`` indexes the tasks whose ``mean_cost`` changed since
    ``prev_rank`` was computed.  A task's rank depends only on its own
    cost and its successors' ranks, so the stale entries are exactly
    ``dirty`` plus its ancestor closure — everything else is carried
    over.  The online executor's re-plan path uses this: a tick dirties
    only the observed rows' instances, so the re-rank touches the
    affected ancestor chains instead of the whole DAG (``topo`` can be
    passed in to amortise the one remaining O(T) pass).

    ``edge_comm`` must be the SAME per-edge average comm costs
    ``prev_rank`` was computed under — edge prices are part of the rank,
    so a bandwidth/topology change (e.g. a node dying re-prices the mean
    transfer rate) invalidates ``prev_rank`` wholesale and requires a
    fresh ``upward_rank_array``, not an incremental patch (the executor
    keys its rank cache on the transfer matrix for exactly this
    reason)."""
    if topo is None:
        topo = _topo_order(succ, pred)
    affected = {int(d) for d in np.asarray(dirty).ravel()}
    stack = list(affected)
    while stack:
        t = stack.pop()
        for p in pred[t]:
            if p not in affected:
                affected.add(p)
                stack.append(p)
    rank = np.array(prev_rank, np.float64, copy=True)
    for t in reversed(topo):
        if t not in affected:
            continue
        best = 0.0
        if edge_comm is None:
            for s in succ[t]:
                best = max(best, comm + rank[s])
        else:
            for c, s in zip(edge_comm[t], succ[t]):
                best = max(best, comm + c + rank[s])
        rank[t] = mean_cost[t] + best
    return rank


def heft_schedule_array(succ: list[list[int]], pred: list[list[int]],
                        cost: np.ndarray,
                        uncertainty: np.ndarray | None = None,
                        risk_k: float = 0.0,
                        node_ready: np.ndarray | None = None,
                        task_ready: np.ndarray | None = None,
                        rank: np.ndarray | None = None,
                        comm: CommCosts | None = None) -> dict:
    """HEFT over a (T, N) cost matrix — the ndarray fast path.

    ``succ`` / ``pred`` are index-based adjacency lists; ``cost[t, n]`` the
    estimated runtime of task t on node n (``uncertainty`` likewise, used
    when risk_k > 0: effective cost = mean + risk_k * sigma).  The
    effective cost drives the schedule END TO END — both the upward rank
    (task priority) and the EFT placement inner loop — so under
    ``risk_k > 0`` uncertain tasks are ranked more urgent (their risk
    inflates every successor chain through them) *and* uncertain
    placements are penalised.  The EFT inner loop is vectorised over the
    node axis.  ``node_ready`` (N,) / ``task_ready`` (T,) or (T, N) are
    earliest-availability floors for mid-execution re-planning: node j is
    busy until node_ready[j], task t's external predecessors (already
    done or running) finish at task_ready[t] — the (T, N) form carries
    per-candidate-node floors (an external predecessor's output still
    has to be *copied* to wherever t lands, so its floor is
    node-dependent under ``comm``).  Returns index-based arrays:
    {assignment (T,) int, start (T,), finish (T,), makespan,
    order (T,) int}.

    ``comm`` (a ``CommCosts``) makes the schedule data-aware: the rank
    gains the per-edge average transfer cost and the EFT inner loop the
    placement-dependent arrival floor ``finish[p] + gb·spg[node(p), n]``,
    vectorised over (preds × nodes) so the solve stays O(T·N + E·N).
    The term vanishes when t lands on its predecessor's node (zero
    diagonal) and shrinks within a zone (the ``Topology`` discount).
    ``comm=None`` is bit-exact with the compute-only schedule
    (trace-signature-tested on the five paper workflows).

    ``rank`` short-circuits the internal upward-rank pass with a
    caller-maintained priority vector (e.g. an incrementally refreshed
    ``upward_rank_incremental`` slice) — it must equal what
    ``upward_rank_array`` would compute over this subgraph (same
    ``edge_comm`` pricing when ``comm`` is set) for the schedule to be
    unchanged."""
    cost = np.asarray(cost, np.float64)
    T, N = cost.shape
    if comm is not None and comm.secs_per_gb.shape[0] != N:
        raise ValueError(f"comm prices {comm.secs_per_gb.shape[0]} nodes "
                         f"but cost has {N} columns")
    eff = cost
    if uncertainty is not None and risk_k > 0:
        eff = cost + risk_k * np.asarray(uncertainty, np.float64)
    if rank is None:
        rank = upward_rank_array(
            succ, pred, eff.mean(axis=1),
            edge_comm=comm.edge_comm(succ) if comm is not None else None)
    else:
        rank = np.asarray(rank, np.float64)
    order = np.argsort(-rank, kind="stable")
    node_free = (np.zeros(N) if node_ready is None
                 else np.asarray(node_ready, np.float64).copy())
    floors = (np.zeros(T) if task_ready is None
              else np.asarray(task_ready, np.float64))
    floors_2d = floors.ndim == 2
    start = np.zeros(T)
    finish = np.zeros(T)
    assignment = np.zeros(T, np.int64)
    for t in order:
        if comm is None and not floors_2d:
            ready = floors[t]
            for p in pred[t]:
                if finish[p] > ready:
                    ready = finish[p]
        elif comm is None:
            ready = floors[t]                      # (N,) external floors
            for p in pred[t]:
                ready = np.maximum(ready, finish[p])
        else:
            # data-aware arrival: each placed predecessor's output reaches
            # candidate node n at finish[p] + gb * spg[node(p), n] — free
            # on node(p) itself, discounted within its zone
            ready = floors[t]                      # scalar or (N,)
            arr = comm.ready_floor(t, finish, assignment)
            if arr is not None:
                ready = np.maximum(ready, arr)
        st = np.maximum(node_free, ready)          # (N,)
        ft = st + eff[t]
        j = int(np.argmin(ft))
        assignment[t] = j
        start[t] = st[j] if np.ndim(st) else float(st)
        finish[t] = ft[j]
        node_free[j] = ft[j]
    return {"assignment": assignment, "start": start, "finish": finish,
            "makespan": float(finish.max()) if T else 0.0, "order": order}


def heft_schedule(tasks: dict[str, SchedTask],
                  cost: dict[str, dict[str, float]],
                  nodes: list[str],
                  uncertainty: dict[str, dict[str, float]] | None = None,
                  risk_k: float = 0.0,
                  edge_gb: dict[tuple[str, str], float] | None = None,
                  secs_per_gb: np.ndarray | None = None) -> dict:
    """cost[task][node] = estimated runtime; uncertainty likewise (sigma).

    risk_k > 0 gives the uncertainty-aware variant: effective cost =
    mean + risk_k * sigma, applied to both the upward rank and the EFT
    placement.  Returns {assignment, start, finish, makespan, order}.
    Thin dict wrapper over ``heft_schedule_array``.

    Contract: ``uncertainty`` participates ONLY when ``risk_k > 0``.
    With ``risk_k == 0`` the dict is never indexed (so it may be sparse
    or partial) and the schedule is identical to not passing it at all —
    a ``UserWarning`` flags the combination, since silently dropping a
    supplied sigma surprised real callers.

    ``edge_gb`` maps ``(producer_id, consumer_id)`` to the GB shipped
    along that edge; ``secs_per_gb`` is the (N, N) node-pair transfer
    price aligned with ``nodes`` (see ``Topology.secs_per_gb``).  Both
    must be supplied for data-aware placement — edge sizes without a
    bandwidth matrix cannot be priced, and by the same
    silently-dropped-input contract as ``uncertainty`` the combination
    warns (once per call site) and schedules compute-only."""
    ids = list(tasks)
    if uncertainty is not None and risk_k == 0:
        warnings.warn(
            "heft_schedule: uncertainty was provided but risk_k == 0, so "
            "it is ignored — pass risk_k > 0 for uncertainty-aware "
            "ranking/placement (effective cost = mean + risk_k * sigma)",
            UserWarning, stacklevel=2)
    if edge_gb is not None and secs_per_gb is None:
        warnings.warn(
            "heft_schedule: edge data sizes (edge_gb) were provided but no "
            "bandwidth matrix (secs_per_gb) is configured, so transfer "
            "costs are ignored — pass a Topology-derived secs_per_gb for "
            "data-aware ranking/placement",
            UserWarning, stacklevel=2)
    if not ids:
        return {"assignment": {}, "start": {}, "finish": {},
                "makespan": 0.0, "order": []}
    idx = {tid: i for i, tid in enumerate(ids)}
    C = np.array([[cost[t][n] for n in nodes] for t in ids])
    # only materialise sigma when it will be used: a sparse/partial
    # uncertainty dict with risk_k == 0 must not be indexed (reference
    # semantics)
    U = (np.array([[uncertainty[t][n] for n in nodes] for t in ids])
         if uncertainty is not None and risk_k > 0 else None)
    succ = [[idx[s] for s in tasks[t].succ] for t in ids]
    pred = [[idx[p] for p in tasks[t].pred] for t in ids]
    comm = None
    if edge_gb is not None and secs_per_gb is not None:
        comm = CommCosts(pred,
                         {(idx[p], idx[s]): g
                          for (p, s), g in edge_gb.items()
                          if p in idx and s in idx},
                         secs_per_gb)
    r = heft_schedule_array(succ, pred, C, U, risk_k, comm=comm)
    return {"assignment": {ids[i]: nodes[r["assignment"][i]]
                           for i in range(len(ids))},
            "start": {ids[i]: float(r["start"][i]) for i in range(len(ids))},
            "finish": {ids[i]: float(r["finish"][i]) for i in range(len(ids))},
            "makespan": r["makespan"],
            "order": [ids[i] for i in r["order"]]}


def heft_schedule_reference(tasks: dict[str, SchedTask],
                            cost: dict[str, dict[str, float]],
                            nodes: list[str],
                            uncertainty: dict[str, dict[str, float]] | None = None,
                            risk_k: float = 0.0,
                            edge_gb: dict[tuple[str, str], float] | None = None,
                            secs_per_gb: np.ndarray | None = None) -> dict:
    """The original pure-Python dict-of-dicts HEFT, kept as the equivalence
    oracle for tests and the baseline for benchmarks/bench_predict.py.
    Like the fast path, the risk-adjusted effective cost drives both the
    upward rank and the EFT placement.

    ``edge_gb`` / ``secs_per_gb`` mirror ``heft_schedule``'s data-aware
    knobs with the same semantics, independently implemented over dicts:
    the rank charges the placement-free average price per edge, the EFT
    loop the placement-dependent ``finish[p] + gb * spg[node(p)][n]``
    arrival floor.  The property suite in ``tests/test_comm_sched.py``
    holds the array path to this oracle bit-for-bit, comm on and off."""
    def eff(tid: str, node: str) -> float:
        c = cost[tid][node]
        if uncertainty is not None and risk_k > 0:
            c = c + risk_k * uncertainty[tid][node]
        return c

    if uncertainty is not None and risk_k > 0:
        eff_cost = {t: {n: eff(t, n) for n in nodes} for t in tasks}
    else:
        eff_cost = cost
    spg = None
    edge_comm = None
    if edge_gb is not None and secs_per_gb is not None:
        spg = np.asarray(secs_per_gb, np.float64)
        mean_spg = float(spg.mean())
        edge_comm = {(p, s): float(g) * mean_spg
                     for (p, s), g in edge_gb.items()}
    rank = _upward_rank(tasks, eff_cost, edge_comm=edge_comm)
    order = sorted(tasks, key=lambda t: -rank[t])
    nidx = {n: i for i, n in enumerate(nodes)}
    node_free = {n: 0.0 for n in nodes}
    finish: dict[str, float] = {}
    start: dict[str, float] = {}
    assignment: dict[str, str] = {}
    for tid in order:
        best, best_ft, best_st = None, float("inf"), 0.0
        for n in nodes:
            if spg is None:
                ready = max((finish[p] for p in tasks[tid].pred),
                            default=0.0)
            else:
                ready = 0.0
                for p in tasks[tid].pred:
                    gb = float(edge_gb.get((p, tid), 0.0))
                    arr = finish[p] + gb * spg[nidx[assignment[p]], nidx[n]]
                    if arr > ready:
                        ready = arr
            st = max(node_free[n], ready)
            ft = st + eff(tid, n)
            if ft < best_ft:
                best, best_ft, best_st = n, ft, st
        assignment[tid] = best
        start[tid] = best_st
        finish[tid] = best_ft
        node_free[best] = best_ft
    return {"assignment": assignment, "start": start, "finish": finish,
            "makespan": max(finish.values()) if finish else 0.0,
            "order": order}


def realized_makespan(succ: list[list[int]], pred: list[list[int]],
                      dur: np.ndarray, assignment: np.ndarray,
                      order: np.ndarray,
                      comm: CommCosts | None = None) -> float:
    """Replay a fixed placement under *true* per-task durations and
    transfer prices — the neutral judge for the data-locality bench.

    A plan's quality is not its own optimistic makespan: a comm-blind
    schedule claims transfers are free, so comparing planners by their
    self-reported makespans would reward the blindness.  This evaluator
    executes both plans (``assignment`` + dispatch ``order`` from any
    ``heft_schedule_array`` result) in list-scheduling order and charges
    every edge the REAL arrival delay ``finish[p] + gb·spg[node(p),
    node(t)]``, so the cross-rack copy the blind planner ignored shows
    up in its realized number."""
    dur = np.asarray(dur, np.float64)
    T = len(dur)
    node_free: dict[int, float] = {}
    finish = np.zeros(T)
    for t in order:
        t = int(t)
        j = int(assignment[t])
        ready = 0.0
        if comm is None:
            for p in pred[t]:
                if finish[p] > ready:
                    ready = finish[p]
        else:
            pi, gbs = comm.pred_idx[t], comm.pred_gb[t]
            for p, gb in zip(pi, gbs):
                arr = finish[p] + float(gb) * comm.secs_per_gb[
                    int(assignment[p]), j]
                if arr > ready:
                    ready = arr
        st = max(node_free.get(j, 0.0), ready)
        finish[t] = st + dur[t]
        node_free[j] = finish[t]
    return float(finish.max()) if T else 0.0


def round_robin_schedule(tasks: dict[str, SchedTask], nodes: list[str]) -> dict:
    """FIFO/fair baseline (what resource managers do without estimates)."""
    assignment = {tid: nodes[i % len(nodes)]
                  for i, tid in enumerate(sorted(tasks))}
    return {"assignment": assignment}


# ---------------------------------------------------------------------------
# Straggler mitigation + elastic rescheduling (simulation-level)
# ---------------------------------------------------------------------------
def detect_stragglers(records: list[dict], predictions: dict[str, tuple],
                      k: float = 3.0) -> list[str]:
    """records: [{id, node, duration}]; predictions[id] = (mean, sigma).
    Returns ids whose measured duration exceeds mean + k*sigma."""
    out = []
    for r in records:
        mean, sigma = predictions.get(r["id"], (None, None))
        if mean is None:
            continue
        if r["duration"] > mean + k * max(sigma, 1e-9):
            out.append(r["id"])
    return out


def simulate_with_stragglers(tasks, cost, nodes, true_runtime,
                             predictions, straggler_k: float = 3.0,
                             speculative: bool = True):
    """Execute a HEFT schedule where true runtimes may include stragglers;
    speculative copies launch on the fastest other node when the predicted
    envelope (mean + k*sigma) is exceeded.  Returns makespans with and
    without mitigation (list-scheduling approximation)."""
    sched = heft_schedule(tasks, cost, nodes)
    node_free = {n: 0.0 for n in nodes}
    finish: dict[str, float] = {}
    rank = _upward_rank(tasks, cost)
    mitigated = 0
    for tid in sorted(tasks, key=lambda t: -rank[t]):
        ready = max((finish[p] for p in tasks[tid].pred), default=0.0)
        node = sched["assignment"][tid]
        st = max(node_free[node], ready)
        dur = true_runtime(tid, node)
        mean, sigma = predictions[tid]
        envelope = mean + straggler_k * max(sigma, 1e-9)
        if speculative and dur > envelope:
            # launch a copy at the envelope time on the best other node,
            # preferring a different node TYPE (the "type/i" prefix) —
            # compare the type segment exactly: a prefix test would
            # falsely exclude distinct nodes sharing a name prefix
            # (e.g. "n1" knocking out "n10")
            ntype = node.split("/")[0]
            others = [n for n in nodes if n.split("/")[0] != ntype]
            others = others or [n for n in nodes if n != node]
            alt = min(others, key=lambda n: cost[tid][n]) if others else node
            alt_st = max(node_free[alt], st + envelope)
            alt_ft = alt_st + true_runtime(tid, alt)
            orig_ft = st + dur
            if alt_ft < orig_ft:
                mitigated += 1
                finish[tid] = alt_ft
                node_free[alt] = alt_ft
                # the original is killed the moment the straggler is
                # detected (envelope exceeded), freeing its node then —
                # not when either attempt would have finished
                node_free[node] = st + envelope
                continue
        finish[tid] = st + dur
        node_free[node] = st + dur
    return {"makespan": max(finish.values()) if finish else 0.0,
            "mitigated": mitigated}


def reschedule_elastic(tasks, cost, nodes_alive, done: set[str]) -> dict:
    """Re-run HEFT over the unfinished subgraph on surviving nodes."""
    remaining = {tid: t for tid, t in tasks.items() if tid not in done}
    pruned = {}
    for tid, t in remaining.items():
        pruned[tid] = SchedTask(id=tid,
                                succ=[s for s in t.succ if s in remaining],
                                pred=[p for p in t.pred if p in remaining])
    cost_sub = {tid: {n: cost[tid][n] for n in nodes_alive}
                for tid in pruned}
    return heft_schedule(pruned, cost_sub, nodes_alive)
