"""HEFT (Topcuoglu et al.) + Lotaru-informed variants.

The paper's motivation (§2.2): HEFT-class schedulers need runtime estimates
for every (task, node) pair, which Lotaru supplies online.  We implement:

  * ``heft_schedule``     — classic HEFT over a (task x node) estimate matrix
  * uncertainty-aware variant: ranks use mean + k*sigma (Bayesian predictive
    std from Lotaru), penalising placements whose runtime is *uncertain* —
    the paper's "advanced scheduling methods" consumer.
  * straggler mitigation — runtime > mean + k*sigma triggers speculative
    re-execution on the fastest idle node.
  * elastic rescheduling — on node loss/join, unfinished tasks re-ranked.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field

import numpy as np


@dataclass
class SchedTask:
    id: str
    succ: list[str] = field(default_factory=list)
    pred: list[str] = field(default_factory=list)


def _upward_rank(tasks: dict[str, SchedTask], cost: dict[str, dict[str, float]],
                 comm: float = 0.0) -> dict[str, float]:
    mean_cost = {t: float(np.mean(list(cost[t].values()))) for t in tasks}
    rank: dict[str, float] = {}

    def rec(tid: str) -> float:
        if tid in rank:
            return rank[tid]
        t = tasks[tid]
        best_succ = max((comm + rec(s) for s in t.succ), default=0.0)
        rank[tid] = mean_cost[tid] + best_succ
        return rank[tid]

    for tid in tasks:
        rec(tid)
    return rank


def _topo_order(succ: list[list[int]], pred: list[list[int]]) -> list[int]:
    """Kahn's algorithm; iterative, so 10k-deep chains don't blow the
    Python recursion limit like the recursive reference rank does."""
    indeg = [len(p) for p in pred]
    queue = [i for i, d in enumerate(indeg) if d == 0]
    topo: list[int] = []
    head = 0
    while head < len(queue):
        t = queue[head]
        head += 1
        topo.append(t)
        for s in succ[t]:
            indeg[s] -= 1
            if indeg[s] == 0:
                queue.append(s)
    if len(topo) != len(succ):
        raise ValueError("task graph contains a cycle")
    return topo


def upward_rank_array(succ: list[list[int]], pred: list[list[int]],
                      mean_cost: np.ndarray, comm: float = 0.0) -> np.ndarray:
    """Iterative upward rank over index-based adjacency; (T,) array."""
    topo = _topo_order(succ, pred)
    rank = np.zeros(len(succ))
    for t in reversed(topo):
        best = 0.0
        for s in succ[t]:
            best = max(best, comm + rank[s])
        rank[t] = mean_cost[t] + best
    return rank


def upward_rank_incremental(succ: list[list[int]], pred: list[list[int]],
                            mean_cost: np.ndarray, prev_rank: np.ndarray,
                            dirty, comm: float = 0.0,
                            topo: list[int] | None = None) -> np.ndarray:
    """Refresh an upward rank after a sparse cost change — bitwise equal
    to recomputing ``upward_rank_array`` from scratch (test-enforced
    oracle, see ``tests/test_scheduler.py``).

    ``dirty`` indexes the tasks whose ``mean_cost`` changed since
    ``prev_rank`` was computed.  A task's rank depends only on its own
    cost and its successors' ranks, so the stale entries are exactly
    ``dirty`` plus its ancestor closure — everything else is carried
    over.  The online executor's re-plan path uses this: a tick dirties
    only the observed rows' instances, so the re-rank touches the
    affected ancestor chains instead of the whole DAG (``topo`` can be
    passed in to amortise the one remaining O(T) pass)."""
    if topo is None:
        topo = _topo_order(succ, pred)
    affected = {int(d) for d in np.asarray(dirty).ravel()}
    stack = list(affected)
    while stack:
        t = stack.pop()
        for p in pred[t]:
            if p not in affected:
                affected.add(p)
                stack.append(p)
    rank = np.array(prev_rank, np.float64, copy=True)
    for t in reversed(topo):
        if t not in affected:
            continue
        best = 0.0
        for s in succ[t]:
            best = max(best, comm + rank[s])
        rank[t] = mean_cost[t] + best
    return rank


def heft_schedule_array(succ: list[list[int]], pred: list[list[int]],
                        cost: np.ndarray,
                        uncertainty: np.ndarray | None = None,
                        risk_k: float = 0.0,
                        node_ready: np.ndarray | None = None,
                        task_ready: np.ndarray | None = None,
                        rank: np.ndarray | None = None) -> dict:
    """HEFT over a (T, N) cost matrix — the ndarray fast path.

    ``succ`` / ``pred`` are index-based adjacency lists; ``cost[t, n]`` the
    estimated runtime of task t on node n (``uncertainty`` likewise, used
    when risk_k > 0: effective cost = mean + risk_k * sigma).  The
    effective cost drives the schedule END TO END — both the upward rank
    (task priority) and the EFT placement inner loop — so under
    ``risk_k > 0`` uncertain tasks are ranked more urgent (their risk
    inflates every successor chain through them) *and* uncertain
    placements are penalised.  The EFT inner loop is vectorised over the
    node axis.  ``node_ready`` (N,) / ``task_ready`` (T,) are
    earliest-availability floors for mid-execution re-planning: node j is
    busy until node_ready[j], task t's external predecessors (already
    done or running) finish at task_ready[t].  Returns index-based
    arrays: {assignment (T,) int, start (T,), finish (T,), makespan,
    order (T,) int}.

    ``rank`` short-circuits the internal upward-rank pass with a
    caller-maintained priority vector (e.g. an incrementally refreshed
    ``upward_rank_incremental`` slice) — it must equal what
    ``upward_rank_array`` would compute over this subgraph for the
    schedule to be unchanged."""
    cost = np.asarray(cost, np.float64)
    T, N = cost.shape
    eff = cost
    if uncertainty is not None and risk_k > 0:
        eff = cost + risk_k * np.asarray(uncertainty, np.float64)
    if rank is None:
        rank = upward_rank_array(succ, pred, eff.mean(axis=1))
    else:
        rank = np.asarray(rank, np.float64)
    order = np.argsort(-rank, kind="stable")
    node_free = (np.zeros(N) if node_ready is None
                 else np.asarray(node_ready, np.float64).copy())
    floors = (np.zeros(T) if task_ready is None
              else np.asarray(task_ready, np.float64))
    start = np.zeros(T)
    finish = np.zeros(T)
    assignment = np.zeros(T, np.int64)
    for t in order:
        ready = floors[t]
        for p in pred[t]:
            if finish[p] > ready:
                ready = finish[p]
        st = np.maximum(node_free, ready)          # (N,)
        ft = st + eff[t]
        j = int(np.argmin(ft))
        assignment[t] = j
        start[t] = st[j]
        finish[t] = ft[j]
        node_free[j] = ft[j]
    return {"assignment": assignment, "start": start, "finish": finish,
            "makespan": float(finish.max()) if T else 0.0, "order": order}


def heft_schedule(tasks: dict[str, SchedTask],
                  cost: dict[str, dict[str, float]],
                  nodes: list[str],
                  uncertainty: dict[str, dict[str, float]] | None = None,
                  risk_k: float = 0.0) -> dict:
    """cost[task][node] = estimated runtime; uncertainty likewise (sigma).

    risk_k > 0 gives the uncertainty-aware variant: effective cost =
    mean + risk_k * sigma, applied to both the upward rank and the EFT
    placement.  Returns {assignment, start, finish, makespan, order}.
    Thin dict wrapper over ``heft_schedule_array``.

    Contract: ``uncertainty`` participates ONLY when ``risk_k > 0``.
    With ``risk_k == 0`` the dict is never indexed (so it may be sparse
    or partial) and the schedule is identical to not passing it at all —
    a ``UserWarning`` flags the combination, since silently dropping a
    supplied sigma surprised real callers."""
    ids = list(tasks)
    if uncertainty is not None and risk_k == 0:
        warnings.warn(
            "heft_schedule: uncertainty was provided but risk_k == 0, so "
            "it is ignored — pass risk_k > 0 for uncertainty-aware "
            "ranking/placement (effective cost = mean + risk_k * sigma)",
            UserWarning, stacklevel=2)
    if not ids:
        return {"assignment": {}, "start": {}, "finish": {},
                "makespan": 0.0, "order": []}
    idx = {tid: i for i, tid in enumerate(ids)}
    C = np.array([[cost[t][n] for n in nodes] for t in ids])
    # only materialise sigma when it will be used: a sparse/partial
    # uncertainty dict with risk_k == 0 must not be indexed (reference
    # semantics)
    U = (np.array([[uncertainty[t][n] for n in nodes] for t in ids])
         if uncertainty is not None and risk_k > 0 else None)
    succ = [[idx[s] for s in tasks[t].succ] for t in ids]
    pred = [[idx[p] for p in tasks[t].pred] for t in ids]
    r = heft_schedule_array(succ, pred, C, U, risk_k)
    return {"assignment": {ids[i]: nodes[r["assignment"][i]]
                           for i in range(len(ids))},
            "start": {ids[i]: float(r["start"][i]) for i in range(len(ids))},
            "finish": {ids[i]: float(r["finish"][i]) for i in range(len(ids))},
            "makespan": r["makespan"],
            "order": [ids[i] for i in r["order"]]}


def heft_schedule_reference(tasks: dict[str, SchedTask],
                            cost: dict[str, dict[str, float]],
                            nodes: list[str],
                            uncertainty: dict[str, dict[str, float]] | None = None,
                            risk_k: float = 0.0) -> dict:
    """The original pure-Python dict-of-dicts HEFT, kept as the equivalence
    oracle for tests and the baseline for benchmarks/bench_predict.py.
    Like the fast path, the risk-adjusted effective cost drives both the
    upward rank and the EFT placement."""
    def eff(tid: str, node: str) -> float:
        c = cost[tid][node]
        if uncertainty is not None and risk_k > 0:
            c = c + risk_k * uncertainty[tid][node]
        return c

    if uncertainty is not None and risk_k > 0:
        eff_cost = {t: {n: eff(t, n) for n in nodes} for t in tasks}
    else:
        eff_cost = cost
    rank = _upward_rank(tasks, eff_cost)
    order = sorted(tasks, key=lambda t: -rank[t])
    node_free = {n: 0.0 for n in nodes}
    finish: dict[str, float] = {}
    start: dict[str, float] = {}
    assignment: dict[str, str] = {}
    for tid in order:
        ready = max((finish[p] for p in tasks[tid].pred), default=0.0)
        best, best_ft, best_st = None, float("inf"), 0.0
        for n in nodes:
            st = max(node_free[n], ready)
            ft = st + eff(tid, n)
            if ft < best_ft:
                best, best_ft, best_st = n, ft, st
        assignment[tid] = best
        start[tid] = best_st
        finish[tid] = best_ft
        node_free[best] = best_ft
    return {"assignment": assignment, "start": start, "finish": finish,
            "makespan": max(finish.values()) if finish else 0.0,
            "order": order}


def round_robin_schedule(tasks: dict[str, SchedTask], nodes: list[str]) -> dict:
    """FIFO/fair baseline (what resource managers do without estimates)."""
    assignment = {tid: nodes[i % len(nodes)]
                  for i, tid in enumerate(sorted(tasks))}
    return {"assignment": assignment}


# ---------------------------------------------------------------------------
# Straggler mitigation + elastic rescheduling (simulation-level)
# ---------------------------------------------------------------------------
def detect_stragglers(records: list[dict], predictions: dict[str, tuple],
                      k: float = 3.0) -> list[str]:
    """records: [{id, node, duration}]; predictions[id] = (mean, sigma).
    Returns ids whose measured duration exceeds mean + k*sigma."""
    out = []
    for r in records:
        mean, sigma = predictions.get(r["id"], (None, None))
        if mean is None:
            continue
        if r["duration"] > mean + k * max(sigma, 1e-9):
            out.append(r["id"])
    return out


def simulate_with_stragglers(tasks, cost, nodes, true_runtime,
                             predictions, straggler_k: float = 3.0,
                             speculative: bool = True):
    """Execute a HEFT schedule where true runtimes may include stragglers;
    speculative copies launch on the fastest other node when the predicted
    envelope (mean + k*sigma) is exceeded.  Returns makespans with and
    without mitigation (list-scheduling approximation)."""
    sched = heft_schedule(tasks, cost, nodes)
    node_free = {n: 0.0 for n in nodes}
    finish: dict[str, float] = {}
    rank = _upward_rank(tasks, cost)
    mitigated = 0
    for tid in sorted(tasks, key=lambda t: -rank[t]):
        ready = max((finish[p] for p in tasks[tid].pred), default=0.0)
        node = sched["assignment"][tid]
        st = max(node_free[node], ready)
        dur = true_runtime(tid, node)
        mean, sigma = predictions[tid]
        envelope = mean + straggler_k * max(sigma, 1e-9)
        if speculative and dur > envelope:
            # launch a copy at the envelope time on the best other node,
            # preferring a different node TYPE (the "type/i" prefix) —
            # compare the type segment exactly: a prefix test would
            # falsely exclude distinct nodes sharing a name prefix
            # (e.g. "n1" knocking out "n10")
            ntype = node.split("/")[0]
            others = [n for n in nodes if n.split("/")[0] != ntype]
            others = others or [n for n in nodes if n != node]
            alt = min(others, key=lambda n: cost[tid][n]) if others else node
            alt_st = max(node_free[alt], st + envelope)
            alt_ft = alt_st + true_runtime(tid, alt)
            orig_ft = st + dur
            if alt_ft < orig_ft:
                mitigated += 1
                finish[tid] = alt_ft
                node_free[alt] = alt_ft
                # the original is killed the moment the straggler is
                # detected (envelope exceeded), freeing its node then —
                # not when either attempt would have finished
                node_free[node] = st + envelope
                continue
        finish[tid] = st + dur
        node_free[node] = st + dur
    return {"makespan": max(finish.values()) if finish else 0.0,
            "mitigated": mitigated}


def reschedule_elastic(tasks, cost, nodes_alive, done: set[str]) -> dict:
    """Re-run HEFT over the unfinished subgraph on surviving nodes."""
    remaining = {tid: t for tid, t in tasks.items() if tid not in done}
    pruned = {}
    for tid, t in remaining.items():
        pruned[tid] = SchedTask(id=tid,
                                succ=[s for s in t.succ if s in remaining],
                                pred=[p for p in t.pred if p in remaining])
    cost_sub = {tid: {n: cost[tid][n] for n in nodes_alive}
                for tid in pruned}
    return heft_schedule(pruned, cost_sub, nodes_alive)
