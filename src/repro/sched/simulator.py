"""Heterogeneous-cluster ground-truth simulator.

Two workload planes share the node registry:

* genomics plane — nf-core-like tasks with hidden (cpu_unit, io_unit)
  ground truth (see workflows.py).  Supports the paper's CPU-frequency
  reduction faithfully via ``cpu_factor``.
* ML plane — (arch x shape) workload cells whose hidden ground truth is the
  three-term roofline of the *actual compiled dry-run HLO*, scaled by each
  node type's rates and hidden per-family efficiency.

Also provides the discrete-event engine used by the scheduler benchmarks
(task queues per node, failures, stragglers, elastic node loss/join).
"""
from __future__ import annotations

import heapq
import json
import warnings
from dataclasses import dataclass, field
from pathlib import Path

import numpy as np

from repro.core.nodes import NodeType, get_node, target_nodes
from .workflows import REF_CPU, REF_IO, TaskDef, effective_size


class ClusterSimulator:
    """Ground-truth runtimes; Lotaru never sees the units, only runtimes.

    ``systematic`` adds a fixed per-(task, node) efficiency multiplier
    (lognormal, derived from a stable hash): real tools hit different
    codepaths / cache behaviour on different machines, which is exactly why
    scalar factor adjustment has an error floor in the paper's Tables 4-6.

    ``het`` makes the run-to-run noise heteroscedastic per (task, node)
    pair: the lognormal sd becomes ``noise * (1 + het * u)`` with a
    stable-hash ``u`` in [0, 1), so some pairs are far jitterier than
    others — the regime where risk-aware (mean + k*sigma) placement beats
    risk-neutral placement.  ``het=0`` (default) keeps the homoscedastic
    behaviour bit-exactly.
    """

    def __init__(self, seed: int = 0, noise: float = 0.05,
                 systematic: float = 0.10, het: float = 0.0,
                 topology: "Topology | None" = None):
        self.rng = np.random.default_rng(seed)
        self.noise = noise
        self.systematic = systematic
        self.het = het
        self.topology = topology

    # ---- data plane --------------------------------------------------------
    def transfer_time(self, gb: float, src: str, dst: str,
                      noisy: bool = True) -> float:
        """Ground-truth seconds to ship ``gb`` from node ``src`` to
        ``dst`` under the configured ``Topology`` (0 without one, or on
        the same node — the data is already there).  ``noisy`` applies
        the same lognormal run-to-run jitter as task runtimes; the
        noise-free value is what a perfectly-informed planner would
        price, so bench arms compare against ``noisy=False`` truth."""
        if self.topology is None or src == dst or gb <= 0:
            return 0.0
        t = float(gb) * self.topology.pair_secs_per_gb(src, dst)
        if noisy and t > 0:
            t *= self.rng.lognormal(0.0, self.noise)
        return float(t)

    @staticmethod
    def _pair_rng(task_name: str, node_name: str,
                  tag: str) -> np.random.Generator:
        """Deterministic per-(task, node, property) generator from a
        stable hash (crc32, not builtin ``hash`` — stable across
        processes): hidden pair properties are fixed facts of the
        cluster, not draws from the simulation stream."""
        import zlib
        h = zlib.crc32(f"{task_name}|{node_name}|{tag}".encode()) % (2 ** 31)
        return np.random.default_rng(h)

    def _sys_mult(self, task_name: str, node_name: str) -> float:
        if self.systematic <= 0:
            return 1.0
        g = self._pair_rng(task_name, node_name, "sys").normal(
            0.0, self.systematic)
        return float(np.exp(g))

    def noise_sd(self, task_name: str, node_name: str) -> float:
        """Lognormal sd of this pair's run-to-run jitter (``noise`` unless
        ``het > 0``; the per-pair factor comes from a stable hash, so it
        is a fixed property of the pair, not a draw)."""
        if self.het <= 0:
            return self.noise
        u = float(self._pair_rng(task_name, node_name, "het").random())
        return self.noise * (1.0 + self.het * u)

    # ---- genomics plane ---------------------------------------------------
    def run_task(self, task: TaskDef, node: NodeType, size_gb: float,
                 cpu_factor: float = 1.0, noisy: bool = True) -> float:
        s = effective_size(task, size_gb)
        cpu_t = (task.base * task.cpu_share + task.cpu_unit * s) \
            * (REF_CPU / node.cpu_score) / cpu_factor
        io_t = (task.base * (1 - task.cpu_share) + task.io_unit * s) \
            * (REF_IO / node.io_bw)
        t = (cpu_t + io_t) * self._sys_mult(task.name, node.name)
        if noisy:
            t *= self.rng.lognormal(0.0, self.noise_sd(task.name, node.name))
        return float(t)

    def expected_task_runtime(self, task: TaskDef, node: NodeType,
                              size_gb: float) -> float:
        return self.run_task(task, node, size_gb, noisy=False)

    def actual_factor(self, task: TaskDef, local: NodeType, target: NodeType,
                      size_gb: float) -> float:
        """True runtime ratio target/local (paper Tables 4-5)."""
        return (self.expected_task_runtime(task, target, size_gb)
                / self.expected_task_runtime(task, local, size_gb))

    # ---- ML plane ----------------------------------------------------------
    def run_cell(self, cell: dict, node: NodeType, token_fraction: float = 1.0,
                 chips: int | None = None, cpu_factor: float = 1.0,
                 noisy: bool = True) -> float:
        """Step time of a dry-run cell record on `chips` of `node`'s type.
        ``cpu_factor < 1`` throttles the compute units (the paper's reduced
        CPU-frequency probe, phase 2)."""
        r = cell["roofline"]
        base_chips = r["chips"]
        chips = chips or base_chips
        scale = token_fraction * base_chips / chips
        family = cell.get("family", "*")
        eff = node.eff(family)
        compute = r["flops_per_device"] * scale / (node.peak_flops * eff
                                                   * cpu_factor)
        memory = r["bytes_per_device"] * scale / node.hbm_bw
        coll = r["coll_bytes_per_device"] * scale / node.link_bw
        t = max(compute, memory, coll) + 0.35 * min(compute, memory, coll)
        if noisy:
            t *= self.rng.lognormal(0.0, self.noise)
        return float(t)


# ---------------------------------------------------------------------------
# Zone/rack topology (bandwidth matrix for data-aware scheduling)
# ---------------------------------------------------------------------------
class Topology:
    """Zone (rack) placement + pairwise bandwidth — the cluster-side half
    of data-aware HEFT (``repro.sched.heft.CommCosts`` is the DAG-side
    half).

    ``zones`` maps node name -> zone label; ``bandwidth_gbps`` prices a
    zone *pair* in GB/s (unordered — ``(a, b)`` and ``(b, a)`` are the
    same link; the zone-keyed dict shape follows the grid-engine
    ``COMM_COSTS`` convention).  Unlisted pairs fall back to
    ``intra_gbps`` within a zone and ``cross_gbps`` across zones, so the
    common two-tier rack model needs no explicit table at all.  The
    scheduler consumes the *reciprocal*: seconds per GB, zero on the
    diagonal (same node — no copy), small within a zone, large across
    racks.
    """

    def __init__(self, zones: dict[str, str],
                 bandwidth_gbps: dict[tuple[str, str], float] | None = None,
                 intra_gbps: float = 10.0, cross_gbps: float = 1.0):
        if intra_gbps <= 0 or cross_gbps <= 0:
            raise ValueError("bandwidths must be positive (zero bandwidth "
                             "would make every transfer infinite)")
        self.zones = {str(n): str(z) for n, z in zones.items()}
        self.bandwidth_gbps: dict[frozenset, float] = {}
        for (z1, z2), g in (bandwidth_gbps or {}).items():
            if g <= 0:
                raise ValueError(f"bandwidth for zone pair ({z1}, {z2}) "
                                 f"must be positive, got {g}")
            self.bandwidth_gbps[frozenset((str(z1), str(z2)))] = float(g)
        self.intra_gbps = float(intra_gbps)
        self.cross_gbps = float(cross_gbps)

    @classmethod
    def split(cls, names: list[str], n_zones: int = 2,
              **kw) -> "Topology":
        """Deal ``names`` round-robin into ``rack0..rack{n-1}`` — the
        stock cross-rack scenario used by the bench and tests.
        Round-robin (not contiguous blocks) so every node *type* spans
        racks: with ``from_types``-style ``type/0, type/1, ...`` naming,
        a type's instances land in different zones and placement has a
        real locality choice to make."""
        if n_zones < 1:
            raise ValueError(f"n_zones must be >= 1, got {n_zones}")
        return cls({n: f"rack{i % n_zones}" for i, n in enumerate(names)},
                   **kw)

    @classmethod
    def blocks(cls, names: list[str], n_zones: int = 2,
               **kw) -> "Topology":
        """Deal ``names`` in contiguous blocks into ``rack0..rack{n-1}``.
        With ``from_types`` ordering this concentrates each node type in
        one rack — racks become heterogeneous in speed, so chasing the
        fastest hardware means leaving the rack your data is on.  The
        adversarial counterpart to ``split`` for locality benches."""
        if n_zones < 1:
            raise ValueError(f"n_zones must be >= 1, got {n_zones}")
        per = max(1, -(-len(names) // n_zones))
        return cls({n: f"rack{min(i // per, n_zones - 1)}"
                    for i, n in enumerate(names)}, **kw)

    def zone(self, name: str) -> str:
        return self.zones[name]

    def gbps(self, z1: str, z2: str) -> float:
        """Bandwidth between two zones (symmetric)."""
        key = frozenset((z1, z2))
        if key in self.bandwidth_gbps:
            return self.bandwidth_gbps[key]
        return self.intra_gbps if z1 == z2 else self.cross_gbps

    def pair_secs_per_gb(self, src: str, dst: str) -> float:
        """Transfer price for one node pair: 0 on the same node."""
        if src == dst:
            return 0.0
        return 1.0 / self.gbps(self.zones[src], self.zones[dst])

    def secs_per_gb(self, names: list[str],
                    alive: dict[str, bool] | None = None) -> np.ndarray:
        """(N, N) seconds-per-GB matrix over ``names`` — what
        ``CommCosts`` consumes.  Zero diagonal; same-zone pairs get the
        intra rate (the zone discount), cross-zone the link rate.

        ``alive`` masks dead nodes *as data sources*: a crashed node's
        outgoing rows are re-priced at the worst finite off-diagonal
        rate in the matrix, so the planner can never treat a dead
        replica as a cheap place to read an input from (placement ON
        dead nodes is already impossible via the executor's ``+inf``
        ``ready_vector``; this closes the source side).  The masking is
        stateless — recomputing after a rejoin restores the node's real
        prices automatically."""
        unknown = [n for n in names if n not in self.zones]
        if unknown:
            raise KeyError(f"nodes missing from topology zones: {unknown}")
        N = len(names)
        spg = np.zeros((N, N))
        for i, a in enumerate(names):
            for j, b in enumerate(names):
                if i != j:
                    spg[i, j] = 1.0 / self.gbps(self.zones[a], self.zones[b])
        if alive is not None:
            dead = [i for i, n in enumerate(names) if not alive.get(n, True)]
            if dead and N > 1:
                off = spg[~np.eye(N, dtype=bool)]
                worst = float(off.max())
                for i in dead:
                    spg[i, :] = worst
                    spg[i, i] = 0.0   # CommCosts' free-diagonal invariant
        return spg

    def secs_per_gb_dict(self, names: list[str]
                         ) -> dict[str, dict[str, float]]:
        """Dict-of-dicts view of ``secs_per_gb`` for the string-keyed
        ``heft_schedule`` API and debugging."""
        spg = self.secs_per_gb(names)
        return {a: {b: float(spg[i, j]) for j, b in enumerate(names)}
                for i, a in enumerate(names)}


# ---------------------------------------------------------------------------
# Fault process (node crashes, transient outages, attempt failures)
# ---------------------------------------------------------------------------
class FaultInjector:
    """Deterministic, seeded fault process for the online execution loop.

    Three failure modes, mirroring real grid-engine churn:

    * **permanent crashes** — ``crash_at[node] = t``: the node dies at
      ``t`` and never returns; running attempts there are lost.
    * **transient outages** — ``outages[node] = (down, up)``: the node is
      lost at ``down`` (running attempts killed) and rejoins at ``up``.
    * **attempt failures** — each (task, node) pair carries a fixed
      failure probability derived from a stable hash, exactly like the
      cluster's hidden ``het``/``systematic`` pair properties:
      ``p = min(1, p_fail * (1 + p_spread * u))`` with ``u`` uniform in
      [0, 1) per pair.  Whether attempt ``k`` of a task on a node fails —
      and at what fraction of its runtime the failure manifests — is a
      deterministic function of (task, node, attempt, seed), so the same
      scenario replays bit-identically.

    The injector only *describes* faults; the ``OnlineExecutor`` applies
    them (``faults=None`` there keeps the fault-free loop bit-exact).
    """

    def __init__(self, *, crash_at: dict[str, float] | None = None,
                 outages: dict[str, tuple[float, float]] | None = None,
                 p_fail: float = 0.0, p_spread: float = 1.0, seed: int = 0):
        if not 0.0 <= p_fail <= 1.0:
            raise ValueError(f"p_fail must be in [0, 1], got {p_fail}")
        self.crash_at = {str(k): float(v)
                         for k, v in (crash_at or {}).items()}
        self.outages = {str(k): (float(v[0]), float(v[1]))
                        for k, v in (outages or {}).items()}
        for node, (down, up) in self.outages.items():
            if up <= down:
                raise ValueError(f"outage on {node!r}: up {up} <= down "
                                 f"{down}")
        self.p_fail = float(p_fail)
        self.p_spread = float(p_spread)
        self.seed = int(seed)

    def _rng(self, *parts) -> np.random.Generator:
        """Stable-hash generator (crc32, like ``ClusterSimulator._pair_rng``
        — stable across processes): fault properties are fixed facts of
        the scenario, not draws from a shared stream."""
        import zlib
        key = "|".join(str(p) for p in parts) + f"|{self.seed}"
        return np.random.default_rng(zlib.crc32(key.encode()) % (2 ** 31))

    def node_events(self) -> list[tuple[float, str, str]]:
        """Time-sorted membership events: ``(time, node, 'down'|'up')``."""
        evs = [(t, n, "down") for n, t in self.crash_at.items()]
        for n, (down, up) in self.outages.items():
            evs.append((down, n, "down"))
            evs.append((up, n, "up"))
        return sorted(evs)

    def attempt_fail_prob(self, task_id: str, node: str) -> float:
        """The pair's fixed per-attempt failure probability."""
        if self.p_fail <= 0.0:
            return 0.0
        u = float(self._rng("p", task_id, node).random())
        return min(1.0, self.p_fail * (1.0 + self.p_spread * u))

    def attempt_outcome(self, task_id: str, node: str,
                        attempt: int) -> float | None:
        """``None`` if attempt ``attempt`` of ``task_id`` on ``node``
        succeeds; otherwise the fraction of the attempt's runtime at
        which the failure manifests (in (0, 1) — the elapsed time up to
        it is a *censored* lower bound on the true runtime)."""
        p = self.attempt_fail_prob(task_id, node)
        if p <= 0.0:
            return None
        g = self._rng("draw", task_id, node, attempt)
        if float(g.random()) >= p:
            return None
        return float(g.uniform(0.05, 0.95))


# ---------------------------------------------------------------------------
# Discrete-event engine (scheduler benchmarks, straggler/failure injection)
# ---------------------------------------------------------------------------
@dataclass(order=True)
class _Event:
    time: float
    seq: int
    kind: str = field(compare=False)
    payload: dict = field(compare=False, default_factory=dict)


@dataclass
class SimNode:
    name: str
    node_type: NodeType
    busy_until: float = 0.0
    alive: bool = True
    slowdown: float = 1.0      # straggler multiplier (hidden)


class GridEngine:
    """Named-node availability registry — the minimal cluster-state API the
    online executor drives (grid-engine style: concrete node instances of
    heterogeneous types, each busy until some time).

    Deliberately dumb: it knows who is free when, nothing about tasks.
    The executor owns queues and decisions; ``EventSimulator`` remains the
    batch-mode engine for pre-computed schedules."""

    def __init__(self, nodes: list[SimNode],
                 topology: Topology | None = None):
        self.nodes = {n.name: n for n in nodes}
        self.topology = topology
        # observability: membership churn (fail/join) is emitted through
        # this tracer; NULL_TRACER is the zero-cost disabled default and
        # OnlineExecutor(tracer=...) swaps in its live EventLog
        from repro.obs.trace import NULL_TRACER
        self.tracer = NULL_TRACER

    @classmethod
    def from_types(cls, nodes_per_type: int = 2,
                   types: list[NodeType] | None = None,
                   topology: Topology | None = None) -> "GridEngine":
        """Expand node types into `nodes_per_type` instances each
        (named ``<type>/<i>``, like the scheduler benchmarks)."""
        types = list(types) if types is not None else target_nodes()
        return cls([SimNode(name=f"{nt.name}/{i}", node_type=nt)
                    for nt in types for i in range(nodes_per_type)],
                   topology=topology)

    def secs_per_gb(self) -> np.ndarray | None:
        """Current (N, N) transfer-price matrix in ``names()`` order, with
        dead nodes masked as data sources (see ``Topology.secs_per_gb``) —
        ``None`` when no topology is configured (comm-blind engine).
        Recomputed from live membership on every call, so a rejoining
        node re-enters real comm pricing immediately."""
        if self.topology is None:
            return None
        return self.topology.secs_per_gb(
            self.names(), alive={n: sn.alive
                                 for n, sn in self.nodes.items()})

    def names(self) -> list[str]:
        return list(self.nodes)

    def type_of(self, name: str) -> NodeType:
        return self.nodes[name].node_type

    def occupy(self, name: str, until: float) -> None:
        self.nodes[name].busy_until = until

    def release(self, name: str, at: float) -> None:
        """Free a node earlier than its booked end — a running attempt was
        killed (e.g. a speculative-copy race resolved elsewhere)."""
        sn = self.nodes[name]
        sn.busy_until = min(sn.busy_until, at)

    def idle(self, t: float) -> list[str]:
        return [n for n, sn in self.nodes.items()
                if sn.alive and sn.busy_until <= t + 1e-12]

    def ready_vector(self, t: float) -> np.ndarray:
        """(N,) earliest availability per node (``names()`` order) — the
        ``node_ready`` floor for a mid-execution HEFT re-plan.  Dead
        nodes are masked with ``+inf``: their EFT is infinite, so a
        re-plan can never place frontier work there (``idle`` filters
        them for dispatch; this is the planning-side twin)."""
        return np.array([max(sn.busy_until, t) if sn.alive else np.inf
                         for sn in self.nodes.values()])

    # ---- elastic membership -----------------------------------------------
    def fail(self, name: str, at: float) -> None:
        """The node dies (crash or outage start) at ``at``: it stops
        accepting work (``idle``/``ready_vector`` mask it) and anything
        booked on it is void — the caller is responsible for re-queueing
        the killed attempts."""
        sn = self.nodes[name]
        sn.alive = False
        sn.busy_until = float(at)
        if self.tracer.enabled:
            self.tracer.emit("node_down", t_sim=at, node=name)

    def join(self, node: "SimNode | str", at: float = 0.0) -> None:
        """A node (re-)joins at ``at``: an existing name is revived (an
        outage ending), a new ``SimNode`` is registered (cluster grows).
        Consumers that pinned the node universe at construction (e.g. a
        running ``OnlineExecutor``) only see revivals; genuinely new
        nodes are picked up by executors built afterwards."""
        if isinstance(node, SimNode):
            node.alive = True
            node.busy_until = max(node.busy_until, float(at))
            self.nodes[node.name] = node
            if self.tracer.enabled:
                self.tracer.emit("node_up", t_sim=at, node=node.name,
                                 new=True)
            return
        sn = self.nodes[node]
        sn.alive = True
        sn.busy_until = max(sn.busy_until, float(at))
        if self.tracer.enabled:
            self.tracer.emit("node_up", t_sim=at, node=node)


class EventSimulator:
    """Executes a scheduled task DAG over concrete nodes with optional
    failure/straggler injection.  Returns per-task records + makespan."""

    def __init__(self, nodes: list[SimNode], sim: ClusterSimulator,
                 seed: int = 0):
        self.nodes = {n.name: n for n in nodes}
        self.sim = sim
        self.rng = np.random.default_rng(seed + 17)

    def run_schedule(self, tasks: list[dict], deps: dict[str, list[str]],
                     assignment: dict[str, str],
                     runtime_fn=None,
                     fail_at: dict[str, float] | None = None,
                     reassign_fn=None,
                     on_incomplete: str = "raise") -> dict:
        """tasks: [{id, task(TaskDef), size}]; deps: id -> prereq ids;
        assignment: id -> node name.  runtime_fn overrides the ground truth.
        ``fail_at``: node -> time (node dies; queued work is re-assigned via
        ``reassign_fn(task_id, dead_node) -> node``).

        When the schedule cannot complete — a dependency deadlock, or a
        failed node's work with no ``reassign_fn`` — the result would
        silently truncate ``records``; ``on_incomplete`` controls the
        signal: ``"raise"`` (default) raises ``RuntimeError`` naming the
        stranded task ids, ``"warn"`` emits a ``RuntimeWarning`` and
        returns the partial result, ``"ignore"`` returns it silently
        (the pre-fix behaviour; ``completed < total`` is then the only
        indicator)."""
        if on_incomplete not in ("raise", "warn", "ignore"):
            raise ValueError(f"on_incomplete must be 'raise', 'warn' or "
                             f"'ignore', got {on_incomplete!r}")
        fail_at = dict(fail_at or {})
        by_id = {t["id"]: t for t in tasks}
        done: dict[str, float] = {}
        records = []
        remaining = set(by_id)
        node_free = {n: 0.0 for n in self.nodes}
        t_now = 0.0
        guard = 0
        while remaining and guard < 10 * len(by_id):
            guard += 1
            ready = [tid for tid in sorted(remaining)
                     if all(d in done for d in deps.get(tid, []))]
            if not ready:
                break
            progressed = False
            for tid in ready:
                rec = by_id[tid]
                node_name = assignment[tid]
                node = self.nodes[node_name]
                # node failure: re-assign
                if node_name in fail_at and max(
                        node_free[node_name],
                        max([done[d] for d in deps.get(tid, [])], default=0.0)
                ) >= fail_at[node_name]:
                    node.alive = False
                    if reassign_fn is None:
                        continue
                    node_name = reassign_fn(tid, node_name)
                    node = self.nodes[node_name]
                start = max(node_free[node_name],
                            max([done[d] for d in deps.get(tid, [])],
                                default=0.0))
                dur = (runtime_fn(rec, node) if runtime_fn else
                       self.sim.run_task(rec["task"], node.node_type,
                                         rec["size"]))
                dur *= node.slowdown
                done[tid] = start + dur
                node_free[node_name] = start + dur
                records.append({"id": tid, "node": node_name, "start": start,
                                "duration": dur, "end": start + dur})
                remaining.discard(tid)
                progressed = True
            if not progressed:
                break
        if remaining and on_incomplete != "ignore":
            stranded = sorted(remaining)
            shown = ", ".join(stranded[:8]) + \
                (", ..." if len(stranded) > 8 else "")
            on_dead = sorted(t for t in remaining
                             if not self.nodes[assignment[t]].alive)
            why = (f"{len(on_dead)} assigned to failed nodes with no "
                   f"reassign_fn" if on_dead else "dependency deadlock")
            msg = (f"run_schedule incomplete: {len(stranded)} of "
                   f"{len(by_id)} tasks stranded ({shown}) — {why}")
            if on_incomplete == "raise":
                raise RuntimeError(msg)
            warnings.warn(msg, RuntimeWarning, stacklevel=2)
        makespan = max((r["end"] for r in records), default=0.0)
        return {"records": records, "makespan": makespan,
                "completed": len(records), "total": len(by_id)}


def load_dryrun_cells(art_dir: str | Path) -> list[dict]:
    """Load dry-run artifacts (the ML-plane task universe)."""
    out = []
    for p in sorted(Path(art_dir).glob("*.json")):
        r = json.loads(p.read_text())
        if r.get("status") == "ok":
            out.append(r)
    return out
