"""Heterogeneous-cluster ground-truth simulator.

Two workload planes share the node registry:

* genomics plane — nf-core-like tasks with hidden (cpu_unit, io_unit)
  ground truth (see workflows.py).  Supports the paper's CPU-frequency
  reduction faithfully via ``cpu_factor``.
* ML plane — (arch x shape) workload cells whose hidden ground truth is the
  three-term roofline of the *actual compiled dry-run HLO*, scaled by each
  node type's rates and hidden per-family efficiency.

Also provides the discrete-event engine used by the scheduler benchmarks
(task queues per node, failures, stragglers, elastic node loss/join).
"""
from __future__ import annotations

import heapq
import json
from dataclasses import dataclass, field
from pathlib import Path

import numpy as np

from repro.core.nodes import NodeType, get_node, target_nodes
from .workflows import REF_CPU, REF_IO, TaskDef, effective_size


class ClusterSimulator:
    """Ground-truth runtimes; Lotaru never sees the units, only runtimes.

    ``systematic`` adds a fixed per-(task, node) efficiency multiplier
    (lognormal, derived from a stable hash): real tools hit different
    codepaths / cache behaviour on different machines, which is exactly why
    scalar factor adjustment has an error floor in the paper's Tables 4-6.

    ``het`` makes the run-to-run noise heteroscedastic per (task, node)
    pair: the lognormal sd becomes ``noise * (1 + het * u)`` with a
    stable-hash ``u`` in [0, 1), so some pairs are far jitterier than
    others — the regime where risk-aware (mean + k*sigma) placement beats
    risk-neutral placement.  ``het=0`` (default) keeps the homoscedastic
    behaviour bit-exactly.
    """

    def __init__(self, seed: int = 0, noise: float = 0.05,
                 systematic: float = 0.10, het: float = 0.0):
        self.rng = np.random.default_rng(seed)
        self.noise = noise
        self.systematic = systematic
        self.het = het

    @staticmethod
    def _pair_rng(task_name: str, node_name: str,
                  tag: str) -> np.random.Generator:
        """Deterministic per-(task, node, property) generator from a
        stable hash (crc32, not builtin ``hash`` — stable across
        processes): hidden pair properties are fixed facts of the
        cluster, not draws from the simulation stream."""
        import zlib
        h = zlib.crc32(f"{task_name}|{node_name}|{tag}".encode()) % (2 ** 31)
        return np.random.default_rng(h)

    def _sys_mult(self, task_name: str, node_name: str) -> float:
        if self.systematic <= 0:
            return 1.0
        g = self._pair_rng(task_name, node_name, "sys").normal(
            0.0, self.systematic)
        return float(np.exp(g))

    def noise_sd(self, task_name: str, node_name: str) -> float:
        """Lognormal sd of this pair's run-to-run jitter (``noise`` unless
        ``het > 0``; the per-pair factor comes from a stable hash, so it
        is a fixed property of the pair, not a draw)."""
        if self.het <= 0:
            return self.noise
        u = float(self._pair_rng(task_name, node_name, "het").random())
        return self.noise * (1.0 + self.het * u)

    # ---- genomics plane ---------------------------------------------------
    def run_task(self, task: TaskDef, node: NodeType, size_gb: float,
                 cpu_factor: float = 1.0, noisy: bool = True) -> float:
        s = effective_size(task, size_gb)
        cpu_t = (task.base * task.cpu_share + task.cpu_unit * s) \
            * (REF_CPU / node.cpu_score) / cpu_factor
        io_t = (task.base * (1 - task.cpu_share) + task.io_unit * s) \
            * (REF_IO / node.io_bw)
        t = (cpu_t + io_t) * self._sys_mult(task.name, node.name)
        if noisy:
            t *= self.rng.lognormal(0.0, self.noise_sd(task.name, node.name))
        return float(t)

    def expected_task_runtime(self, task: TaskDef, node: NodeType,
                              size_gb: float) -> float:
        return self.run_task(task, node, size_gb, noisy=False)

    def actual_factor(self, task: TaskDef, local: NodeType, target: NodeType,
                      size_gb: float) -> float:
        """True runtime ratio target/local (paper Tables 4-5)."""
        return (self.expected_task_runtime(task, target, size_gb)
                / self.expected_task_runtime(task, local, size_gb))

    # ---- ML plane ----------------------------------------------------------
    def run_cell(self, cell: dict, node: NodeType, token_fraction: float = 1.0,
                 chips: int | None = None, cpu_factor: float = 1.0,
                 noisy: bool = True) -> float:
        """Step time of a dry-run cell record on `chips` of `node`'s type.
        ``cpu_factor < 1`` throttles the compute units (the paper's reduced
        CPU-frequency probe, phase 2)."""
        r = cell["roofline"]
        base_chips = r["chips"]
        chips = chips or base_chips
        scale = token_fraction * base_chips / chips
        family = cell.get("family", "*")
        eff = node.eff(family)
        compute = r["flops_per_device"] * scale / (node.peak_flops * eff
                                                   * cpu_factor)
        memory = r["bytes_per_device"] * scale / node.hbm_bw
        coll = r["coll_bytes_per_device"] * scale / node.link_bw
        t = max(compute, memory, coll) + 0.35 * min(compute, memory, coll)
        if noisy:
            t *= self.rng.lognormal(0.0, self.noise)
        return float(t)


# ---------------------------------------------------------------------------
# Discrete-event engine (scheduler benchmarks, straggler/failure injection)
# ---------------------------------------------------------------------------
@dataclass(order=True)
class _Event:
    time: float
    seq: int
    kind: str = field(compare=False)
    payload: dict = field(compare=False, default_factory=dict)


@dataclass
class SimNode:
    name: str
    node_type: NodeType
    busy_until: float = 0.0
    alive: bool = True
    slowdown: float = 1.0      # straggler multiplier (hidden)


class GridEngine:
    """Named-node availability registry — the minimal cluster-state API the
    online executor drives (grid-engine style: concrete node instances of
    heterogeneous types, each busy until some time).

    Deliberately dumb: it knows who is free when, nothing about tasks.
    The executor owns queues and decisions; ``EventSimulator`` remains the
    batch-mode engine for pre-computed schedules."""

    def __init__(self, nodes: list[SimNode]):
        self.nodes = {n.name: n for n in nodes}

    @classmethod
    def from_types(cls, nodes_per_type: int = 2,
                   types: list[NodeType] | None = None) -> "GridEngine":
        """Expand node types into `nodes_per_type` instances each
        (named ``<type>/<i>``, like the scheduler benchmarks)."""
        types = list(types) if types is not None else target_nodes()
        return cls([SimNode(name=f"{nt.name}/{i}", node_type=nt)
                    for nt in types for i in range(nodes_per_type)])

    def names(self) -> list[str]:
        return list(self.nodes)

    def type_of(self, name: str) -> NodeType:
        return self.nodes[name].node_type

    def occupy(self, name: str, until: float) -> None:
        self.nodes[name].busy_until = until

    def release(self, name: str, at: float) -> None:
        """Free a node earlier than its booked end — a running attempt was
        killed (e.g. a speculative-copy race resolved elsewhere)."""
        sn = self.nodes[name]
        sn.busy_until = min(sn.busy_until, at)

    def idle(self, t: float) -> list[str]:
        return [n for n, sn in self.nodes.items()
                if sn.alive and sn.busy_until <= t + 1e-12]

    def ready_vector(self, t: float) -> np.ndarray:
        """(N,) earliest availability per node (``names()`` order) — the
        ``node_ready`` floor for a mid-execution HEFT re-plan."""
        return np.array([max(sn.busy_until, t)
                         for sn in self.nodes.values()])


class EventSimulator:
    """Executes a scheduled task DAG over concrete nodes with optional
    failure/straggler injection.  Returns per-task records + makespan."""

    def __init__(self, nodes: list[SimNode], sim: ClusterSimulator,
                 seed: int = 0):
        self.nodes = {n.name: n for n in nodes}
        self.sim = sim
        self.rng = np.random.default_rng(seed + 17)

    def run_schedule(self, tasks: list[dict], deps: dict[str, list[str]],
                     assignment: dict[str, str],
                     runtime_fn=None,
                     fail_at: dict[str, float] | None = None,
                     reassign_fn=None) -> dict:
        """tasks: [{id, task(TaskDef), size}]; deps: id -> prereq ids;
        assignment: id -> node name.  runtime_fn overrides the ground truth.
        ``fail_at``: node -> time (node dies; queued work is re-assigned via
        ``reassign_fn(task_id, dead_node) -> node``)."""
        fail_at = dict(fail_at or {})
        by_id = {t["id"]: t for t in tasks}
        done: dict[str, float] = {}
        records = []
        remaining = set(by_id)
        node_free = {n: 0.0 for n in self.nodes}
        t_now = 0.0
        guard = 0
        while remaining and guard < 10 * len(by_id):
            guard += 1
            ready = [tid for tid in sorted(remaining)
                     if all(d in done for d in deps.get(tid, []))]
            if not ready:
                break
            progressed = False
            for tid in ready:
                rec = by_id[tid]
                node_name = assignment[tid]
                node = self.nodes[node_name]
                # node failure: re-assign
                if node_name in fail_at and max(
                        node_free[node_name],
                        max([done[d] for d in deps.get(tid, [])], default=0.0)
                ) >= fail_at[node_name]:
                    node.alive = False
                    if reassign_fn is None:
                        continue
                    node_name = reassign_fn(tid, node_name)
                    node = self.nodes[node_name]
                start = max(node_free[node_name],
                            max([done[d] for d in deps.get(tid, [])],
                                default=0.0))
                dur = (runtime_fn(rec, node) if runtime_fn else
                       self.sim.run_task(rec["task"], node.node_type,
                                         rec["size"]))
                dur *= node.slowdown
                done[tid] = start + dur
                node_free[node_name] = start + dur
                records.append({"id": tid, "node": node_name, "start": start,
                                "duration": dur, "end": start + dur})
                remaining.discard(tid)
                progressed = True
            if not progressed:
                break
        makespan = max((r["end"] for r in records), default=0.0)
        return {"records": records, "makespan": makespan,
                "completed": len(records), "total": len(by_id)}


def load_dryrun_cells(art_dir: str | Path) -> list[dict]:
    """Load dry-run artifacts (the ML-plane task universe)."""
    out = []
    for p in sorted(Path(art_dir).glob("*.json")):
        r = json.loads(p.read_text())
        if r.get("status") == "ok":
            out.append(r)
    return out
