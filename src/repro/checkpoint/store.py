"""Sharded, atomic, async checkpointing with elastic-resharding restore.

Layout:  <dir>/step_<N>/
             manifest.json           (tree structure, shapes, dtypes, step)
             leaf_<i>.npy            (full logical array per leaf)
         <dir>/step_<N>.tmp/ ...     (atomic: rename on completion)
         <dir>/LATEST                (text file: last complete step)

On a real multi-host fleet each host writes only the shards it owns;
single-process here, every leaf is materialised full (np.asarray gathers
across the process-local mesh) — the manifest format is host-count
independent, which is what makes *elastic* restore (different mesh shape /
device count) work: restore() re-shards each logical array onto the new
mesh via device_put with the new NamedSharding.

``async_save`` runs serialisation on a background thread (training
continues on the next step's compute while the previous step's state is
written — checkpoint/compute overlap).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path

import jax
import numpy as np


def _tree_paths(tree, prefix=()):
    """Deterministic (path, leaf) enumeration."""
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _tree_paths(tree[k], prefix + (str(k),))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _tree_paths(v, prefix + (str(i),))
    else:
        yield prefix, tree


def _set_path(out, path, value):
    cur = out
    for p in path[:-1]:
        cur = cur.setdefault(p, {})
    cur[path[-1]] = value


def save(ckpt_dir: str | Path, step: int, state, metadata: dict | None = None) -> Path:
    """Atomic checkpoint write. Returns the final directory."""
    ckpt_dir = Path(ckpt_dir)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    manifest = {"step": step, "time": time.time(),
                "metadata": metadata or {}, "leaves": []}
    for i, (path, leaf) in enumerate(_tree_paths(state)):
        arr = np.asarray(leaf)
        fname = f"leaf_{i:05d}.npy"
        np.save(tmp / fname, arr)
        manifest["leaves"].append({"path": list(path), "file": fname,
                                   "shape": list(arr.shape),
                                   "dtype": str(arr.dtype)})
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    (ckpt_dir / "LATEST").write_text(str(step))
    return final


def latest_step(ckpt_dir: str | Path) -> int | None:
    p = Path(ckpt_dir) / "LATEST"
    if not p.exists():
        return None
    step = int(p.read_text().strip())
    if not (Path(ckpt_dir) / f"step_{step:08d}" / "manifest.json").exists():
        # crashed mid-write with stale LATEST: fall back to newest complete
        steps = sorted(int(d.name.split("_")[1])
                       for d in Path(ckpt_dir).glob("step_*")
                       if d.is_dir() and (d / "manifest.json").exists())
        return steps[-1] if steps else None
    return step


def restore(ckpt_dir: str | Path, step: int | None = None,
            shardings=None):
    """Restore a checkpoint.  ``shardings``: optional pytree of NamedSharding
    (same structure) to re-shard onto a (possibly different — elastic) mesh.
    Returns (state, manifest_metadata)."""
    ckpt_dir = Path(ckpt_dir)
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = ckpt_dir / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    shard_leaves = None
    if shardings is not None:
        shard_leaves = {tuple(p): s for p, s in _tree_paths(shardings)}
    out: dict = {}
    for rec in manifest["leaves"]:
        arr = np.load(d / rec["file"])
        path = tuple(rec["path"])
        if shard_leaves is not None and path in shard_leaves:
            arr = jax.device_put(arr, shard_leaves[path])
        _set_path(out, list(path), arr)
    return out, manifest


class AsyncCheckpointer:
    """Overlaps checkpoint serialisation with training compute."""

    def __init__(self, ckpt_dir: str | Path, keep: int = 3):
        self.ckpt_dir = Path(ckpt_dir)
        self.keep = keep
        self._thread: threading.Thread | None = None
        self.last_error: Exception | None = None

    def save(self, step: int, state, metadata: dict | None = None) -> None:
        self.wait()
        # snapshot to host memory synchronously (cheap), write async
        host_state = jax.tree.map(lambda x: np.asarray(x), state)

        def work():
            try:
                save(self.ckpt_dir, step, host_state, metadata)
                self._gc()
            except (OSError, ValueError, TypeError) as e:
                # surfaced on next wait(): disk/permission failures
                # (OSError), np.save on a malformed leaf (ValueError),
                # non-JSON-serialisable metadata (TypeError) — anything
                # else is a programming error and should crash the thread
                self.last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err

    def _gc(self) -> None:
        steps = sorted(int(d.name.split("_")[1])
                       for d in self.ckpt_dir.glob("step_*") if d.is_dir()
                       and not d.name.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(self.ckpt_dir / f"step_{s:08d}", ignore_errors=True)
