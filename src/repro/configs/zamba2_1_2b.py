"""zamba2-1.2b [arXiv:2411.15242; hf] — Mamba2 blocks + weight-tied shared
attention block applied every 6 layers.

38L d_model=2048; shared attn 32H (kv=32) d_ff=8192; vocab=32000; ssm_state=64.
"""
from repro.models import ModelConfig, SSMConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        arch="zamba2-1.2b", family="hybrid",
        n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192,
        vocab=32000, head_dim=64, norm="rmsnorm", act="gelu",
        hybrid_attn_every=6,
        ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64,
                      chunk=128, n_groups=1))


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch="zamba2-1.2b", family="hybrid",
        n_layers=5, d_model=32, n_heads=4, n_kv_heads=4, d_ff=64,
        vocab=128, head_dim=8, norm="rmsnorm", act="gelu",
        hybrid_attn_every=2,
        ssm=SSMConfig(d_state=8, d_conv=4, expand=2, head_dim=8,
                      chunk=16, n_groups=1),
        attn_chunk=16, xent_chunk=32)
