"""stablelm-12b [hf:stabilityai/stablelm-2-12b] — dense decoder-only.

40L d_model=5120 32H (GQA kv=8) d_ff=13824 vocab=100352.
"""
from repro.models import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        arch="stablelm-12b", family="dense",
        n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, d_ff=13824,
        vocab=100352, head_dim=160, norm="layernorm", act="swiglu",
        rope_theta=10_000.0)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch="stablelm-12b", family="dense",
        n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, d_ff=64,
        vocab=128, head_dim=8, norm="layernorm", act="swiglu",
        attn_chunk=16, xent_chunk=32)
