"""qwen2-7b [arXiv:2407.10671; hf] — dense, GQA, QKV bias.

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.
"""
from repro.models import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        arch="qwen2-7b", family="dense",
        n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, d_ff=18944,
        vocab=152064, head_dim=128, norm="rmsnorm", act="swiglu",
        qkv_bias=True, rope_theta=1_000_000.0)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch="qwen2-7b", family="dense",
        n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, d_ff=64,
        vocab=128, head_dim=8, norm="rmsnorm", act="swiglu",
        qkv_bias=True, attn_chunk=16, xent_chunk=32)
