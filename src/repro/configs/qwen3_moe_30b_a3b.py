"""qwen3-moe-30b-a3b [hf:Qwen/Qwen3-30B-A3B] — 128 experts, top-8, all layers.

48L d_model=2048 32H (GQA kv=4) expert d_ff=768 vocab=151936.
"""
from repro.models import ModelConfig, MoEConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        arch="qwen3-moe-30b-a3b", family="moe",
        n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, d_ff=768,
        vocab=151936, head_dim=128, norm="rmsnorm", act="swiglu",
        rope_theta=1_000_000.0,
        moe=MoEConfig(n_experts=128, top_k=8, d_ff_expert=768, every=1,
                      shared_expert=False, capacity_factor=1.25))


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch="qwen3-moe-30b-a3b", family="moe",
        n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, d_ff=32,
        vocab=128, head_dim=8, norm="rmsnorm", act="swiglu",
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32, every=1,
                      shared_expert=False, capacity_factor=1.25),
        attn_chunk=16, xent_chunk=32)
