"""mamba2-1.3b [arXiv:2405.21060] — pure SSM (SSD), attention-free.

48L d_model=2048 vocab=50280 ssm_state=128 (d_inner=4096, 64 SSD heads).
"""
from repro.models import ModelConfig, SSMConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        arch="mamba2-1.3b", family="ssm",
        n_layers=48, d_model=2048, n_heads=1, n_kv_heads=1, d_ff=0,
        # vocab 50280 padded to 50432 (divisible by 256) for TP16 sharding
        vocab=50_432, head_dim=64, norm="rmsnorm", act="swiglu",
        ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64,
                      chunk=128, n_groups=1))


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch="mamba2-1.3b", family="ssm",
        n_layers=2, d_model=32, n_heads=1, n_kv_heads=1, d_ff=0,
        vocab=128, head_dim=8, norm="rmsnorm", act="swiglu",
        ssm=SSMConfig(d_state=8, d_conv=4, expand=2, head_dim=8,
                      chunk=16, n_groups=1),
        attn_chunk=16, xent_chunk=32)
