"""qwen2-vl-7b [arXiv:2409.12191; hf] — qwen2-7b backbone + M-RoPE.

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.  The vision patch
frontend is a stub: ``input_specs()`` provides precomputed patch embeddings
and (temporal, h, w) position ids.
"""
from repro.models import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        arch="qwen2-vl-7b", family="vlm",
        n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, d_ff=18944,
        vocab=152064, head_dim=128, norm="rmsnorm", act="swiglu",
        qkv_bias=True, mrope=True, rope_theta=1_000_000.0)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch="qwen2-vl-7b", family="vlm",
        n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, d_ff=64,
        vocab=128, head_dim=16, norm="rmsnorm", act="swiglu",
        qkv_bias=True, mrope=True, attn_chunk=16, xent_chunk=32)
