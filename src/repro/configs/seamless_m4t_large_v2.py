"""seamless-m4t-large-v2 [arXiv:2308.11596; hf] — enc-dec multimodal backbone.

24L d_model=1024 16H (kv=16) d_ff=8192 vocab=256206.  Interpreted as 24
encoder + 24 decoder layers (text backbone of the M4T v2 stack); the audio
frontend is a stub — ``input_specs()`` provides precomputed frame embeddings.
"""
from repro.models import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        arch="seamless-m4t-large-v2", family="encdec",
        n_layers=48, enc_layers=24, dec_layers=24,
        d_model=1024, n_heads=16, n_kv_heads=16, d_ff=8192,
        # vocab 256206 padded to 256256 (Megatron-style divisibility for
        # TP16 vocab sharding; pad logits train toward -inf via the lse term)
        vocab=256_256, head_dim=64, norm="layernorm", act="gelu",
        rope_theta=10_000.0)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch="seamless-m4t-large-v2", family="encdec",
        n_layers=4, enc_layers=2, dec_layers=2,
        d_model=32, n_heads=4, n_kv_heads=4, d_ff=64,
        vocab=128, head_dim=8, norm="layernorm", act="gelu",
        attn_chunk=16, xent_chunk=32)
