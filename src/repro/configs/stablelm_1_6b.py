"""stablelm-1.6b [hf:stabilityai/stablelm-2-1_6b] — dense (MHA: kv=heads).

24L d_model=2048 32H (kv=32) d_ff=5632 vocab=100352.
"""
from repro.models import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        arch="stablelm-1.6b", family="dense",
        n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=5632,
        vocab=100352, head_dim=64, norm="layernorm", act="swiglu",
        rope_theta=10_000.0)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch="stablelm-1.6b", family="dense",
        n_layers=2, d_model=32, n_heads=4, n_kv_heads=4, d_ff=64,
        vocab=128, head_dim=8, norm="layernorm", act="swiglu",
        attn_chunk=16, xent_chunk=32)
