"""llama4-maverick-400b-a17b [hf:meta-llama/Llama-4-*] — interleaved MoE.

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048; 128 routed experts
top-1 + shared expert, MoE every 2nd layer (interleaved, per Llama-4).
~400B total / ~17B active parameters.
"""
from repro.models import ModelConfig, MoEConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        arch="llama4-maverick-400b-a17b", family="moe",
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=8192,
        vocab=202048, head_dim=128, norm="rmsnorm", act="swiglu",
        rope_theta=500_000.0,
        moe=MoEConfig(n_experts=128, top_k=1, d_ff_expert=8192, every=2,
                      shared_expert=True, capacity_factor=2.0))


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch="llama4-maverick-400b-a17b", family="moe",
        n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, d_ff=64,
        vocab=128, head_dim=8, norm="rmsnorm", act="swiglu",
        moe=MoEConfig(n_experts=8, top_k=1, d_ff_expert=64, every=2,
                      shared_expert=True, capacity_factor=2.0),
        attn_chunk=16, xent_chunk=32)
