"""starcoder2-15b [arXiv:2402.19173; hf] — dense, GQA, RoPE, biased projections.

40L d_model=6144 48H (GQA kv=4) d_ff=24576 vocab=49152.
"""
from repro.models import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        arch="starcoder2-15b", family="dense",
        n_layers=40, d_model=6144, n_heads=48, n_kv_heads=4, d_ff=24576,
        vocab=49152, head_dim=128, norm="layernorm", act="gelu",
        qkv_bias=True, rope_theta=100_000.0)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch="starcoder2-15b", family="dense",
        n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, d_ff=64,
        vocab=128, head_dim=8, norm="layernorm", act="gelu",
        qkv_bias=True, attn_chunk=16, xent_chunk=32)
