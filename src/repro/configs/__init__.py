"""Architecture registry: one module per assigned architecture.

``get_config(arch)`` returns the full published config; ``smoke_config``
returns a reduced same-family config for CPU smoke tests (the full configs
are only exercised abstractly via the dry-run).
"""
from __future__ import annotations

import importlib

ARCHS = [
    "seamless-m4t-large-v2",
    "stablelm-12b",
    "starcoder2-15b",
    "qwen2-7b",
    "stablelm-1.6b",
    "llama4-maverick-400b-a17b",
    "qwen3-moe-30b-a3b",
    "zamba2-1.2b",
    "qwen2-vl-7b",
    "mamba2-1.3b",
]


def _module(arch: str):
    return importlib.import_module(
        "repro.configs." + arch.replace("-", "_").replace(".", "_"))


def get_config(arch: str):
    return _module(arch).full_config()


def smoke_config(arch: str):
    return _module(arch).smoke_config()


def list_archs() -> list[str]:
    return list(ARCHS)
