"""Assigned input-shape cells and abstract input specs.

Every (arch x shape) cell is defined here: train/prefill cells lower
``train_step``/``prefill_step`` over the full sequence; decode cells lower
``decode_step`` (one new token against a KV cache of ``seq`` tokens).
``long_500k`` requires sub-quadratic attention and is skipped for pure
full-attention archs (recorded as a skip, see DESIGN.md §6).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import ModelConfig
from repro.models.common import AxisRules

VLM_VISION_TOKENS = 1024     # patch-embedding stub length inside the seq budget
AUDIO_FRAME_RATIO = 1.0      # encoder frames per "seq_len" unit (stub frontend)


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}

SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


def cell_applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    if shape.name == "long_500k" and cfg.family not in SUBQUADRATIC_FAMILIES:
        return False, "long_500k skipped: pure full-attention arch (DESIGN.md §6)"
    return True, ""


def _dp_batch_spec(rules: AxisRules, global_batch: int, mesh) -> P:
    """Shard batch over dp axes when divisible, else replicate."""
    dp = 1
    for a in rules.dp_axes:
        dp *= mesh.shape[a]
    if dp > 1 and global_batch % dp == 0:
        axes = rules.dp_axes if len(rules.dp_axes) > 1 else rules.dp_axes[0]
        return P(axes)
    return P(None)


def input_specs(cfg: ModelConfig, shape: ShapeSpec, mesh, rules: AxisRules) -> dict:
    """ShapeDtypeStruct stand-ins for the step function's *batch* argument."""
    B, T = shape.global_batch, shape.seq
    bspec = _dp_batch_spec(rules, B, mesh)
    b_axes = bspec[0] if len(bspec) else None

    def sds(shp, dtype, spec):
        return jax.ShapeDtypeStruct(shp, dtype, sharding=NamedSharding(mesh, spec))

    if shape.kind == "decode":
        batch = {"tokens": sds((B, 1), jnp.int32, P(b_axes, None))}
        if cfg.family == "vlm" and cfg.mrope:
            batch["positions"] = sds((B, 1, 3), jnp.int32, P(b_axes, None, None))
        return batch

    if cfg.family == "encdec":
        batch = {
            "src_embeds": sds((B, T, cfg.d_model), jnp.bfloat16, P(b_axes, None, None)),
            "tokens": sds((B, T), jnp.int32, P(b_axes, None)),
        }
        if shape.kind == "train":
            batch["labels"] = sds((B, T), jnp.int32, P(b_axes, None))
        return batch

    if cfg.family == "vlm":
        nv = min(VLM_VISION_TOKENS, T // 4)
        batch = {
            "tokens": sds((B, T - nv), jnp.int32, P(b_axes, None)),
            "vision_embeds": sds((B, nv, cfg.d_model), jnp.bfloat16,
                                 P(b_axes, None, None)),
            "positions": sds((B, T, 3), jnp.int32, P(b_axes, None, None)),
        }
        if shape.kind == "train":
            batch["labels"] = sds((B, T - nv), jnp.int32, P(b_axes, None))
        return batch

    batch = {"tokens": sds((B, T), jnp.int32, P(b_axes, None))}
    if shape.kind == "train":
        batch["labels"] = sds((B, T), jnp.int32, P(b_axes, None))
    return batch


def concrete_batch(cfg: ModelConfig, kind: str, B: int, T: int, key=None) -> dict:
    """Small concrete batch for smoke tests / examples (mirrors input_specs)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    if kind == "decode":
        batch = {"tokens": jnp.zeros((B, 1), jnp.int32)}
        if cfg.family == "vlm" and cfg.mrope:
            batch["positions"] = jnp.zeros((B, 1, 3), jnp.int32)
        return batch
    tokens = jax.random.randint(key, (B, T), 0, cfg.vocab)
    if cfg.family == "encdec":
        batch = {"src_embeds": 0.1 * jax.random.normal(key, (B, T, cfg.d_model)),
                 "tokens": tokens}
        if kind == "train":
            batch["labels"] = tokens
        return batch
    if cfg.family == "vlm":
        nv = max(2, T // 4)
        pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None, :, None], (B, T, 3))
        batch = {"tokens": tokens[:, :T - nv],
                 "vision_embeds": 0.1 * jnp.ones((B, nv, cfg.d_model), jnp.bfloat16),
                 "positions": pos}
        if kind == "train":
            batch["labels"] = tokens[:, :T - nv]
        return batch
    batch = {"tokens": tokens}
    if kind == "train":
        batch["labels"] = tokens
    return batch
