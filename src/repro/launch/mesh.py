"""Production meshes.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module does not touch jax device state.  The single-pod mesh
is 16x16 = 256 chips ("data", "model"); the multi-pod mesh adds a leading
"pod" axis: 2 x 16 x 16 = 512 chips.
"""
from __future__ import annotations

import jax

from repro.models.common import AxisRules, mesh_axis_sizes


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = 1
    for s in shape:
        need *= s
    devices = jax.devices()
    if len(devices) > need:   # e.g. single-pod mesh under a 512-device dry-run
        devices = devices[:need]
    return jax.make_mesh(shape, axes, devices=devices)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh (tests use small ones, elastic re-meshing uses this)."""
    return jax.make_mesh(shape, axes)


def make_fleet_mesh(*, wf: int | None = None, task: int = 1):
    """("wf", "task") mesh for the multi-workflow estimator fleet
    (``repro.online.fleet``): workflows shard over "wf", task rows over
    "task".  ``wf`` defaults to all remaining devices after the "task"
    axis takes ``task``; on a single device this is a (1, 1) mesh and
    ``shard_fleet`` replicates — the exact single-state layout.
    """
    n = len(jax.devices())
    if n % task != 0:
        raise ValueError(f"{n} devices not divisible by task={task}")
    if wf is None:
        wf = n // task
    return jax.make_mesh((wf, task), ("wf", "task"))


def make_rules(mesh, *, fsdp_over_pod: bool = False,
               overrides: dict | None = None) -> AxisRules:
    """Sharding rules for a mesh.

    Default multi-pod scheme is hierarchical: FSDP within a pod (ICI),
    pure data parallelism across pods (DCN) — gradients all-reduce over
    "pod", parameters are not gathered across pods every layer.
    ``fsdp_over_pod=True`` shards parameters/optimizer over the pod axis
    too (ZeRO across pods) — required for the 400B MoE to fit 16 GB chips.
    """
    names = mesh.axis_names
    sizes = mesh_axis_sizes(mesh)
    if "pod" in names:
        fsdp = ("pod", "data") if fsdp_over_pod else ("data",)
        return AxisRules(fsdp_axes=fsdp, dp_axes=("pod", "data"),
                         overrides=overrides or {}, axis_sizes=sizes)
    if "data" in names:
        return AxisRules(fsdp_axes=("data",), dp_axes=("data",),
                         overrides=overrides or {}, axis_sizes=sizes)
    return AxisRules(fsdp_axes=(), dp_axes=(), overrides=overrides or {},
                     axis_sizes=sizes)
