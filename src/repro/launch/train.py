"""Fault-tolerant training driver.

Features (all exercised by tests/examples on CPU; mesh-agnostic):
  * checkpoint/restart — async sharded checkpoints every ``ckpt_every``
    steps (interval can come from Young/Daly over Lotaru's predicted step
    time), bitwise-deterministic resume (synthetic data is a function of
    step).
  * failure injection — ``fail_at_step`` raises mid-run; ``run`` restarts
    from the last complete checkpoint.
  * elastic restart — restore accepts a different mesh (re-shards params/
    optimizer state via the manifest's logical arrays).
  * straggler watch — per-step wall time compared against the Lotaru
    predictive envelope (mean + k*sigma); slow steps are logged/counted
    (on a real fleet this triggers hot-spare swap).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from pathlib import Path

import jax
import numpy as np

from repro.checkpoint import AsyncCheckpointer, latest_step, restore
from repro.data import SyntheticLMData
from repro.launch.steps import make_train_step
from repro.models import AxisRules, Model, build_model
from repro.models.common import (ModelConfig, tree_defs_to_specs,
                                 tree_defs_init)
from repro.optim import AdamWConfig, state_defs


class InjectedFailure(RuntimeError):
    pass


@dataclass
class TrainReport:
    steps_run: int
    final_step: int
    losses: list = field(default_factory=list)
    restarts: int = 0
    straggler_steps: int = 0
    step_times: list = field(default_factory=list)


def _named_shardings(defs, mesh, rules):
    from jax.sharding import NamedSharding
    specs = tree_defs_to_specs(defs, rules)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)


def train(cfg: ModelConfig, *, steps: int, seq: int, global_batch: int,
          ckpt_dir: str | Path | None = None, ckpt_every: int = 50,
          mesh=None, rules: AxisRules | None = None,
          opt_cfg: AdamWConfig | None = None,
          fail_at_step: int | None = None,
          step_time_envelope: tuple[float, float] | None = None,
          straggler_k: float = 3.0,
          seed: int = 0, log_every: int = 10, verbose: bool = False) -> TrainReport:
    """One training run (resumes from ckpt_dir if a checkpoint exists)."""
    rules = rules or AxisRules(fsdp_axes=(), dp_axes=())
    model = build_model(cfg)
    opt_cfg = opt_cfg or AdamWConfig(lr=1e-3, warmup_steps=20,
                                     total_steps=steps)
    data = SyntheticLMData(cfg, seq=seq, global_batch=global_batch, seed=seed)
    step_fn = make_train_step(model, rules, opt_cfg)
    if mesh is not None:
        step_fn = jax.jit(step_fn, donate_argnums=(0, 1))
    else:
        step_fn = jax.jit(step_fn, donate_argnums=(0, 1))

    start_step = 0
    params = opt_state = None
    ckpt = None
    if ckpt_dir is not None:
        ckpt = AsyncCheckpointer(ckpt_dir)
        last = latest_step(ckpt_dir)
        if last is not None:
            shardings = None
            if mesh is not None:
                shardings = {"params": _named_shardings(model.param_defs, mesh, rules),
                             "opt": _named_shardings(state_defs(model.param_defs, opt_cfg), mesh, rules)}
            state, manifest = restore(ckpt_dir, shardings=shardings)
            params, opt_state = state["params"], state["opt"]
            # npy roundtrip loses jnp dtypes -> cast back per defs
            params = _cast_like_defs(params, model.param_defs)
            opt_state = _cast_like_defs(opt_state, state_defs(model.param_defs, opt_cfg))
            start_step = manifest["step"] + 1
    if params is None:
        key = jax.random.PRNGKey(seed)
        params = model.init(key)
        opt_state = tree_defs_init(state_defs(model.param_defs, opt_cfg),
                                   jax.random.PRNGKey(seed + 1))
        if mesh is not None:
            params = jax.device_put(params, _named_shardings(model.param_defs, mesh, rules))
            opt_state = jax.device_put(opt_state, _named_shardings(
                state_defs(model.param_defs, opt_cfg), mesh, rules))

    report = TrainReport(steps_run=0, final_step=start_step)
    for step in range(start_step, steps):
        if fail_at_step is not None and step == fail_at_step:
            if ckpt is not None:
                ckpt.wait()
            raise InjectedFailure(f"injected node failure at step {step}")
        batch = data.batch(step)
        t0 = time.perf_counter()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        report.step_times.append(dt)
        if step_time_envelope is not None and step > start_step:
            mean, sigma = step_time_envelope
            if dt > mean + straggler_k * sigma:
                report.straggler_steps += 1
        report.losses.append(loss)
        report.steps_run += 1
        report.final_step = step
        if verbose and step % log_every == 0:
            print(f"step {step:5d} loss {loss:.4f} ({dt*1e3:.0f} ms)",
                  flush=True)
        if ckpt is not None and (step + 1) % ckpt_every == 0:
            ckpt.save(step, {"params": params, "opt": opt_state},
                      metadata={"loss": loss})
    if ckpt is not None:
        ckpt.save(report.final_step, {"params": params, "opt": opt_state},
                  metadata={"final": True})
        ckpt.wait()
    report.params = params  # type: ignore[attr-defined]
    return report


def _cast_like_defs(tree, defs):
    import jax.numpy as jnp
    from repro.models.common import is_def

    flat_d = {tuple(p): d for p, d in _walk(defs)}

    def walk_apply(t, prefix=()):
        if isinstance(t, dict):
            return {k: walk_apply(v, prefix + (str(k),)) for k, v in t.items()}
        d = flat_d.get(prefix)
        if d is not None:
            return jnp.asarray(t, d.dtype)
        return jnp.asarray(t)
    return walk_apply(tree)


def _walk(tree, prefix=()):
    from repro.models.common import is_def
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _walk(tree[k], prefix + (str(k),))
    else:
        yield prefix, tree


def train_with_restarts(cfg: ModelConfig, *, steps: int, seq: int,
                        global_batch: int, ckpt_dir: str | Path,
                        failures: list[int] | None = None,
                        max_restarts: int = 5, **kw) -> TrainReport:
    """Supervisor loop: run, catch (injected) failures, restart from the
    last checkpoint — the single-process analogue of a fleet controller."""
    failures = list(failures or [])
    restarts = 0
    while True:
        fail_at = failures[0] if failures else None
        try:
            rep = train(cfg, steps=steps, seq=seq, global_batch=global_batch,
                        ckpt_dir=ckpt_dir, fail_at_step=fail_at, **kw)
            rep.restarts = restarts
            return rep
        except InjectedFailure:
            failures.pop(0)
            restarts += 1
            if restarts > max_restarts:
                raise
