"""Batched serving driver: continuous prefill/decode over a request queue.

Single-host reference implementation of the serving loop the decode cells
model: requests arrive with prompts, are batched up to ``max_batch``,
prefetched through ``prefill_step`` and stepped with ``decode_step``
against a shared KV cache.  Per-step wall time is checked against the
LotaruML predictive envelope (mean + k*sigma) when an estimator is given —
a breach marks the node a straggler candidate for the fleet controller.

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b --smoke
"""
from __future__ import annotations

import argparse
import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models import AxisRules, build_model


@dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int = 16
    out: list = field(default_factory=list)


class ServeLoop:
    def __init__(self, cfg, *, max_batch: int = 4, max_len: int = 128,
                 rules: AxisRules | None = None, envelope=None,
                 straggler_k: float = 3.0):
        self.cfg = cfg
        self.rules = rules or AxisRules(fsdp_axes=(), dp_axes=())
        self.model = build_model(cfg)
        self.params = self.model.init(jax.random.PRNGKey(0))
        self.max_batch = max_batch
        self.max_len = max_len
        self.prefill = jax.jit(make_prefill_step(self.model, self.rules))
        self.decode = jax.jit(make_decode_step(self.model, self.rules))
        self.envelope = envelope            # (mean_s, sigma_s) or None
        self.straggler_k = straggler_k
        self.straggler_steps = 0
        self.step_times: list[float] = []

    def run_batch(self, requests: list[Request]) -> list[Request]:
        assert len(requests) <= self.max_batch
        B = len(requests)
        T = max(len(r.prompt) for r in requests)
        toks = np.zeros((B, T), np.int32)
        for i, r in enumerate(requests):
            toks[i, T - len(r.prompt):] = r.prompt      # left-pad
        batch = {"tokens": jnp.asarray(toks)}
        caches = self.model.init_caches(B, max_len=T + max(
            r.max_new for r in requests), cross_len=T)
        logits, caches = self.prefill(self.params, batch, caches)
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
        n_steps = max(r.max_new for r in requests)
        for step in range(n_steps):
            t0 = time.perf_counter()
            tok, logits, caches = self.decode(
                self.params, {"tokens": tok[:, None]}, caches,
                jnp.asarray(T + step, jnp.int32))
            tok.block_until_ready()
            dt = time.perf_counter() - t0
            self.step_times.append(dt)
            if self.envelope is not None and step > 0:
                mean, sigma = self.envelope
                if dt > mean + self.straggler_k * sigma:
                    self.straggler_steps += 1
            for i, r in enumerate(requests):
                if step < r.max_new:
                    r.out.append(int(tok[i]))
        return requests


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-friendly)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    loop = ServeLoop(cfg)
    rng = np.random.default_rng(0)
    queue = [Request(rid=i,
                     prompt=rng.integers(0, cfg.vocab, rng.integers(4, 17)),
                     max_new=args.max_new)
             for i in range(args.requests)]
    t0 = time.time()
    done = []
    while queue:
        batch, queue = queue[:loop.max_batch], queue[loop.max_batch:]
        done.extend(loop.run_batch(batch))
    dt = time.time() - t0
    toks = sum(len(r.out) for r in done)
    print(f"served {len(done)} requests, {toks} tokens in {dt:.1f}s "
          f"({toks/dt:.1f} tok/s); median decode step "
          f"{1e3*np.median(loop.step_times):.1f} ms")


if __name__ == "__main__":
    main()
