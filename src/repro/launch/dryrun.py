import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: for each cell
we build abstract (ShapeDtypeStruct + NamedSharding) params / optimizer
state / caches / batch, lower the right step function, compile it, and
record memory_analysis(), cost_analysis() and the collective-bytes census
of the compiled HLO into experiments/artifacts/dryrun/<cell>.json.

Usage:
  python -m repro.launch.dryrun                        # all cells, both meshes
  python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k --mesh multi
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis.hlo_stats import analyze_hlo
from repro.analysis.roofline import Roofline, model_flops
from repro.configs import get_config, list_archs
from repro.launch.mesh import make_production_mesh, make_rules
from repro.launch.shapes import SHAPES, cell_applicable, input_specs
from repro.launch.steps import make_decode_step, make_prefill_step, make_train_step
from repro.models import build_model
from repro.models.common import tree_defs_to_abstract
from repro.optim import AdamWConfig, state_defs

ART_DIR = Path(__file__).resolve().parents[3] / "experiments" / "artifacts" / "dryrun"

# Per-arch distribution overrides (the hillclimb ledger lives in
# EXPERIMENTS.md §Perf; these are the production defaults).
ARCH_DIST = {
    # 400B params on 16GB chips: mixed precision (bf16 params + fp32 master
    # in optimizer state), bf16 optimizer moments, bf16 gradient wire,
    # ZeRO over the pod axis, and 4-way gradient-accumulation microbatching
    # to bound activation temps.
    # §Perf iterations: mb=1 (microbatching multiplied FSDP weight gathers
    # 4x — refuted as a default; memory handled by the 1024-chip recipe),
    # capacity factor 2.0 -> 1.25 (top-1 dispatch waste)
    "llama4-maverick-400b-a17b": dict(fsdp_over_pod=True,
                                      opt_state_dtype="bf16",
                                      param_dtype="bf16",
                                      master_fp32=True,
                                      microbatches=1,
                                      capacity_factor=1.25),
    # §Perf iteration: bf16 params halve every FSDP weight all-gather
    # (fp32 master lives in the optimizer state).  Validated on the
    # hillclimb cells, then promoted to the fleet-wide production default:
    "qwen2-7b": dict(param_dtype="bf16", master_fp32=True),
    "qwen2-vl-7b": dict(param_dtype="bf16", master_fp32=True),
    "stablelm-12b": dict(param_dtype="bf16", master_fp32=True),
    "stablelm-1.6b": dict(param_dtype="bf16", master_fp32=True),
    "starcoder2-15b": dict(param_dtype="bf16", master_fp32=True),
    "seamless-m4t-large-v2": dict(param_dtype="bf16", master_fp32=True),
    "qwen3-moe-30b-a3b": dict(param_dtype="bf16", master_fp32=True),
    "mamba2-1.3b": dict(param_dtype="bf16", master_fp32=True),
    # §Perf iterations: ssd_chunk 256 REFUTED (+46% collective — bigger
    # per-chunk tensors at the seq-shard boundary); seq_shard off CONFIRMED
    # (mamba blocks are channel-parallel: sequence sharding forced per-layer
    # seq<->channel reshards); microbatches=2 BLOCKED by an XLA SPMD
    # verifier bug (dynamic-slice of the partitioned embedding gather
    # inside the accumulation loop) — see EXPERIMENTS.md §Perf.
    "zamba2-1.2b": dict(param_dtype="bf16", master_fp32=True,
                        seq_shard=False),
}


def _cell_name(arch: str, shape: str, mesh: str) -> str:
    return f"{arch}__{shape}__{mesh}"


def _moe_groups_for(cfg, mesh, rules):
    dp = 1
    for a in rules.dp_axes:
        dp *= mesh.shape[a]
    return dp


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             opt_cfg: AdamWConfig | None = None) -> dict:
    shape = SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cfg = get_config(arch)
    ok, why = cell_applicable(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "kind": shape.kind, "family": cfg.family,
           "status": "skip" if not ok else "pending", "reason": why}
    if not ok:
        return rec

    dist = ARCH_DIST.get(arch, {})
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    overrides = dict(dist.get("overrides", {}))
    tp = int(mesh.shape["model"])
    if cfg.n_kv_heads % tp != 0:
        # GQA with kv_heads < tp: shard caches along the sequence instead
        # (kv_heads/act_kv_heads fall back to replication automatically via
        # dimension-aware AxisRules).
        overrides.setdefault("kv_seq", "model")
    rules = make_rules(mesh, fsdp_over_pod=dist.get("fsdp_over_pod", False),
                       overrides=overrides)
    cfg = cfg.with_(moe_groups=_moe_groups_for(cfg, mesh, rules))
    if dist.get("param_dtype") == "bf16":
        cfg = cfg.with_(param_dtype=jnp.bfloat16)
    if "ssd_chunk" in dist and cfg.ssm is not None:
        import dataclasses as _dc
        cfg = cfg.with_(ssm=_dc.replace(cfg.ssm, chunk=dist["ssd_chunk"]))
    if "capacity_factor" in dist and cfg.moe is not None:
        import dataclasses as _dc
        cfg = cfg.with_(moe=_dc.replace(cfg.moe,
                                        capacity_factor=dist["capacity_factor"]))
    if "seq_shard" in dist:
        cfg = cfg.with_(seq_shard=dist["seq_shard"])
    model = build_model(cfg)
    opt_cfg = opt_cfg or AdamWConfig(
        state_dtype=dist.get("opt_state_dtype", "fp32"),
        master_fp32=dist.get("master_fp32", False))

    chips = mesh.size
    params_abs = model.abstract_params(mesh, rules)

    with mesh:
        if shape.kind == "train":
            opt_abs = tree_defs_to_abstract(state_defs(model.param_defs, opt_cfg),
                                            mesh, rules)
            batch = input_specs(cfg, shape, mesh, rules)
            gd = dist.get("grad_dtype")
            step = make_train_step(model, rules, opt_cfg,
                                   microbatches=dist.get("microbatches", 1),
                                   grad_dtype=jnp.bfloat16 if gd == "bf16" else None)
            lowered = jax.jit(step, donate_argnums=(0, 1)).lower(
                params_abs, opt_abs, batch)
        elif shape.kind == "prefill":
            caches = model.abstract_caches(mesh, rules, shape.global_batch,
                                           max_len=shape.seq, cross_len=shape.seq)
            batch = input_specs(cfg, shape, mesh, rules)
            step = make_prefill_step(model, rules)
            lowered = jax.jit(step, donate_argnums=(2,)).lower(
                params_abs, batch, caches)
        else:  # decode
            caches = model.abstract_caches(mesh, rules, shape.global_batch,
                                           max_len=shape.seq, cross_len=shape.seq)
            batch = input_specs(cfg, shape, mesh, rules)
            index = jax.ShapeDtypeStruct((), jnp.int32,
                                         sharding=NamedSharding(mesh, P()))
            step = make_decode_step(model, rules)
            lowered = jax.jit(step, donate_argnums=(2,)).lower(
                params_abs, batch, caches, index)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    stats = analyze_hlo(hlo, default_group=chips)

    mflops, tokens = model_flops(cfg, shape.kind, shape.seq, shape.global_batch)
    # memory term uses the Pallas-kernel-aware accounting: the production
    # TPU path runs attention/SSD as fused kernels whose loop-internal
    # tensors are VMEM-resident (raw XLA-path bytes kept for the ablation)
    roof = Roofline(arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
                    flops_per_device=stats.flops,
                    bytes_per_device=stats.hbm_bytes_kernel_adj,
                    coll_bytes_per_device=float(stats.collective_bytes),
                    model_flops_total=mflops, step_tokens=tokens)

    rec.update(
        status="ok",
        chips=chips,
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        memory=dict(
            argument_bytes=getattr(mem, "argument_size_in_bytes", 0),
            output_bytes=getattr(mem, "output_size_in_bytes", 0),
            temp_bytes=getattr(mem, "temp_size_in_bytes", 0),
            alias_bytes=getattr(mem, "alias_size_in_bytes", 0),
            generated_code_bytes=getattr(mem, "generated_code_size_in_bytes", 0),
        ),
        cost={k: float(v) for k, v in cost.items()
              if isinstance(v, (int, float)) and "{" not in k},
        collectives=dict(bytes_per_device=stats.collective_bytes,
                         counts=stats.collective_counts,
                         bytes_by_op=stats.collective_bytes_by_op),
        hlo_census=dict(n_while_loops=stats.n_while_loops,
                        static_collectives=stats.static_collectives,
                        kernel_blocks=stats.kernel_blocks,
                        hbm_bytes_raw=stats.hbm_bytes,
                        hbm_bytes_naive=stats.hbm_bytes_naive,
                        flops_by_block=stats.dot_flops_by_block,
                        xla_cost_flops=float(cost.get("flops", 0.0)),
                        xla_bytes_accessed=float(cost.get("bytes accessed", 0.0))),
        roofline=roof.to_dict(),
        params_total=cfg.param_count(),
        params_active=cfg.active_param_count(),
    )
    # per-device HBM pressure (args include donated params/opt/caches)
    hbm = (rec["memory"]["argument_bytes"] + rec["memory"]["temp_bytes"]
           + rec["memory"]["output_bytes"] - rec["memory"]["alias_bytes"])
    rec["memory"]["hbm_estimate_bytes"] = hbm
    rec["memory"]["fits_16gb"] = bool(hbm < 16e9)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default=str(ART_DIR))
    args = ap.parse_args()

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = 0
    for arch in archs:
        for shape in shapes:
            for multi_pod in meshes:
                name = _cell_name(arch, shape, "pod2x16x16" if multi_pod else "pod16x16")
                path = out_dir / f"{name}.json"
                if path.exists():
                    print(f"[cached] {name}")
                    continue
                t0 = time.time()
                try:
                    rec = run_cell(arch, shape, multi_pod)
                except (ValueError, TypeError, KeyError, RuntimeError,
                        NotImplementedError) as e:
                    # record the failure, keep sweeping: shape/sharding
                    # mismatches (ValueError/TypeError), unknown arch or
                    # missing config key (KeyError), XLA compile errors
                    # (XlaRuntimeError is a RuntimeError), unimplemented
                    # lowerings (NotImplementedError)
                    failures += 1
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "pod2x16x16" if multi_pod else "pod16x16",
                           "status": "error", "error": repr(e),
                           "traceback": traceback.format_exc()[-4000:]}
                path.write_text(json.dumps(rec, indent=1))
                status = rec["status"]
                extra = ""
                if status == "ok":
                    r = rec["roofline"]
                    extra = (f" bound={r['bound']} roofline_frac={r['roofline_fraction']:.3f}"
                             f" hbm={rec['memory']['hbm_estimate_bytes']/1e9:.2f}GB"
                             f" compile={rec['compile_s']:.0f}s")
                print(f"[{status}] {name}{extra} ({time.time()-t0:.0f}s)", flush=True)
    print(f"done; failures={failures}")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
