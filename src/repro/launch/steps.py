"""Step functions: train / prefill / decode, built per (model, rules).

``make_train_step`` supports microbatched gradient accumulation (scan over
microbatches, grads averaged in fp32) and optional int8 gradient
compression across the "pod" axis (error feedback carried in opt extras).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import Model
from repro.models.common import AxisRules, tree_defs_to_specs
from repro.optim import AdamWConfig, apply_updates


def _constrain_like_params(grads, model: Model, rules: AxisRules):
    """Pin gradient shardings to the parameter shardings.  Without this,
    sharding propagation through the rematted backward can replicate large
    gradient leaves (measured +5x temp HBM on the MoE cells)."""
    specs = tree_defs_to_specs(model.param_defs, rules)
    try:
        return jax.tree.map(
            lambda g, s: jax.lax.with_sharding_constraint(g, s), grads, specs)
    except (ValueError, RuntimeError):
        return grads


def make_train_step(model: Model, rules: AxisRules, opt_cfg: AdamWConfig,
                    microbatches: int = 1, grad_dtype=None) -> Callable:
    def grad_fn(params, batch):
        def loss_fn(p):
            return model.loss(p, batch, rules)
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        grads = _constrain_like_params(grads, model, rules)
        if grad_dtype is not None:
            # bf16 gradient cast: halves grad HBM + cross-pod all-reduce wire
            grads = jax.tree.map(lambda g: g.astype(grad_dtype), grads)
        return loss, metrics, grads

    def train_step(params, opt_state, batch):
        if microbatches > 1:
            def split(x):
                return x.reshape((microbatches, x.shape[0] // microbatches)
                                 + x.shape[1:])
            mb = jax.tree.map(split, batch)

            def body(acc, one):
                loss, metrics, grads = grad_fn(params, one)
                acc = jax.tree.map(jnp.add, acc,
                                   jax.tree.map(lambda g: g / microbatches, grads))
                return acc, (loss, metrics)

            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, (losses, metricss) = jax.lax.scan(body, zero, mb)
            loss = jnp.mean(losses)
            metrics = jax.tree.map(jnp.mean, metricss)
        else:
            loss, metrics, grads = grad_fn(params, batch)
        new_params, new_opt, opt_metrics = apply_updates(params, grads,
                                                         opt_state, opt_cfg)
        return new_params, new_opt, {**metrics, **opt_metrics, "loss": loss}

    return train_step


def make_prefill_step(model: Model, rules: AxisRules) -> Callable:
    def prefill_step(params, batch, caches):
        return model.prefill(params, batch, caches, rules)
    return prefill_step


def make_decode_step(model: Model, rules: AxisRules) -> Callable:
    def decode_step(params, batch, caches, cache_index):
        logits, new_caches = model.decode(params, batch, caches, cache_index, rules)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, logits, new_caches
    return decode_step
