"""Online re-estimation quickstart: fit locally, execute, watch the median
prediction error drop as observations stream in.

    PYTHONPATH=src python examples/online_reestimation.py

The flow is the full closed loop of the online subsystem:

  1. fit Lotaru from downsampled local runs (the paper's phases 1-3);
  2. HEFT-plan a fan-out eager workflow over the heterogeneous cluster;
  3. execute on grid-engine-style nodes, feeding every finished task's
     realised runtime back through ``LotaruEstimator.observe`` (an O(d²)
     incremental conjugate update — no refit);
  4. when a runtime falls outside its predictive interval, re-plan the
     not-yet-started frontier with the refreshed estimates.

The static baseline runs the same plan with frozen predictions.
"""
import numpy as np

from repro.core import (LotaruEstimator, get_node, profile_cluster,
                        profile_node, target_nodes)
from repro.online import (OnlineExecutor, fanout_chain_dag,
                          run_static_and_online)
from repro.sched.simulator import ClusterSimulator, GridEngine
from repro.sched.workflows import INPUTS, WORKFLOWS

WORKFLOW = "eager"
N_SAMPLES = 8          # physical inputs fanned through the abstract chain


def main():
    local = get_node("local-cpu")
    local_bench = profile_node(local, np.random.default_rng(7))
    tbenches = profile_cluster(target_nodes(), seed=13)
    size = INPUTS[(WORKFLOW, 1)]
    by_name = {t.name: t for t in WORKFLOWS[WORKFLOW]}
    tasks, task_name = fanout_chain_dag(list(by_name), N_SAMPLES)

    # ground truth: an independent simulator seed, so realised runtimes
    # carry noise + systematic per-(task, node) efficiency the initial
    # factor adjustment cannot see
    truth = ClusterSimulator(seed=2000)
    truth_tab = {(tid, nt.name): truth.run_task(by_name[task_name[tid]],
                                                nt, size)
                 for tid in tasks for nt in target_nodes()}

    def make_executor(online):
        sim = ClusterSimulator(seed=0)
        est = LotaruEstimator(local_bench, tbenches)
        est.fit_tasks(list(by_name), size,
                      lambda n, s, cf: sim.run_task(by_name[n], local, s,
                                                    cpu_factor=cf))
        grid = GridEngine.from_types(nodes_per_type=2)
        return OnlineExecutor(
            est, tasks, task_name, size, grid,
            lambda tid, node: truth_tab[(tid, grid.type_of(node).name)],
            online=online, confidence=0.9)

    static, online = run_static_and_online(make_executor)

    print(f"{WORKFLOW} x {N_SAMPLES} samples "
          f"({len(tasks)} task instances) on the heterogeneous cluster\n")
    print(f"{'':12s} {'makespan':>10s} {'final MPE':>10s} "
          f"{'replans':>8s} {'surprises':>10s}")
    print(f"{'static':12s} {static.makespan:10.0f} "
          f"{static.final_mpe():10.3f} {0:8d} {0:10d}")
    print(f"{'online':12s} {online.makespan:10.0f} "
          f"{online.final_mpe():10.3f} {online.replans:8d} "
          f"{online.surprises:10d}")

    print("\ncumulative MPE trajectory (every 10th completion):")
    ts, to = static.cumulative_mpe(), online.cumulative_mpe()
    print("  completion:", "".join(f"{k:8d}" for k in
                                   range(0, len(ts), 10)))
    print("  static    :", "".join(f"{v:8.3f}" for v in ts[::10]))
    print("  online    :", "".join(f"{v:8.3f}" for v in to[::10]))
    gain = (static.final_mpe() - online.final_mpe()) / static.final_mpe()
    print(f"\nonline estimation cut the median prediction error by "
          f"{100 * gain:.0f}% while the workflow ran.")


if __name__ == "__main__":
    main()
