"""Online re-estimation quickstart: fit locally, execute, watch the median
prediction error drop as observations stream in — and the per-(task, node)
bias layer squeeze out the systematic residual the factor cannot see.

    PYTHONPATH=src python examples/online_reestimation.py

The flow is the full closed loop of the online subsystem:

  1. fit Lotaru from downsampled local runs (the paper's phases 1-3);
  2. HEFT-plan a fan-out eager workflow over the heterogeneous cluster;
  3. execute on grid-engine-style nodes; every simulation tick's finished
     tasks are fed back in ONE ``LotaruEstimator.observe_batch`` scan
     (incremental conjugate update, O(d²) per row — no refit);
  4. each residual updates a conjugate per-(task, node) multiplicative
     bias posterior: predictions are scaled by its point estimate and
     their intervals widened by its remaining uncertainty;
  5. when a runtime falls outside its predictive interval, the
     not-yet-started frontier is re-planned with the refreshed estimates —
     and a still-running task on a node whose bias has drifted high gets
     a speculative copy on the best idle node (first finish wins).

Two baselines run the same scenario: the static plan with frozen
predictions, and the PR-2 online loop with the bias layer disabled
(``bias_correction=False``).  A fourth, risk-aware arm closes the loop
the paper only gestures at: empirical-Bayes pooling of the bias noise
scale, HEFT placement on the effective cost mean + risk_k * widened
sigma, and speculative admission from the bias posterior's tail mass.
"""
import numpy as np

from repro.core import (LotaruEstimator, get_node, profile_cluster,
                        profile_node, target_nodes)
from repro.online import OnlineExecutor, fanout_chain_dag
from repro.sched.simulator import ClusterSimulator, GridEngine
from repro.sched.workflows import INPUTS, WORKFLOWS

WORKFLOW = "eager"
N_SAMPLES = 8          # physical inputs fanned through the abstract chain


def main():
    local = get_node("local-cpu")
    local_bench = profile_node(local, np.random.default_rng(7))
    tbenches = profile_cluster(target_nodes(), seed=13)
    size = INPUTS[(WORKFLOW, 1)]
    by_name = {t.name: t for t in WORKFLOWS[WORKFLOW]}
    tasks, task_name = fanout_chain_dag(list(by_name), N_SAMPLES)

    # ground truth: an independent simulator seed, so realised runtimes
    # carry noise + systematic per-(task, node) efficiency the initial
    # factor adjustment cannot see — exactly what the bias layer learns
    truth = ClusterSimulator(seed=2000)
    truth_tab = {(tid, nt.name): truth.run_task(by_name[task_name[tid]],
                                                nt, size)
                 for tid in tasks for nt in target_nodes()}

    estimators = {}

    def make_executor(online, bias_correction=True, risk=False):
        sim = ClusterSimulator(seed=0)
        est = LotaruEstimator(local_bench, tbenches,
                              bias_correction=bias_correction,
                              bias_empirical_bayes=risk)
        est.fit_tasks(list(by_name), size,
                      lambda n, s, cf: sim.run_task(by_name[n], local, s,
                                                    cpu_factor=cf))
        grid = GridEngine.from_types(nodes_per_type=2)
        estimators[(online, bias_correction, risk)] = est
        return OnlineExecutor(
            est, tasks, task_name, size, grid,
            lambda tid, node: truth_tab[(tid, grid.type_of(node).name)],
            online=online, confidence=0.9, speculate=True,
            risk_k=1.0 if risk else 0.0, spec_tail=0.8 if risk else None)

    static = make_executor(online=False).run()
    pr2 = make_executor(online=True, bias_correction=False).run()
    online = make_executor(online=True).run()
    risk = make_executor(online=True, risk=True).run()

    print(f"{WORKFLOW} x {N_SAMPLES} samples "
          f"({len(tasks)} task instances) on the heterogeneous cluster\n")
    print(f"{'':14s} {'makespan':>10s} {'final MPE':>10s} "
          f"{'replans':>8s} {'surprises':>10s} {'spec/won':>9s}")
    for label, tr in (("static", static), ("online (PR2)", pr2),
                      ("online+bias", online), ("bias+risk", risk)):
        print(f"{label:14s} {tr.makespan:10.0f} {tr.final_mpe():10.3f} "
              f"{tr.replans:8d} {tr.surprises:10d} "
              f"{tr.speculations:4d}/{tr.spec_wins:d}")

    print("\ncumulative MPE trajectory (every 10th completion):")
    ts, to = static.cumulative_mpe(), online.cumulative_mpe()
    print("  completion:", "".join(f"{k:8d}" for k in
                                   range(0, len(ts), 10)))
    print("  static    :", "".join(f"{v:8.3f}" for v in ts[::10]))
    print("  online    :", "".join(f"{v:8.3f}" for v in to[::10]))

    est = estimators[(True, True, False)]
    bias = est.bias
    obs_pairs = int((bias.counts > 0).sum())
    b = bias.matrix()
    print(f"\nlearned per-(task, node) bias: {obs_pairs} pairs observed, "
          f"range [{b[bias.counts > 0].min():.2f}, "
          f"{b[bias.counts > 0].max():.2f}] "
          f"(unobserved pairs stay at exactly 1.0)")
    # the same-tick batches the executor actually absorbed
    ticks = online.observations.by_tick()
    batched = sum(1 for _, g in ticks if len(g) > 1)
    print(f"observation stream: {len(online.observations)} completions in "
          f"{len(ticks)} ticks ({batched} multi-completion ticks fed "
          "observe_batch as one scan)")

    gain = (static.final_mpe() - online.final_mpe()) / static.final_mpe()
    gain2 = (pr2.final_mpe() - online.final_mpe()) / pr2.final_mpe()
    print(f"\nonline estimation cut the median prediction error by "
          f"{100 * gain:.0f}% vs the static plan "
          f"({100 * gain2:.0f}% of it from the bias layer).")

    est_risk = estimators[(True, True, True)]
    print(f"risk-aware arm: makespan {risk.makespan:.0f} vs "
          f"{online.makespan:.0f} (bias), EB-pooled sigma_r = "
          f"{est_risk.bias.effective_sigma_r():.3f} "
          f"(configured {est_risk.bias.sigma_r}), "
          f"{risk.speculations} tail-mass speculations")


if __name__ == "__main__":
    main()
