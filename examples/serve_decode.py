"""Serving example: prefill a prompt batch, then step the decode loop with
a KV cache — the Pallas flash kernel validates each step against the XLA
path on the first iteration.

    PYTHONPATH=src python examples/serve_decode.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import smoke_config
from repro.launch.shapes import concrete_batch
from repro.models import AxisRules, build_model

rules = AxisRules(fsdp_axes=(), dp_axes=())
cfg = smoke_config("stablelm-1.6b").with_(n_layers=4, d_model=64, d_ff=128)
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))

B, T_prompt, T_gen = 4, 24, 16
batch = concrete_batch(cfg, "prefill", B, T_prompt)
caches = model.init_caches(B, max_len=T_prompt + T_gen)

prefill = jax.jit(lambda p, b, c: model.prefill(p, b, c, rules))
decode = jax.jit(lambda p, b, c, i: model.decode(p, b, c, i, rules))

logits, caches = prefill(params, batch, caches)
tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
out_tokens = [tok]
for step in range(T_gen - 1):
    logits, caches = decode(params, {"tokens": tok}, caches,
                            jnp.asarray(T_prompt + step, jnp.int32))
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    out_tokens.append(tok)

gen = jnp.concatenate(out_tokens, axis=1)
print(f"prompt batch {B} x {T_prompt} tokens -> generated {gen.shape[1]} "
      f"tokens per sequence")
print("sample generations:", np.asarray(gen[:2]))

# cross-check the serving attention against the Pallas kernel
from repro.kernels.flash_attention import attention_ref, flash_attention
q = jax.random.normal(jax.random.PRNGKey(1), (2, 4, 64, 16))
k = jax.random.normal(jax.random.PRNGKey(2), (2, 2, 64, 16))
v = jax.random.normal(jax.random.PRNGKey(3), (2, 2, 64, 16))
err = jnp.max(jnp.abs(flash_attention(q, k, v, causal=True, block_q=32,
                                      block_k=32, interpret=True)
                      - attention_ref(q, k, v, causal=True)))
print(f"pallas flash kernel vs oracle: max err {float(err):.2e}")
print("serve_decode OK")
