"""Quickstart: Lotaru's four phases in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (LotaruEstimator, get_node, profile_cluster,
                        profile_local, profile_node, target_nodes)
from repro.sched.simulator import ClusterSimulator
from repro.sched.workflows import INPUTS, WORKFLOWS

# ---- phase 1: infrastructure profiling ------------------------------------
print("phase 1: profiling this machine (real microbenchmarks) ...")
local_bench = profile_local(fast=True)
print(f"  local: {local_bench.cpu_events_s:.0f} cpu ev/s, "
      f"{local_bench.matmul_gflops:.1f} GFLOP/s, "
      f"{local_bench.mem_gbps:.1f} GB/s mem, "
      f"{local_bench.io_read_mbps:.0f} MB/s io")
target_benches = profile_cluster(target_nodes(), seed=13)
for b in target_benches.values():
    print(f"  {b.node}: {b.matmul_gflops/1e3:.0f} TFLOP/s, "
          f"{b.mem_gbps:.0f} GB/s HBM, {b.link_gbps:.0f} GB/s link")

# ---- phases 2+3: downsampled local runs + Bayesian regression -------------
sim = ClusterSimulator(seed=0)
local = get_node("local-cpu")
wf = WORKFLOWS["eager"]
by_name = {t.name: t for t in wf}
size = INPUTS[("eager", 1)]
est = LotaruEstimator(profile_node(local, np.random.default_rng(7)),
                      target_benches)
print(f"\nphases 2+3: downsampling eager-1 input ({size} GB) and running "
      f"locally (normal + 20% CPU-throttled) ...")
est.fit_tasks([t.name for t in wf], size,
              lambda name, s, cf: sim.run_task(by_name[name], local, s,
                                               cpu_factor=cf))

# ---- phase 4: adjusted predictions for every (task, node) pair ------------
print("\nphase 4: (task x node) predictions with Bayesian uncertainty:")
print(f"{'task':18s} {'node':9s} {'pred':>9s} {'±σ':>8s} {'w':>5s}")
for name in ("bwa", "fastqc", "markduplicates", "bcftools_stats"):
    for node in target_nodes()[:3]:
        mean, std = est.predict(name, node.name, size)
        print(f"{name:18s} {node.name:9s} {mean:8.1f}s {std:7.1f}s "
              f"{est.tasks[name].w:5.2f}")
print("\ndone — these estimates feed the HEFT scheduler "
      "(examples/heterogeneous_schedule.py)")
