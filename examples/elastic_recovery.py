"""Fault tolerance end to end: train on a multi-device mesh, inject a
failure, restart from the async checkpoint onto a *smaller* (elastic) mesh,
and verify training continues with identical semantics.

Needs >1 device, so this example forces 8 host platform devices — run it
standalone (not under pytest):

    PYTHONPATH=src python examples/elastic_recovery.py
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import tempfile

import jax

from repro.configs import smoke_config
from repro.launch.mesh import make_mesh, make_rules
from repro.launch.train import InjectedFailure, train

cfg = smoke_config("qwen2-7b").with_(n_layers=4, d_model=64, d_ff=128)

with tempfile.TemporaryDirectory() as ckpt:
    mesh_a = make_mesh((4, 2), ("data", "model"))
    rules_a = make_rules(mesh_a)
    print(f"phase 1: training on mesh {dict(mesh_a.shape)} ...")
    try:
        train(cfg, steps=20, seq=32, global_batch=8, ckpt_dir=ckpt,
              ckpt_every=5, mesh=mesh_a, rules=rules_a, fail_at_step=12,
              seed=0)
    except InjectedFailure as e:
        print(f"  !! {e}")

    # half the fleet is gone: rebuild a 4-device mesh and resume
    mesh_b = make_mesh((2, 2), ("data", "model"))
    rules_b = make_rules(mesh_b)
    print(f"phase 2: elastic restart on mesh {dict(mesh_b.shape)} "
          f"(params re-sharded from the checkpoint manifest) ...")
    rep = train(cfg, steps=20, seq=32, global_batch=8, ckpt_dir=ckpt,
                ckpt_every=5, mesh=mesh_b, rules=rules_b, seed=0)
    print(f"  resumed at step {20 - rep.steps_run}, finished at "
          f"{rep.final_step}; final loss {rep.losses[-1]:.4f}")
    print("elastic recovery OK")
