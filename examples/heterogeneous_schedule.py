"""Lotaru -> HEFT, end to end: profile the cluster, learn task models from
downsampled local runs, predict every (task, node) runtime + uncertainty,
and gang-schedule a fan-out physical workflow across the heterogeneous
fleet.  Also schedules the ML workload cells from the dry-run artifacts if
present (the accelerator plane).

    PYTHONPATH=src python examples/heterogeneous_schedule.py
"""
from pathlib import Path

import numpy as np

from repro.core import (LotaruEstimator, LotaruML, get_node, profile_cluster,
                        profile_node, target_nodes, young_daly_interval)
from repro.sched.heft import SchedTask, heft_schedule
from repro.sched.simulator import ClusterSimulator, load_dryrun_cells
from repro.sched.workflows import INPUTS, WORKFLOWS

ART = Path(__file__).resolve().parents[1] / "experiments" / "artifacts" / "dryrun"

sim = ClusterSimulator(seed=0)
local = get_node("local-cpu")
local_bench = profile_node(local, np.random.default_rng(7))
tbenches = profile_cluster(target_nodes(), seed=13)

# ---- genomics-plane workflow scheduling ------------------------------------
wf = WORKFLOWS["chipseq"]
by_name = {t.name: t for t in wf}
size = INPUTS[("chipseq", 1)]
est = LotaruEstimator(local_bench, tbenches)
est.fit_tasks(list(by_name), size,
              lambda n, s, cf: sim.run_task(by_name[n], local, s,
                                            cpu_factor=cf))

n_samples = 6
tasks, cost, unc = {}, {}, {}
chain = [t.name for t in wf]
nodes = [f"{nt.name}/{i}" for nt in target_nodes() for i in range(2)]
ntype = {n: n.rsplit("/", 1)[0] for n in nodes}
for s in range(n_samples):
    prev = None
    for name in chain:
        tid = f"s{s}.{name}"
        tasks[tid] = SchedTask(id=tid)
        if prev:
            tasks[tid].pred.append(prev)
            tasks[prev].succ.append(tid)
        prev = tid
        cost[tid] = {}
        unc[tid] = {}
        for n in nodes:
            m, sd = est.predict(name, ntype[n], size)
            cost[tid][n] = m
            unc[tid][n] = sd

sched = heft_schedule(tasks, cost, nodes, uncertainty=unc, risk_k=1.0)
print(f"chipseq-1 x {n_samples} samples over {len(nodes)} nodes: "
      f"predicted makespan {sched['makespan']/60:.1f} min")
per_node = {}
for tid, n in sched["assignment"].items():
    per_node[n] = per_node.get(n, 0) + 1
for n in sorted(per_node):
    print(f"  {n:12s} {per_node[n]:3d} tasks")

# ---- ML plane: schedule (arch x shape) cells over pod slices ---------------
cells = [c for c in load_dryrun_cells(ART) if c["mesh"] == "pod16x16"
         and c["shape"] == "train_4k"]
if cells:
    ml = LotaruML(local_bench, tbenches)
    for c in cells:
        ml.fit_cell(c, lambda cell, f: sim.run_cell(cell, local, f),
                    run_local_throttled=lambda cell, f: sim.run_cell(
                        cell, local, f, cpu_factor=0.8))
    print("\nML cells — predicted step time per pod type (s) "
          "+ Young/Daly checkpoint interval @ MTBF 6h:")
    for c in cells[:6]:
        name = f"{c['arch']}__{c['shape']}"
        preds = {nt.name: ml.predict(name, nt.name)[0]
                 for nt in target_nodes()}
        best = min(preds, key=preds.get)
        mean, std = ml.predict(name, best)
        yd = young_daly_interval(mean, mtbf_s=6 * 3600,
                                 checkpoint_cost_s=30.0)
        print(f"  {name:45s} best={best} {preds[best]:7.3f}s  "
              f"ckpt_every={yd:6.0f}s  straggler_thr={mean+3*std:7.3f}s")
else:
    print("\n(no dry-run artifacts; run python -m repro.launch.dryrun for "
          "the ML-plane demo)")
