"""End-to-end driver: train a ~100M-param dense LM with the full stack —
synthetic deterministic data pipeline, AdamW (cosine schedule), chunked
xent, scan-over-layers, async checkpointing, restart safety.

    PYTHONPATH=src python examples/train_lm.py --steps 300

On this single-CPU container a step takes a few seconds; the loss should
fall well below ln(vocab) ~ 9.2 within a few hundred steps (the synthetic
stream has learnable structure).
"""
import argparse
import time

from repro.launch.train import train
from repro.models import ModelConfig
from repro.optim import AdamWConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = ModelConfig(
        arch="demo-100m", family="dense",
        n_layers=14, d_model=640, n_heads=10, n_kv_heads=10, d_ff=2560,
        vocab=8_192, head_dim=64, norm="rmsnorm", act="swiglu",
        attn_chunk=128, xent_chunk=128, remat="full")
    n = cfg.param_count()
    print(f"arch demo-100m: {n/1e6:.1f}M params, "
          f"{args.steps} steps @ {args.seq}x{args.batch}")
    t0 = time.time()
    rep = train(cfg, steps=args.steps, seq=args.seq, global_batch=args.batch,
                ckpt_dir=args.ckpt, ckpt_every=50,
                opt_cfg=AdamWConfig(lr=1e-3, warmup_steps=30,
                                    total_steps=args.steps),
                verbose=True, log_every=10)
    dt = time.time() - t0
    print(f"\nfinal loss {rep.losses[-1]:.4f} (start {rep.losses[0]:.4f}) "
          f"in {dt/60:.1f} min; {1e3*dt/rep.steps_run:.0f} ms/step")
    assert rep.losses[-1] < rep.losses[0], "loss did not improve"


if __name__ == "__main__":
    main()
