"""Per-kernel allclose vs pure-jnp oracles: shape/dtype sweeps (interpret)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.ssd import ssd_ref, ssd_scan


@pytest.mark.parametrize("B,Hq,Hkv,Sq,Sk,D,causal", [
    (1, 2, 2, 64, 64, 32, True),
    (2, 4, 2, 128, 128, 64, True),      # GQA
    (1, 4, 1, 96, 160, 32, False),      # MQA, unaligned, bidir
    (1, 2, 2, 1, 256, 64, False),       # decode shape
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_oracle(B, Hq, Hkv, Sq, Sk, D, causal, dtype):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(0, 1, (B, Hq, Sq, D)), dtype)
    k = jnp.asarray(rng.normal(0, 1, (B, Hkv, Sk, D)), dtype)
    v = jnp.asarray(rng.normal(0, 1, (B, Hkv, Sk, D)), dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64,
                          interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


def test_flash_attention_kv_len_mask():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(0, 1, (1, 2, 8, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (1, 2, 128, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (1, 2, 128, 32)), jnp.float32)
    out = flash_attention(q, k, v, causal=False, kv_len=50, block_k=32,
                          interpret=True)
    ref = attention_ref(q, k, v, causal=False, kv_len=50)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)
    # keys beyond kv_len must not affect the output
    k2 = k.at[:, :, 50:].set(1e3)
    out2 = flash_attention(q, k2, v, causal=False, kv_len=50, block_k=32,
                           interpret=True)
    np.testing.assert_allclose(np.asarray(out2), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("B,T,H,P,G,N,chunk", [
    (1, 32, 2, 8, 1, 8, 8),
    (2, 64, 4, 16, 2, 16, 16),
    (1, 50, 4, 8, 1, 8, 16),            # unaligned T
])
def test_ssd_matches_naive_recurrence(B, T, H, P, G, N, chunk):
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(0, 1, (B, T, H, P)), jnp.float32)
    dt = jnp.asarray(np.abs(rng.normal(0.05, 0.02, (B, T, H))), jnp.float32)
    a = jnp.asarray(-np.abs(rng.normal(1.0, 0.3, (H,))), jnp.float32)
    B_ = jnp.asarray(rng.normal(0, 1, (B, T, G, N)), jnp.float32)
    C_ = jnp.asarray(rng.normal(0, 1, (B, T, G, N)), jnp.float32)
    out = ssd_scan(x, dt, a, B_, C_, chunk=chunk, interpret=True)
    ref = ssd_ref(x, dt, a, B_, C_)
    scale = float(jnp.max(jnp.abs(ref)))
    np.testing.assert_allclose(np.asarray(out) / scale,
                               np.asarray(ref) / scale, atol=1e-5)


def test_models_ssd_chunked_matches_kernel_ref():
    from repro.models.mamba2 import ssd_chunked
    rng = np.random.default_rng(3)
    B, T, H, P, G, N = 2, 48, 4, 8, 1, 8
    x = jnp.asarray(rng.normal(0, 1, (B, T, H, P)), jnp.float32)
    dt = jnp.asarray(np.abs(rng.normal(0.05, 0.02, (B, T, H))), jnp.float32)
    a = jnp.asarray(-np.abs(rng.normal(1.0, 0.3, (H,))), jnp.float32)
    B_ = jnp.asarray(rng.normal(0, 1, (B, T, G, N)), jnp.float32)
    C_ = jnp.asarray(rng.normal(0, 1, (B, T, G, N)), jnp.float32)
    y, _ = ssd_chunked(x, dt, a, B_, C_, chunk=16)
    ref = ssd_ref(x, dt, a, B_, C_)
    scale = float(jnp.max(jnp.abs(ref)))
    np.testing.assert_allclose(np.asarray(y) / scale,
                               np.asarray(ref) / scale, atol=1e-5)
