"""Fault tolerance: deterministic fault injection, elastic grid
membership, retry/backoff under an attempt budget, censored observations,
Beta-Binomial reliability posteriors, and the executor's completion
guarantees under churn."""
import json

import numpy as np
import pytest

from repro.core import LotaruEstimator, ReliabilityModel, SCHEMA_VERSION
from repro.core.nodes import get_node
from repro.core.profiler import BenchResult
from repro.online import OnlineExecutor, fanout_chain_dag
from repro.sched.heft import heft_schedule_array
from repro.sched.simulator import (EventSimulator, FaultInjector,
                                   GridEngine, SimNode)


def _bench(name, cpu, io):
    return BenchResult(node=name, cpu_events_s=cpu, matmul_gflops=100.0,
                       mem_gbps=20.0, io_read_mbps=io, io_write_mbps=io,
                       link_gbps=0.0)


def _make_est():
    local = _bench("local-cpu", 450.0, 420.0)
    benches = {"tpu-v2": _bench("tpu-v2", 600.0, 500.0),
               "tpu-v3": _bench("tpu-v3", 650.0, 550.0)}
    est = LotaruEstimator(local, benches)
    slopes = {f"t{i}": (i + 1) * 2.0 for i in range(3)}
    est.fit_tasks(list(slopes), 64.0,
                  lambda n, s, cf: slopes[n] * s / cf + 5.0,
                  n_partitions=8)
    return est, list(slopes)


def _scenario(*, online=True, faults=None, rel_k=None, strict=True,
              max_attempts=4, n_samples=6, nodes_per_type=2, bias=1.5,
              slow=None, noise_seed=None, **kw):
    """Chain workflow over ``n_samples`` inputs; ground truth is a
    systematic ``bias`` off the estimator's initial belief (``slow``
    additionally slows the tpu-v2 type and ``noise_seed`` adds +-10%
    jitter, for speculation scenarios)."""
    est, chain = _make_est()
    truth, _ = _make_est()                      # frozen initial beliefs
    tasks, task_name = fanout_chain_dag(chain, n_samples)
    grid = GridEngine.from_types(nodes_per_type=nodes_per_type,
                                 types=[get_node("tpu-v2"),
                                        get_node("tpu-v3")])
    size = 32.0
    rng = (np.random.default_rng(noise_seed)
           if noise_seed is not None else None)

    def runtime_fn(tid, node):
        nt = grid.type_of(node).name
        m, _ = truth.predict(task_name[tid], nt, size)
        f = slow if (slow is not None and nt == "tpu-v2") else 1.0
        jitter = float(rng.uniform(0.9, 1.1)) if rng is not None else 1.0
        return m * bias * f * jitter

    return OnlineExecutor(est, tasks, task_name, size, grid, runtime_fn,
                          online=online, confidence=0.2, faults=faults,
                          rel_k=rel_k, strict=strict,
                          max_attempts=max_attempts, **kw)


# ---------------------------------------------------------------------------
# FaultInjector: deterministic, seeded, validated
# ---------------------------------------------------------------------------
def test_fault_injector_validation():
    with pytest.raises(ValueError):
        FaultInjector(p_fail=1.5)
    with pytest.raises(ValueError):
        FaultInjector(p_fail=-0.1)
    with pytest.raises(ValueError):
        FaultInjector(outages={"n": (5.0, 5.0)})


def test_fault_injector_draws_are_stable_per_seed():
    a = FaultInjector(p_fail=0.3, seed=11)
    b = FaultInjector(p_fail=0.3, seed=11)
    c = FaultInjector(p_fail=0.3, seed=12)
    assert a.attempt_fail_prob("t", "n") == b.attempt_fail_prob("t", "n")
    assert a.attempt_outcome("t", "n", 0) == b.attempt_outcome("t", "n", 0)
    assert a.attempt_fail_prob("t", "n") != c.attempt_fail_prob("t", "n")
    # p = p_fail * (1 + p_spread * u) with u in [0, 1)
    p = a.attempt_fail_prob("t", "n")
    assert 0.3 <= p < 0.6
    # different attempts of the same pair draw independently
    outs = {a.attempt_outcome("x", "n", k) is None for k in range(40)}
    assert outs == {True, False}
    # a failure manifests strictly mid-run
    fr = [a.attempt_outcome("x", "n", k) for k in range(40)]
    assert all(0.05 <= f <= 0.95 for f in fr if f is not None)


def test_fault_injector_inert_by_default():
    fi = FaultInjector()
    assert fi.attempt_fail_prob("t", "n") == 0.0
    assert fi.attempt_outcome("t", "n", 0) is None
    assert fi.node_events() == []


def test_node_events_time_sorted():
    fi = FaultInjector(crash_at={"a": 5.0}, outages={"b": (1.0, 9.0)})
    assert fi.node_events() == [(1.0, "b", "down"), (5.0, "a", "down"),
                                (9.0, "b", "up")]


# ---------------------------------------------------------------------------
# Elastic membership on the grid (satellite: ready_vector alive fix)
# ---------------------------------------------------------------------------
def test_grid_fail_masks_ready_vector_and_idle():
    grid = GridEngine.from_types(nodes_per_type=1,
                                 types=[get_node("tpu-v2"),
                                        get_node("tpu-v3")])
    names = list(grid.nodes)
    grid.occupy(names[0], 10.0)
    grid.fail(names[0], 3.0)
    rv = grid.ready_vector(0.0)
    assert np.isinf(rv[0])                     # regression: was busy_until
    assert np.isfinite(rv[1])
    assert names[0] not in grid.idle(100.0)
    grid.join(names[0], 50.0)                  # outage ends
    rv2 = grid.ready_vector(0.0)
    assert rv2[0] == 50.0                      # availability floor kept
    assert names[0] in grid.idle(60.0)


def test_grid_join_registers_new_node():
    grid = GridEngine.from_types(nodes_per_type=1,
                                 types=[get_node("tpu-v2")])
    n0 = len(grid.nodes)
    grid.join(SimNode("extra", get_node("tpu-v3")), at=5.0)
    assert len(grid.nodes) == n0 + 1
    assert grid.nodes["extra"].alive
    assert grid.nodes["extra"].busy_until == 5.0


def test_heft_never_places_on_infinite_ready_node():
    # the planning-side twin of the idle() mask: a dead node's +inf
    # availability makes every EFT there infinite
    n_tasks = 4
    succ = [[] for _ in range(n_tasks)]
    pred = [[] for _ in range(n_tasks)]
    cost = np.ones((n_tasks, 2))
    sched = heft_schedule_array(succ, pred, cost, None, 0.0,
                                node_ready=np.array([0.0, np.inf]),
                                task_ready=np.zeros(n_tasks))
    assert all(int(a) == 0 for a in sched["assignment"])


# ---------------------------------------------------------------------------
# EventSimulator: incomplete schedules must not truncate silently
# ---------------------------------------------------------------------------
def _ev_sim():
    nodes = [SimNode("a", get_node("tpu-v2")),
             SimNode("b", get_node("tpu-v3"))]
    return EventSimulator(nodes, sim=None)


_EV_TASKS = [{"id": "x", "task": None, "size": 1.0},
             {"id": "y", "task": None, "size": 1.0}]


def test_run_schedule_raises_on_dependency_deadlock():
    with pytest.raises(RuntimeError, match=r"stranded.*x, y.*deadlock"):
        _ev_sim().run_schedule(_EV_TASKS, {"x": ["y"], "y": ["x"]},
                               {"x": "a", "y": "b"},
                               runtime_fn=lambda rec, node: 1.0)


def test_run_schedule_names_work_stranded_on_dead_node():
    with pytest.raises(RuntimeError,
                       match=r"x, y.*failed nodes with no reassign_fn"):
        _ev_sim().run_schedule(_EV_TASKS, {}, {"x": "a", "y": "a"},
                               runtime_fn=lambda rec, node: 1.0,
                               fail_at={"a": 0.0})


def test_run_schedule_warn_and_ignore_modes():
    with pytest.warns(RuntimeWarning, match="stranded"):
        res = _ev_sim().run_schedule(_EV_TASKS, {}, {"x": "a", "y": "a"},
                                     runtime_fn=lambda rec, node: 1.0,
                                     fail_at={"a": 0.0},
                                     on_incomplete="warn")
    assert res["completed"] == 0 and res["total"] == 2
    res = _ev_sim().run_schedule(_EV_TASKS, {}, {"x": "a", "y": "a"},
                                 runtime_fn=lambda rec, node: 1.0,
                                 fail_at={"a": 0.0}, on_incomplete="ignore")
    assert res["completed"] < res["total"]
    with pytest.raises(ValueError):
        _ev_sim().run_schedule(_EV_TASKS, {}, {"x": "a", "y": "b"},
                               runtime_fn=lambda rec, node: 1.0,
                               on_incomplete="loudly")


# ---------------------------------------------------------------------------
# Executor: fault-free path stays inert
# ---------------------------------------------------------------------------
def test_fault_free_counters_inert():
    ex = _scenario()
    tr = ex.run()
    assert (tr.failures, tr.retries, tr.lost_nodes, tr.stranded) == \
        (0, 0, 0, 0)
    assert tr.censored == []
    assert tr.completed == tr.total == len(tr.records)
    assert tr.completed_fraction() == 1.0
    assert ex.est.reliability is None   # no tracking unless asked


def test_executor_validates_fault_knobs():
    with pytest.raises(ValueError):
        _scenario(max_attempts=0)
    with pytest.raises(ValueError):
        _scenario(backoff_base=-1.0)
    with pytest.raises(ValueError):
        _scenario(backoff_cap=-0.5)


# ---------------------------------------------------------------------------
# Attempt failures: retry with backoff, censored bookkeeping
# ---------------------------------------------------------------------------
def test_attempt_failures_retry_to_completion():
    fi = FaultInjector(p_fail=0.3, p_spread=0.5, seed=3)
    tr = _scenario(faults=fi, rel_k=1.0, max_attempts=8).run()
    assert tr.completed == tr.total
    assert tr.failures > 0
    assert tr.retries == tr.failures       # every lost attempt re-queued
    assert len(tr.censored) == tr.failures
    assert all(c.reason == "attempt" for c in tr.censored)
    assert all(c.elapsed > 0.0 for c in tr.censored)
    # censored attempts never reach the runtime posterior: exactly one
    # observation per *completed* task despite the extra attempts
    assert len(tr.observations) == tr.total
    # the final record of a retried task is its successful attempt
    ids = [r.id for r in tr.records]
    assert len(ids) == len(set(ids)) == tr.total


def test_backoff_grows_and_caps():
    ex = _scenario(backoff_base=1.0, backoff_cap=30.0)
    assert [ex._backoff(k) for k in range(1, 6)] == \
        [1.0, 2.0, 4.0, 8.0, 16.0]
    assert ex._backoff(10) == 30.0          # capped
    assert _scenario(backoff_base=0.0)._backoff(5) == 0.0


def test_retry_respects_backoff_delay():
    # every first attempt fails at a known fraction; the retry must not
    # start before failure time + backoff_base
    class OneShotFaults:
        def node_events(self):
            return []

        def attempt_outcome(self, tid, node, attempt):
            return 0.5 if attempt == 0 else None

    tr = _scenario(faults=OneShotFaults(), max_attempts=3,
                   backoff_base=5.0, backoff_cap=5.0, n_samples=2).run()
    assert tr.completed == tr.total
    assert tr.retries == tr.total          # each task lost its 1st attempt
    by_id = {c.id: c for c in tr.censored}
    for r in tr.records:
        assert r.start >= by_id[r.id].lost_at + 5.0 - 1e-9


def test_attempt_budget_exhaustion_strict_raises():
    fi = FaultInjector(p_fail=1.0, p_spread=0.0, seed=0)
    with pytest.raises(RuntimeError, match="attempt budget"):
        _scenario(faults=fi, max_attempts=3).run()


def test_attempt_budget_exhaustion_nonstrict_strands():
    fi = FaultInjector(p_fail=1.0, p_spread=0.0, seed=0)
    tr = _scenario(faults=fi, max_attempts=2, strict=False).run()
    assert tr.completed == 0
    assert tr.stranded == tr.total
    assert tr.completed_fraction() == 0.0
    assert tr.records == []                # no phantom completions
    assert tr.makespan == 0.0


# ---------------------------------------------------------------------------
# Node churn: crashes, outages, static-plan contrast
# ---------------------------------------------------------------------------
def test_crash_recovery_completes_while_static_strands():
    base = _scenario().run()
    crash = {"tpu-v2/0": 0.25 * base.makespan,
             "tpu-v3/1": 0.5 * base.makespan}

    def faults():
        return FaultInjector(crash_at=crash, p_fail=0.05, seed=5)

    ft = _scenario(faults=faults(), rel_k=1.0, max_attempts=8).run()
    assert ft.completed == ft.total and ft.stranded == 0
    assert ft.lost_nodes == 2
    assert any(c.reason == "node" for c in ft.censored)
    assert ft.makespan >= base.makespan    # recovery is not free
    # nothing is (re-)placed on a node after it died
    for r in ft.records:
        if r.node in crash:
            assert r.start < crash[r.node] + 1e-9
    static = _scenario(online=False, faults=faults(), strict=False,
                       max_attempts=8).run()
    assert static.stranded > 0
    assert static.completed_fraction() < 1.0
    assert len(static.records) == static.completed


def test_outage_node_rejoins_and_is_reused():
    base = _scenario().run()
    down, up = 0.15 * base.makespan, 0.35 * base.makespan
    fi = FaultInjector(outages={"tpu-v3/0": (down, up)}, seed=1)
    tr = _scenario(faults=fi).run()
    assert tr.completed == tr.total
    assert tr.lost_nodes == 1
    on_node = [r for r in tr.records if r.node == "tpu-v3/0"]
    assert any(r.start >= up - 1e-9 for r in on_node)   # reused after up
    for r in on_node:                      # never placed while down
        assert not (down - 1e-9 < r.start < up - 1e-9)


def test_fault_scenarios_replay_bit_identically():
    def run_once():
        base_ms = 800.0
        fi = FaultInjector(crash_at={"tpu-v2/1": 0.3 * base_ms},
                           p_fail=0.2, seed=7)
        return _scenario(faults=fi, rel_k=1.0, max_attempts=8).run()

    a, b = run_once(), run_once()
    assert a.makespan == b.makespan
    assert [(r.id, r.node, r.start, r.end) for r in a.records] == \
        [(r.id, r.node, r.start, r.end) for r in b.records]
    assert [(c.id, c.node, c.lost_at, c.reason) for c in a.censored] == \
        [(c.id, c.node, c.lost_at, c.reason) for c in b.censored]
    assert (a.failures, a.retries, a.lost_nodes) == \
        (b.failures, b.retries, b.lost_nodes)


# ---------------------------------------------------------------------------
# Speculative-race bookkeeping under churn (satellite)
# ---------------------------------------------------------------------------
def _churny_spec(faults):
    return _scenario(online=True, faults=faults, max_attempts=8,
                     n_samples=8, bias=1.0, slow=1.8, noise_seed=17,
                     speculate=True, spec_k=0.5, bias_drift=1.1)


def test_speculative_race_bookkeeping_under_churn():
    clean = _churny_spec(None).run()
    assert clean.speculations > 0          # the scenario does speculate
    fi = FaultInjector(crash_at={"tpu-v3/1": 0.3 * clean.makespan},
                       p_fail=0.1, seed=2)
    tr = _churny_spec(fi).run()
    assert tr.completed == tr.total
    assert tr.spec_wins <= tr.speculations
    ids = [r.id for r in tr.records]
    assert len(ids) == len(set(ids)) == tr.total   # no twin double-counts
    # a record never starts on the crashed node after its death
    for r in tr.records:
        if r.node == "tpu-v3/1":
            assert r.start < 0.3 * clean.makespan + 1e-9


def test_lost_spec_race_does_not_hit_reliability():
    # scheduler-ordered kills are not node failures: with no faults but
    # rel_k tracking on, a speculative race must leave only successes
    ex = _churny_spec(None)
    ex.rel_k = 1.0
    ex._track_rel = hasattr(ex.est, "record_attempt")
    tr = ex.run()
    assert tr.speculations > 0
    rel = ex.est.reliability
    assert rel is not None
    for node in rel.state:
        assert rel.counts(node)[1] == 0.0   # zero recorded failures


# ---------------------------------------------------------------------------
# Reliability posterior and pricing
# ---------------------------------------------------------------------------
def test_reliability_model_posterior_and_factor():
    rm = ReliabilityModel()
    p0, f0 = rm.p_mean("n"), rm.factor("n")
    assert f0 >= 1.0
    for _ in range(10):
        rm.record("bad", False)
        rm.record("good", True)
    assert rm.p_mean("bad") < p0 < rm.p_mean("good")
    assert rm.factor("bad") > rm.factor("good")
    fs = rm.factors(["good", "bad"])
    assert fs[1] > fs[0]
    # more uncertainty aversion prices the same node higher
    assert rm.factor("bad", k=2.0) >= rm.factor("bad", k=0.0)
    # floor: overwhelming failure evidence stays finite
    for _ in range(500):
        rm.record("bad", False)
    assert rm.factor("bad") <= 1.0 / ReliabilityModel.P_FLOOR + 1e-9
    rt = ReliabilityModel.from_dict(rm.to_dict())
    assert rt.counts("bad") == rm.counts("bad")
    assert rt.p_mean("good") == rm.p_mean("good")
    with pytest.raises(ValueError):
        ReliabilityModel(a0=0.0)


def test_reliability_pricing_steers_placement_away():
    ex = _scenario(rel_k=1.0)
    for _ in range(30):                     # one poisoned twin instance
        ex.est.record_attempt("tpu-v2/0", False)
    tr = ex.run()
    assert tr.completed == tr.total
    loads = {}
    for r in tr.records:
        loads[r.node] = loads.get(r.node, 0) + 1
    assert loads.get("tpu-v2/0", 0) < loads.get("tpu-v2/1", 0)


def test_flaky_node_learned_and_avoided_end_to_end():
    # one instance fails most attempts; with reliability pricing the
    # executor learns to stop placing work there within one run
    class FlakyNode:
        def node_events(self):
            return []

        def attempt_outcome(self, tid, node, attempt):
            if node == "tpu-v2/0" and attempt < 3:
                return 0.5
            return None

    tr = _scenario(faults=FlakyNode(), rel_k=1.0, max_attempts=10,
                   n_samples=8).run()
    assert tr.completed == tr.total
    assert tr.failures > 0
    late = [r for r in tr.records if r.node == "tpu-v2/0"]
    early_failures = [c for c in tr.censored if c.node == "tpu-v2/0"]
    assert early_failures                  # it was tried, and it failed
    # after the posterior absorbs the failures, the healthy twin carries
    # more of the load than the flaky instance
    loads = {}
    for r in tr.records:
        loads[r.node] = loads.get(r.node, 0) + 1
    assert loads.get("tpu-v2/0", 0) <= loads.get("tpu-v2/1", 0)
    assert late is not None                # (placements may still finish)


# ---------------------------------------------------------------------------
# Stall diagnostics (satellite: named blockers)
# ---------------------------------------------------------------------------
def test_stall_error_names_blocked_tasks_and_predecessors():
    est, chain = _make_est()
    tasks, task_name = fanout_chain_dag(chain, 2)
    tasks["s0.t1"].pred.append("ghost")     # predecessor outside the DAG
    grid = GridEngine.from_types(nodes_per_type=1,
                                 types=[get_node("tpu-v2"),
                                        get_node("tpu-v3")])
    ex = OnlineExecutor(est, tasks, task_name, 32.0, grid,
                        lambda tid, node: 10.0, online=True)
    with pytest.raises(RuntimeError,
                       match=r"(?s)stalled with 2 tasks.*s0\.t1.*ghost"):
        ex.run()


def test_stall_nonstrict_strands_instead_of_raising():
    est, chain = _make_est()
    tasks, task_name = fanout_chain_dag(chain, 2)
    tasks["s0.t1"].pred.append("ghost")
    grid = GridEngine.from_types(nodes_per_type=1,
                                 types=[get_node("tpu-v2"),
                                        get_node("tpu-v3")])
    ex = OnlineExecutor(est, tasks, task_name, 32.0, grid,
                        lambda tid, node: 10.0, online=True, strict=False)
    tr = ex.run()
    assert tr.stranded == 2                 # s0.t1 and its dependent
    assert tr.completed == tr.total - 2
    assert len(tr.records) == tr.completed


# ---------------------------------------------------------------------------
# Persistence: reliability (v5) round trip, older files still load
# ---------------------------------------------------------------------------
def test_schema_roundtrips_reliability(tmp_path):
    est, _ = _make_est()
    est.record_attempt("tpu-v2/0", False)
    est.record_attempt("tpu-v2/0", True)
    est.record_attempt("tpu-v3/0", True)
    p = tmp_path / "est.json"
    est.save(p)
    d = json.loads(p.read_text())
    assert d["version"] == SCHEMA_VERSION == 6
    assert d["reliability"]["state"]["tpu-v2/0"] == [1.0, 1.0]
    loaded = LotaruEstimator.load(p)
    assert loaded.reliability is not None
    assert loaded.reliability.counts("tpu-v2/0") == (1.0, 1.0)
    assert loaded.reliability_factor("tpu-v2/0") == \
        est.reliability_factor("tpu-v2/0")
    nodes = list(est.target_benches)
    M0, _ = est.predict_matrix(nodes, 40.0)
    M1, _ = loaded.predict_matrix(nodes, 40.0)
    np.testing.assert_allclose(M1, M0, rtol=5e-4, atol=1e-6)


def test_v4_file_without_reliability_loads(tmp_path):
    est, _ = _make_est()
    p = tmp_path / "v4.json"
    est.save(p)
    d = json.loads(p.read_text())
    d["version"] = 4
    del d["reliability"]
    p.write_text(json.dumps(d))
    loaded = LotaruEstimator.load(p)
    assert loaded.reliability is None
    assert loaded.reliability_factor("anything") == 1.0
    np.testing.assert_allclose(
        loaded.reliability_factors(["a", "b"]), np.ones(2))


# ---------------------------------------------------------------------------
# Elastic membership x data-aware placement: a dead node must never be a
# cheap data source, and a rejoining node re-enters real comm pricing
# ---------------------------------------------------------------------------
def _two_rack_grid():
    from repro.sched.simulator import Topology
    nodes = [SimNode(name=n, node_type=get_node("tpu-v2"))
             for n in ("a0", "a1", "b0", "b1")]
    topo = Topology({"a0": "r0", "a1": "r0", "b0": "r1", "b1": "r1"},
                    intra_gbps=10.0, cross_gbps=0.1)
    return GridEngine(nodes, topology=topo), topo


def test_dead_node_masks_transfer_term():
    grid, topo = _two_rack_grid()
    names = grid.names()
    live = grid.secs_per_gb()
    worst = live[np.isfinite(live)].max()
    # same-rack pair is cheap while both ends are alive
    assert live[0, 1] == pytest.approx(1.0 / 10.0)
    grid.fail("a0", at=5.0)
    masked = grid.secs_per_gb()
    # data stranded on the dead a0 now costs the WORST finite rate to
    # every other node — the planner can no longer treat it as local ...
    assert (masked[0, 1:] == worst).all()
    # ... while the diagonal stays zero (CommCosts rejects anything else)
    assert masked[0, 0] == 0.0
    # pricing between live nodes is untouched
    assert (masked[1:, 1:] == live[1:, 1:]).all()


def test_rejoined_node_reenters_comm_pricing():
    grid, topo = _two_rack_grid()
    before = grid.secs_per_gb().copy()
    grid.fail("b0", at=1.0)
    assert not (grid.secs_per_gb() == before).all()
    grid.join("b0", at=2.0)
    # secs_per_gb is recomputed from live membership on every call, so
    # the revived node's original zone pricing is restored exactly
    np.testing.assert_array_equal(grid.secs_per_gb(), before)


def test_replan_avoids_dead_data_source():
    """End-to-end: with the producer's node dead, a comm-aware re-plan
    must price its output at the worst rate rather than clustering
    successors 'near' the corpse."""
    from repro.sched.heft import CommCosts
    grid, topo = _two_rack_grid()
    names = grid.names()
    succ, pred = [[1], []], [[], [0]]
    eg = {(0, 1): 50.0}  # 50 GB: placement is all about this edge
    cost = np.array([[10.0, 10.0, 10.0, 10.0]] * 2)
    comm = CommCosts(pred, eg, grid.secs_per_gb())
    s = heft_schedule_array(succ, pred, cost, comm=comm)
    # alive: consumer co-locates with the producer (transfer is free)
    assert s["assignment"][1] == s["assignment"][0]
    src = names[s["assignment"][0]]
    grid.fail(src, at=0.0)
    masked = CommCosts(pred, eg, grid.secs_per_gb())
    floors = masked.ready_floor(1, np.array([10.0, 0.0]),
                                np.array(s["assignment"]))
    live_js = [j for j, n in enumerate(names) if n != src]
    # the stranded output costs the same (worst) rate toward every live
    # node: proximity to the dead source buys nothing anymore
    assert len({round(float(floors[j]), 9) for j in live_js}) == 1
    assert float(floors[live_js[0]]) > 10.0
