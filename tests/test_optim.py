"""Optimizer: convergence, state dtypes, master weights, compression."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models.common import ParamDef, tree_defs_init
from repro.optim import (AdamWConfig, apply_updates, compress_grads,
                         decompress_grads, global_norm, lr_at, state_defs)


def _setup(state_dtype="fp32", master=False):
    defs = {"w": ParamDef((8, 16), (None, None)),
            "b": ParamDef((16,), (None,), init="zeros")}
    cfg = AdamWConfig(lr=0.05, weight_decay=0.0, clip_norm=0.0,
                      warmup_steps=0, schedule="constant",
                      state_dtype=state_dtype, master_fp32=master)
    params = tree_defs_init(defs, jax.random.PRNGKey(0))
    state = tree_defs_init(state_defs(defs, cfg), jax.random.PRNGKey(1))
    if master:
        state["mv"] = jax.tree.map(
            lambda x: x, state["mv"],
            is_leaf=lambda x: isinstance(x, dict) and "m" in x)
        # master starts at the param values
        state["mv"]["w"]["master"] = params["w"].astype(jnp.float32)
        state["mv"]["b"]["master"] = params["b"].astype(jnp.float32)
    return defs, cfg, params, state


@pytest.mark.parametrize("state_dtype", ["fp32", "bf16", "int8"])
def test_adamw_minimises_quadratic(state_dtype):
    defs, cfg, params, state = _setup(state_dtype)
    target = {"w": jnp.ones((8, 16)), "b": jnp.full((16,), 0.5)}

    def loss_fn(p):
        return (jnp.mean((p["w"] - target["w"]) ** 2)
                + jnp.mean((p["b"] - target["b"]) ** 2))

    step = jax.jit(lambda p, s: apply_updates(
        p, jax.grad(loss_fn)(p), s, cfg))
    l0 = float(loss_fn(params))
    for _ in range(150):
        params, state, _ = step(params, state)
    l1 = float(loss_fn(params))
    assert l1 < l0 * 0.05, (state_dtype, l0, l1)


def test_master_fp32_tracks_params():
    defs, cfg, params, state = _setup("bf16", master=True)
    params = jax.tree.map(lambda x: x.astype(jnp.bfloat16), params)
    g = jax.tree.map(lambda x: jnp.ones_like(x, jnp.bfloat16) * 0.1, params)
    p2, s2, _ = apply_updates(params, g, state, cfg)
    # params follow the fp32 master (cast down)
    np.testing.assert_allclose(
        np.asarray(p2["w"], np.float32),
        np.asarray(s2["mv"]["w"]["master"], np.float32), atol=1e-2)
    assert p2["w"].dtype == jnp.bfloat16
    assert s2["mv"]["w"]["master"].dtype == jnp.float32


def test_grad_clip_and_lr_schedule():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      schedule="cosine")
    assert float(lr_at(cfg, 0)) < 0.2
    assert float(lr_at(cfg, 10)) == pytest.approx(1.0, abs=0.05)
    assert float(lr_at(cfg, 100)) < 0.05
    t = {"x": jnp.full((4,), 3.0), "y": jnp.full((4,), 4.0)}
    assert float(global_norm(t)) == pytest.approx(10.0)


def test_compression_roundtrip_and_error_feedback():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(0, 1, (64, 32)), jnp.float32)}
    q, ef = compress_grads(g)
    deq = decompress_grads(q, g)
    rel = float(jnp.linalg.norm(deq["w"] - g["w"]) / jnp.linalg.norm(g["w"]))
    assert rel < 0.02                      # int8 blockwise: <2% rel error
    # error feedback: repeated compression of the same grad converges
    acc = jnp.zeros_like(g["w"])
    ef = None
    for _ in range(20):
        q, ef = compress_grads(g, ef)
        acc = acc + decompress_grads(q, g)["w"] / 20.0
    drift = float(jnp.linalg.norm(acc - g["w"]) / jnp.linalg.norm(g["w"]))
    assert drift < 0.01
