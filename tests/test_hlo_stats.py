"""Trip-count-aware HLO accounting on synthetic and real modules."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.hlo_stats import analyze_hlo, _shape_bytes

SYNTH = """
HloModule test

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8] get-tuple-element(%p), index=1
  %d = f32[8,8] dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8] all-reduce(%d), replica_groups=[2,4]<=[8], to_apply=%add
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]) tuple(%ni, %ar)
}

%cond (p2: (s32[], f32[8,8])) -> pred[] {
  %p2 = (s32[], f32[8,8]) parameter(0)
  %i2 = s32[] get-tuple-element(%p2), index=0
  %lim = s32[] constant(5)
  ROOT %lt = pred[] compare(%i2, %lim), direction=LT
}

ENTRY %main_spmd (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8] parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[8,8]) tuple(%zero, %a)
  %w = (s32[], f32[8,8]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %out = f32[8,8] get-tuple-element(%w), index=1
}
"""


def test_shape_bytes():
    assert _shape_bytes("f32[8,8]") == 256
    assert _shape_bytes("bf16[4,2]{1,0}") == 16
    assert _shape_bytes("(f32[2], s32[4])") == 24


def test_while_trip_count_multiplies_costs():
    s = analyze_hlo(SYNTH)
    # dot flops: 2*8*8*8 = 1024 per iteration x 5 trips
    assert s.flops == 1024 * 5
    # all-reduce: 256B payload, ring 2x(g-1)/g with g=4 -> 384B x 5
    assert s.collective_counts["all-reduce"] == 5
    assert s.collective_bytes == int(2 * 256 * 3 / 4) * 5
    assert s.n_while_loops == 1


def test_real_compiled_module_flops_close_to_analytic():
    """Compile a scanned matmul stack and compare accounted flops."""
    L, n = 6, 32
    w = jnp.stack([jnp.eye(n) for _ in range(L)])

    def f(x, w):
        def body(h, wi):
            return jnp.dot(h, wi, preferred_element_type=jnp.float32), None
        h, _ = jax.lax.scan(body, x, w)
        return h

    compiled = jax.jit(f).lower(jnp.ones((n, n)), w).compile()
    s = analyze_hlo(compiled.as_text())
    expect = 2 * n ** 3 * L
    assert abs(s.flops - expect) / expect < 0.05, (s.flops, expect)
