"""Distribution plumbing: steps lower+compile on a real (small) SPMD mesh.

The production 512-device dry-run runs via ``repro.launch.dryrun`` (its own
process sets XLA_FLAGS before jax init).  Here we exercise the identical
code path on a subprocess-local 8-device mesh so the test env keeps its
single default device.
"""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    from repro.configs import smoke_config
    from repro.launch.mesh import make_mesh, make_rules
    from repro.launch.shapes import ShapeSpec, input_specs
    from repro.launch.steps import make_train_step, make_decode_step
    from repro.models import build_model
    from repro.models.common import tree_defs_to_abstract
    from repro.optim import AdamWConfig, state_defs
    from repro.analysis.hlo_stats import analyze_hlo
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    rules = make_rules(mesh)
    out = {}
    for arch in ["qwen2-7b", "mamba2-1.3b"]:
        cfg = smoke_config(arch).with_(moe_groups=4)
        model = build_model(cfg)
        opt = AdamWConfig()
        with mesh:
            pa = model.abstract_params(mesh, rules)
            oa = tree_defs_to_abstract(state_defs(model.param_defs, opt), mesh, rules)
            batch = input_specs(cfg, ShapeSpec("t", "train", 64, 8), mesh, rules)
            step = make_train_step(model, rules, opt)
            c = jax.jit(step, donate_argnums=(0, 1)).lower(pa, oa, batch).compile()
            stats = analyze_hlo(c.as_text(), default_group=8)
            mem = c.memory_analysis()
            out[arch] = {
                "flops": stats.flops,
                "coll": stats.collective_bytes,
                "whiles": stats.n_while_loops,
                "temp": mem.temp_size_in_bytes,
            }
            # decode path must also compile on the mesh
            caches = model.abstract_caches(mesh, rules, 8, max_len=64)
            dbatch = input_specs(cfg, ShapeSpec("d", "decode", 64, 8), mesh, rules)
            idx = jax.ShapeDtypeStruct((), jnp.int32,
                                       sharding=NamedSharding(mesh, P()))
            dstep = make_decode_step(model, rules)
            jax.jit(dstep, donate_argnums=(2,)).lower(pa, dbatch, caches, idx).compile()
    print(json.dumps(out))
""")


@pytest.mark.slow
def test_small_mesh_spmd_compile():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    for arch, rec in out.items():
        assert rec["flops"] > 0, arch
        assert rec["coll"] > 0, arch          # SPMD inserted collectives
        assert rec["whiles"] >= 1, arch       # scan-over-layers survived
        assert rec["temp"] < 4e9, arch


def test_cell_applicability_rules():
    from repro.configs import get_config
    from repro.launch.shapes import SHAPES, cell_applicable
    ok, _ = cell_applicable(get_config("qwen2-7b"), SHAPES["long_500k"])
    assert not ok
    ok, _ = cell_applicable(get_config("mamba2-1.3b"), SHAPES["long_500k"])
    assert ok
    ok, _ = cell_applicable(get_config("zamba2-1.2b"), SHAPES["long_500k"])
    assert ok
    for arch in ("qwen2-7b", "zamba2-1.2b"):
        for shape in ("train_4k", "prefill_32k", "decode_32k"):
            ok, _ = cell_applicable(get_config(arch), SHAPES[shape])
            assert ok


def test_dryrun_artifacts_complete_if_present():
    art = ROOT / "experiments" / "artifacts" / "dryrun"
    files = list(art.glob("*.json"))
    if not files:
        pytest.skip("dry-run artifacts not generated yet")
    recs = [json.loads(f.read_text()) for f in files]
    assert len(recs) == 80                      # 10 archs x 4 shapes x 2 meshes
    by_status = {}
    for r in recs:
        by_status.setdefault(r["status"], []).append(r)
    assert not by_status.get("error"), [r["arch"] for r in by_status["error"]]
    assert len(by_status.get("skip", [])) == 16  # 8 full-attn archs x long_500k x 2
    for r in by_status["ok"]:
        assert r["roofline"]["flops_per_device"] > 0, r["arch"]
        assert r["memory"]["hbm_estimate_bytes"] > 0
