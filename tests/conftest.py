"""Suite-wide fixtures.

Every test module that drives the online executor compiles its own
spread of XLA executables (one scan per distinct tick batch size, one
HEFT solve per frontier shape).  Left to accumulate across the whole
suite they exhaust the kernel's ``vm.max_map_count`` long before they
exhaust memory — the process dies with a segfault inside
``backend_compile``, not a Python error.  Clearing the jit cache
between modules bounds the growth (same mitigation as
``benchmarks/bench_online.py`` uses between arms) at the cost of a
recompile per module.
"""
import pytest


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_per_module():
    yield
    import jax
    jax.clear_caches()
