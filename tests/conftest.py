"""Suite-wide fixtures.

Every test module that drives the online executor compiles its own
spread of XLA executables (one scan per distinct tick batch size, one
HEFT solve per frontier shape).  Left to accumulate across the whole
suite they exhaust the kernel's ``vm.max_map_count`` long before they
exhaust memory — the process dies with a segfault inside
``backend_compile``, not a Python error.  Clearing the jit cache
between modules bounds the growth (same mitigation as
``benchmarks/bench_online.py`` uses between arms) at the cost of a
recompile per module.
"""
import os

import pytest

#: REPRO_SANITIZE=1 turns on jax's runtime sanitizers for the whole
#: session (must happen before any trace is built): ``jax_debug_nans``
#: re-runs any primitive that produced a NaN un-jitted and raises with
#: the offending op, ``jax_enable_checks`` enables jax's internal
#: invariant assertions.  CI runs a fast numeric test subset under this
#: mode (see .github/workflows/ci.yml `lint` job) — the dynamic
#: complement to the static RA00x passes in repro.analysis.lint.
if os.environ.get("REPRO_SANITIZE") == "1":
    import jax
    jax.config.update("jax_debug_nans", True)
    jax.config.update("jax_enable_checks", True)


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_per_module():
    yield
    import jax
    jax.clear_caches()
