"""Per-(task, node) residual bias layer: conjugate posterior behaviour,
MPE reduction under injected multiplicative skew, dirty-row cache
correctness with bias folding, observe_batch/sequential equivalence,
schema-v3 persistence, and the bias-coupled straggler speculation."""
import json

import numpy as np
import pytest

from repro.core import SCHEMA_VERSION, BiasModel, LotaruEstimator
from repro.core.profiler import BenchResult
from repro.online import OnlineExecutor, fanout_chain_dag
from repro.sched.simulator import GridEngine
from repro.core.nodes import get_node


def _bench(name, cpu, io):
    return BenchResult(node=name, cpu_events_s=cpu, matmul_gflops=100.0,
                       mem_gbps=20.0, io_read_mbps=io, io_write_mbps=io,
                       link_gbps=0.0)


def _fitted(seed=0, n_tasks=5, bias_correction=True):
    rng = np.random.default_rng(seed)
    local = _bench("local-cpu", 450.0, 420.0)
    benches = {f"n{j}": _bench(f"n{j}", float(rng.uniform(150, 900)),
                               float(rng.uniform(100, 900)))
               for j in range(3)}
    est = LotaruEstimator(local, benches, bias_correction=bias_correction)
    slopes = {f"t{i}": (i + 1) * 2.0 for i in range(n_tasks)}
    est.fit_tasks(list(slopes), 64.0,
                  lambda n, s, cf: slopes[n] * s / cf + 5.0,
                  n_partitions=8)
    return est


# ---------------------------------------------------------------------------
# BiasModel unit behaviour
# ---------------------------------------------------------------------------
def test_bias_shrinks_toward_one_and_tightens():
    bm = BiasModel(2, 2)
    assert bm.point(0, 0) == 1.0                      # inert before evidence
    true_log = np.log(1.5)
    last = None
    for k in range(1, 30):
        bm.update([0], [0], [true_log])
        b = bm.point(0, 0)
        assert 1.0 < b < 1.5 + 1e-9                   # shrunk toward 1.0
        if last is not None:
            assert b >= last - 1e-12                  # monotone approach
        last = b
    _, v = bm.posterior()
    assert v[0, 0] < bm.tau0 ** 2                     # tighter than prior
    assert bm.point(0, 0) == pytest.approx(1.5, rel=0.05)
    assert bm.point(1, 1) == 1.0                      # other pairs untouched


def test_bias_fold_scalar_matches_matrix():
    bm = BiasModel(3, 2)
    bm.update([1, 1, 2], [0, 0, 1], np.log([1.4, 1.6, 0.7]))
    mean = np.arange(1.0, 7.0).reshape(3, 2)
    std = 0.1 * mean
    folded_mean = mean * bm.matrix()
    folded_std = bm.widen_std(mean, std)
    for i in range(3):
        for j in range(2):
            m, s = bm.fold_scalar(i, j, mean[i, j], std[i, j])
            assert m == pytest.approx(folded_mean[i, j], rel=1e-12)
            assert s == pytest.approx(folded_std[i, j], rel=1e-12)
    # unobserved pairs pass through bitwise
    assert folded_mean[0, 0] == mean[0, 0]
    assert folded_std[0, 0] == std[0, 0]


def test_bias_interval_scale_widens_with_uncertainty():
    bm = BiasModel(1, 1)
    bm.update([0], [0], [np.log(1.3)])
    lo1, hi1 = bm.interval_scale(0, 0, z=1.645)
    assert lo1 < bm.point(0, 0) < hi1
    for _ in range(50):
        bm.update([0], [0], [np.log(1.3)])
    lo2, hi2 = bm.interval_scale(0, 0, z=1.645)
    assert (hi2 - lo2) < (hi1 - lo1)                  # evidence narrows it


def test_bias_residual_spread_recovers_noise_sd():
    rng = np.random.default_rng(0)
    bm = BiasModel(4, 3)
    assert np.isnan(bm.residual_spread())          # no pair has 2 obs yet
    true_sd = 0.2
    for _ in range(400):
        i, j = int(rng.integers(4)), int(rng.integers(3))
        pair_mean = 0.3 * (i - j)                  # arbitrary per-pair bias
        bm.update([i], [j], [pair_mean + rng.normal(0, true_sd)])
    assert bm.residual_spread() == pytest.approx(true_sd, rel=0.15)


# ---------------------------------------------------------------------------
# Estimator integration
# ---------------------------------------------------------------------------
def _skew(n_tasks, n_nodes, seed=42, scale=0.35):
    rng = np.random.default_rng(seed)
    return np.exp(rng.normal(0.0, scale, (n_tasks, n_nodes)))


def test_bias_correction_reduces_mpe_under_injected_skew():
    """Ground truth carries a fixed per-(task, node) multiplicative skew
    the factor adjustment cannot represent: the bias-corrected estimator
    drives per-pair error toward zero, the bias-free one cannot."""
    truth = _fitted(seed=9)                       # frozen initial beliefs
    skew = _skew(len(truth.task_names()), 3)
    nodes = list(truth.target_benches)

    def run_stream(est):
        for size in (24.0, 36.0, 48.0, 56.0):
            batch = []
            for i, tn in enumerate(truth.task_names()):
                for j, nd in enumerate(nodes):
                    m, _ = truth.predict(tn, nd, size)
                    batch.append((tn, nd, size, m * skew[i, j]))
            est.observe_batch(batch)

    est_bias = _fitted(seed=9, bias_correction=True)
    est_plain = _fitted(seed=9, bias_correction=False)
    run_stream(est_bias)
    run_stream(est_plain)

    size_q = 40.0
    M_truth, _ = truth.predict_matrix(nodes, size_q)
    target = M_truth * skew
    M_b, _ = est_bias.predict_matrix(nodes, size_q)
    M_p, _ = est_plain.predict_matrix(nodes, size_q)
    err_b = np.median(np.abs(M_b - target) / target)
    err_p = np.median(np.abs(M_p - target) / target)
    assert err_b < err_p
    assert err_b < 0.05


def test_dirty_row_cache_correct_with_bias_updates():
    est = _fitted(seed=1)
    nodes = list(est.target_benches)
    M1, S1 = est.predict_matrix(nodes, 32.0)
    i = est.task_names().index("t2")
    est.observe("t2", nodes[1], 32.0, 500.0)
    M2, S2 = est.predict_matrix(nodes, 32.0)          # row-patched + folded
    others = [k for k in range(len(est.task_names())) if k != i]
    assert np.array_equal(M2[others], M1[others])     # bitwise clean rows
    assert np.array_equal(S2[others], S1[others])
    assert not np.allclose(M2[i], M1[i])
    est._mat_cache = None
    M3, S3 = est.predict_matrix(nodes, 32.0)          # from-scratch oracle
    np.testing.assert_allclose(M2, M3, rtol=1e-6)
    np.testing.assert_allclose(S2, S3, rtol=1e-6)
    # scalar oracle agrees with the bias-folded matrix cell
    m, s = est.predict("t2", nodes[1], 32.0)
    assert M2[i, 1] == pytest.approx(m, rel=1e-6)
    assert S2[i, 1] == pytest.approx(s, rel=1e-6)
    # std of the observed pair is WIDENED by the bias posterior
    assert S2[i, 1] > 0


def test_observe_batch_matches_sequential_observes():
    """One tick over distinct tasks is exactly N sequential observes:
    same de-adjusted runtimes, same bias state, same predictions."""
    obs = [("t0", "n0", 30.0, 140.0), ("t1", "n1", 28.0, 260.0),
           ("t2", "n2", 35.0, 410.0), ("t3", "n0", 31.0, 515.0)]
    est_seq = _fitted(seed=5)
    est_bat = _fitted(seed=5)
    seq_rts = [est_seq.observe(*o) for o in obs]
    bat_rts = est_bat.observe_batch(obs)
    np.testing.assert_allclose(bat_rts, seq_rts, rtol=1e-12)
    np.testing.assert_allclose(est_bat.bias.counts, est_seq.bias.counts,
                               rtol=0)
    np.testing.assert_allclose(est_bat.bias.log_sum, est_seq.bias.log_sum,
                               rtol=1e-12)
    nodes = list(est_seq.target_benches)
    Ms, Ss = est_seq.predict_matrix(nodes, 33.0)
    Mb, Sb = est_bat.predict_matrix(nodes, 33.0)
    np.testing.assert_allclose(Mb, Ms, rtol=1e-12)
    np.testing.assert_allclose(Sb, Ss, rtol=1e-12)


def test_interval_widened_by_bias_uncertainty():
    est = _fitted(seed=3)
    node = list(est.target_benches)[0]
    lo0, hi0 = est.predict_interval_node("t1", node, 32.0, confidence=0.9)
    m0, _ = est.predict("t1", node, 32.0)
    est.observe("t1", node, 32.0, m0 * 1.6)           # high residual
    lo1, hi1 = est.predict_interval_node("t1", node, 32.0, confidence=0.9)
    b = est.bias_point("t1", node)
    assert b > 1.0
    # interval shifted up with the bias AND wider than a pure shift
    assert hi1 > hi0 * b - 1e-9
    assert (hi1 - lo1) > (hi0 - lo0) * b * 0.999


# ---------------------------------------------------------------------------
# Persistence (schema v3)
# ---------------------------------------------------------------------------
def test_save_load_roundtrips_bias_state(tmp_path):
    est = _fitted(seed=7)
    nodes = list(est.target_benches)
    m, _ = est.predict("t0", nodes[0], 30.0)
    est.observe_batch([("t0", nodes[0], 30.0, m * 1.3),
                       ("t1", nodes[1], 25.0, 180.0)])
    p = tmp_path / "est.json"
    est.save(p)
    d = json.loads(p.read_text())
    assert d["version"] == SCHEMA_VERSION
    assert d["bias"] is not None
    loaded = LotaruEstimator.load(p)
    assert np.array_equal(loaded.bias.counts, est.bias.counts)
    assert np.array_equal(loaded.bias.log_sum, est.bias.log_sum)
    assert loaded.bias_nodes == est.bias_nodes
    M0, S0 = est.predict_matrix(nodes, 40.0)
    M1, S1 = loaded.predict_matrix(nodes, 40.0)
    np.testing.assert_allclose(M1, M0, rtol=5e-4, atol=1e-6)
    np.testing.assert_allclose(S1, S0, rtol=5e-4, atol=1e-6)
    assert loaded.bias_point("t0", nodes[0]) == est.bias_point("t0", nodes[0])


def test_v2_file_without_bias_still_loads(tmp_path):
    est = _fitted(seed=8)
    p = tmp_path / "v2.json"
    est.save(p)
    d = json.loads(p.read_text())
    d["version"] = 2
    del d["bias"]
    del d["bias_correction"]
    p.write_text(json.dumps(d))
    loaded = LotaruEstimator.load(p)
    assert loaded.bias is None                        # fresh (inert) layer
    node = list(loaded.target_benches)[0]
    assert loaded.bias_point("t0", node) == 1.0
    m0, _ = est.predict("t0", node, 40.0)
    m1, _ = loaded.predict("t0", node, 40.0)
    assert m1 == pytest.approx(m0, rel=5e-4)


# ---------------------------------------------------------------------------
# Straggler coupling (speculative copies in the executor)
# ---------------------------------------------------------------------------
def _spec_scenario(online=True, speculate=True):
    """One node type is secretly 3x slower: completions there drive the
    (task, node) bias high, and still-running instances on that type
    blow their dispatch-time envelope -> speculative copies."""
    rng = np.random.default_rng(17)
    local = _bench("local-cpu", 450.0, 420.0)
    benches = {"tpu-v2": _bench("tpu-v2", 600.0, 500.0),
               "tpu-v3": _bench("tpu-v3", 650.0, 550.0)}
    est = LotaruEstimator(local, benches)
    slopes = {f"t{i}": (i + 1) * 2.0 for i in range(3)}
    est.fit_tasks(list(slopes), 64.0,
                  lambda n, s, cf: slopes[n] * s / cf + 5.0,
                  n_partitions=8)
    truth = LotaruEstimator(local, benches)
    truth.fit_tasks(list(slopes), 64.0,
                    lambda n, s, cf: slopes[n] * s / cf + 5.0,
                    n_partitions=8)
    tasks, task_name = fanout_chain_dag(list(slopes), 8)
    grid = GridEngine.from_types(nodes_per_type=2,
                                 types=[get_node("tpu-v2"),
                                        get_node("tpu-v3")])
    size = 32.0

    def runtime_fn(tid, node):
        nt = grid.type_of(node).name
        m, _ = truth.predict(task_name[tid], nt, size)
        slow = 3.0 if nt == "tpu-v2" else 1.0
        return m * slow * float(rng.uniform(0.98, 1.02))

    return OnlineExecutor(est, tasks, task_name, size, grid, runtime_fn,
                          online=online, confidence=0.2,
                          speculate=speculate, spec_k=2.0, bias_drift=1.1)


def test_bias_drift_triggers_speculative_copies():
    trace = _spec_scenario().run()
    assert len(trace.records) == 24                   # one record per task
    assert trace.speculations > 0
    assert trace.spec_wins <= trace.speculations
    # every record reflects the attempt that actually finished
    by_id = {r.id: r for r in trace.records}
    for tid, rec in by_id.items():
        sample, name = tid.split(".", 1)
        k = int(name[1:])
        if k > 0:
            assert rec.start >= by_id[f"{sample}.t{k-1}"].end - 1e-9
    assert trace.makespan == pytest.approx(max(r.end for r in by_id.values()))


def test_speculation_off_keeps_pr2_loop():
    trace = _spec_scenario(speculate=False).run()
    assert trace.speculations == 0 and trace.spec_wins == 0
    assert len(trace.records) == 24


def test_speculation_helps_makespan_or_is_neutral():
    with_spec = _spec_scenario(speculate=True).run()
    without = _spec_scenario(speculate=False).run()
    # the copy only ever replaces a run that would have finished later,
    # so mitigation can't lose by construction of the race
    assert with_spec.makespan <= without.makespan * 1.05
