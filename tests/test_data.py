"""Synthetic data pipeline: determinism + host sharding."""
import numpy as np

from repro.configs import smoke_config
from repro.data import SyntheticLMData


def test_batches_deterministic_per_step():
    cfg = smoke_config("qwen2-7b")
    d = SyntheticLMData(cfg, seq=16, global_batch=4, seed=1)
    a = d.batch(5)
    b = d.batch(5)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))
    c = d.batch(6)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))


def test_labels_are_shifted_tokens():
    cfg = smoke_config("qwen2-7b")
    d = SyntheticLMData(cfg, seq=16, global_batch=2, seed=0)
    b = d.batch(0)
    np.testing.assert_array_equal(np.asarray(b["labels"][:, :-1]),
                                  np.asarray(b["tokens"][:, 1:]))


def test_host_sharding_partitions_batch():
    cfg = smoke_config("qwen2-7b")
    d = SyntheticLMData(cfg, seq=8, global_batch=8, seed=2)
    h0 = d.batch(3, host_index=0, host_count=2)
    h1 = d.batch(3, host_index=1, host_count=2)
    assert h0["tokens"].shape == (4, 8)
    assert not np.array_equal(np.asarray(h0["tokens"]),
                              np.asarray(h1["tokens"]))


def test_tokens_in_vocab():
    for arch in ("qwen2-vl-7b", "seamless-m4t-large-v2", "mamba2-1.3b"):
        cfg = smoke_config(arch)
        d = SyntheticLMData(cfg, seq=12, global_batch=2, seed=0)
        b = d.batch(0)
        toks = np.asarray(b["tokens"])
        assert toks.min() >= 0 and toks.max() < cfg.vocab
