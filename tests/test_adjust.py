"""Adjustment-factor math (paper eqs. 5-6) properties."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev dep; skip, don't die
from hypothesis import given, settings, strategies as st

from repro.core import (cpu_weight, deviation, roofline_weights,
                        runtime_factor, runtime_factor3)
from repro.core.profiler import BenchResult


def _bench(node="x", cpu=400.0, gf=100.0, mem=50.0, io=400.0, link=10.0):
    return BenchResult(node=node, cpu_events_s=cpu, matmul_gflops=gf,
                       mem_gbps=mem, io_read_mbps=io, io_write_mbps=io,
                       link_gbps=link)


@settings(max_examples=60, deadline=None)
@given(st.floats(-0.5, 2.0), st.floats(0.5, 0.95))
def test_cpu_weight_clamped(median_dev, freq_new):
    w = cpu_weight(median_dev, 1.0, freq_new)
    assert 0.0 <= w <= 1.0


def test_cpu_weight_pure_cpu_task():
    # 20% CPU reduction -> 25% slowdown for a fully CPU-bound task
    w = cpu_weight(0.25, 1.0, 0.8)
    assert abs(w - 1.0) < 1e-9
    # io-bound task: no slowdown
    assert cpu_weight(0.0, 1.0, 0.8) == 0.0


@settings(max_examples=40, deadline=None)
@given(st.floats(0.0, 1.0), st.floats(100.0, 1000.0), st.floats(100.0, 1000.0),
       st.floats(100.0, 1000.0), st.floats(100.0, 1000.0))
def test_factor_interpolates_resource_ratios(w, cl, ct, il, it):
    local = _bench(cpu=cl, io=il)
    target = _bench(cpu=ct, io=it)
    f = runtime_factor(w, local, target)
    lo = min(cl / ct, il / it)
    hi = max(cl / ct, il / it)
    assert lo - 1e-9 <= f <= hi + 1e-9


def test_factor_identity_for_identical_nodes():
    b = _bench()
    assert abs(runtime_factor(0.7, b, b) - 1.0) < 1e-9
    assert abs(runtime_factor3((0.5, 0.3, 0.2), b, b) - 1.0) < 1e-9


@settings(max_examples=40, deadline=None)
@given(st.floats(0, 1e3), st.floats(0, 1e3), st.floats(0, 1e3))
def test_roofline_weights_normalised(c, m, n):
    wc, wm, wn = roofline_weights(c, m, n)
    assert abs(wc + wm + wn - 1.0) < 1e-6
    assert min(wc, wm, wn) >= 0


def test_deviation_sign():
    assert deviation(125.0, 100.0) == 0.25
    assert deviation(90.0, 100.0) == -0.1
