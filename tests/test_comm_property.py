"""Property suite (hypothesis) for data-aware HEFT: over arbitrary seeded
synthetic DAGs and random clusters, the array engine must agree with the
independent dict reference bit-for-bit (comm on AND off), and every
schedule must satisfy the structural scheduling invariants — precedence
with transfer floors, per-node no-overlap, and free same-node edges."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev dep; skip, don't die
from hypothesis import given, settings, strategies as st

from repro.data.synthetic import synthetic_dag
from repro.sched import CommCosts, Topology, heft_schedule_array
from repro.sched.heft import SchedTask, heft_schedule_reference


def _cluster(seed: int, n_nodes: int, n_zones: int):
    rng = np.random.default_rng(seed)
    names = [f"n{j}" for j in range(n_nodes)]
    speeds = rng.uniform(0.25, 4.0, n_nodes)
    topo = Topology.blocks(names, n_zones,
                           intra_gbps=float(rng.uniform(2.0, 20.0)),
                           cross_gbps=float(rng.uniform(0.05, 0.5)))
    return names, speeds, topo


def _as_dicts(dag, cost, names):
    ids = [f"t{i}" for i in range(dag.n_tasks)]
    tasks = {ids[i]: SchedTask(id=ids[i],
                               pred=[ids[p] for p in dag.pred[i]],
                               succ=[ids[s] for s in dag.succ[i]])
             for i in range(dag.n_tasks)}
    dcost = {ids[i]: {names[j]: float(cost[i, j])
                      for j in range(len(names))}
             for i in range(dag.n_tasks)}
    deg = {(ids[p], ids[t]): g for (p, t), g in dag.edge_dict().items()}
    return ids, tasks, dcost, deg


DAGS = st.tuples(st.integers(0, 2**31 - 1),   # seed
                 st.integers(2, 6),           # width
                 st.integers(2, 8),           # depth
                 st.floats(1.0, 3.0),         # fanout
                 st.integers(2, 8),           # n_nodes
                 st.integers(2, 3))           # n_zones


@settings(max_examples=20, deadline=None)
@given(DAGS, st.booleans())
def test_array_matches_reference(params, comm_on):
    seed, width, depth, fanout, n_nodes, n_zones = params
    dag = synthetic_dag(width=width, depth=depth, fanout=fanout,
                        data_gb_mean=2.0, seed=seed)
    names, speeds, topo = _cluster(seed ^ 0x5EED, n_nodes, n_zones)
    cost = dag.cost_matrix(speeds)
    spg = topo.secs_per_gb(names)
    comm = (CommCosts(dag.pred, dag.edge_dict(), spg)
            if comm_on else None)
    arr = heft_schedule_array(dag.succ, dag.pred, cost, comm=comm)
    ids, tasks, dcost, deg = _as_dicts(dag, cost, names)
    ref = heft_schedule_reference(
        tasks, dcost, names,
        edge_gb=deg if comm_on else None,
        secs_per_gb=spg if comm_on else None)
    nidx = {n: j for j, n in enumerate(names)}
    assert [nidx[ref["assignment"][t]] for t in ids] == \
        list(arr["assignment"])
    assert [int(t[1:]) for t in ref["order"]] == list(arr["order"])
    for i, tid in enumerate(ids):
        assert ref["start"][tid] == arr["start"][i], tid
        assert ref["finish"][tid] == arr["finish"][i], tid
    assert ref["makespan"] == arr["makespan"]


@settings(max_examples=20, deadline=None)
@given(DAGS)
def test_schedule_invariants_hold_under_comm(params):
    seed, width, depth, fanout, n_nodes, n_zones = params
    dag = synthetic_dag(width=width, depth=depth, fanout=fanout,
                        data_gb_mean=2.0, seed=seed)
    names, speeds, topo = _cluster(seed ^ 0xD1A6, n_nodes, n_zones)
    cost = dag.cost_matrix(speeds)
    spg = topo.secs_per_gb(names)
    eg = dag.edge_dict()
    comm = CommCosts(dag.pred, eg, spg)
    s = heft_schedule_array(dag.succ, dag.pred, cost, comm=comm)
    asg, start, fin = s["assignment"], s["start"], s["finish"]
    T = dag.n_tasks
    # duration consistency: finish - start is exactly the chosen cost
    for t in range(T):
        assert fin[t] - start[t] == pytest.approx(cost[t, asg[t]],
                                                  rel=0, abs=1e-9)
    # precedence + transfer floor: a task may not start before every
    # predecessor's output has ARRIVED at its node (same node: free)
    for t in range(T):
        for p in dag.pred[t]:
            gb = eg[(p, t)]
            delay = gb * spg[asg[p], asg[t]]
            assert start[t] >= fin[p] + delay - 1e-9, (p, t)
            if asg[p] == asg[t]:
                assert spg[asg[p], asg[t]] == 0.0
    # no-overlap: tasks sharing a node never run concurrently
    by_node: dict[int, list[tuple[float, float]]] = {}
    for t in range(T):
        by_node.setdefault(int(asg[t]), []).append((start[t], fin[t]))
    for spans in by_node.values():
        spans.sort()
        for (s0, f0), (s1, _f1) in zip(spans, spans[1:]):
            assert s1 >= f0 - 1e-9
    # makespan is the latest finish
    assert s["makespan"] == fin.max()


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(2, 5), st.integers(2, 6))
def test_comm_none_is_independent_of_topology(seed, width, depth):
    """comm=None must be byte-identical to simply not knowing about the
    topology at all — the knob-off path is the pre-PR scheduler."""
    dag = synthetic_dag(width=width, depth=depth, seed=seed)
    rng = np.random.default_rng(seed + 9)
    cost = dag.cost_matrix(rng.uniform(0.5, 2.0, 4))
    a = heft_schedule_array(dag.succ, dag.pred, cost)
    b = heft_schedule_array(dag.succ, dag.pred, cost, comm=None)
    assert (a["assignment"] == b["assignment"]).all()
    assert (a["start"] == b["start"]).all()
    assert (a["finish"] == b["finish"]).all()
    assert a["makespan"] == b["makespan"]
