"""End-to-end learning: a small LM on the synthetic stream must collapse
well below the uniform baseline within a few dozen steps (validates the
loss path, optimizer, schedule and data jointly)."""
import math

import jax

from repro.data import SyntheticLMData
from repro.launch.steps import make_train_step
from repro.models import AxisRules, ModelConfig, build_model
from repro.models.common import tree_defs_init
from repro.optim import AdamWConfig, state_defs


def test_small_lm_learns():
    rules = AxisRules(fsdp_axes=(), dp_axes=())
    cfg = ModelConfig(arch="conv-test", family="dense", n_layers=2,
                      d_model=128, n_heads=4, n_kv_heads=4, d_ff=512,
                      vocab=2048, head_dim=32, norm="rmsnorm", act="swiglu",
                      attn_chunk=64, xent_chunk=64, remat="full")
    model = build_model(cfg)
    opt = AdamWConfig(lr=2e-3, warmup_steps=5, total_steps=100,
                      schedule="constant")
    params = model.init(jax.random.PRNGKey(0))
    state = tree_defs_init(state_defs(model.param_defs, opt),
                           jax.random.PRNGKey(1))
    data = SyntheticLMData(cfg, seq=64, global_batch=8, seed=0)
    step = jax.jit(make_train_step(model, rules, opt), donate_argnums=(0, 1))
    first = None
    for i in range(40):
        params, state, m = step(params, state, data.batch(i))
        if first is None:
            first = float(m["loss"])
    last = float(m["loss"])
    uniform = math.log(cfg.vocab)
    assert first > uniform - 1.0          # starts near uniform
    assert last < first - 1.5, (first, last)   # collapsed by >1.5 nats
    assert last < uniform - 1.0           # clearly below uniform
