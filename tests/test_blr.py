"""BLR + Pearson gating: unit + hypothesis property tests."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev dep; skip, don't die
from hypothesis import given, settings, strategies as st

from repro.core import blr


def test_perfect_linear_recovery():
    x = np.array([1.0, 2.0, 4.0, 8.0, 16.0])
    y = 3.0 * x + 5.0
    model = blr.fit_task(x, y)
    assert model.correlated
    pred, std = model.predict(32.0)
    assert abs(pred - 101.0) / 101.0 < 0.05
    assert std >= 0


def test_pearson_bounds_and_known_values():
    x = np.arange(10.0)
    assert blr.pearson(x, 2 * x + 1) == pytest.approx(1.0)
    assert blr.pearson(x, -x) == pytest.approx(-1.0)
    assert blr.pearson(x, np.ones(10)) == 0.0


def test_median_fallback_for_uncorrelated():
    rng = np.random.default_rng(0)
    x = np.linspace(1, 10, 20)
    y = 50.0 + rng.normal(0, 0.5, 20)   # flat: no size correlation
    model = blr.fit_task(x, y)
    assert not model.correlated
    pred, _ = model.predict(1000.0)     # wild extrapolation stays at median
    assert abs(pred - 50.0) < 2.0


def test_uncertainty_interval_covers():
    rng = np.random.default_rng(1)
    x = np.linspace(1, 8, 8)
    y = 10 * x + rng.normal(0, 2.0, 8)
    post = blr.fit(x, y)
    lo, hi = blr.predict_interval(post, 5.0, confidence=0.9)
    assert float(lo) < 50.0 < float(hi)


@settings(max_examples=40, deadline=None)
@given(st.lists(st.floats(1.0, 1e4), min_size=3, max_size=12, unique=True),
       st.floats(0.1, 100.0), st.floats(0.0, 50.0))
def test_blr_linear_data_predicts_linearly(xs, slope, intercept):
    x = np.sort(np.array(xs))
    y = slope * x + intercept
    post = blr.fit(x, y)
    mean, std = blr.predict(post, x)
    # predictions at the training points are close to the data, measured
    # against the data scale (the L2 prior shrinks small-n fits, so tiny
    # y-values can carry large *pointwise* relative error by design)
    rel = np.abs(np.asarray(mean) - y) / float(np.max(np.abs(y)))
    assert float(np.median(rel)) < 0.15
    assert np.all(np.asarray(std) >= 0)


@settings(max_examples=40, deadline=None)
@given(st.lists(st.floats(-1e3, 1e3), min_size=2, max_size=20),
       st.lists(st.floats(-1e3, 1e3), min_size=2, max_size=20))
def test_pearson_always_in_unit_interval(xs, ys):
    n = min(len(xs), len(ys))
    p = blr.pearson(np.array(xs[:n]), np.array(ys[:n]))
    assert -1.0 - 1e-9 <= p <= 1.0 + 1e-9


@settings(max_examples=25, deadline=None)
@given(st.integers(3, 10), st.floats(0.5, 20.0))
def test_more_data_not_more_uncertain(n, slope):
    """Posterior predictive std at a fixed point shrinks (weakly) as
    consistent observations accumulate."""
    x_full = np.linspace(1, 10, 10)
    y_full = slope * x_full
    post_small = blr.fit(x_full[:3], y_full[:3])
    post_big = blr.fit(x_full, y_full)
    _, std_small = blr.predict(post_small, 5.0)
    _, std_big = blr.predict(post_big, 5.0)
    assert float(std_big) <= float(std_small) * 1.5 + 1e-6
