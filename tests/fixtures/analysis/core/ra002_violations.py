"""RA002 fixture (estimator-plane scope: lives under a ``core/`` path).

Literal float32 casts in a policy module — the PR 1 blr.predict class.
"""
import jax.numpy as jnp
import numpy as np


def bad_cast(x):
    return jnp.asarray(x, jnp.float32)          # line 10: RA002


def bad_astype(x):
    return x.astype(np.float32)                 # line 14: RA002


def bad_ctor(x):
    return np.float32(x)                        # line 18: RA002


def bad_kw(x):
    return jnp.zeros((3,), dtype=jnp.float32)   # line 22: RA002


def ok_policy(x, dt):
    return jnp.asarray(x, dt)                   # dtype from policy: clean


def ok_serialise(x):
    return np.asarray(x, np.float64)            # full-width JSON path: clean
