"""RA001 fixture: the fused-tick seed list (`_SEED_TRACED`).

These defs carry NO visible jit/vmap plumbing — they are traced purely
by the seed-list contract (the real kernels' wrapping can move behind a
factory).  Line numbers are asserted exactly in
tests/test_analysis_lint.py — append new cases at the end or renumber
the expectations.
"""
import jax.numpy as jnp

TICK_LOG = []
CACHE = {}


def _tick_core(state, obs):
    print("ticking", obs)          # line 16: RA001 print in seeded kernel
    return state


def tick_step(state, obs):
    TICK_LOG.append(obs)           # line 21: RA001 captured mutation
    return _helper(state, obs)


def _helper(state, obs):
    # transitively traced: called by name from seeded `tick_step`
    return float(obs) + 1.0        # line 27: RA001 float() on traced param


def _fleet_tick_core(fleet, obs):
    CACHE["last"] = obs            # line 31: RA001 captured subscript store
    return fleet


def plain_host_helper(obs):
    # negative control: NOT seeded, NOT called from a traced def —
    # host-side prints here are fine and must stay unflagged
    print("host summary", obs)
    return jnp.asarray(obs)
