"""RA005 fixture: broad and bare exception handlers."""
import logging

log = logging.getLogger(__name__)


def bad_bare():
    try:
        risky()
    except:                            # line 10: RA005 bare except
        pass


def bad_broad_silent():
    try:
        risky()
    except Exception:                  # line 17: RA005 silent broad catch
        return None


def bad_base(out):
    try:
        risky()
    except BaseException as e:         # line 24: RA005 BaseException, no raise
        out.append(e)


def ok_named_and_used():
    try:
        risky()
    except Exception as e:             # bound AND used: record-and-continue
        log.warning("risky failed: %r", e)


def ok_reraise():
    try:
        risky()
    except Exception:
        raise


def ok_narrow():
    try:
        risky()
    except (ValueError, KeyError):
        return None


def risky():
    raise ValueError("boom")
