"""A file none of the passes should flag."""
import jax
import jax.numpy as jnp


@jax.jit
def pure(x):
    return jnp.tanh(x) * 2.0


def host_side(model, xs):
    results = []
    for x in xs:                 # host loop, mutation of a local: fine
        results.append(pure(x))
    print("done")                # print outside any traced region: fine
    return results
