"""RA000 fixture: justified vs bare vs unknown-rule suppressions."""
import jax


@jax.jit
def justified(x):
    print(x)  # repro: ignore[RA001] -- frozen trace-time debug aid, fires once by design
    return x


@jax.jit
def bare(x):
    print(x)  # repro: ignore[RA001]
    return x                           # line 13 comment: RA000 (no why)


@jax.jit
def unknown_rule(x):
    print(x)  # repro: ignore[RA999] -- this rule id does not exist anywhere
    return x                           # RA000 unknown rule + RA001 unsuppressed


@jax.jit
def line_above(x):
    # repro: ignore[RA001] -- suppression on the preceding line also binds here
    print(x)
    return x
