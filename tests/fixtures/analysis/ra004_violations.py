"""RA004 fixture: schema round-trip holes and non-monotone guards."""

SCHEMA_VERSION = 5


class LeakyState:
    def to_dict(self) -> dict:
        return {"version": SCHEMA_VERSION,
                "kept": 1,
                "dropped": 2,          # line 10: RA004 never consumed
                "pinned": 3}

    @classmethod
    def from_dict(cls, d):
        version = d.get("version", 1)
        out = cls()
        out.kept = d["kept"]
        if version == 3:               # line 18: RA004 non-monotone pin
            out.pinned = d["pinned"]
        if version >= 9:               # line 20: RA004 out of range 1..5
            pass
        return out


class CleanState:
    def to_dict(self) -> dict:
        return {"version": SCHEMA_VERSION, "a": 1, "b": 2}

    @classmethod
    def from_dict(cls, d):
        version = d.get("version", 1)
        out = cls()
        out.a = d["a"]
        if version >= 2:
            out.b = d.get("b", 0)
        return out
