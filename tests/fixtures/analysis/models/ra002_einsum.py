"""RA002 fixture (models/ scope): mixed-precision einsum operands."""
import jax.numpy as jnp


def bad_mixed(h, w):
    return jnp.einsum("btd,dv->btv", h.astype(jnp.float32),
                      w.astype(jnp.bfloat16))   # line 6: RA002 fp32 x bf16


def bad_half_cast(h, w):
    return jnp.einsum("btd,dv->btv",
                      h.astype(jnp.float32), w)  # line 11: RA002 one uncast


def ok_consistent(h, w):
    return jnp.einsum("btd,dv->btv", h.astype(jnp.float32),
                      w.astype(jnp.float32))


def ok_preferred(h, w):
    return jnp.einsum("btd,dv->btv", h, w,
                      preferred_element_type=jnp.float32)
