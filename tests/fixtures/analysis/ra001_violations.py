"""RA001 fixture: host-side effects inside traced functions.

Line numbers are asserted exactly in tests/test_analysis_lint.py —
append new cases at the end or renumber the expectations.
"""
import jax
import jax.numpy as jnp
from functools import partial

HISTORY = []


@jax.jit
def bad_print(x):
    print("tracing", x)            # line 15: RA001 print under trace
    return x * 2


@partial(jax.jit, static_argnames=("n",))
def bad_sync(x, n):
    y = x.sum()
    return float(y) + n            # not flagged: y is a local, not a param


@jax.jit
def bad_param_sync(x):
    return float(x) + 1.0          # line 27: RA001 float() on traced param


@jax.jit
def bad_item(x):
    return x.sum().item()          # line 32: RA001 .item() sync


@jax.jit
def bad_capture(x):
    HISTORY.append(x)              # line 37: RA001 captured-container mutation
    return x + 1


class Model:
    @jax.jit
    def bad_attach(self, x):
        self.last = x              # line 44: RA001 attribute store on self
        return x


def outer(xs):
    def body(carry, x):
        print(carry)               # line 50: RA001 print in scan body
        return carry + x, x
    total, _ = jax.lax.scan(body, jnp.zeros(()), xs)
    return total


def fine_shapes(x):
    pass


@jax.jit
def ok_static_shape(x):
    return x.reshape(int(x.shape[0]), -1)   # shape read: NOT flagged
