"""RA003 fixture: taxonomy with an unemitted kind, emit sites with an
unknown kind and an unresolvable kind.  Self-contained: defines its own
EVENT_KINDS so the pass binds to this file when linting the fixture dir.
"""

EVENT_KINDS = frozenset({
    "start",        # emitted below: fine
    "finish",       # emitted below: fine
    "ghost",        # line 9 area — never emitted: RA003 on EVENT_KINDS line 6
})

RESERVED_EVENT_KINDS = frozenset({
    "reserved_ok",  # documented as reserved; absence is NOT flagged
})


def run(tracer, dynamic_kind):
    tracer.emit("start", t_sim=0.0)
    tracer.emit("finish", t_sim=1.0)
    tracer.emit("fnish", t_sim=2.0)          # line 20: RA003 typo'd kind
    tracer.emit(dynamic_kind, t_sim=3.0)     # line 21: RA003 unresolvable
