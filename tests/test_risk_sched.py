"""Risk-aware scheduling layer: forgetting-factor + empirical-Bayes bias
hyperparameters, tail-mass speculative admission, risk-priced HEFT
(effective cost = mean + risk_k * widened sigma, rank AND placement), and
the bit-exactness of the inert defaults against the PR 3 behaviour."""
import json

import numpy as np
import pytest

from repro.core import BiasModel, LotaruEstimator, SCHEMA_VERSION
from repro.core.nodes import get_node
from repro.core.profiler import BenchResult
from repro.online import OnlineExecutor, fanout_chain_dag
from repro.sched.heft import SchedTask, heft_schedule, heft_schedule_array
from repro.sched.simulator import ClusterSimulator, GridEngine


def _bench(name, cpu, io):
    return BenchResult(node=name, cpu_events_s=cpu, matmul_gflops=100.0,
                       mem_gbps=20.0, io_read_mbps=io, io_write_mbps=io,
                       link_gbps=0.0)


def _fitted(seed=0, n_tasks=5, **kw):
    rng = np.random.default_rng(seed)
    local = _bench("local-cpu", 450.0, 420.0)
    benches = {f"n{j}": _bench(f"n{j}", float(rng.uniform(150, 900)),
                               float(rng.uniform(100, 900)))
               for j in range(3)}
    est = LotaruEstimator(local, benches, **kw)
    slopes = {f"t{i}": (i + 1) * 2.0 for i in range(n_tasks)}
    est.fit_tasks(list(slopes), 64.0,
                  lambda n, s, cf: slopes[n] * s / cf + 5.0,
                  n_partitions=8)
    return est


# ---------------------------------------------------------------------------
# Inert defaults reproduce PR 3 bit-exactly
# ---------------------------------------------------------------------------
def test_inert_defaults_bitexact_pr3():
    """decay=1.0 + default sigma_r + empirical_bayes off, passed
    explicitly, must be byte-for-byte the default construction: same bias
    sufficient statistics, same predictions, after the same stream."""
    est_default = _fitted(seed=11)
    est_explicit = _fitted(seed=11, bias_decay=1.0, bias_sigma_r=0.25,
                           bias_empirical_bayes=False)
    nodes = list(est_default.target_benches)
    obs = [("t0", nodes[0], 30.0, 140.0), ("t1", nodes[1], 28.0, 260.0),
           ("t2", nodes[2], 35.0, 410.0), ("t0", nodes[1], 31.0, 150.0)]
    for est in (est_default, est_explicit):
        est.observe_batch(obs)
        est.observe("t3", nodes[0], 26.0, 333.0)
    assert np.array_equal(est_default.bias.counts, est_explicit.bias.counts)
    assert np.array_equal(est_default.bias.log_sum,
                          est_explicit.bias.log_sum)
    assert np.array_equal(est_default.bias.log_sq, est_explicit.bias.log_sq)
    M0, S0 = est_default.predict_matrix(nodes, 40.0)
    M1, S1 = est_explicit.predict_matrix(nodes, 40.0)
    assert np.array_equal(M0, M1)
    assert np.array_equal(S0, S1)
    lo0, hi0 = est_default.predict_interval_node("t0", nodes[0], 40.0)
    lo1, hi1 = est_explicit.predict_interval_node("t0", nodes[0], 40.0)
    assert (lo0, hi0) == (lo1, hi1)


def test_biasmodel_decay_one_is_bitexact():
    a = BiasModel(3, 2)
    b = BiasModel(3, 2, decay=1.0)
    for rows, cols, lrs in ([[0], [1], [0.3]],
                            [[1, 2], [0, 1], [0.1, -0.2]],
                            [[0], [1], [0.05]]):
        a.update(rows, cols, lrs)
        b.update(rows, cols, lrs)
    assert np.array_equal(a.counts, b.counts)
    assert np.array_equal(a.log_sum, b.log_sum)
    assert np.array_equal(a.log_sq, b.log_sq)
    assert np.array_equal(a.matrix(), b.matrix())


def test_risk_zero_executor_matches_default():
    """risk_k=0 + spec_tail=None is the PR 3 loop exactly."""
    def run(**kw):
        ex = _spec_scenario(spec_tail=None, seed=31)
        for k, v in kw.items():
            setattr(ex, k, v)
        return ex.run()

    t_default = run()
    t_explicit = run(risk_k=0.0, spec_tail=None)
    assert t_default.makespan == t_explicit.makespan
    assert [(r.id, r.node, r.end) for r in t_default.records] == \
           [(r.id, r.node, r.end) for r in t_explicit.records]


# ---------------------------------------------------------------------------
# Empirical-Bayes sigma_r pooling
# ---------------------------------------------------------------------------
def test_eb_pooled_sigma_beats_fixed_under_heteroscedastic_residuals():
    """True residual noise is far below the fixed 0.25: the fixed-scale
    model over-shrinks every pair toward bias 1.0, the EB-pooled one
    learns the actual (small, pair-varying) spread and lands its
    posterior means much closer to the true per-pair biases."""
    rng = np.random.default_rng(0)
    T, N = 6, 4
    true_log_bias = rng.normal(0.0, 0.5, (T, N))
    pair_sd = rng.uniform(0.01, 0.04, (T, N))     # heteroscedastic noise
    fixed = BiasModel(T, N)                        # sigma_r = 0.25
    pooled = BiasModel(T, N, empirical_bayes=True)
    # few observations per pair: this is where the fixed 0.25 noise scale
    # over-shrinks every posterior toward bias 1.0 while the pooled scale
    # (~0.03 here) knows each residual is nearly noise-free
    for _ in range(2):
        rows, cols = np.meshgrid(np.arange(T), np.arange(N), indexing="ij")
        lrs = true_log_bias + rng.normal(0.0, pair_sd)
        fixed.update(rows.ravel(), cols.ravel(), lrs.ravel())
        pooled.update(rows.ravel(), cols.ravel(), lrs.ravel())
    # the pooled noise scale found the injected spread, not the 0.25 prior
    assert pooled.effective_sigma_r() < 0.1
    assert fixed.effective_sigma_r() == 0.25
    mu_f, _ = fixed.posterior()
    mu_p, _ = pooled.posterior()
    err_f = np.abs(mu_f - true_log_bias).mean()
    err_p = np.abs(mu_p - true_log_bias).mean()
    assert err_p < err_f


def test_eb_falls_back_to_fixed_until_two_observations():
    bm = BiasModel(2, 2, empirical_bayes=True)
    assert bm.effective_sigma_r() == bm.sigma_r
    bm.update([0], [0], [0.2])
    assert bm.effective_sigma_r() == bm.sigma_r    # one obs: spread is NaN
    bm.update([0], [0], [0.3])
    assert bm.effective_sigma_r() != bm.sigma_r


def test_eb_sigma_floor():
    bm = BiasModel(1, 1, empirical_bayes=True)
    for _ in range(10):
        bm.update([0], [0], [0.5])                 # zero spread
    assert bm.effective_sigma_r() == BiasModel.SIGMA_R_FLOOR


# ---------------------------------------------------------------------------
# Forgetting factor
# ---------------------------------------------------------------------------
def test_decay_tracks_drift_faster():
    """After a regime change in the pair's residual, the decayed posterior
    reaches the new level while the infinite-memory one still averages
    the stale history in."""
    slow = BiasModel(1, 1)
    fast = BiasModel(1, 1, decay=0.8)
    for _ in range(30):                            # long stable regime
        slow.update([0], [0], [0.0])
        fast.update([0], [0], [0.0])
    for _ in range(10):                            # drift: bias jumps to 1.5
        slow.update([0], [0], [np.log(1.5)])
        fast.update([0], [0], [np.log(1.5)])
    assert abs(fast.point(0, 0) - 1.5) < abs(slow.point(0, 0) - 1.5)
    assert fast.point(0, 0) > 1.35
    assert slow.point(0, 0) < 1.2


def test_decay_validated():
    with pytest.raises(ValueError):
        BiasModel(1, 1, decay=0.0)
    with pytest.raises(ValueError):
        BiasModel(1, 1, decay=1.5)


def test_decay_widens_stale_posteriors():
    """Forgetting drains effective sample count, so an unrefreshed pair's
    posterior variance grows back toward the prior as other pairs keep
    updating (each update call is one forgetting step)."""
    bm = BiasModel(2, 1, decay=0.9)
    for _ in range(20):
        bm.update([0], [0], [0.2])
    _, v0 = bm.posterior()
    stale_v = v0[0, 0]
    for _ in range(25):                            # only the OTHER pair
        bm.update([1], [0], [0.1])
    _, v1 = bm.posterior()
    assert v1[0, 0] > stale_v                      # pair 0 grew uncertain
    assert v1[0, 0] <= bm.tau0 ** 2 + 1e-12        # bounded by the prior


# ---------------------------------------------------------------------------
# Tail mass
# ---------------------------------------------------------------------------
def test_tail_mass_unit_behaviour():
    bm = BiasModel(1, 1)
    assert bm.tail_mass(0, 0, 1.15) == 0.0         # unobserved: no evidence
    assert bm.tail_mass(0, 0, -1.0) == 0.0         # unobserved beats edge
    bm.update([0], [0], [np.log(1.3)])
    one = bm.tail_mass(0, 0, 1.15)
    # point estimate exactly at the threshold <=> tail mass exactly 0.5
    assert bm.tail_mass(0, 0, bm.point(0, 0)) == pytest.approx(0.5)
    for _ in range(40):
        bm.update([0], [0], [np.log(1.3)])
    many = bm.tail_mass(0, 0, 1.15)
    assert many > one                              # evidence accumulates
    assert many > 0.99
    assert bm.tail_mass(0, 0, 2.0) < 0.01          # far above the posterior
    # bias is a.s. positive: a non-positive threshold holds the full mass,
    # matching the point-estimate comparison at the same threshold
    assert bm.tail_mass(0, 0, -1.0) == 1.0
    assert bm.tail_mass(0, 0, 0.0) == 1.0


def test_estimator_bias_tail_mass():
    est = _fitted(seed=4)
    node = list(est.target_benches)[0]
    assert est.bias_tail_mass("t0", node, 1.1) == 0.0
    m, _ = est.predict("t0", node, 32.0)
    for _ in range(6):
        est.observe("t0", node, 32.0, m * 1.5)
    assert est.bias_tail_mass("t0", node, 1.1) > 0.5
    assert est.bias_tail_mass("t0", "not-a-node", 1.1) == 0.0
    est_off = _fitted(seed=4, bias_correction=False)
    assert est_off.bias_tail_mass("t0", node, 1.1) == 0.0


def _spec_scenario(spec_tail, slow=1.8, spec_k=0.5, seed=17):
    """One node type secretly slower: marginal drift, so the point
    estimate crosses the admission line on early noisy residuals while
    the posterior tail mass needs consistent evidence."""
    rng = np.random.default_rng(seed)
    local = _bench("local-cpu", 450.0, 420.0)
    benches = {"tpu-v2": _bench("tpu-v2", 600.0, 500.0),
               "tpu-v3": _bench("tpu-v3", 650.0, 550.0)}
    est = LotaruEstimator(local, benches)
    slopes = {f"t{i}": (i + 1) * 2.0 for i in range(3)}
    est.fit_tasks(list(slopes), 64.0,
                  lambda n, s, cf: slopes[n] * s / cf + 5.0,
                  n_partitions=8)
    truth = LotaruEstimator(local, benches)
    truth.fit_tasks(list(slopes), 64.0,
                    lambda n, s, cf: slopes[n] * s / cf + 5.0,
                    n_partitions=8)
    tasks, task_name = fanout_chain_dag(list(slopes), 8)
    grid = GridEngine.from_types(nodes_per_type=2,
                                 types=[get_node("tpu-v2"),
                                        get_node("tpu-v3")])
    size = 32.0

    def runtime_fn(tid, node):
        nt = grid.type_of(node).name
        m, _ = truth.predict(task_name[tid], nt, size)
        f = slow if nt == "tpu-v2" else 1.0
        return m * f * float(rng.uniform(0.9, 1.1))

    return OnlineExecutor(est, tasks, task_name, size, grid, runtime_fn,
                          online=True, confidence=0.2, speculate=True,
                          spec_k=spec_k, bias_drift=1.1,
                          spec_tail=spec_tail)


def test_tail_mass_admission_fires_less_than_point_estimate():
    point = _spec_scenario(spec_tail=None).run()
    tail = _spec_scenario(spec_tail=0.8).run()
    assert point.speculations > 0
    assert tail.speculations < point.speculations
    # same completion guarantee either way
    assert len(tail.records) == len(point.records) == 24


def test_spec_tail_validated():
    est = _fitted(seed=2)
    tasks, task_name = fanout_chain_dag(est.task_names(), 2)
    grid = GridEngine.from_types(nodes_per_type=1)
    with pytest.raises(ValueError):
        OnlineExecutor(est, tasks, task_name, 32.0, grid,
                       lambda t, n: 1.0, spec_tail=1.5)


# ---------------------------------------------------------------------------
# Risk-aware HEFT
# ---------------------------------------------------------------------------
def test_risk_aware_heft_reduces_realized_makespan_under_variance():
    """Node 0 quotes slightly lower means but huge sigma; realised
    runtimes land at mean + 1 sigma.  Risk-neutral HEFT piles work onto
    the jittery node and pays for it; risk-aware placement spreads it."""
    rng = np.random.default_rng(0)
    T, N = 12, 3
    mean = rng.uniform(8.0, 12.0, (T, N))
    mean[:, 0] *= 0.9                              # tempting on paper
    std = np.full((T, N), 0.3)
    std[:, 0] = 6.0                                # but wildly uncertain
    realized = mean + std                          # the bad draw
    succ = [[] for _ in range(T)]
    pred = [[] for _ in range(T)]

    def realized_makespan(sched):
        node_free = np.zeros(N)
        for t in sched["order"]:
            j = sched["assignment"][t]
            node_free[j] += realized[t, j]
        return node_free.max()

    neutral = heft_schedule_array(succ, pred, mean)
    averse = heft_schedule_array(succ, pred, mean, uncertainty=std,
                                 risk_k=1.0)
    assert realized_makespan(averse) < realized_makespan(neutral)


def test_risk_k_inflates_upward_rank_priority():
    """The effective cost drives the RANK too: an uncertain task becomes
    more urgent under risk_k, not just differently placed."""
    succ = [[], []]
    pred = [[], []]
    cost = np.array([[10.0, 10.0], [11.0, 11.0]])
    unc = np.array([[20.0, 20.0], [0.1, 0.1]])
    plain = heft_schedule_array(succ, pred, cost)
    risky = heft_schedule_array(succ, pred, cost, uncertainty=unc,
                                risk_k=1.0)
    assert list(plain["order"]) == [1, 0]          # higher mean first
    assert list(risky["order"]) == [0, 1]          # higher risk first


def test_heft_dict_wrapper_warns_on_ignored_uncertainty():
    tasks = {"a": SchedTask(id="a")}
    cost = {"a": {"n": 1.0}}
    unc = {"a": {"n": 5.0}}
    with pytest.warns(UserWarning, match="risk_k == 0"):
        heft_schedule(tasks, cost, ["n"], uncertainty=unc, risk_k=0.0)


def test_predict_matrix_with_std_false_is_mean_only():
    est = _fitted(seed=6)
    nodes = list(est.target_benches)
    m, _ = est.predict("t1", nodes[1], 30.0)
    est.observe("t1", nodes[1], 30.0, m * 1.4)     # activate a bias pair
    M, S = est.predict_matrix(nodes, 30.0)
    M2, S2 = est.predict_matrix(nodes, 30.0, with_std=False)
    assert S2 is None
    assert np.array_equal(M2, M)


def test_executor_risk_k_steers_off_high_variance_node():
    """End-to-end: with a drifted, high-variance pair learned online, the
    risk-aware executor re-plans remaining work off that node at least as
    well as the risk-neutral one (never worse makespan here)."""
    neutral = _spec_scenario(spec_tail=None, slow=2.5, seed=23)
    neutral.risk_k = 0.0
    risky = _spec_scenario(spec_tail=None, slow=2.5, seed=23)
    risky.risk_k = 1.5
    tn = neutral.run()
    tr = risky.run()
    assert len(tr.records) == len(tn.records)
    assert tr.makespan <= tn.makespan * 1.05


# ---------------------------------------------------------------------------
# Persistence of the v4 hyperparameters
# ---------------------------------------------------------------------------
def test_save_load_roundtrips_bias_hyperparams(tmp_path):
    est = _fitted(seed=8, bias_decay=0.95, bias_sigma_r=0.1,
                  bias_empirical_bayes=True)
    node = list(est.target_benches)[0]
    m, _ = est.predict("t0", node, 30.0)
    est.observe("t0", node, 30.0, m * 1.2)
    p = tmp_path / "est.json"
    est.save(p)
    d = json.loads(p.read_text())
    assert d["version"] == SCHEMA_VERSION
    assert d["bias_opts"] == {"decay": 0.95, "sigma_r": 0.1,
                              "empirical_bayes": True}
    loaded = LotaruEstimator.load(p)
    assert loaded.bias.decay == 0.95
    assert loaded.bias.sigma_r == 0.1
    assert loaded.bias.empirical_bayes is True
    nodes = list(est.target_benches)
    M0, S0 = est.predict_matrix(nodes, 40.0)
    M1, S1 = loaded.predict_matrix(nodes, 40.0)
    np.testing.assert_allclose(M1, M0, rtol=5e-4, atol=1e-6)
    np.testing.assert_allclose(S1, S0, rtol=5e-4, atol=1e-6)


def test_v3_file_without_opts_loads_with_inert_defaults(tmp_path):
    est = _fitted(seed=9)
    node = list(est.target_benches)[0]
    est.observe("t0", node, 30.0, 200.0)
    p = tmp_path / "v3.json"
    est.save(p)
    d = json.loads(p.read_text())
    d["version"] = 3
    del d["bias_opts"]
    for k in ("decay", "empirical_bayes"):
        del d["bias"]["state"][k]
    p.write_text(json.dumps(d))
    loaded = LotaruEstimator.load(p)
    assert loaded.bias.decay == 1.0
    assert loaded.bias.empirical_bayes is False
    assert np.array_equal(loaded.bias.counts, est.bias.counts)


# ---------------------------------------------------------------------------
# Heteroscedastic simulator noise (the regime risk pricing targets)
# ---------------------------------------------------------------------------
def test_simulator_het_noise_varies_per_pair_and_default_is_bitexact():
    from repro.sched.workflows import WORKFLOWS
    task = WORKFLOWS["eager"][0]
    node = get_node("tpu-v2")
    plain = ClusterSimulator(seed=1)
    het0 = ClusterSimulator(seed=1, het=0.0)
    assert plain.run_task(task, node, 8.0) == het0.run_task(task, node, 8.0)
    het = ClusterSimulator(seed=1, het=3.0)
    sds = {het.noise_sd(t.name, n.name)
           for t in WORKFLOWS["eager"] for n in (node, get_node("tpu-v3"))}
    assert len(sds) > 1                            # pair-dependent
    assert min(sds) >= het.noise
    assert het.noise_sd(task.name, node.name) == \
        het.noise_sd(task.name, node.name)         # a fixed pair property
