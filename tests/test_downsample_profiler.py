"""Downsampling ladder + profiler properties."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev dep; skip, don't die
from hypothesis import given, settings, strategies as st

from repro.core.downsample import (downsample_workload, partition_sizes,
                                   reduced_model_factor)
from repro.core.profiler import BenchResult, profile_node
from repro.core.nodes import NODE_TYPES, get_node, target_nodes


@settings(max_examples=30, deadline=None)
@given(st.floats(0.1, 1e4), st.integers(1, 16))
def test_partition_ladder_geometric(x, n):
    parts = partition_sizes(x, n)
    assert len(parts) == n
    assert abs(parts[0] - x / 2) < 1e-9
    for a, b in zip(parts, parts[1:]):
        assert abs(b - a / 2) < 1e-9
    # cumulative size is strictly less than the original input
    assert sum(parts) < x


def test_workload_downsampling_halves_tokens():
    parts = downsample_workload(seq=4096, global_batch=256, n=6)
    toks = [p.tokens for p in parts]
    for a, b in zip(toks, toks[1:]):
        assert b * 2 == a
    assert toks[0] == 4096 * 128


def test_workload_downsampling_batch_floor():
    parts = downsample_workload(seq=64, global_batch=2, n=8, min_seq=32)
    assert all(p.batch >= 1 and p.seq >= 32 for p in parts)


def test_reduced_model_factor():
    assert reduced_model_factor(7_600_000_000, 76_000_000) == 100.0


def test_profile_node_measurement_noise_bounded():
    node = get_node("tpu-v5e")
    rng = np.random.default_rng(0)
    benches = [profile_node(node, rng) for _ in range(20)]
    gf = np.array([b.matmul_gflops for b in benches])
    true = node.peak_flops / 1e9
    assert abs(np.mean(gf) - true) / true < 0.05
    assert np.std(gf) / true < 0.10


def test_node_registry_consistency():
    assert len(target_nodes()) == 5
    for n in NODE_TYPES.values():
        assert n.peak_flops > 0 and n.hbm_bw > 0 and n.link_bw > 0
        assert 0 < n.eff("dense") <= 1.0
    # ordering matches the paper's machine spread (old < new)
    assert (NODE_TYPES["tpu-v2"].peak_flops
            < NODE_TYPES["tpu-v4"].peak_flops
            < NODE_TYPES["tpu-v5p"].peak_flops)


def test_real_local_profile_runs():
    from repro.core.profiler import profile_local
    b = profile_local(fast=True)
    assert b.cpu_events_s > 0
    assert b.matmul_gflops > 0.1
    assert b.mem_gbps > 0.01
    assert b.io_read_mbps > 0.1
