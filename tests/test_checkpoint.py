"""Checkpoint store: roundtrip, atomicity, async overlap, GC, restart."""
import json
import shutil
import numpy as np
import jax.numpy as jnp
import pytest

from repro.checkpoint import AsyncCheckpointer, latest_step, restore, save


def _state(seed=0):
    rng = np.random.default_rng(seed)
    return {"params": {"w": jnp.asarray(rng.normal(0, 1, (4, 8)), jnp.float32),
                       "b": jnp.asarray(rng.normal(0, 1, (8,)), jnp.bfloat16)},
            "opt": {"m": jnp.zeros((4, 8)), "step": jnp.asarray(7)}}


def test_roundtrip(tmp_path):
    st = _state()
    save(tmp_path, 3, st, metadata={"loss": 1.5})
    out, manifest = restore(tmp_path)
    assert manifest["step"] == 3
    assert manifest["metadata"]["loss"] == 1.5
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                  np.asarray(st["params"]["w"]))
    # bf16 survives via its numpy view roundtrip
    assert out["params"]["b"].dtype.name in ("bfloat16", "float32", "void16")


def test_latest_falls_back_on_stale_pointer(tmp_path):
    save(tmp_path, 1, _state())
    save(tmp_path, 2, _state(1))
    (tmp_path / "LATEST").write_text("99")        # stale/corrupt pointer
    assert latest_step(tmp_path) == 2


def test_incomplete_checkpoint_invisible(tmp_path):
    save(tmp_path, 1, _state())
    # simulate a crash mid-write: .tmp dir exists, no manifest rename
    (tmp_path / "step_00000002.tmp").mkdir()
    assert latest_step(tmp_path) == 1


def test_async_checkpointer_gc(tmp_path):
    ck = AsyncCheckpointer(tmp_path, keep=2)
    for s in range(5):
        ck.save(s, _state(s))
    ck.wait()
    steps = sorted(d.name for d in tmp_path.glob("step_*"))
    assert len(steps) == 2
    assert latest_step(tmp_path) == 4


def test_restart_determinism(tmp_path):
    from repro.configs import smoke_config
    from repro.launch.train import train, train_with_restarts
    cfg = smoke_config("stablelm-1.6b")
    a = tmp_path / "a"
    b = tmp_path / "b"
    rep_a = train(cfg, steps=6, seq=16, global_batch=2, ckpt_dir=a,
                  ckpt_every=2, seed=5)
    rep_b = train_with_restarts(cfg, steps=6, seq=16, global_batch=2,
                                ckpt_dir=b, ckpt_every=2, failures=[4], seed=5)
    assert rep_b.restarts == 1
    np.testing.assert_allclose(rep_a.losses[-1], rep_b.losses[-1], atol=1e-4)
