"""Property test (satellite of the online subsystem): an arbitrary shuffled
observation stream absorbed via ``update_task_batch`` must match
``fit_task_batch`` on the concatenated data — means, stds, and the Pearson
gate — because the NIG posterior is a function of sufficient statistics."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev dep; skip, don't die
from hypothesis import given, settings, strategies as st

import jax

from repro.core import blr

# float32 default leaves ~1e-5 headroom on accumulated moments; under x64
# (the benchmark's config) the observed gap is ~1e-15
RTOL = 1e-6 if jax.config.jax_enable_x64 else 5e-4


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(2, 6), st.integers(1, 10))
def test_shuffled_stream_matches_concat_refit(seed, n_tasks, per_task):
    rng = np.random.default_rng(seed)
    base = []
    for i in range(n_tasks):
        m = int(rng.integers(3, 9))
        xs = np.sort(rng.uniform(1.0, 100.0, m))
        if i % 2 == 0:   # clearly correlated (gate on, away from 0.8)
            ys = rng.uniform(0.5, 5.0) * xs + rng.uniform(0.0, 20.0)
        else:            # clearly flat (gate off)
            ys = np.full(m, rng.uniform(10.0, 100.0))
            ys = ys + rng.normal(0.0, 1e-3, m)
        base.append((xs, np.abs(ys)))
    model = blr.fit_task_batch([b[0] for b in base], [b[1] for b in base])

    stream = []
    for i in range(n_tasks):
        for _ in range(per_task):
            x = float(rng.uniform(1.0, 200.0))
            y = float(rng.uniform(1.0, 500.0))
            stream.append((i, x, y))
    rng.shuffle(stream)
    for i, x, y in stream:
        model = blr.update_task_batch(model, i, x, y)

    concat = [(np.concatenate([base[i][0],
                               [s[1] for s in stream if s[0] == i]]),
               np.concatenate([base[i][1],
                               [s[2] for s in stream if s[0] == i]]))
              for i in range(n_tasks)]
    refit = blr.fit_task_batch([c[0] for c in concat],
                               [c[1] for c in concat])

    assert np.array_equal(np.asarray(model.correlated),
                          np.asarray(refit.correlated))
    for xq in (2.0, 75.0, 180.0):
        mi, si = blr.predict_task_batch(model, xq)
        mr, sr = blr.predict_task_batch(refit, xq)
        np.testing.assert_allclose(np.asarray(mi), np.asarray(mr),
                                   rtol=RTOL, atol=1e-5)
        np.testing.assert_allclose(np.asarray(si), np.asarray(sr),
                                   rtol=RTOL, atol=1e-5)
    np.testing.assert_allclose(np.asarray(model.median),
                               np.asarray(refit.median),
                               rtol=RTOL, atol=1e-5)
