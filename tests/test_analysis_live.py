"""The lint suite against the LIVE tree — not fixtures.

Three acceptance properties:

* the repo tree is lint-clean (the CI gate at merge);
* RA003 sees every real ``emit(``/``Event(kind=``) call site and the
  kinds it collects are exactly the runtime ``EVENT_KINDS`` taxonomy —
  proving the closure over the code as it exists today;
* RA004 sees the real schema writers/readers (estimator v1–v5, bias,
  reliability, execution trace, events, observation buffer) and finds
  every written key consumed.
"""
import ast
from pathlib import Path

import pytest

from repro.analysis.lint import parse_file, run_paths
from repro.analysis.lint.passes.schema_roundtrip import (_consumed_keys,
                                                         _written_keys)

ROOT = Path(__file__).resolve().parents[1]
SRC = ROOT / "src"
LINT_ROOTS = [SRC, ROOT / "benchmarks", ROOT / "scripts"]


def _class_fns(path: Path, cls_name: str) -> dict:
    tree = parse_file(path).tree
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and node.name == cls_name:
            return {n.name: n for n in node.body
                    if isinstance(n, ast.FunctionDef)}
    raise AssertionError(f"{cls_name} not found in {path}")


# ---------------------------------------------------------------------------
# the merge gate
# ---------------------------------------------------------------------------
def test_repo_tree_is_lint_clean():
    diags, project = run_paths(LINT_ROOTS)
    assert len(project.files) > 80, "lint saw suspiciously few files"
    assert diags == [], "\n".join(str(d) for d in diags)


# ---------------------------------------------------------------------------
# RA003 closure over the live taxonomy
# ---------------------------------------------------------------------------
def _live_emit_kinds() -> tuple[set, int]:
    """(kinds, site count) from every emit()/Event(kind=) call under
    src/, collected independently of the pass implementation."""
    kinds, sites = set(), 0
    for path in sorted(SRC.rglob("*.py")):
        tree = parse_file(path).tree
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            if isinstance(node.func, ast.Attribute) and \
                    node.func.attr == "emit":
                sites += 1
                if node.args and isinstance(node.args[0], ast.Constant):
                    kinds.add(node.args[0].value)
                for kw in node.keywords:
                    if kw.arg == "kind" and isinstance(kw.value, ast.Constant):
                        kinds.add(kw.value.value)
            elif isinstance(node.func, ast.Name) and node.func.id == "Event":
                for kw in node.keywords:
                    if kw.arg == "kind" and isinstance(kw.value, ast.Constant):
                        kinds.add(kw.value.value)
    return kinds, sites


def test_ra003_covers_all_live_emit_sites():
    from repro.obs.trace import EVENT_KINDS
    kinds, sites = _live_emit_kinds()
    # the executor + simulator + span exporter emit today; if this number
    # shrinks the pass lost visibility, if kinds drift the closure broke
    assert sites >= 19, f"only {sites} emit sites seen"
    assert kinds == set(EVENT_KINDS), (
        f"taxonomy drift: emitted-not-registered {kinds - set(EVENT_KINDS)}, "
        f"registered-never-emitted {set(EVENT_KINDS) - kinds}")


def test_ra003_clean_on_live_tree_but_catches_injected_typo(tmp_path):
    diags, _ = run_paths([SRC], select=["RA003"])
    assert diags == [], "\n".join(str(d) for d in diags)
    # inject a typo'd emit next to the real taxonomy: the pass must fire
    trace_src = (SRC / "repro" / "obs" / "trace.py").read_text()
    bad = tmp_path / "obs_copy.py"
    bad.write_text(trace_src + "\n\ndef _bad(tr):\n"
                   "    tr.emit('fnish', t_sim=0.0)\n")
    diags, _ = run_paths([bad], select=["RA003"])
    assert any("fnish" in d.message for d in diags)


# ---------------------------------------------------------------------------
# RA004 over the live schemas (v1–v5)
# ---------------------------------------------------------------------------
ESTIMATOR = SRC / "repro" / "core" / "estimator.py"
BLR = SRC / "repro" / "core" / "blr.py"
EXECUTOR = SRC / "repro" / "online" / "executor.py"
TRACE = SRC / "repro" / "obs" / "trace.py"
BUFFER = SRC / "repro" / "online" / "buffer.py"
SYNTHETIC = SRC / "repro" / "data" / "synthetic.py"

#: the keys each schema version introduced — the write side of the
#: on-disk format, pinned so a writer edit that drops a version's keys
#: fails here even before the round-trip tests notice
ESTIMATOR_SCHEMA_KEYS = {
    1: {"version", "freq_reduction", "local_bench", "target_benches",
        "tasks", "w", "sizes", "runtimes"},
    2: {"model", "correlated", "median", "spread", "post",
        "mu", "V", "a", "b", "x_scale", "y_scale"},
    3: {"bias", "nodes", "state", "bias_correction"},
    4: {"bias_opts"},
    5: {"reliability"},
}


@pytest.mark.parametrize("cls,path,writer,reader", [
    ("LotaruEstimator", ESTIMATOR, "save", "load"),
    ("BiasModel", BLR, "to_dict", "from_dict"),
    ("ReliabilityModel", BLR, "to_dict", "from_dict"),
    ("ExecutionTrace", EXECUTOR, "to_dict", "from_dict"),
    ("Event", TRACE, "to_json", "from_json"),
    ("ObservationBuffer", BUFFER, "to_dict", "from_dict"),
    ("SyntheticDAG", SYNTHETIC, "to_dict", "from_dict"),
])
def test_ra004_live_writer_keys_all_consumed(cls, path, writer, reader):
    fns = _class_fns(path, cls)
    assert writer in fns and reader in fns, f"{cls} lost its schema pair"
    written = set(_written_keys(fns[writer]))
    consumed = _consumed_keys(fns[reader])
    assert written, f"{cls}.{writer} writes no keys — collector broke?"
    missing = written - consumed
    assert not missing, (f"{cls}: keys written by {writer} but never "
                         f"consumed by {reader}: {sorted(missing)}")


def test_ra004_estimator_covers_every_schema_version_key():
    fns = _class_fns(ESTIMATOR, "LotaruEstimator")
    written = set(_written_keys(fns["save"]))
    consumed = _consumed_keys(fns["load"])
    for version, keys in ESTIMATOR_SCHEMA_KEYS.items():
        assert keys <= written, (f"schema v{version} keys no longer "
                                 f"written: {sorted(keys - written)}")
        assert keys <= consumed, (f"schema v{version} keys no longer "
                                  f"consumed: {sorted(keys - consumed)}")


def test_ra004_live_version_guards_are_monotone():
    diags, _ = run_paths([ESTIMATOR, BLR, EXECUTOR, TRACE, BUFFER],
                         select=["RA004"])
    assert diags == [], "\n".join(str(d) for d in diags)


def test_ra004_catches_injected_schema_leak(tmp_path):
    # add a written-but-never-read key to a copy of the live estimator:
    # the pass must notice on the real schema shape, not a toy fixture
    text = ESTIMATOR.read_text()
    needle = '"tasks": {}}'
    assert needle in text
    bad = tmp_path / "estimator_leaky.py"
    bad.write_text(text.replace(
        needle, '"tasks": {}, "leaked_key": 1}'))
    diags, _ = run_paths([bad], select=["RA004"])
    assert any("leaked_key" in d.message for d in diags), \
        "RA004 missed a planted leak in the live writer"
