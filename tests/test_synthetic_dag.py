"""WfCommons-style synthetic DAG generator: determinism, serialisation
round-trip, parameter validation (errors must NAME the offending knob),
and structural guarantees (layered acyclic shape, bounded in-degree)."""
import numpy as np
import pytest

from repro.data import DAG_SCHEMA_VERSION, SyntheticDAG, synthetic_dag


# ---------------------------------------------------------------------------
# determinism
# ---------------------------------------------------------------------------
def test_same_seed_is_bit_identical():
    a = synthetic_dag(width=7, depth=9, fanout=2.5, seed=123)
    b = synthetic_dag(width=7, depth=9, fanout=2.5, seed=123)
    assert a.succ == b.succ
    assert a.pred == b.pred
    assert a.data_gb == b.data_gb          # exact float equality
    assert (a.work == b.work).all()
    assert a.params == b.params


def test_different_seeds_differ():
    a = synthetic_dag(width=7, depth=9, seed=0)
    b = synthetic_dag(width=7, depth=9, seed=1)
    assert a.succ != b.succ or not (a.work == b.work).all()


def test_generator_is_layered_and_sized():
    dag = synthetic_dag(width=6, depth=12, fanout=2.0, seed=4)
    # every layer jitters within [ceil(width/2), width]
    assert 12 * 3 <= dag.n_tasks <= 12 * 6
    # roots only in the first layer: every later task has >= 1 pred
    n_roots = sum(1 for p in dag.pred if not p)
    assert n_roots <= 6
    # bounded in-degree keeps E linear in T
    assert dag.n_edges <= dag.n_tasks * 6


# ---------------------------------------------------------------------------
# serialisation round-trip
# ---------------------------------------------------------------------------
def test_to_dict_from_dict_round_trip():
    dag = synthetic_dag(width=5, depth=7, fanout=2.2, seed=77)
    d = dag.to_dict()
    assert d["version"] == DAG_SCHEMA_VERSION
    back = SyntheticDAG.from_dict(d)
    assert back.succ == dag.succ
    assert back.pred == dag.pred
    assert back.data_gb == dag.data_gb
    assert (back.work == dag.work).all()
    assert back.params == dag.params
    # and the round trip is a fixed point
    assert back.to_dict() == d


def test_from_dict_rejects_unknown_version():
    d = synthetic_dag(width=3, depth=3, seed=0).to_dict()
    d["version"] = 0
    with pytest.raises(ValueError, match="version"):
        SyntheticDAG.from_dict(d)


def test_edge_dict_matches_adjacency():
    dag = synthetic_dag(width=4, depth=5, seed=9)
    ed = dag.edge_dict()
    assert len(ed) == dag.n_edges
    for t in range(dag.n_tasks):
        for p, g in zip(dag.pred[t], dag.data_gb[t]):
            assert ed[(p, t)] == g


# ---------------------------------------------------------------------------
# validation: every error names its parameter
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kw,name", [
    ({"width": 0}, "width"),
    ({"depth": 0}, "depth"),
    ({"fanout": 0.5}, "fanout"),
    ({"data_gb_mean": 0.0}, "data_gb_mean"),
    ({"data_gb_sigma": -0.1}, "data_gb_sigma"),
    ({"work_mean": -1.0}, "work_mean"),
    ({"work_sigma": -2.0}, "work_sigma"),
])
def test_degenerate_params_raise_naming_parameter(kw, name):
    with pytest.raises(ValueError, match=name):
        synthetic_dag(**kw)


def test_cyclic_edges_raise():
    # 0 -> 1 -> 2 -> 0
    with pytest.raises(ValueError, match="cycle"):
        SyntheticDAG(succ=[[1], [2], [0]], pred=[[2], [0], [1]],
                     data_gb=[[1.0], [1.0], [1.0]], work=[1.0, 1.0, 1.0])


def test_mirror_inconsistency_raises():
    with pytest.raises(ValueError, match="mirror"):
        SyntheticDAG(succ=[[1], []], pred=[[], []],
                     data_gb=[[], []], work=[1.0, 1.0])


def test_misaligned_data_gb_raises():
    with pytest.raises(ValueError, match="data_gb"):
        SyntheticDAG(succ=[[1], []], pred=[[], [0]],
                     data_gb=[[], []], work=[1.0, 1.0])


def test_negative_volume_raises():
    with pytest.raises(ValueError, match="negative"):
        SyntheticDAG(succ=[[1], []], pred=[[], [0]],
                     data_gb=[[], [-0.5]], work=[1.0, 1.0])


def test_cost_matrix_validates_speeds():
    dag = synthetic_dag(width=3, depth=3, seed=0)
    with pytest.raises(ValueError, match="speeds"):
        dag.cost_matrix([1.0, 0.0])
    c = dag.cost_matrix([1.0, 2.0])
    assert c.shape == (dag.n_tasks, 2)
    np.testing.assert_allclose(c[:, 0], 2.0 * c[:, 1])


def test_scales_past_10k_tasks():
    dag = synthetic_dag(width=100, depth=140, seed=0)
    assert dag.n_tasks >= 10_000
    # flat-triple serialisation stays linear in E
    assert len(dag.to_dict()["edges"]) == dag.n_edges
