"""The static-analysis framework itself: every rule fires on its
planted fixture with the exact rule id and line, suppressions behave,
and the registry/CLI plumbing holds.

Pure-stdlib under test (no jax import needed to lint), so this module
is cheap to run under the REPRO_SANITIZE CI arm too.
"""
from pathlib import Path

import pytest

from repro.analysis.lint import (RULE_DOCS, Diagnostic, LintPass, Project,
                                 parse_file, register, registered_passes,
                                 run_paths, run_project)

FIXTURES = Path(__file__).parent / "fixtures" / "analysis"


def lint(*names, select=None):
    paths = [FIXTURES / n for n in names] if names else [FIXTURES]
    diags, _ = run_paths(paths, select=select)
    return diags


def rule_lines(diags, rule):
    return [d.line for d in diags if d.rule == rule]


# ---------------------------------------------------------------------------
# per-rule fixture checks: exact ids and line numbers
# ---------------------------------------------------------------------------
def test_ra001_exact_lines():
    diags = lint("ra001_violations.py")
    assert rule_lines(diags, "RA001") == [15, 27, 32, 37, 44, 50]
    assert {d.rule for d in diags} == {"RA001"}


def test_ra001_seed_list_exact_lines():
    # the fused-tick kernels are traced by CONTRACT (`_SEED_TRACED`):
    # no visible jit/vmap plumbing in the fixture, yet every planted
    # violation fires — incl. transitively through a same-file call
    diags = lint("ra001_tick_seed.py")
    assert rule_lines(diags, "RA001") == [16, 21, 27, 31]
    assert {d.rule for d in diags} == {"RA001"}


def test_ra001_seed_list_negative_control():
    # a def NOT on the seed list (and not called from one) keeps its
    # host-side print: seeding must not blanket the whole module
    diags = lint("ra001_tick_seed.py")
    assert 38 not in rule_lines(diags, "RA001")


def test_ra001_local_float_not_flagged():
    # float(y) on a local intermediate (bad_sync, line 22) must NOT fire:
    # the heuristic only flags syncs rooted at traced parameters
    diags = lint("ra001_violations.py")
    assert 22 not in rule_lines(diags, "RA001")


def test_ra002_policy_modules_exact_lines():
    diags = lint("core/ra002_violations.py")
    assert rule_lines(diags, "RA002") == [10, 14, 18, 22]
    assert {d.rule for d in diags} == {"RA002"}


def test_ra002_einsum_exact_lines():
    diags = lint("models/ra002_einsum.py")
    assert rule_lines(diags, "RA002") == [6, 11]


def test_ra002_scope_is_path_based():
    # the same literal casts outside a policy path are not RA002's business
    clean = FIXTURES / "ra005_violations.py"   # not under core/ or models/
    diags, _ = run_paths([clean], select=["RA002"])
    assert diags == []


def test_ra003_exact_lines():
    diags = lint("ra003_violations.py")
    lines = rule_lines(diags, "RA003")
    assert lines == [6, 20, 21]
    msgs = {d.line: d.message for d in diags}
    assert "ghost" in msgs[6]             # unemitted kind, at the taxonomy
    assert "fnish" in msgs[20]            # typo'd kind, at the emit site
    assert "not a string literal" in msgs[21]
    # reserved kinds are exempt from the closure check
    assert not any("reserved_ok" in d.message for d in diags)


def test_ra004_exact_lines():
    diags = lint("ra004_violations.py")
    lines = rule_lines(diags, "RA004")
    assert lines == [10, 18, 20]
    msgs = {d.line: d.message for d in diags}
    assert "'dropped'" in msgs[10]
    assert "version == 3" in msgs[18]
    assert "outside the known schema range" in msgs[20]
    # CleanState must not be flagged
    assert all(d.line < 24 for d in diags)


def test_ra005_exact_lines():
    diags = lint("ra005_violations.py")
    assert rule_lines(diags, "RA005") == [10, 17, 24]


def test_clean_file_is_clean():
    assert lint("clean.py") == []


# ---------------------------------------------------------------------------
# suppressions
# ---------------------------------------------------------------------------
def test_justified_suppression_suppresses():
    diags = lint("suppressions.py")
    # the justified ignore on line 7 leaves no RA001 and no RA000 there
    assert all(d.line != 7 for d in diags)


def test_bare_suppression_is_flagged_as_ra000():
    diags = lint("suppressions.py")
    ra000 = [d for d in diags if d.rule == "RA000"]
    assert any(d.line == 13 and "without justification" in d.message
               for d in ra000)
    # ...but it still suppresses the named rule (no double report)
    assert 13 not in rule_lines(diags, "RA001")


def test_unknown_rule_suppression():
    diags = lint("suppressions.py")
    assert any(d.rule == "RA000" and "RA999" in d.message for d in diags)
    # an unknown-rule ignore does not suppress the real finding
    assert 19 in rule_lines(diags, "RA001")


def test_suppression_on_line_above_binds():
    diags = lint("suppressions.py")
    assert 26 not in rule_lines(diags, "RA001")


def test_suppression_in_string_literal_is_inert():
    # core.py's own docstring contains example ignore comments; tokenize-
    # based parsing must not treat them as live suppressions
    src = parse_file(Path("src/repro/analysis/lint/core.py"))
    doc_lines = {s.line for s in src.suppressions}
    assert doc_lines == set()


# ---------------------------------------------------------------------------
# framework plumbing
# ---------------------------------------------------------------------------
def test_select_filters_rules():
    diags = lint(select=["RA005"])
    assert diags and all(d.rule == "RA005" for d in diags)


def test_select_unknown_rule_raises():
    with pytest.raises(ValueError, match="unknown rule"):
        run_paths([FIXTURES], select=["RA777"])


def test_rule_docs_catalogue_complete():
    registered_passes()
    assert set(RULE_DOCS) >= {"RA000", "RA001", "RA002", "RA003",
                              "RA004", "RA005"}
    assert all(RULE_DOCS[r] for r in RULE_DOCS)


def test_plugin_registration_roundtrip():
    class Probe(LintPass):
        rule = "RA900"
        doc = "test-only probe pass"

        def check(self, src, project):
            yield self.diag(src, 1, "probe")

    try:
        register(Probe)
        diags, _ = run_paths([FIXTURES / "clean.py"], select=["RA900"])
        assert [d.rule for d in diags] == ["RA900"]
    finally:
        from repro.analysis.lint.core import _REGISTRY
        _REGISTRY.pop("RA900", None)
        RULE_DOCS.pop("RA900", None)


def test_unparseable_file_reports_not_crashes(tmp_path):
    bad = tmp_path / "broken.py"
    bad.write_text("def broken(:\n")
    diags, _ = run_paths([bad])
    assert len(diags) == 1 and diags[0].rule == "RA000"
    assert "unparseable" in diags[0].message


def test_diagnostics_are_ordered_and_unique():
    diags = lint()
    assert diags == sorted(set(diags))


def test_cli_exit_codes(tmp_path):
    import subprocess
    import sys
    root = Path(__file__).resolve().parents[1]
    r = subprocess.run(
        [sys.executable, str(root / "scripts" / "lint_repro.py"),
         str(FIXTURES / "clean.py")], capture_output=True, text=True)
    assert r.returncode == 0, r.stdout + r.stderr
    r = subprocess.run(
        [sys.executable, str(root / "scripts" / "lint_repro.py"),
         str(FIXTURES / "ra005_violations.py")],
        capture_output=True, text=True)
    assert r.returncode == 1
    assert "RA005" in r.stdout
