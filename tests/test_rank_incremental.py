"""Incremental upward-rank oracle (PR 9).

``upward_rank_incremental`` reuses the previous full-graph ranks and
recomputes only dirty instances plus their ancestor closure; its output
must be BITWISE equal to ``upward_rank_array`` from scratch — the
executor's incremental re-plan rests on this (and on frontier
exactness, proven by the bitwise executor test in
``tests/test_tick_engine.py``).  Deterministic seeds, no hypothesis: the
oracle must hold in every environment CI runs.
"""
import numpy as np
import pytest

from repro.sched.heft import (_topo_order, heft_schedule_array,
                              upward_rank_array, upward_rank_incremental)


def _random_dag(rng, n):
    succ = [[] for _ in range(n)]
    pred = [[] for _ in range(n)]
    for i in range(n):
        for j in range(i + 1, n):
            if rng.random() < 0.3:
                succ[i].append(j)
                pred[j].append(i)
    return succ, pred


@pytest.mark.parametrize("seed", range(12))
def test_incremental_rank_equals_full_recompute(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 30))
    succ, pred = _random_dag(rng, n)
    cost = rng.uniform(1.0, 100.0, n)
    rank = upward_rank_array(succ, pred, cost)
    topo = _topo_order(succ, pred)
    for _ in range(5):
        cost = cost.copy()
        n_dirty = int(rng.integers(0, n + 1))
        dirty = rng.choice(n, size=n_dirty, replace=False)
        cost[dirty] = rng.uniform(1.0, 100.0, n_dirty)
        oracle = upward_rank_array(succ, pred, cost)
        rank = upward_rank_incremental(succ, pred, cost, rank, dirty,
                                       topo=topo)
        assert np.array_equal(rank, oracle)      # bitwise, not approx


def test_incremental_rank_empty_dirty_is_identity():
    rng = np.random.default_rng(99)
    succ, pred = _random_dag(rng, 15)
    cost = rng.uniform(1.0, 100.0, 15)
    rank = upward_rank_array(succ, pred, cost)
    out = upward_rank_incremental(succ, pred, cost, rank, np.array([], int))
    assert np.array_equal(out, rank)
    assert out is not rank                       # no aliasing of the cache


def test_incremental_rank_comm_term():
    # a -> b -> c chain with communication cost folded into the max
    succ, pred = [[1], [2], []], [[], [0], [1]]
    cost = np.array([5.0, 3.0, 2.0])
    full = upward_rank_array(succ, pred, cost, comm=1.5)
    inc = upward_rank_incremental(succ, pred, cost,
                                  np.zeros(3), np.arange(3), comm=1.5)
    assert np.array_equal(inc, full)
    assert full[0] == 5.0 + 1.5 + 3.0 + 1.5 + 2.0


def test_heft_schedule_array_accepts_precomputed_rank():
    rng = np.random.default_rng(5)
    succ, pred = _random_dag(rng, 12)
    cost = rng.uniform(1.0, 100.0, (12, 3))
    internal = heft_schedule_array(succ, pred, cost)
    rank = upward_rank_array(succ, pred, cost.mean(axis=1))
    external = heft_schedule_array(succ, pred, cost, rank=rank)
    assert np.array_equal(internal["order"], external["order"])
    assert np.array_equal(internal["assignment"], external["assignment"])
    assert internal["makespan"] == external["makespan"]
