"""Multi-workflow fleet (PR 9): the vmapped fused tick over padded,
stacked ``EstimatorState``s must equal per-workflow ``tick_step`` loops
cell for cell, and the single-device mesh layout must degrade to the
unsharded arrays bit-exactly.

Runs under x64 (module fixture) like the tick-engine spine: the bar is
algorithmic identity.  The hypothesis property test explores random
(task counts, batch fills, observation values) envelopes; a
deterministic twin keeps the invariant covered when hypothesis is not
installed.
"""
import jax
import numpy as np
import pytest

from repro.core import LotaruEstimator, build_state
from repro.core.profiler import BenchResult
from repro.core.tick import predict_state, tick_step
from repro.launch.mesh import make_fleet_mesh
from repro.online.fleet import (FleetState, fleet_predict, fleet_slice,
                                fleet_tick_step, pad_obs, pad_state,
                                shard_fleet, stack_states)

TOL = 1e-12


@pytest.fixture(scope="module", autouse=True)
def _x64():
    prev = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    jax.clear_caches()
    yield
    jax.config.update("jax_enable_x64", prev)
    jax.clear_caches()


NODES = ["n0", "n1", "n2"]


def _bench(name, cpu, io):
    return BenchResult(node=name, cpu_events_s=cpu, matmul_gflops=100.0,
                       mem_gbps=20.0, io_read_mbps=io, io_write_mbps=io,
                       link_gbps=0.0)


def _make_est(n_tasks, seed):
    rng = np.random.default_rng(seed)
    local = _bench("local-cpu", 450.0, 420.0)
    benches = {n: _bench(n, float(rng.uniform(500, 800)),
                         float(rng.uniform(300, 600))) for n in NODES}
    est = LotaruEstimator(local, benches, bias_correction=True,
                          bias_decay=0.97, bias_empirical_bayes=True)
    slopes = {f"t{i}": float(rng.uniform(1.0, 4.0))
              for i in range(n_tasks)}
    est.fit_tasks(list(slopes), 64.0,
                  lambda n, s, cf: slopes[n] * s / cf + 5.0,
                  n_partitions=8)
    return est


def _rand_obs(state, t_count, size, rng, batch):
    """Packed device-de-adjust observation rows for one workflow."""
    k = int(rng.integers(0, batch + 1))
    out = []
    factors = np.asarray(state.factors)
    log = state.model.stats.log
    for _ in range(k):
        r = int(rng.integers(0, t_count))
        c = int(rng.integers(0, len(NODES)))
        y_raw = float(rng.uniform(5.0, 60.0))
        # approximate local runtime feeds the host-side median history;
        # any consistent med/spr works — both sides see the same rows
        log.append(r, float(size), y_raw / max(factors[r, c], 1e-12))
        med, spr = log.median_spread(r)
        out.append([r, c, size, y_raw, 0.0, med, spr, 1.0])
    return np.asarray(out, np.float64).reshape(k, 8)


def _fleet_vs_loops(t_counts, seeds, sizes, n_ticks, rng):
    """The invariant: fleet_tick_step == per-workflow tick_step loops."""
    ests = [_make_est(t, seed=s) for t, s in zip(t_counts, seeds)]
    states = [build_state(e, NODES)[0] for e in ests]
    # an independent twin set for the per-workflow loops (tick_step
    # donates its input state; the fleet stack holds copies already)
    loop_states = [build_state(_make_est(t, seed=s), NODES)[0]
                   for t, s in zip(t_counts, seeds)]
    fleet = stack_states(states)
    batch = 3
    for _ in range(n_ticks):
        per_wf = [_rand_obs(states[i], t_counts[i], sizes[i], rng, batch)
                  for i in range(len(ests))]
        obs = np.stack([np.asarray(pad_obs(o, batch)) for o in per_wf])
        fleet, fmean, fstd = fleet_tick_step(
            fleet, obs, np.asarray(sizes, np.float64))
        for i, o in enumerate(per_wf):
            loop_states[i], m, s, _y = tick_step(
                loop_states[i], np.asarray(pad_obs(o, batch)),
                float(sizes[i]), host_deadjust=False)
            np.testing.assert_allclose(
                fleet_slice(fmean, fleet, i), np.asarray(m),
                rtol=TOL, atol=TOL)
            np.testing.assert_allclose(
                fleet_slice(fstd, fleet, i), np.asarray(s),
                rtol=TOL, atol=TOL)


def test_fleet_matches_per_workflow_loops_deterministic():
    rng = np.random.default_rng(0)
    _fleet_vs_loops(t_counts=[4, 6, 5], seeds=[10, 11, 12],
                    sizes=[32.0, 48.0, 24.0], n_ticks=4, rng=rng)


def test_fleet_matches_per_workflow_loops_property():
    hyp = pytest.importorskip("hypothesis")  # optional dev dep; skip, don't die
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=10, deadline=None)
    @given(st.lists(st.integers(min_value=2, max_value=7),
                    min_size=1, max_size=4),
           st.integers(min_value=0, max_value=2 ** 31 - 1))
    def prop(t_counts, seed):
        rng = np.random.default_rng(seed)
        seeds = [seed % 1000 + i for i in range(len(t_counts))]
        sizes = [float(rng.uniform(16.0, 64.0)) for _ in t_counts]
        _fleet_vs_loops(t_counts, seeds, sizes, n_ticks=2, rng=rng)

    prop()


def test_fleet_predict_matches_predict_state():
    ests = [_make_est(4, seed=1), _make_est(6, seed=2)]
    pairs = [build_state(e, NODES) for e in ests]
    fleet = stack_states([p[0] for p in pairs])
    sizes = np.array([32.0, 40.0])
    pm, ps = fleet_predict(fleet, sizes)
    for i, (st_i, _n) in enumerate(pairs):
        m, s = predict_state(st_i, float(sizes[i]))
        np.testing.assert_allclose(fleet_slice(pm, fleet, i),
                                   np.asarray(m), rtol=TOL, atol=TOL)
        np.testing.assert_allclose(fleet_slice(ps, fleet, i),
                                   np.asarray(s), rtol=TOL, atol=TOL)


def test_single_device_mesh_degrades_bit_exact():
    ests = [_make_est(4, seed=3), _make_est(4, seed=4)]
    fleet = stack_states([build_state(e, NODES)[0] for e in ests])
    sizes = np.array([32.0, 32.0])
    pm, ps = fleet_predict(fleet, sizes)
    mesh = make_fleet_mesh()                 # (1, 1) on one device
    assert dict(mesh.shape) == {"wf": 1, "task": 1} or \
        tuple(mesh.devices.shape) == (1, 1)
    sharded = shard_fleet(fleet, mesh)
    pm2, ps2 = fleet_predict(sharded, sizes)
    assert np.array_equal(np.asarray(pm2), np.asarray(pm))
    assert np.array_equal(np.asarray(ps2), np.asarray(ps))


def test_pad_state_real_cells_unchanged_and_validation():
    est = _make_est(3, seed=5)
    state, _names = build_state(est, NODES)
    padded = pad_state(state, 8, 5)
    assert padded.model.median.shape == (8,)
    assert padded.factors.shape == (8, 5)
    np.testing.assert_array_equal(
        np.asarray(padded.factors)[:3, :3], np.asarray(state.factors))
    np.testing.assert_array_equal(
        np.asarray(padded.model.stats.moments)[:3],
        np.asarray(state.model.stats.moments))
    assert np.all(np.asarray(padded.node_cols)[3:] == -1)
    assert np.all(np.asarray(padded.factors)[3:, :] == 1.0)
    with pytest.raises(ValueError, match="cannot shrink"):
        pad_state(state, 2, 5)


def test_stack_states_rejects_mismatched_hyperparams():
    a = _make_est(3, seed=6)
    local = _bench("local-cpu", 450.0, 420.0)
    benches = {n: _bench(n, 600.0, 500.0) for n in NODES}
    b = LotaruEstimator(local, benches, bias_correction=True,
                        bias_decay=0.5)    # different forgetting factor
    slopes = {"t0": 2.0, "t1": 3.0}
    b.fit_tasks(list(slopes), 64.0,
                lambda n, s, cf: slopes[n] * s / cf + 5.0, n_partitions=8)
    sa = build_state(a, NODES)[0]
    sb = build_state(b, NODES)[0]
    with pytest.raises(ValueError, match="StateMeta"):
        stack_states([sa, sb])


def test_shard_fleet_rejects_indivisible_axes():
    ests = [_make_est(3, seed=7) for _ in range(3)]
    fleet = stack_states([build_state(e, NODES)[0] for e in ests])
    mesh = make_fleet_mesh()
    if int(np.prod(mesh.devices.shape)) == 1:
        # a (2, 1) mesh needs 2 devices; on one device exercise the W
        # check by hand instead
        assert isinstance(fleet, FleetState)
        pytest.skip("indivisibility needs a multi-device mesh")
    with pytest.raises(ValueError, match="not divisible"):
        shard_fleet(fleet, mesh)
