"""HEFT + straggler/elastic invariants, with hypothesis over random DAGs."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev dep; skip, don't die
from hypothesis import given, settings, strategies as st

from repro.sched.heft import (SchedTask, _topo_order, heft_schedule,
                              reschedule_elastic, detect_stragglers,
                              upward_rank_array, upward_rank_incremental)


def _random_dag(rng, n_tasks, n_nodes):
    tasks = {f"t{i}": SchedTask(id=f"t{i}") for i in range(n_tasks)}
    for i in range(n_tasks):
        for j in range(i + 1, n_tasks):
            if rng.random() < 0.25:
                tasks[f"t{i}"].succ.append(f"t{j}")
                tasks[f"t{j}"].pred.append(f"t{i}")
    nodes = [f"n{k}" for k in range(n_nodes)]
    cost = {t: {n: float(rng.uniform(1, 100)) for n in nodes} for t in tasks}
    return tasks, cost, nodes


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 14), st.integers(1, 5))
def test_heft_schedule_valid(seed, n_tasks, n_nodes):
    rng = np.random.default_rng(seed)
    tasks, cost, nodes = _random_dag(rng, n_tasks, n_nodes)
    s = heft_schedule(tasks, cost, nodes)
    # every task assigned to a real node
    assert set(s["assignment"]) == set(tasks)
    assert all(n in nodes for n in s["assignment"].values())
    # dependencies respected
    for tid, t in tasks.items():
        for p in t.pred:
            assert s["start"][tid] >= s["finish"][p] - 1e-9
    # no overlap on a node
    by_node: dict = {}
    for tid, n in s["assignment"].items():
        by_node.setdefault(n, []).append((s["start"][tid], s["finish"][tid]))
    for spans in by_node.values():
        spans.sort()
        for (s1, f1), (s2, f2) in zip(spans, spans[1:]):
            assert s2 >= f1 - 1e-9
    # makespan >= longest single task's best placement
    assert s["makespan"] >= max(min(cost[t].values()) for t in tasks) - 1e-9


def test_heft_prefers_fast_node_for_serial_chain():
    tasks = {"a": SchedTask(id="a", succ=["b"]),
             "b": SchedTask(id="b", pred=["a"], succ=["c"]),
             "c": SchedTask(id="c", pred=["b"])}
    cost = {t: {"slow": 10.0, "fast": 1.0} for t in tasks}
    s = heft_schedule(tasks, cost, ["slow", "fast"])
    assert all(v == "fast" for v in s["assignment"].values())
    assert abs(s["makespan"] - 3.0) < 1e-9


def test_uncertainty_aware_avoids_risky_node():
    tasks = {"a": SchedTask(id="a")}
    cost = {"a": {"n1": 10.0, "n2": 11.0}}
    unc = {"a": {"n1": 10.0, "n2": 0.1}}
    plain = heft_schedule(tasks, cost, ["n1", "n2"])
    risky = heft_schedule(tasks, cost, ["n1", "n2"], uncertainty=unc,
                          risk_k=2.0)
    assert plain["assignment"]["a"] == "n1"
    assert risky["assignment"]["a"] == "n2"


def test_elastic_reschedule_drops_dead_nodes():
    rng = np.random.default_rng(0)
    tasks, cost, nodes = _random_dag(rng, 8, 3)
    done = {"t0", "t1"}
    s = reschedule_elastic(tasks, cost, nodes[:2], done)
    assert set(s["assignment"]) == set(tasks) - done
    assert all(n in nodes[:2] for n in s["assignment"].values())


def test_detect_stragglers_threshold():
    records = [{"id": "a", "node": "n", "duration": 10.0},
               {"id": "b", "node": "n", "duration": 30.0}]
    preds = {"a": (9.0, 1.0), "b": (9.0, 1.0)}
    out = detect_stragglers(records, preds, k=3.0)
    assert out == ["b"]


def test_straggler_copy_node_filter_not_prefix_fooled():
    """Regression: the speculative-copy node filter compared name PREFIXES,
    so "n1" excluded the distinct node "n10" (and with a third node
    present the fallback never kicked in) — the copy landed on the far
    worse "n2" instead of the eligible "n10"."""
    from repro.sched.heft import simulate_with_stragglers
    tasks = {"a": SchedTask(id="a")}
    nodes = ["n1", "n10", "n2"]
    cost = {"a": {"n1": 5.0, "n10": 6.0, "n2": 50.0}}
    preds = {"a": (10.0, 0.1)}

    def true_runtime(tid, node):
        return {"n1": 100.0, "n10": 5.0, "n2": 50.0}[node]

    r = simulate_with_stragglers(tasks, cost, nodes, true_runtime, preds,
                                 straggler_k=3.0)
    assert r["mitigated"] == 1
    # HEFT picks n1 (cheapest estimate); it straggles past the envelope
    # 10 + 3*0.1; the copy must go to n10 (cheapest OTHER node) and land
    # at 10.3 + 5 — the prefix filter would have sent it to n2 (60.3)
    assert r["makespan"] == pytest.approx(10.3 + 5.0, abs=1e-6)


def test_straggler_kill_frees_node_at_detection_time():
    """The killed original releases its node when the straggler is
    DETECTED (st + envelope), so queued work behind it starts then — not
    at the time either attempt would have finished."""
    from repro.sched.heft import simulate_with_stragglers
    tasks = {"a": SchedTask(id="a"), "b": SchedTask(id="b")}
    # two independent tasks; estimates put both on fast/0, b after a
    nodes = ["fast/0", "alt/0"]
    cost = {"a": {"fast/0": 10.0, "alt/0": 30.0},
            "b": {"fast/0": 10.0, "alt/0": 30.0}}
    preds = {"a": (10.0, 0.1), "b": (10.0, 0.1)}

    def true_runtime(tid, node):
        if tid == "a" and node == "fast/0":
            return 100.0                          # a straggles on fast/0
        return 10.0

    r = simulate_with_stragglers(tasks, cost, nodes, true_runtime, preds,
                                 straggler_k=3.0)
    assert r["mitigated"] == 1
    # a: copy on alt/0 at detection 10.3, finishes 20.3; fast/0 freed at
    # 10.3 so b runs 10.3 -> 20.3; the old min(orig_ft, alt_ft) rule
    # would have held fast/0 until 20.3 and pushed b to 30.3
    assert r["makespan"] == pytest.approx(20.3, abs=1e-6)


def _index_dag(rng, n_tasks):
    succ = [[] for _ in range(n_tasks)]
    pred = [[] for _ in range(n_tasks)]
    for i in range(n_tasks):
        for j in range(i + 1, n_tasks):
            if rng.random() < 0.25:
                succ[i].append(j)
                pred[j].append(i)
    return succ, pred


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 24),
       st.floats(0.0, 1.0), st.integers(1, 4))
def test_incremental_rank_oracle_random_dags(seed, n_tasks, dirty_frac,
                                             rounds):
    """Property twin of tests/test_rank_incremental.py: over random DAGs
    and dirty sets of every density, the incremental rank is BITWISE the
    from-scratch rank."""
    rng = np.random.default_rng(seed)
    succ, pred = _index_dag(rng, n_tasks)
    cost = rng.uniform(1.0, 100.0, n_tasks)
    rank = upward_rank_array(succ, pred, cost)
    topo = _topo_order(succ, pred)
    for _ in range(rounds):
        cost = cost.copy()
        k = int(round(dirty_frac * n_tasks))
        dirty = rng.choice(n_tasks, size=k, replace=False)
        cost[dirty] = rng.uniform(1.0, 100.0, k)
        rank = upward_rank_incremental(succ, pred, cost, rank, dirty,
                                       topo=topo)
        assert np.array_equal(rank, upward_rank_array(succ, pred, cost))
