"""Per-architecture smoke tests: reduced same-family configs, one
forward/train step on CPU, shape + finiteness asserts, and prefill/decode
consistency against the full forward pass."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import list_archs, smoke_config
from repro.launch.shapes import concrete_batch
from repro.models import AxisRules, build_model

RULES = AxisRules(fsdp_axes=(), dp_axes=())
B, T = 2, 24


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_train_step(arch):
    cfg = smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = concrete_batch(cfg, "train", B, T)
    loss, metrics = jax.jit(lambda p, b: model.loss(p, b, RULES))(params, batch)
    assert np.isfinite(float(loss)), arch
    assert float(loss) > 0
    # one SGD-flavoured step moves the loss (gradients flow everywhere)
    grads = jax.grad(lambda p: model.loss(p, batch, RULES)[0])(params)
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, arch


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_prefill_decode_shapes(arch):
    cfg = smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = concrete_batch(cfg, "prefill", B, T)
    caches = model.init_caches(B, max_len=T + 4, cross_len=T)
    logits, caches = jax.jit(lambda p, b, c: model.prefill(p, b, c, RULES))(
        params, batch, caches)
    assert logits.shape == (B, 1, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    dbatch = {"tokens": jnp.zeros((B, 1), jnp.int32)}
    if cfg.family == "vlm":
        dbatch["positions"] = jnp.full((B, 1, 3), T, jnp.int32)
    logits2, _ = jax.jit(lambda p, b, c, i: model.decode(p, b, c, i, RULES))(
        params, dbatch, caches, jnp.asarray(T, jnp.int32))
    assert logits2.shape == (B, 1, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits2, np.float32)))


@pytest.mark.parametrize("arch", ["qwen2-7b", "mamba2-1.3b", "zamba2-1.2b",
                                  "stablelm-1.6b"])
def test_decode_consistent_with_full_forward(arch):
    """Prefill T tokens then decode token T must equal running the trunk
    over the full T+1 sequence (same final-position logits)."""
    cfg = smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, T + 1), 0, cfg.vocab)

    # full pass: loss path exposes logits via prefill over T+1 with caches
    caches_full = model.init_caches(B, max_len=T + 1)
    logits_full, _ = model.prefill(params, {"tokens": tokens}, caches_full, RULES)

    # incremental: prefill T, decode 1
    caches = model.init_caches(B, max_len=T + 1)
    _, caches = model.prefill(params, {"tokens": tokens[:, :T]}, caches, RULES)
    logits_dec, _ = model.decode(params, {"tokens": tokens[:, T:]}, caches,
                                 jnp.asarray(T, jnp.int32), RULES)
    np.testing.assert_allclose(
        np.asarray(logits_full[:, -1], np.float32),
        np.asarray(logits_dec[:, -1], np.float32), atol=3e-2, rtol=3e-2)


@pytest.mark.parametrize("arch", ["zamba2-1.2b", "mamba2-1.3b"])
def test_decode_consistent_second_length(arch):
    """Regression for the zamba2 decode divergence: the decode step's
    depthwise conv ran as an fp32 einsum while prefill quantised through
    the bf16 conv kernel; the per-layer ulp drift was amplified past
    tolerance by the hybrid's shared-attention blocks.  Decode now routes
    through the same conv op.  T2+1 = 18 also lands one token past the
    smoke SSD chunk (16), exercising the chunked scan's pad path + carry."""
    T2 = 17
    cfg = smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(3))
    tokens = jax.random.randint(jax.random.PRNGKey(4), (B, T2 + 1), 0,
                                cfg.vocab)
    caches_full = model.init_caches(B, max_len=T2 + 1)
    logits_full, _ = model.prefill(params, {"tokens": tokens}, caches_full,
                                   RULES)
    caches = model.init_caches(B, max_len=T2 + 1)
    _, caches = model.prefill(params, {"tokens": tokens[:, :T2]}, caches,
                              RULES)
    logits_dec, _ = model.decode(params, {"tokens": tokens[:, T2:]}, caches,
                                 jnp.asarray(T2, jnp.int32), RULES)
    np.testing.assert_allclose(
        np.asarray(logits_full[:, -1], np.float32),
        np.asarray(logits_dec[:, -1], np.float32), atol=3e-2, rtol=3e-2)


def test_param_counts_match_published_sizes():
    """Analytic param counts of the full configs land near the published
    model sizes (sanity for roofline MODEL_FLOPS)."""
    from repro.configs import get_config
    expect = {
        "qwen2-7b": (7.6e9, 0.15),
        "stablelm-12b": (12.1e9, 0.15),
        "starcoder2-15b": (16e9, 0.15),
        "stablelm-1.6b": (1.6e9, 0.25),
        "llama4-maverick-400b-a17b": (400e9, 0.15),
        "qwen3-moe-30b-a3b": (30e9, 0.15),
        "mamba2-1.3b": (1.3e9, 0.35),
        "zamba2-1.2b": (1.2e9, 0.45),
    }
    for arch, (n, tol) in expect.items():
        got = get_config(arch).param_count()
        assert abs(got - n) / n < tol, (arch, got, n)


def test_llama4_active_params():
    from repro.configs import get_config
    cfg = get_config("llama4-maverick-400b-a17b")
    active = cfg.active_param_count()
    assert 10e9 < active < 25e9, active   # ~17B active
