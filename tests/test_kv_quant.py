"""int8 KV cache: roundtrip error bounds + attention-quality preservation."""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")  # optional dev dep; skip, don't die
from hypothesis import given, settings, strategies as st

from repro.models.kv_quant import (append_quant_cache,
                                   attention_over_quant_cache,
                                   dequantize_kv, init_quant_cache,
                                   quantize_kv)
from repro.models.layers import chunked_attention


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.floats(0.01, 100.0))
def test_quant_roundtrip_bounded(seed, scale):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, scale, (2, 8, 4, 32)), jnp.float32)
    q, s = quantize_kv(x)
    deq = dequantize_kv(q, s, jnp.float32)
    # absmax int8: error <= scale/2 = absmax/254 per row
    row_max = np.max(np.abs(np.asarray(x)), axis=-1, keepdims=True)
    err = np.abs(np.asarray(deq) - np.asarray(x))
    assert np.all(err <= row_max / 254.0 + 1e-7)


def test_quant_cache_attention_close_to_fp():
    rng = np.random.default_rng(0)
    B, Hq, Hkv, D, T = 2, 4, 2, 32, 64
    q = jnp.asarray(rng.normal(0, 1, (B, 1, Hq, D)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, T, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, T, Hkv, D)), jnp.float32)
    cache = init_quant_cache(B, T + 8, Hkv, D)
    cache = append_quant_cache(cache, k, v, 0)
    out_q = attention_over_quant_cache(q, cache, kv_len=T, chunk=16)
    out_f = chunked_attention(q, k, v, causal=False, chunk=16)
    err = float(jnp.max(jnp.abs(out_q - out_f)))
    assert err < 0.05, err                 # int8 KV keeps decode quality


def test_quant_cache_incremental_append():
    rng = np.random.default_rng(1)
    B, Hkv, D, T = 1, 2, 16, 12
    k = jnp.asarray(rng.normal(0, 1, (B, T, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, T, Hkv, D)), jnp.float32)
    all_at_once = append_quant_cache(init_quant_cache(B, T, Hkv, D), k, v, 0)
    step_by_step = init_quant_cache(B, T, Hkv, D)
    for t in range(T):
        step_by_step = append_quant_cache(step_by_step, k[:, t:t+1],
                                          v[:, t:t+1], t)
    for key in all_at_once:
        np.testing.assert_array_equal(np.asarray(all_at_once[key]),
                                      np.asarray(step_by_step[key]))


def test_memory_footprint_quarter():
    B, T, H, D = 1, 1024, 4, 128
    fp = B * T * H * D * 2 * 2                        # bf16 k+v
    c = init_quant_cache(B, T, H, D)
    q8 = sum(np.asarray(v).nbytes for v in c.values())
    assert q8 < fp * 0.6                              # int8 + scales
