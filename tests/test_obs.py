"""Observability plane: tracer no-op bit-exactness, event emission,
JSONL / Chrome export, ExecutionTrace schema round-trip, running-median
equivalence, calibration diagnostics and latency profiling."""
import json
import math

import numpy as np
import pytest

from repro.obs import (EVENT_KINDS, Event, EventLog, MetricsRegistry,
                       NULL_TRACER, RunningMedian, calibration_summary,
                       chrome_trace_events, load_jsonl, phase_breakdown,
                       pit_uniformity, render_report, report_dict,
                       running_median, tick_latency_summary)
from repro.online.buffer import ObservationBuffer
from repro.online.executor import ExecutionTrace, TaskRun
from repro.sched.simulator import FaultInjector

from tests.test_faults import _scenario


def _faulty(tracer=None, **kw):
    fi = FaultInjector(p_fail=0.15, seed=3,
                       outages={"tpu-v2/0": (20.0, 120.0)})
    return _scenario(online=True, faults=fi, rel_k=0.5, strict=False,
                     tracer=tracer, noise_seed=7, slow=2.5,
                     spec_tail=0.8, **kw)


# ---------------------------------------------------------------------------
# tracing is read-only: attaching a tracer never perturbs the loop
# ---------------------------------------------------------------------------
def test_tracer_disabled_is_bit_exact():
    """The PR 5 contract, extended: the executor's full output — every
    counter, record, censored run and observation, via ``to_dict`` — is
    bit-identical whether no tracer, the NULL_TRACER, or a collecting
    ``EventLog`` is attached.  Tracing observes; it never steers."""
    base = _faulty(tracer=None).run().to_dict()
    for tracer in (NULL_TRACER, EventLog()):
        got = _faulty(tracer=tracer).run().to_dict()
        assert json.dumps(got, sort_keys=True) == \
            json.dumps(base, sort_keys=True)


def test_tracer_bit_exact_fault_free():
    a = _scenario(online=True, tracer=None).run().to_dict()
    b = _scenario(online=True, tracer=EventLog()).run().to_dict()
    assert json.dumps(a, sort_keys=True) == json.dumps(b, sort_keys=True)


# ---------------------------------------------------------------------------
# event emission: the loop's lifecycle lands in the log, typed
# ---------------------------------------------------------------------------
def test_traced_run_emits_lifecycle_events():
    log = EventLog()
    trace = _faulty(tracer=log).run()
    c = log.counters()
    assert c["run_start"] == 1 and c["run_end"] == 1
    # every emitted kind is in the closed taxonomy
    assert {e.kind for e in log.events} <= EVENT_KINDS
    # one observe per completion, coverage flag consistent with the
    # trace's surprise counter, PIT in [0, 1]
    obs = log.filter("observe")
    assert len(obs) == trace.completed
    assert sum(not e.data["covered"] for e in obs) == trace.surprises
    assert c.get("surprise", 0) == trace.surprises
    for e in obs:
        assert 0.0 <= e.data["pit"] <= 1.0
        assert e.data["lo"] <= e.data["hi"]
    # fault machinery shows up under injected churn
    assert c["fault"] == trace.failures
    assert c["retry"] == trace.retries
    assert c["speculation"] == trace.speculations
    assert c["finish"] == trace.completed
    assert c.get("node_down", 0) >= 1 and c.get("node_up", 0) >= 1
    # estimator + plan spans were recorded
    assert log.spans("predict_matrix") and log.spans("plan")
    assert log.spans("update_stream") and log.spans("bias_update")
    # sim clock on events is monotone within the heap's pop order
    ticks = [e.t_sim for e in log.filter("tick")]
    assert all(a <= b + 1e-9 for a, b in zip(ticks, ticks[1:]))


def test_unknown_event_kind_warns_not_raises():
    log = EventLog()
    with pytest.warns(UserWarning, match="unknown trace event kind"):
        log.emit("not_a_kind", t_sim=1.0)
    assert len(log.events) == 1     # still recorded


# ---------------------------------------------------------------------------
# export: JSONL round-trip and Chrome trace_event shape
# ---------------------------------------------------------------------------
def test_jsonl_round_trip(tmp_path):
    log = EventLog()
    _faulty(tracer=log).run()
    p = log.to_jsonl(tmp_path / "t.jsonl")
    header = json.loads(p.read_text().splitlines()[0])
    assert header["trace_format"] == 1
    assert header["events"] == len(log.events)
    back = load_jsonl(p)
    assert back == log.events


def test_jsonl_rejects_newer_format(tmp_path):
    p = tmp_path / "future.jsonl"
    p.write_text(json.dumps({"trace_format": 99, "events": 0}) + "\n")
    with pytest.raises(ValueError, match="newer"):
        load_jsonl(p)


def test_chrome_trace_shape(tmp_path):
    log = EventLog()
    trace = _faulty(tracer=log).run()
    p = log.to_chrome(tmp_path / "t.chrome.json")
    doc = json.loads(p.read_text())
    evs = doc["traceEvents"]
    assert doc["displayTimeUnit"] == "ms"
    assert all({"ph", "pid"} <= set(e) for e in evs)
    # every finish is a sim-clock duration slice whose length is the
    # realised runtime (in microseconds)
    slices = [e for e in evs if e["ph"] == "X" and e["pid"] == 2]
    assert len(slices) == trace.completed
    for s in slices:
        assert s["dur"] == pytest.approx(s["args"]["runtime"] * 1e6)
    # both processes and their thread lanes are named
    meta = [e for e in evs if e["ph"] == "M"]
    assert {m["pid"] for m in meta} == {1, 2}


# ---------------------------------------------------------------------------
# ExecutionTrace / ObservationBuffer: versioned schema round-trip
# ---------------------------------------------------------------------------
def test_execution_trace_dict_round_trip():
    trace = _faulty(tracer=None).run()
    d = json.loads(json.dumps(trace.to_dict()))   # through real JSON
    back = ExecutionTrace.from_dict(d)
    assert back.to_dict() == trace.to_dict()
    assert back.records == trace.records
    assert back.censored == trace.censored
    assert list(back.observations) == list(trace.observations)
    np.testing.assert_allclose(back.cumulative_mpe(),
                               trace.cumulative_mpe())


def test_execution_trace_rejects_newer_schema():
    trace = _scenario(online=True).run()
    d = trace.to_dict()
    d["version"] = 99
    with pytest.raises(ValueError, match="newer"):
        ExecutionTrace.from_dict(d)


def test_observation_buffer_round_trip():
    buf = ObservationBuffer()
    buf.record("t0", "tpu-v2", 32.0, 5.0, 4.2, time=1.5)
    buf.record("t1", "tpu-v3", 32.0, 7.0, 6.1, time=2.5)
    back = ObservationBuffer.from_dict(
        json.loads(json.dumps(buf.to_dict())))
    assert list(back) == list(buf)


# ---------------------------------------------------------------------------
# running median: O(log n) two-heap == naive prefix re-median
# ---------------------------------------------------------------------------
def test_running_median_matches_numpy():
    rng = np.random.default_rng(0)
    for n in (1, 2, 3, 10, 101):
        xs = rng.normal(size=n)
        naive = np.array([np.median(xs[:k + 1]) for k in range(n)])
        np.testing.assert_array_equal(running_median(xs), naive)
    # duplicates and integer plateaus hit the heap rebalance edges
    xs = np.array([3.0, 3.0, 3.0, 1.0, 1.0, 5.0, 5.0, 3.0])
    naive = np.array([np.median(xs[:k + 1]) for k in range(len(xs))])
    np.testing.assert_array_equal(running_median(xs), naive)


def test_running_median_empty_raises():
    with pytest.raises(ValueError):
        RunningMedian().median()
    assert running_median([]).size == 0


def test_cumulative_mpe_incremental_equals_naive():
    """The satellite fix: ``cumulative_mpe`` used to recompute
    ``np.median`` over every prefix (O(n²)); the two-heap running median
    must reproduce it exactly."""
    rng = np.random.default_rng(7)
    records = [TaskRun(id=f"s{i}", name="t", node="n0", node_type="nt",
                       start=0.0, end=1.0,
                       runtime=float(rng.uniform(1.0, 10.0)),
                       pred_mean=float(rng.uniform(1.0, 10.0)),
                       pred_std=1.0)
               for i in range(73)]
    trace = ExecutionTrace(records=records)
    errs = trace.errors()
    naive = np.array([np.median(errs[:k + 1]) for k in range(len(errs))])
    np.testing.assert_array_equal(trace.cumulative_mpe(), naive)


# ---------------------------------------------------------------------------
# calibration diagnostics
# ---------------------------------------------------------------------------
def _obs_event(runtime, lo, hi, pit, pred_mean=1.0):
    return Event(kind="observe", t_sim=0.0, t_wall=0.0,
                 data={"runtime": runtime, "lo": lo, "hi": hi,
                       "covered": lo <= runtime <= hi, "pit": pit,
                       "pred_mean": pred_mean})


def test_calibration_summary_synthetic():
    # 8 covered + 2 not, uniform-ish PITs, unit widths
    events = [_obs_event(0.5 if i < 8 else 2.0, 0.0, 1.0,
                         (i + 0.5) / 10.0) for i in range(10)]
    s = calibration_summary(events, min_obs=0, bins=10)
    assert s["n_obs"] == 10 and s["n_post_warmup"] == 10
    assert s["coverage"] == pytest.approx(0.8)
    assert s["sharpness"] == pytest.approx(1.0)
    assert s["pit_tv"] == pytest.approx(0.0)     # exactly one PIT per bin
    assert s["coverage_timeline_first_last"] == [1.0, 0.8]


def test_calibration_warm_up_exclusion():
    # warm-up half all missed, second half all covered
    events = ([_obs_event(5.0, 0.0, 1.0, 0.99) for _ in range(10)]
              + [_obs_event(0.5, 0.0, 1.0, 0.5) for _ in range(10)])
    s = calibration_summary(events, min_obs=10)
    assert s["coverage_all"] == pytest.approx(0.5)
    assert s["coverage"] == pytest.approx(1.0)   # warm-up excluded
    short = calibration_summary(events[:5], min_obs=10)
    assert short["n_post_warmup"] == 0
    assert math.isnan(short["coverage"])


def test_pit_uniformity_extremes():
    assert pit_uniformity((np.arange(100) + 0.5) / 100.0) == 0.0
    assert pit_uniformity(np.full(100, 0.5)) == pytest.approx(0.9)


def test_predict_pit_node_matches_interval():
    """PIT and interval come from the same predictive distribution: the
    PIT of each interval endpoint must be the corresponding quantile."""
    from tests.test_faults import _make_est
    est, chain = _make_est()
    conf = 0.2
    for task in chain:
        lo, hi = est.predict_interval_node(task, "tpu-v2", 32.0, conf)
        plo = est.predict_pit_node(task, "tpu-v2", 32.0, lo)
        phi = est.predict_pit_node(task, "tpu-v2", 32.0, hi)
        assert plo == pytest.approx((1 - conf) / 2, abs=1e-6)
        assert phi == pytest.approx(1 - (1 - conf) / 2, abs=1e-6)
        # monotone in the runtime
        assert (est.predict_pit_node(task, "tpu-v2", 32.0, lo * 0.5)
                < plo < phi
                < est.predict_pit_node(task, "tpu-v2", 32.0, hi * 2.0))


# ---------------------------------------------------------------------------
# latency profiling: first-call (compile) vs steady state
# ---------------------------------------------------------------------------
def _span(phase, dur, t_wall=0.0):
    return Event(kind="span", t_sim=0.0, t_wall=t_wall,
                 data={"phase": phase, "dur_s": dur})


def test_phase_breakdown_splits_compile():
    events = [_span("predict", 1.0, 0.0), _span("predict", 0.1, 1.0),
              _span("predict", 0.3, 2.0), _span("plan", 0.05, 3.0)]
    pb = phase_breakdown(events)
    assert pb["predict"]["count"] == 3
    assert pb["predict"]["first_s"] == pytest.approx(1.0)
    assert pb["predict"]["steady_mean_s"] == pytest.approx(0.2)
    assert pb["predict"]["steady_max_s"] == pytest.approx(0.3)
    assert pb["predict"]["total_s"] == pytest.approx(1.4)
    assert pb["plan"]["count"] == 1
    assert math.isnan(pb["plan"]["steady_mean_s"])   # no steady sample yet
    s = tick_latency_summary(events)
    assert s["compile_total_s"] == pytest.approx(1.05)
    assert s["traced_total_s"] == pytest.approx(1.45)


def test_profiling_on_real_trace():
    log = EventLog()
    _scenario(online=True, tracer=log).run()
    s = tick_latency_summary(log.events)
    assert set(s["phases"]) >= {"predict_matrix", "update_stream",
                                "bias_update"}
    assert 0.0 < s["compile_frac"] <= 1.0
    pm = s["phases"]["predict_matrix"]
    # the first/steady split is present and self-consistent (whether the
    # first call actually compiled depends on the process's jit cache —
    # under `pytest -x` earlier tests may already have warmed it)
    assert pm["first_s"] > 0.0 and pm["count"] >= 2
    assert pm["steady_p50_s"] <= pm["steady_max_s"]
    assert s["traced_total_s"] >= s["compile_total_s"]


# ---------------------------------------------------------------------------
# registry + report
# ---------------------------------------------------------------------------
def test_metrics_registry_from_events():
    log = EventLog()
    _faulty(tracer=log).run()
    m = MetricsRegistry.from_events(log.events).to_dict()
    assert m["counters"]["events.observe"] == len(log.filter("observe"))
    assert any(k.startswith("span_s.") for k in m["histograms"])
    assert any(k.startswith("final.") for k in m["gauges"])


def test_report_renders(tmp_path):
    log = EventLog()
    _faulty(tracer=log).run()
    text = render_report(log.events, min_obs=5)
    for needle in ("TRACE REPORT", "calibration", "coverage",
                   "PIT histogram", "latency", "fault / retry"):
        assert needle in text
    d = json.loads(json.dumps(report_dict(log.events, min_obs=5),
                              default=float))
    assert {"metrics", "calibration", "latency",
            "slowest_spans", "fault_narrative"} <= set(d)


def test_report_trace_cli(tmp_path):
    import subprocess
    import sys
    from pathlib import Path
    log = EventLog()
    _scenario(online=True, tracer=log).run()
    p = log.to_jsonl(tmp_path / "t.jsonl")
    out_json = tmp_path / "report.json"
    repo = Path(__file__).resolve().parents[1]
    r = subprocess.run(
        [sys.executable, str(repo / "scripts" / "report_trace.py"),
         str(p), "--json", str(out_json), "--min-obs", "5"],
        capture_output=True, text=True, cwd=repo)
    assert r.returncode == 0, r.stderr
    assert "TRACE REPORT" in r.stdout
    assert "t.jsonl" in json.loads(out_json.read_text())
