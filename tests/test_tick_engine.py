"""Equivalence spine of the fused tick (PR 9).

The array-native tick engine (``repro.core.tick``) must be a pure
refactor of the legacy observe → update → bias scatter → re-predict
sequence: every number the executor consumes (estimate matrices,
surprise intervals, PIT values, bias points, writeback posteriors) has
to match the OO path to <= 1e-12.  These tests run under x64 — the bar
is algorithmic identity, not float32 noise — via a module fixture that
flips ``jax_enable_x64`` and clears every jit cache on both edges.

The executor-level spine drives all five paper workflows, faults off
AND on, through a fused and a legacy executor built from identical
seeds, and requires the full trace signatures (assignment, start/end,
dispatch-time predictions, replan/surprise counters) to agree.
"""
import jax
import numpy as np
import pytest

from repro.core import (LotaruEstimator, build_state, get_node,
                        profile_cluster, profile_node, target_nodes)
from repro.core.tick import TickEngine, predict_state, tick_step
from repro.online import OnlineExecutor, fanout_chain_dag
from repro.sched.simulator import (ClusterSimulator, FaultInjector,
                                   GridEngine)
from repro.sched.workflows import INPUTS, WORKFLOWS

TOL = 1e-12


@pytest.fixture(scope="module", autouse=True)
def _x64():
    prev = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    jax.clear_caches()
    yield
    jax.config.update("jax_enable_x64", prev)
    jax.clear_caches()


@pytest.fixture(scope="module")
def cluster():
    local = get_node("local-cpu")
    local_bench = profile_node(local, np.random.default_rng(7))
    tbenches = profile_cluster(target_nodes(), seed=13)
    return local, local_bench, tbenches


def _fitted(cluster, wf: str, size: float, *, seed=0):
    local, local_bench, tbenches = cluster
    by_name = {t.name: t for t in WORKFLOWS[wf]}
    sim = ClusterSimulator(seed=seed)
    est = LotaruEstimator(local_bench, tbenches, bias_correction=True,
                          bias_empirical_bayes=True)
    est.fit_tasks(list(by_name), size,
                  lambda n, s, cf: sim.run_task(by_name[n], local, s,
                                                cpu_factor=cf))
    return est, by_name


# ---------------------------------------------------------------------------
# tick-level: TickEngine vs the legacy estimator, one surface at a time
# ---------------------------------------------------------------------------
def test_tick_engine_matches_legacy_observe_batch(cluster):
    wf, size = "eager", INPUTS[("eager", 1)]
    est_a, by_name = _fitted(cluster, wf, size)
    est_b, _ = _fitted(cluster, wf, size)
    nodes = [nt.name for nt in target_nodes()]
    engine = TickEngine(est_b, nodes, size=size)

    m0a, s0a = est_a.predict_matrix(nodes, size)
    m0b, s0b = engine.predict_matrix(nodes, size)
    np.testing.assert_allclose(m0b, m0a, rtol=TOL, atol=TOL)
    np.testing.assert_allclose(s0b, s0a, rtol=TOL, atol=TOL)

    rng = np.random.default_rng(3)
    names = list(by_name)
    for _ in range(6):
        k = int(rng.integers(1, 4))
        batch = [(names[int(rng.integers(0, len(names)))],
                  nodes[int(rng.integers(0, len(nodes)))],
                  size, float(rng.uniform(5.0, 80.0)))
                 for _ in range(k)]
        ys_a = est_a.observe_batch(batch)
        ys_b = engine.observe_batch(batch)
        np.testing.assert_allclose(ys_b, ys_a, rtol=TOL, atol=TOL)
        ma, sa = est_a.predict_matrix(nodes, size)
        mb, sb = engine.predict_matrix(nodes, size)
        np.testing.assert_allclose(mb, ma, rtol=TOL, atol=TOL)
        np.testing.assert_allclose(sb, sa, rtol=TOL, atol=TOL)
        for name in names[:3]:
            for nt in nodes[:2]:
                lo_a, hi_a = est_a.predict_interval_node(name, nt, size, 0.9)
                lo_b, hi_b = engine.predict_interval_node(name, nt, size, 0.9)
                assert lo_b == pytest.approx(lo_a, rel=TOL, abs=TOL)
                assert hi_b == pytest.approx(hi_a, rel=TOL, abs=TOL)
                pit_a = est_a.predict_pit_node(name, nt, size, 30.0)
                pit_b = engine.predict_pit_node(name, nt, size, 30.0)
                assert pit_b == pytest.approx(pit_a, rel=TOL, abs=TOL)
                assert engine.bias_point(name, nt) == pytest.approx(
                    est_a.bias_point(name, nt), rel=TOL, abs=TOL)

    # finalize folds the device state back: the OO surface continues
    engine.finalize()
    for name in names:
        for nt in nodes:
            pa = est_a.predict(name, nt, size)
            pb = est_b.predict(name, nt, size)
            np.testing.assert_allclose(pb, pa, rtol=TOL, atol=TOL)


def test_tick_step_donates_and_predict_state_matches(cluster):
    wf, size = "bacass", INPUTS[("bacass", 1)]
    est, _ = _fitted(cluster, wf, size)
    nodes = [nt.name for nt in target_nodes()]
    state, _names = build_state(est, nodes)
    m0, s0 = predict_state(state, size)
    m1, s1 = est.predict_matrix(nodes, size)
    np.testing.assert_allclose(np.asarray(m0), m1, rtol=TOL, atol=TOL)
    np.testing.assert_allclose(np.asarray(s0), s1, rtol=TOL, atol=TOL)
    before = np.asarray(state.model.stats.moments).copy()
    obs = np.zeros((2, 8))
    obs[0] = [0, 0, size, 25.0, 25.0, 25.0, 1.0, 1.0]
    obs[1] = [1, 1, size, 40.0, 40.0, 40.0, 1.0, 0.0]   # masked row
    new_state, mean, std, y = tick_step(
        state, np.asarray(obs), size, host_deadjust=True)
    assert np.all(np.isfinite(np.asarray(mean)))
    # donation: the input state's buffers are consumed
    with pytest.raises((RuntimeError, ValueError)):
        jax.block_until_ready(state.model.stats.moments) + 0
    after = np.asarray(new_state.model.stats.moments)
    assert not np.array_equal(after[0], before[0])   # live row absorbed
    assert np.array_equal(after[1], before[1])       # masked row untouched


# ---------------------------------------------------------------------------
# executor-level: full traces agree on all five workflows, faults on/off
# ---------------------------------------------------------------------------
def _trace_sig(trace):
    recs = sorted((r.id, r.node, r.start, r.end, r.pred_mean, r.pred_std)
                  for r in trace.records)
    return recs, (trace.makespan, trace.replans, trace.surprises,
                  trace.completed, trace.failures, trace.retries)


def _run_workflow(cluster, wf, *, fused, with_faults, n_samples=2,
                  nodes_per_type=2, seed=0):
    local, local_bench, tbenches = cluster
    size = INPUTS[(wf, 1)]
    by_name = {t.name: t for t in WORKFLOWS[wf]}
    tasks, task_name = fanout_chain_dag(list(by_name), n_samples)
    truth = ClusterSimulator(seed=seed + 2000)
    truth_tab = {(tid, nt.name): truth.run_task(by_name[task_name[tid]],
                                                nt, size)
                 for tid in tasks for nt in target_nodes()}
    est, _ = _fitted(cluster, wf, size, seed=seed)
    grid = GridEngine.from_types(nodes_per_type=nodes_per_type)
    faults = (FaultInjector(p_fail=0.08, seed=seed + 31)
              if with_faults else None)
    ex = OnlineExecutor(
        est, tasks, task_name, size, grid,
        lambda tid, node: truth_tab[(tid, grid.type_of(node).name)],
        online=True, confidence=0.9, risk_k=0.5, spec_tail=0.6,
        faults=faults, rel_k=1.0 if with_faults else None,
        max_attempts=6, strict=False, fused=fused,
        incremental_replan=fused if fused else False)
    return ex.run()


@pytest.mark.parametrize("wf", list(WORKFLOWS))
@pytest.mark.parametrize("with_faults", [False, True])
def test_fused_executor_matches_legacy(cluster, wf, with_faults):
    legacy = _run_workflow(cluster, wf, fused=False,
                           with_faults=with_faults)
    jax.clear_caches()
    fused = _run_workflow(cluster, wf, fused=True, with_faults=with_faults)
    jax.clear_caches()
    recs_l, tail_l = _trace_sig(legacy)
    recs_f, tail_f = _trace_sig(fused)
    assert tail_f[1:] == tail_l[1:]          # counters identical
    assert tail_f[0] == pytest.approx(tail_l[0], rel=TOL, abs=TOL)
    assert len(recs_f) == len(recs_l)
    for a, b in zip(recs_l, recs_f):
        assert b[:2] == a[:2]                # same task -> node assignment
        np.testing.assert_allclose(b[2:], a[2:], rtol=TOL, atol=TOL)


def test_incremental_replan_alone_is_bitwise(cluster):
    base = _run_workflow(cluster, "methylseq", fused=False,
                         with_faults=False)
    jax.clear_caches()
    # incremental rank reuse without the fused engine: same estimator
    # path, so the traces must be BITWISE equal, not just 1e-12-close
    local, local_bench, tbenches = cluster
    wf, size = "methylseq", INPUTS[("methylseq", 1)]
    by_name = {t.name: t for t in WORKFLOWS[wf]}
    tasks, task_name = fanout_chain_dag(list(by_name), 2)
    truth = ClusterSimulator(seed=2000)
    truth_tab = {(tid, nt.name): truth.run_task(by_name[task_name[tid]],
                                                nt, size)
                 for tid in tasks for nt in target_nodes()}
    est, _ = _fitted(cluster, wf, size)
    grid = GridEngine.from_types(nodes_per_type=2)
    inc = OnlineExecutor(
        est, tasks, task_name, size, grid,
        lambda tid, node: truth_tab[(tid, grid.type_of(node).name)],
        online=True, confidence=0.9, risk_k=0.5, spec_tail=0.6,
        max_attempts=6, strict=False, incremental_replan=True).run()
    assert _trace_sig(inc) == _trace_sig(base)
