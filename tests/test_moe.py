"""MoE dispatch invariants (hypothesis over shapes/routing seeds)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")  # optional dev dep; skip, don't die
from hypothesis import given, settings, strategies as st

from repro.models import ModelConfig, MoEConfig, AxisRules
from repro.models.moe import apply_moe, moe_def, _capacity
from repro.models.common import tree_defs_init

RULES = AxisRules(fsdp_axes=(), dp_axes=())


def _cfg(E=8, K=2, cf=1.25):
    return ModelConfig(arch="t", family="moe", n_layers=1, d_model=32,
                       n_heads=4, n_kv_heads=4, d_ff=32, vocab=64,
                       head_dim=8,
                       moe=MoEConfig(n_experts=E, top_k=K, d_ff_expert=32,
                                     capacity_factor=cf))


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 1000), st.sampled_from([(8, 1), (8, 2), (16, 4)]))
def test_moe_output_finite_and_shaped(seed, ek):
    E, K = ek
    cfg = _cfg(E, K)
    params = tree_defs_init(moe_def(cfg), jax.random.PRNGKey(seed))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, 16, 32))
    out, aux = apply_moe(params, x, cfg, RULES)
    assert out.shape == x.shape
    assert np.all(np.isfinite(np.asarray(out, np.float32)))
    assert float(aux) >= 0.99  # Switch aux >= 1 at balance, >=~1 generally


def test_capacity_formula():
    cfg = _cfg(E=128, K=8, cf=1.25)
    c = _capacity(32768, cfg)
    assert c == 2560                     # 32768*8*1.25/128
    assert _capacity(4, cfg) == 8        # floor at 8


def test_moe_huge_capacity_equals_dense_mixture():
    """With capacity >> tokens (no drops), MoE output equals the explicit
    gate-weighted mixture of expert MLPs."""
    cfg = _cfg(E=4, K=4, cf=64.0)        # route to ALL experts, no drops
    params = tree_defs_init(moe_def(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 32))
    out, _ = apply_moe(params, x, cfg, RULES)

    logits = jnp.einsum("btd,de->bte", x, params["router"])
    gates = jax.nn.softmax(logits, axis=-1)
    dense = jnp.zeros_like(x)
    for e in range(4):
        g = jnp.einsum("btd,df->btf", x, params["wg"][e])
        u = jnp.einsum("btd,df->btf", x, params["wu"][e])
        y = jnp.einsum("btf,fd->btd", jax.nn.silu(g) * u, params["wd"][e])
        dense = dense + gates[..., e:e+1] * y
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(dense, np.float32),
                               atol=5e-2, rtol=5e-2)


def test_moe_gradients_reach_all_params():
    cfg = _cfg()
    params = tree_defs_init(moe_def(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32))

    def loss(p):
        out, aux = apply_moe(p, x, cfg, RULES)
        return jnp.mean(out ** 2) + 0.01 * aux

    g = jax.grad(loss)(params)
    for path, leaf in jax.tree_util.tree_flatten_with_path(g)[0]:
        assert float(jnp.sum(jnp.abs(leaf))) > 0, path
