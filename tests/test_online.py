"""Online estimation subsystem: incremental update equivalence, estimator
feedback (de-adjustment + row-level cache invalidation), HEFT re-planning
floors, and the event-driven executor loop."""
import numpy as np
import pytest

from repro.core import blr
from repro.core.estimator import FittedTask, LotaruEstimator, LotaruML
from repro.core.profiler import BenchResult
from repro.online import ObservationBuffer, OnlineExecutor, fanout_chain_dag
from repro.sched.heft import heft_schedule_array
from repro.sched.simulator import GridEngine
from repro.core.nodes import get_node, target_nodes

RTOL = 5e-4   # float32 default; bench_online observes ~1e-15 under x64


def _tasks(seed=0, n=6):
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        m = int(rng.integers(3, 9))
        xs = np.sort(rng.uniform(1, 100, m))
        if i % 3 != 2:
            ys = (i + 1) * xs + 10 + rng.normal(0, 0.1, m)
        else:
            ys = 50 + rng.normal(0, 0.5, m)
        out.append((xs, np.abs(ys)))
    return out


def _stream(tasks, seed=1, per_task=4):
    rng = np.random.default_rng(seed)
    extra = []
    for i in range(len(tasks)):
        for _ in range(per_task):
            x = float(rng.uniform(1, 200))
            y = (i + 1) * x + 10 if i % 3 != 2 else 50.0
            extra.append((i, x, y))
    rng.shuffle(extra)
    return extra


def _assert_models_close(a, b, xqs=(5.0, 50.0, 150.0)):
    assert np.array_equal(np.asarray(a.correlated), np.asarray(b.correlated))
    for xq in xqs:
        ma, sa = blr.predict_task_batch(a, xq)
        mb, sb = blr.predict_task_batch(b, xq)
        np.testing.assert_allclose(np.asarray(ma), np.asarray(mb),
                                   rtol=RTOL, atol=1e-5)
        np.testing.assert_allclose(np.asarray(sa), np.asarray(sb),
                                   rtol=RTOL, atol=1e-5)
    np.testing.assert_allclose(np.asarray(a.median), np.asarray(b.median),
                               rtol=RTOL, atol=1e-5)
    np.testing.assert_allclose(np.asarray(a.spread), np.asarray(b.spread),
                               rtol=RTOL, atol=1e-5)


def test_incremental_update_matches_concat_refit():
    tasks = _tasks()
    model = blr.fit_task_batch([t[0] for t in tasks], [t[1] for t in tasks])
    extra = _stream(tasks)
    for i, x, y in extra:
        model = blr.update_task_batch(model, i, x, y)
    concat = [(np.concatenate([tasks[i][0],
                               [e[1] for e in extra if e[0] == i]]),
               np.concatenate([tasks[i][1],
                               [e[2] for e in extra if e[0] == i]]))
              for i in range(len(tasks))]
    refit = blr.fit_task_batch([c[0] for c in concat],
                               [c[1] for c in concat])
    _assert_models_close(model, refit)


def test_stream_scan_matches_sequential_updates():
    tasks = _tasks(seed=3)
    extra = _stream(tasks, seed=4)
    # two fresh fits: update_task_batch consumes its input (the raw-sample
    # log is shared and mutated in place), so the paths must not alias
    seq = blr.fit_task_batch([t[0] for t in tasks], [t[1] for t in tasks])
    for i, x, y in extra:
        seq = blr.update_task_batch(seq, i, x, y)
    fresh = blr.fit_task_batch([t[0] for t in tasks], [t[1] for t in tasks])
    scan = blr.update_task_batch_stream(
        fresh, [e[0] for e in extra], [e[1] for e in extra],
        [e[2] for e in extra])
    ms, ss = blr.predict_task_batch(scan, 42.0)
    mq, sq = blr.predict_task_batch(seq, 42.0)
    np.testing.assert_allclose(np.asarray(ms), np.asarray(mq), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(ss), np.asarray(sq), rtol=1e-6)


def test_update_grows_buffer_capacity():
    tasks = _tasks(seed=5, n=3)
    model = blr.fit_task_batch([t[0] for t in tasks], [t[1] for t in tasks])
    cap0 = model.stats.log.x.shape[1]
    n_extra = 3 * cap0
    for k in range(n_extra):
        model = blr.update_task_batch(model, 0, 10.0 + k, 25.0 + 2.5 * k)
    assert model.stats.log.x.shape[1] > cap0
    assert int(model.stats.log.count[0]) == len(tasks[0][0]) + n_extra
    assert float(model.stats.n[0]) == len(tasks[0][0]) + n_extra
    # the grown model still matches a refit on the concatenated data
    xs = np.concatenate([tasks[0][0], 10.0 + np.arange(n_extra)])
    ys = np.concatenate([tasks[0][1], 25.0 + 2.5 * np.arange(n_extra)])
    refit = blr.fit_task_batch([xs], [ys])
    m_new, _ = blr.predict_task_batch(model, 100.0)
    m_ref, _ = blr.predict_task_batch(refit, 100.0)
    assert float(m_new[0]) == pytest.approx(float(m_ref[0]), rel=RTOL)


def test_update_does_not_leak_logs_across_models():
    """Regression: jit outputs resurrect the trace-time pytree meta, so an
    updated model must be re-bound to ITS OWN sample log — otherwise two
    independently fitted models silently share (and corrupt) one history."""
    ta = _tasks(seed=11, n=3)
    tb = _tasks(seed=12, n=3)
    a = blr.fit_task_batch([t[0] for t in ta], [t[1] for t in ta])
    b = blr.fit_task_batch([t[0] for t in tb], [t[1] for t in tb])
    a2 = blr.update_task_batch(a, 0, 5.0, 9.0)
    b2 = blr.update_task_batch(b, 0, 7.0, 3.0)
    assert a2.stats.log is a.stats.log
    assert b2.stats.log is b.stats.log
    assert a2.stats.log is not b2.stats.log
    assert int(a2.stats.log.count[0]) == len(ta[0][0]) + 1
    assert int(b2.stats.log.count[0]) == len(tb[0][0]) + 1


def test_update_requires_sufficient_statistics():
    m = blr.fit_task(np.array([1.0, 2.0, 4.0]), np.array([2.0, 4.0, 8.0]))
    stacked = blr.stack_task_models([m])
    assert stacked.stats is None
    with pytest.raises(ValueError, match="sufficient statistics"):
        blr.update_task_batch(stacked, 0, 8.0, 16.0)


def test_heft_array_ready_floors():
    # a -> b chain plus independent c; node 1 busy until t=100
    succ, pred = [[1], [], []], [[], [0], []]
    cost = np.array([[10.0, 1.0], [10.0, 1.0], [10.0, 1.0]])
    node_ready = np.array([0.0, 100.0])
    task_ready = np.array([5.0, 0.0, 0.0])
    s = heft_schedule_array(succ, pred, cost, node_ready=node_ready,
                            task_ready=task_ready)
    for t in range(3):
        j = s["assignment"][t]
        assert s["start"][t] >= node_ready[j] - 1e-9
    assert s["start"][0] >= 5.0
    assert s["start"][1] >= s["finish"][0] - 1e-9


def test_grid_engine_from_types():
    grid = GridEngine.from_types(nodes_per_type=2)
    names = grid.names()
    assert len(names) == 2 * len(target_nodes())
    assert set(grid.idle(0.0)) == set(names)
    grid.occupy(names[0], 50.0)
    assert names[0] not in grid.idle(10.0)
    assert names[0] in grid.idle(50.0)
    rv = grid.ready_vector(20.0)
    assert rv[0] == 50.0 and rv[1] == 20.0


def _bench(name, cpu, io):
    return BenchResult(node=name, cpu_events_s=cpu, matmul_gflops=100.0,
                       mem_gbps=20.0, io_read_mbps=io, io_write_mbps=io,
                       link_gbps=0.0)


def _fitted_estimator(seed=0, n_tasks=5):
    rng = np.random.default_rng(seed)
    local = _bench("local-cpu", 450.0, 420.0)
    benches = {f"n{j}": _bench(f"n{j}", float(rng.uniform(150, 900)),
                               float(rng.uniform(100, 900)))
               for j in range(3)}
    est = LotaruEstimator(local, benches)
    slopes = {}
    for i in range(n_tasks):
        name = f"t{i}"
        slopes[name] = (i + 1) * 2.0
    est.fit_tasks(list(slopes), 64.0,
                  lambda n, s, cf: slopes[n] * s / cf + 5.0,
                  n_partitions=8)
    return est, slopes


def test_observe_deadjusts_by_node_factor():
    est, _ = _fitted_estimator()
    node = list(est.target_benches)[0]
    f = est.factor("t0", node)
    local_rt = est.observe("t0", node, 32.0, 77.0 * f)
    assert local_rt == pytest.approx(77.0, rel=1e-9)
    assert est.tasks["t0"].runtimes[-1] == pytest.approx(77.0, rel=1e-9)
    assert est.tasks["t0"].sizes[-1] == 32.0


def test_observe_invalidates_only_affected_row():
    est, _ = _fitted_estimator(seed=1)
    nodes = list(est.target_benches)
    M1, S1 = est.predict_matrix(nodes, 32.0)
    i = est.task_names().index("t2")
    est.observe("t2", nodes[1], 32.0, 500.0)
    M2, S2 = est.predict_matrix(nodes, 32.0)
    others = [k for k in range(len(est.task_names())) if k != i]
    assert np.array_equal(M2[others], M1[others])
    assert np.array_equal(S2[others], S1[others])
    assert not np.allclose(M2[i], M1[i])
    # the patched row equals a from-scratch recompute
    est._mat_cache = None
    M3, S3 = est.predict_matrix(nodes, 32.0)
    np.testing.assert_allclose(M2, M3, rtol=1e-6)
    np.testing.assert_allclose(S2, S3, rtol=1e-6)
    # and the scalar oracle agrees with the updated row
    m, _ = est.predict("t2", nodes[0], 32.0)
    assert M2[i, 0] == pytest.approx(m, rel=RTOL, abs=1e-6)


def test_observe_matches_full_refit():
    """The estimator's incremental path is equivalent to refitting the
    batched model over the appended history (cache rebuilt from scratch)."""
    est, _ = _fitted_estimator(seed=2)
    node = list(est.target_benches)[1]
    for k in range(5):
        est.observe("t1", node, 48.0 + k, (100.0 + 3 * k) * est.factor("t1", node))
    nodes = list(est.target_benches)
    M_inc, S_inc = est.predict_matrix(nodes, 40.0)
    est._batch_cache = None     # force a full refit over ft.sizes/runtimes
    est._mat_cache = None
    M_ref, S_ref = est.predict_matrix(nodes, 40.0)
    np.testing.assert_allclose(M_inc, M_ref, rtol=RTOL, atol=1e-5)
    np.testing.assert_allclose(S_inc, S_ref, rtol=RTOL, atol=1e-5)


def test_predict_interval_node_brackets_mean():
    est, _ = _fitted_estimator(seed=3)
    node = list(est.target_benches)[0]
    mean, _ = est.predict("t1", node, 32.0)
    lo, hi = est.predict_interval_node("t1", node, 32.0, confidence=0.9)
    assert lo <= mean <= hi
    assert lo >= 0.0


def test_ml_observe_updates_cell():
    rng = np.random.default_rng(0)
    local = BenchResult(node="local-cpu", cpu_events_s=450.0,
                        matmul_gflops=90.0, mem_gbps=18.0,
                        io_read_mbps=420.0, io_write_mbps=400.0,
                        link_gbps=0.0)
    benches = {"n0": BenchResult(node="n0", cpu_events_s=200.0,
                                 matmul_gflops=2000.0, mem_gbps=400.0,
                                 io_read_mbps=300.0, io_write_mbps=300.0,
                                 link_gbps=25.0)}
    est = LotaruML(local, benches)
    cell = {"arch": "a0", "shape": "s", "roofline": {
        "step_tokens": 4096, "compute_s": 1.0, "memory_s": 0.5,
        "collective_s": 0.1, "flops_per_device": 1e13,
        "bytes_per_device": 1e11, "coll_bytes_per_device": 1e9}}
    est.fit_cell(cell, lambda c, f: 2e-4 * f * 4096 + 0.5
                 + rng.normal(0, 1e-3))
    name = est.cell_names()[0]
    M1, _ = est.predict_matrix(["n0"])
    m_before, _ = est.predict(name, "n0")
    est.observe(name, "n0", 4096.0, m_before * 1.4)
    M2, _ = est.predict_matrix(["n0"])
    assert not np.allclose(M1, M2)
    m_after, _ = est.predict(name, "n0")
    assert M2[0, 0] == pytest.approx(m_after, rel=RTOL, abs=1e-6)
    assert m_after > m_before    # pulled toward the slower observation


def test_observation_buffer_replay_arrays():
    buf = ObservationBuffer()
    buf.record("a", "n0", 8.0, 10.0, 5.0, time=1.0)
    buf.record("b", "n1", 8.0, 20.0, 7.0, time=2.0)
    buf.record("a", "n1", 8.0, 30.0, 6.0, time=3.0)
    assert len(buf) == 3 and buf.count("a") == 2
    idx, sizes, local = buf.arrays({"a": 0, "b": 1})
    assert list(idx) == [0, 1, 0]
    assert list(local) == [5.0, 7.0, 6.0]
    assert set(buf.per_task()) == {"a", "b"}


def test_observation_buffer_unknown_task_names_offender():
    buf = ObservationBuffer()
    buf.record("a", "n0", 8.0, 10.0, 5.0, time=1.0)
    buf.record("rogue", "n1", 8.0, 20.0, 7.0, time=2.0)
    with pytest.raises(ValueError, match="rogue"):
        buf.arrays({"a": 0, "b": 1})


def test_observation_buffer_by_tick_groups_same_time():
    buf = ObservationBuffer()
    buf.record("a", "n0", 8.0, 10.0, 5.0, time=1.0)
    buf.record("b", "n1", 8.0, 20.0, 7.0, time=1.0)
    buf.record("a", "n1", 8.0, 30.0, 6.0, time=2.5)
    ticks = buf.by_tick()
    assert [t for t, _ in ticks] == [1.0, 2.5]
    assert [len(g) for _, g in ticks] == [2, 1]
    assert {o.task for o in ticks[0][1]} == {"a", "b"}


def test_observation_buffer_by_tick_index_matches_full_scan():
    """Regression for the incremental tick index: the default-atol fast
    path (served from the index ``add`` maintains) must equal the legacy
    one-shot scan EXACTLY — same boundaries, same grouping-against-first
    semantics — including after a ``from_dict`` round trip."""
    rng = np.random.default_rng(42)
    buf = ObservationBuffer()
    t = 0.0
    for i in range(200):
        # mix of exact-repeat ticks, sub-atol nudges, and real advances
        r = rng.random()
        if r < 0.4 and i:
            pass                                   # same tick, exactly
        elif r < 0.55 and i:
            t += 0.4e-12                           # within atol of first
        else:
            t += float(rng.uniform(0.1, 2.0))
        buf.record(f"t{i % 7}", f"n{i % 3}", 8.0, 10.0 + i, 5.0 + i,
                   time=t)
    fast = buf.by_tick()
    # the non-default-atol branch is the legacy full scan verbatim
    slow = buf.by_tick(atol=np.nextafter(buf.TICK_ATOL, 0.0))
    assert [tt for tt, _ in fast] == [tt for tt, _ in slow]
    assert [g for _, g in fast] == [g for _, g in slow]
    # round-tripping through from_dict rebuilds the same index
    again = ObservationBuffer.from_dict(buf.to_dict()).by_tick()
    assert again == fast
    # returned groups are copies, not views of the index
    fast[0][1].clear()
    assert [len(g) for _, g in buf.by_tick()] == [len(g) for _, g in slow]


# ---------------------------------------------------------------------------
# Event-driven executor
# ---------------------------------------------------------------------------
def _toy_estimator_on_types(seed=7, n_tasks=3):
    """A fitted estimator whose target benches are named after real node
    types, so a ``GridEngine.from_types`` grid resolves against it."""
    est, slopes = _fitted_estimator(seed=seed, n_tasks=n_tasks)
    est.target_benches = {"tpu-v2": est.target_benches["n0"],
                          "tpu-v3": est.target_benches["n1"]}
    est._mat_cache = None
    return est, list(slopes)


def _executor_scenario(bias=1.5, n_samples=4, online=True):
    """Chain workflow over n_samples inputs; ground truth is a systematic
    `bias` off the estimator's initial belief — the online loop should
    learn it from the first completions, the static plan cannot."""
    est, chain = _toy_estimator_on_types()
    tasks, task_name = fanout_chain_dag(chain, n_samples)
    grid = GridEngine.from_types(nodes_per_type=1,
                                 types=[get_node("tpu-v2"),
                                        get_node("tpu-v3")])
    size = 32.0
    est_truth, _ = _toy_estimator_on_types()   # frozen initial beliefs

    def runtime_fn(tid, node):
        nt = grid.type_of(node).name
        m, _ = est_truth.predict(task_name[tid], nt, size)
        return m * bias

    # confidence=0.2 keeps the surprise band tight: the noiseless toy fit
    # has near-zero residuals, so the b0 prior dominates the predictive
    # spread and a wide-confidence interval would swallow the 1.5x bias
    return OnlineExecutor(est, tasks, task_name, size, grid, runtime_fn,
                          online=online, confidence=0.2)


def test_executor_completes_all_tasks():
    trace = _executor_scenario(online=False).run()
    assert len(trace.records) == 12
    assert trace.makespan > 0
    assert trace.replans == 0 and len(trace.observations) == 0
    assert trace.makespan == pytest.approx(max(r.end for r in trace.records))


def test_online_executor_beats_static_on_systematic_bias():
    static = _executor_scenario(online=False).run()
    online = _executor_scenario(online=True).run()
    assert len(static.records) == len(online.records)
    assert len(online.observations) == len(online.records)
    # ground truth is 1.5x the initial belief everywhere: the static plan
    # carries ~0.33 MPE forever, the online loop learns it away
    assert online.final_mpe() < static.final_mpe()
    assert online.surprises > 0
    # trajectory actually falls
    traj = online.cumulative_mpe()
    assert traj[-1] < traj[0]


def test_executor_dependency_order():
    trace = _executor_scenario(online=True).run()
    by_id = {r.id: r for r in trace.records}
    for tid, rec in by_id.items():
        sample, name = tid.split(".", 1)
        k = int(name[1:])                    # chain t0 -> t1 -> t2
        if k > 0:
            prev = by_id[f"{sample}.t{k-1}"]
            assert rec.start >= prev.end - 1e-9
