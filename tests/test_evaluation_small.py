"""Evaluation protocol unit checks (fast, single workflow)."""
import numpy as np

from repro.sched.evaluation import APPROACHES, run_evaluation
from repro.sched.simulator import ClusterSimulator
from repro.sched.workflows import INPUTS, WORKFLOWS, TaskDef, effective_size
from repro.core.nodes import get_node


def test_effective_size_kinds():
    lin = TaskDef("a", "w", 10, 5, kind="linear")
    flat = TaskDef("b", "w", 10, 5, kind="flat")
    sq = TaskDef("c", "w", 10, 5, kind="sqrt")
    assert effective_size(lin, 9.0) == 9.0
    assert effective_size(flat, 9.0) == 0.0
    assert effective_size(sq, 9.0) == 3.0


def test_simulator_runtime_scales_with_node_speed():
    sim = ClusterSimulator(seed=0, systematic=0.0)
    t = WORKFLOWS["eager"][0]            # bwa (cpu-heavy)
    slow = sim.expected_task_runtime(t, get_node("tpu-v2"), 10.0)
    fast = sim.expected_task_runtime(t, get_node("tpu-v5p"), 10.0)
    assert slow > fast                    # v2 cpu_score 223 < v5p 523


def test_actual_factor_reflects_cpu_io_mix():
    sim = ClusterSimulator(seed=0, systematic=0.0)
    local = get_node("local-cpu")
    v2 = get_node("tpu-v2")
    cpu_task = WORKFLOWS["eager"][0]      # bwa: cpu-dominant
    io_task = [t for t in WORKFLOWS["eager"] if t.name == "markduplicates"][0]
    f_cpu = sim.actual_factor(cpu_task, local, v2, 10.0)
    f_io = sim.actual_factor(io_task, local, v2, 10.0)
    # both slower on v2, with the cpu-bound task hit harder by cpu ratio
    assert f_cpu > 1.0 and f_io > 1.0
    assert abs(f_cpu - 458 / 223) < 0.4


def test_workflow_suite_matches_paper_task_counts():
    counts = {w: len(ts) for w, ts in WORKFLOWS.items()}
    assert counts == {"eager": 13, "methylseq": 8, "chipseq": 14,
                      "atacseq": 14, "bacass": 5}      # paper Table 3
    assert len(INPUTS) == 10                            # 5 workflows x 2


def test_run_evaluation_structure():
    res = run_evaluation(seed=1, n_partitions=6, heterogeneous=False,
                         inputs={("bacass", 1): 3.64})
    for a in APPROACHES:
        assert res.mpe(a) >= 0
        assert len(res.all_errors(a)) == 5              # bacass tasks
