"""Serving loop: batching, cache stepping, straggler envelope."""
import numpy as np

from repro.configs import smoke_config
from repro.launch.serve import Request, ServeLoop


def test_serve_loop_generates():
    cfg = smoke_config("stablelm-1.6b")
    loop = ServeLoop(cfg, max_batch=2)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, 6), max_new=4)
            for i in range(2)]
    done = loop.run_batch(reqs)
    for r in done:
        assert len(r.out) == 4
        assert all(0 <= t < cfg.vocab for t in r.out)


def test_serve_straggler_envelope_counts():
    cfg = smoke_config("qwen2-7b")
    # impossible envelope: every step counts as a straggler breach
    loop = ServeLoop(cfg, max_batch=1, envelope=(0.0, 1e-9), straggler_k=1.0)
    rng = np.random.default_rng(1)
    loop.run_batch([Request(rid=0, prompt=rng.integers(0, cfg.vocab, 4),
                            max_new=5)])
    assert loop.straggler_steps >= 3
