"""End-to-end behaviour tests for the paper's system.

The headline claims, asserted on a reduced-size evaluation:
  * homogeneous: Lotaru's MPE is small and competitive (paper: 5.70%),
  * heterogeneous: Lotaru substantially beats the best node-unaware
    baseline (paper: 48.25% error reduction),
  * the adjustment factor tracks the actual factor (paper Tables 4-5),
  * LotaruML's decomposed predictor beats the scalar-factor ablation on
    accelerator cells,
  * the whole Lotaru->HEFT pipeline produces valid, better-than-FIFO plans.
"""
import numpy as np
import pytest

from repro.core import get_node, target_nodes
from repro.sched.evaluation import factor_table, run_evaluation
from repro.sched.workflows import INPUTS, WORKFLOWS

SMALL_INPUTS = {("eager", 1): INPUTS[("eager", 1)],
                ("bacass", 1): INPUTS[("bacass", 1)],
                ("chipseq", 1): INPUTS[("chipseq", 1)]}


@pytest.fixture(scope="module")
def het_eval():
    return run_evaluation(seed=0, heterogeneous=True, inputs=SMALL_INPUTS)


@pytest.fixture(scope="module")
def hom_eval():
    return run_evaluation(seed=0, heterogeneous=False, inputs=SMALL_INPUTS)


def test_homogeneous_mpe_small(hom_eval):
    assert hom_eval.mpe("lotaru") < 0.12          # paper: 5.70%


def test_heterogeneous_lotaru_beats_baselines(het_eval):
    lot = het_eval.mpe("lotaru")
    best_baseline = min(het_eval.mpe(a) for a in ("naive", "online_m",
                                                  "online_p"))
    assert lot < 0.25                              # paper: 15.99%
    assert lot < 0.75 * best_baseline              # paper: 48% reduction


def test_prediction_errors_finite_and_positive(het_eval):
    for a in ("lotaru", "naive", "online_m", "online_p"):
        errs = het_eval.all_errors(a)
        assert np.all(np.isfinite(errs))
        assert len(errs) > 0


def test_factor_adjustment_tracks_actual():
    rows = factor_table(seed=0, workflow="eager", ds=1)
    names = [n.name for n in target_nodes()]
    med = {n: np.median([r[n]["diff"] for r in rows]) for n in names}
    # paper Table 4 reports diffs 0.03-0.17; allow a loose envelope
    assert all(d < 0.45 for d in med.values()), med
    # nodes closest to local profile best-estimated (paper: C2/N2 best)
    assert med["tpu-v5p"] <= med["tpu-v2"] + 0.05


def test_lotaru_ml_decomposed_beats_scalar():
    from repro.core import LotaruML, profile_cluster, profile_node
    from repro.sched.simulator import ClusterSimulator
    sim = ClusterSimulator(seed=0)
    truth = ClusterSimulator(seed=99)
    local = get_node("local-cpu")
    est = LotaruML(profile_node(local, np.random.default_rng(7)),
                   profile_cluster(target_nodes(), seed=13))
    # synthetic cells spanning compute-/memory-/collective-bound regimes
    cells = []
    for i, (fl, by, co) in enumerate([(5e13, 8e12, 2e11), (1e12, 9e12, 1e11),
                                      (2e13, 2e12, 9e11)]):
        cells.append({"arch": f"synt{i}", "shape": "train", "family": "dense",
                      "roofline": {"chips": 256, "flops_per_device": fl,
                                   "bytes_per_device": by,
                                   "coll_bytes_per_device": co,
                                   "step_tokens": 1_000_000,
                                   "compute_s": fl / 197e12,
                                   "memory_s": by / 819e9,
                                   "collective_s": co / 50e9}})
    errs_d, errs_s = [], []
    for c in cells:
        est.fit_cell(c, lambda cell, f: sim.run_cell(cell, local, f),
                     run_local_throttled=lambda cell, f: sim.run_cell(
                         cell, local, f, cpu_factor=0.8))
        name = f"{c['arch']}__{c['shape']}"
        for node in target_nodes():
            actual = truth.run_cell(c, node)
            pd, _ = est.predict(name, node.name)
            ps, _ = est.predict_scalar(name, node.name)
            errs_d.append(abs(pd - actual) / actual)
            errs_s.append(abs(ps - actual) / actual)
    assert np.median(errs_d) < np.median(errs_s)
    assert np.median(errs_d) < 0.8


def test_full_pipeline_heft_validity():
    from repro.core import (LotaruEstimator, profile_cluster, profile_node)
    from repro.sched.heft import SchedTask, heft_schedule
    from repro.sched.simulator import ClusterSimulator
    sim = ClusterSimulator(seed=0)
    local = get_node("local-cpu")
    wf = WORKFLOWS["bacass"]
    by_name = {t.name: t for t in wf}
    size = INPUTS[("bacass", 1)]
    est = LotaruEstimator(profile_node(local, np.random.default_rng(7)),
                          profile_cluster(target_nodes(), seed=13))
    est.fit_tasks(list(by_name), size,
                  lambda n, s, cf: sim.run_task(by_name[n], local, s,
                                                cpu_factor=cf),
                  n_partitions=6)
    nodes = [n.name for n in target_nodes()]
    tasks, cost = {}, {}
    for s_i in range(4):
        prev = None
        for t in wf:
            tid = f"s{s_i}.{t.name}"
            tasks[tid] = SchedTask(id=tid)
            if prev:
                tasks[tid].pred.append(prev)
                tasks[prev].succ.append(tid)
            prev = tid
            cost[tid] = {n: est.predict(t.name, n, size)[0] for n in nodes}
    sched = heft_schedule(tasks, cost, nodes)
    assert sched["makespan"] > 0
    for tid, t in tasks.items():
        for p in t.pred:
            assert sched["start"][tid] >= sched["finish"][p] - 1e-9
    # uncertainty available for every (task, node) pair
    for t in wf:
        for n in nodes:
            mean, std = est.predict(t.name, n, size)
            assert mean > 0 and std >= 0


def test_estimator_offline_reuse(tmp_path):
    """Paper §1: learned models reused for future executions (save/load)."""
    from repro.core import (LotaruEstimator, profile_cluster, profile_node)
    from repro.sched.simulator import ClusterSimulator
    sim = ClusterSimulator(seed=0)
    local = get_node("local-cpu")
    wf = WORKFLOWS["bacass"]
    by_name = {t.name: t for t in wf}
    size = INPUTS[("bacass", 1)]
    est = LotaruEstimator(profile_node(local, np.random.default_rng(7)),
                          profile_cluster(target_nodes(), seed=13))
    est.fit_tasks(list(by_name), size,
                  lambda n, s, cf: sim.run_task(by_name[n], local, s,
                                                cpu_factor=cf),
                  n_partitions=6)
    p = tmp_path / "est.json"
    est.save(p)
    est2 = LotaruEstimator.load(p)
    for t in wf:
        for node in ("tpu-v2", "tpu-v5p"):
            a = est.predict(t.name, node, size)
            b = est2.predict(t.name, node, size)
            assert abs(a[0] - b[0]) / a[0] < 1e-6
            assert abs(est.tasks[t.name].w - est2.tasks[t.name].w) < 1e-9


def test_uncertainty_calibration_tail():
    """The 95% predictive interval must cover ~95% of actual runtimes
    (the level straggler envelopes operate at); central levels may be
    conservative (fat-tailed small-n Student-t) but never under-cover
    grossly."""
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
    from benchmarks import calibration
    import numpy as np
    rows = calibration.run(n_draws=2)
    emp = {r[0]: float(r[2].split("empirical=")[1]) for r in rows}
    assert emp["calibration.cov95"] > 0.85
    assert emp["calibration.cov50"] > 0.45
