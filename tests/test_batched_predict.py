"""Batched prediction engine: batched-vs-scalar equivalence (ragged sample
counts, Pearson gating, median fallback), predict_matrix shape/factor
correctness for both estimators, and array-HEFT vs the dict reference."""
import numpy as np
import pytest

from repro.core import blr
from repro.core.adjust import runtime_factor, runtime_factor3, stack_benches
from repro.core.estimator import FittedTask, LotaruEstimator, LotaruML
from repro.core.profiler import BenchResult
from repro.sched.heft import (SchedTask, heft_schedule, heft_schedule_array,
                              heft_schedule_reference)

RTOL = 1e-4   # float32 default; the x64 benchmark observes ~1e-15


def _ragged_tasks(seed=0, n=7):
    """Mix of correlated (linear) and flat tasks with ragged sample counts."""
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        m = int(rng.integers(3, 11))
        xs = np.sort(rng.uniform(1, 100, m))
        if i % 3 != 2:
            ys = (i + 1) * xs + 10 + rng.normal(0, 0.1, m)
        else:
            ys = 50 + rng.normal(0, 0.5, m)
        out.append((xs, np.abs(ys)))
    return out


def test_batched_fit_matches_scalar_ragged():
    tasks = _ragged_tasks()
    scalars = [blr.fit_task(x, y) for x, y in tasks]
    batch = blr.fit_task_batch([t[0] for t in tasks], [t[1] for t in tasks])
    # gating decisions agree
    assert list(np.asarray(batch.correlated)) == [m.correlated
                                                  for m in scalars]
    for x_star in (5.0, 55.0, 150.0):
        mb, sb = blr.predict_task_batch(batch, x_star)
        for i, m in enumerate(scalars):
            ms, ss = m.predict(x_star)
            assert float(mb[i]) == pytest.approx(float(ms), rel=RTOL,
                                                 abs=1e-6)
            assert float(sb[i]) == pytest.approx(float(ss), rel=RTOL,
                                                 abs=1e-6)


def test_batched_grid_shapes():
    tasks = _ragged_tasks(seed=1, n=4)
    batch = blr.fit_task_batch([t[0] for t in tasks], [t[1] for t in tasks])
    xs = np.array([10.0, 20.0, 40.0])
    mean, std = blr.predict_task_batch_grid(batch, xs)
    assert mean.shape == (4, 3) and std.shape == (4, 3)
    assert bool((np.asarray(std) >= 0).all())
    # per-task x_star vector
    mean1, std1 = blr.predict_task_batch(batch, np.full(4, 20.0))
    assert np.allclose(np.asarray(mean1), np.asarray(mean)[:, 1], rtol=1e-6)


def test_batched_interval_no_python_loop():
    tasks = _ragged_tasks(seed=2, n=5)
    batch = blr.fit_task_batch([t[0] for t in tasks], [t[1] for t in tasks])
    lo, hi = blr.predict_interval(batch.post, 25.0, confidence=0.8)
    assert lo.shape == (5,) and hi.shape == (5,)
    assert bool((hi >= lo).all())
    # consistent with the scalar interval on a correlated task
    post0 = blr.fit(*tasks[0])
    lo0, hi0 = blr.predict_interval(post0, 25.0, confidence=0.8)
    assert float(lo[0]) == pytest.approx(float(lo0), rel=1e-3, abs=1e-3)
    assert float(hi[0]) == pytest.approx(float(hi0), rel=1e-3, abs=1e-3)


def test_predict_dtype_follows_posterior():
    x = np.array([1.0, 2.0, 4.0, 8.0])
    post = blr.fit(x, 2 * x + 1)
    mean, _ = blr.predict(post, np.array([3.0, 5.0]))
    assert mean.dtype == post.mu.dtype


def _bench(name, cpu, io, mat=100.0, mem=20.0, link=0.0):
    return BenchResult(node=name, cpu_events_s=cpu, matmul_gflops=mat,
                       mem_gbps=mem, io_read_mbps=io, io_write_mbps=io,
                       link_gbps=link)


def test_runtime_factor_stacked_matches_scalar():
    local = _bench("local", 450.0, 420.0)
    targets = [_bench(f"n{i}", 150.0 + 100 * i, 200.0 + 50 * i)
               for i in range(4)]
    w = np.array([0.0, 0.3, 1.0])
    F = runtime_factor(w, local, stack_benches(targets))
    assert F.shape == (3, 4)
    for i, wi in enumerate(w):
        for j, t in enumerate(targets):
            assert F[i, j] == pytest.approx(
                runtime_factor(float(wi), local, t), rel=1e-12)


def test_runtime_factor3_stacked_matches_scalar():
    local = _bench("local", 450.0, 420.0, mat=90.0, mem=18.0, link=0.0)
    targets = [_bench(f"n{i}", 200.0, 300.0, mat=1000.0 * (i + 1),
                      mem=100.0 * (i + 1), link=25.0 * i)  # i=0: link fallback
               for i in range(3)]
    W = np.array([[0.6, 0.3, 0.1], [0.1, 0.8, 0.1]])
    F = runtime_factor3(W, local, stack_benches(targets))
    assert F.shape == (2, 3)
    for i in range(2):
        for j, t in enumerate(targets):
            assert F[i, j] == pytest.approx(
                runtime_factor3(tuple(W[i]), local, t), rel=1e-12)


def _toy_estimator(n_tasks=6, n_nodes=3, seed=0):
    rng = np.random.default_rng(seed)
    local = _bench("local-cpu", 450.0, 420.0)
    benches = {f"n{j}": _bench(f"n{j}", float(rng.uniform(150, 900)),
                               float(rng.uniform(100, 900)))
               for j in range(n_nodes)}
    est = LotaruEstimator(local, benches)
    for i in range(n_tasks):
        sizes = np.geomspace(1, 64, 8)
        if i % 2 == 0:
            rts = (i + 1.0) * sizes + 5 + rng.normal(0, 0.05, 8)
        else:
            rts = 40 + rng.normal(0, 0.5, 8)
        est.tasks[f"t{i}"] = FittedTask(model=blr.fit_task(sizes, rts),
                                        w=float(rng.uniform(0, 1)),
                                        sizes=sizes, runtimes=np.abs(rts))
    return est


def test_predict_matrix_matches_scalar_and_local_identity():
    est = _toy_estimator()
    nodes = list(est.target_benches) + ["local-cpu"]
    M, S = est.predict_matrix(nodes, 32.0)
    assert M.shape == (6, len(nodes)) and S.shape == M.shape
    for i, tn in enumerate(est.task_names()):
        for j, nd in enumerate(nodes):
            if nd == "local-cpu":
                m, s = est.predict_local(tn, 32.0)
            else:
                m, s = est.predict(tn, nd, 32.0)
            assert M[i, j] == pytest.approx(m, rel=RTOL, abs=1e-6)
            assert S[i, j] == pytest.approx(s, rel=RTOL, abs=1e-6)
    # local column carries factor exactly 1: matrix mean == local mean
    j_local = nodes.index("local-cpu")
    F = est.factor_matrix(nodes)
    assert np.allclose(F[:, j_local], 1.0)


def test_predict_matrix_per_task_sizes():
    est = _toy_estimator(seed=3)
    nodes = list(est.target_benches)
    sizes = np.linspace(4, 64, len(est.tasks))
    M, _ = est.predict_matrix(nodes, sizes)
    for i, tn in enumerate(est.task_names()):
        m, _ = est.predict(tn, nodes[0], float(sizes[i]))
        assert M[i, 0] == pytest.approx(m, rel=RTOL, abs=1e-6)


def _toy_ml(seed=0, n_cells=5):
    rng = np.random.default_rng(seed)
    local = _bench("local-cpu", 450.0, 420.0, mat=90.0, mem=18.0)
    benches = {f"n{j}": _bench(f"n{j}", 200.0, 300.0,
                               mat=float(rng.uniform(500, 5000)),
                               mem=float(rng.uniform(100, 900)),
                               link=float(rng.uniform(0, 60)))
               for j in range(3)}
    est = LotaruML(local, benches)
    for i in range(n_cells):
        slope = rng.uniform(1e-4, 1e-3)
        cell = {"arch": f"a{i}", "shape": "s", "roofline": {
            "step_tokens": 2048 * (i + 1),
            "compute_s": rng.uniform(0.1, 2), "memory_s": rng.uniform(0.1, 2),
            "collective_s": rng.uniform(0.0, 1),
            "flops_per_device": rng.uniform(1e12, 5e13),
            "bytes_per_device": rng.uniform(1e10, 1e12),
            "coll_bytes_per_device": rng.uniform(1e8, 1e10)}}
        throttled = (lambda c, f: slope * f * c["roofline"]["step_tokens"]
                     * 1.25 + 0.6) if i % 2 == 0 else None
        est.fit_cell(cell,
                     lambda c, f: slope * f * c["roofline"]["step_tokens"]
                     + 0.5 + rng.normal(0, 1e-3),
                     run_local_throttled=throttled)
    return est


def test_ml_predict_matrix_matches_scalar():
    est = _toy_ml()
    nodes = list(est.target_benches) + ["local-cpu"]
    M, S = est.predict_matrix(nodes)
    Ms, Ss = est.predict_matrix_scalar(nodes)
    assert M.shape == (5, 4)
    for i, cn in enumerate(est.cell_names()):
        for j, nd in enumerate(nodes):
            m, s = est.predict(cn, nd)
            assert M[i, j] == pytest.approx(m, rel=RTOL, abs=1e-6)
            assert S[i, j] == pytest.approx(s, rel=RTOL, abs=1e-6)
            m2, s2 = est.predict_scalar(cn, nd)
            assert Ms[i, j] == pytest.approx(m2, rel=RTOL, abs=1e-6)
            assert Ss[i, j] == pytest.approx(s2, rel=RTOL, abs=1e-6)


def _reference_dag():
    tasks = {
        "a": SchedTask(id="a", succ=["b", "c"]),
        "b": SchedTask(id="b", pred=["a"], succ=["d"]),
        "c": SchedTask(id="c", pred=["a"], succ=["d"]),
        "d": SchedTask(id="d", pred=["b", "c"], succ=["e"]),
        "e": SchedTask(id="e", pred=["d"]),
        "f": SchedTask(id="f"),          # disconnected
    }
    rng = np.random.default_rng(7)
    nodes = ["n0", "n1", "n2"]
    cost = {t: {n: float(rng.uniform(1, 50)) for n in nodes} for t in tasks}
    unc = {t: {n: float(rng.uniform(0, 10)) for n in nodes} for t in tasks}
    return tasks, cost, unc, nodes


def test_array_heft_matches_dict_reference():
    tasks, cost, unc, nodes = _reference_dag()
    for u, k in ((None, 0.0), (unc, 1.5)):
        fast = heft_schedule(tasks, cost, nodes, uncertainty=u, risk_k=k)
        ref = heft_schedule_reference(tasks, cost, nodes, uncertainty=u,
                                      risk_k=k)
        assert fast["assignment"] == ref["assignment"]
        assert fast["order"] == ref["order"]
        assert fast["makespan"] == pytest.approx(ref["makespan"], rel=1e-12)
        for t in tasks:
            assert fast["start"][t] == pytest.approx(ref["start"][t])
            assert fast["finish"][t] == pytest.approx(ref["finish"][t])


def test_array_heft_random_dags_match_reference():
    rng = np.random.default_rng(11)
    for _ in range(20):
        n_tasks = int(rng.integers(2, 20))
        n_nodes = int(rng.integers(1, 6))
        tasks = {f"t{i}": SchedTask(id=f"t{i}") for i in range(n_tasks)}
        for i in range(n_tasks):
            for j in range(i + 1, n_tasks):
                if rng.random() < 0.25:
                    tasks[f"t{i}"].succ.append(f"t{j}")
                    tasks[f"t{j}"].pred.append(f"t{i}")
        nodes = [f"n{k}" for k in range(n_nodes)]
        cost = {t: {n: float(rng.uniform(1, 100)) for n in nodes}
                for t in tasks}
        fast = heft_schedule(tasks, cost, nodes)
        ref = heft_schedule_reference(tasks, cost, nodes)
        assert fast["assignment"] == ref["assignment"]
        assert fast["makespan"] == pytest.approx(ref["makespan"])


def test_array_heft_deep_chain_no_recursion_limit():
    T = 3000
    tasks = {f"t{i}": SchedTask(id=f"t{i}") for i in range(T)}
    for i in range(T - 1):
        tasks[f"t{i}"].succ.append(f"t{i+1}")
        tasks[f"t{i+1}"].pred.append(f"t{i}")
    cost = {t: {"a": 1.0, "b": 2.0} for t in tasks}
    s = heft_schedule(tasks, cost, ["a", "b"])
    assert s["makespan"] == pytest.approx(float(T))
    assert all(v == "a" for v in s["assignment"].values())


def test_array_heft_rejects_cycles():
    tasks = {"a": SchedTask(id="a", succ=["b"], pred=["b"]),
             "b": SchedTask(id="b", succ=["a"], pred=["a"])}
    cost = {t: {"n": 1.0} for t in tasks}
    with pytest.raises(ValueError):
        heft_schedule(tasks, cost, ["n"])


def test_fit_task_batch_rejects_length_mismatch():
    with pytest.raises(ValueError):
        blr.fit_task_batch([[1.0, 2.0, 3.0]], [[5.0, 6.0]])


def test_predict_matrix_cache_sees_in_place_task_replacement():
    est = _toy_estimator()
    nodes = list(est.target_benches)
    M1, _ = est.predict_matrix(nodes, 32.0)
    name = est.task_names()[0]
    sizes = np.geomspace(1, 64, 8)
    rts = 100.0 * sizes + 7.0
    est.tasks[name] = FittedTask(model=blr.fit_task(sizes, rts), w=0.5,
                                 sizes=sizes, runtimes=rts)
    M2, _ = est.predict_matrix(nodes, 32.0)
    m, _ = est.predict(name, nodes[0], 32.0)
    assert M2[0, 0] == pytest.approx(m, rel=RTOL)
    assert not np.allclose(M1[0], M2[0])


def test_heft_sparse_uncertainty_ignored_when_risk_zero():
    tasks, cost, _, nodes = _reference_dag()
    partial_unc = {"a": {n: 1.0 for n in nodes}}   # sigma for one task only
    # the contract: uncertainty participates only when risk_k > 0 — the
    # sparse dict must not be indexed, and the surprising combination is
    # flagged with a UserWarning instead of silently dropped
    with pytest.warns(UserWarning, match="risk_k == 0"):
        s = heft_schedule(tasks, cost, nodes, uncertainty=partial_unc,
                          risk_k=0.0)
    assert set(s["assignment"]) == set(tasks)
    np.testing.assert_array_equal(
        [s["assignment"][t] for t in tasks],
        [heft_schedule(tasks, cost, nodes)["assignment"][t] for t in tasks])


def test_heft_schedule_array_direct_api():
    cost = np.array([[3.0, 1.0], [2.0, 5.0], [1.0, 1.0]])
    succ = [[1], [2], []]
    pred = [[], [0], [1]]
    s = heft_schedule_array(succ, pred, cost)
    assert s["assignment"].shape == (3,)
    assert s["makespan"] >= cost.min(axis=1).sum() - 1e-9
    # chain order respected
    assert s["start"][1] >= s["finish"][0] - 1e-9
    assert s["start"][2] >= s["finish"][1] - 1e-9
