"""LotaruEstimator persistence: schema versioning, bit-exact round trips,
and legacy (v1) file compatibility."""
import json

import numpy as np
import pytest

from repro.core import SCHEMA_VERSION, LotaruEstimator
from repro.core.profiler import BenchResult


def _bench(name, cpu, io):
    return BenchResult(node=name, cpu_events_s=cpu, matmul_gflops=100.0,
                       mem_gbps=20.0, io_read_mbps=io, io_write_mbps=io,
                       link_gbps=0.0)


def _fitted(seed=0):
    rng = np.random.default_rng(seed)
    local = _bench("local-cpu", 450.0, 420.0)
    benches = {f"n{j}": _bench(f"n{j}", float(rng.uniform(150, 900)),
                               float(rng.uniform(100, 900)))
               for j in range(3)}
    est = LotaruEstimator(local, benches, freq_reduction=0.25)
    laws = {"lin0": lambda s: 3.0 * s + 4.0,
            "lin1": lambda s: 11.0 * s + 1.0,
            "flat": lambda s: 42.0}          # exercises the median fallback
    est.fit_tasks(list(laws), 64.0,
                  lambda n, s, cf: laws[n](s) / cf, n_partitions=8)
    return est


def test_save_writes_schema_version(tmp_path):
    est = _fitted()
    p = tmp_path / "est.json"
    est.save(p)
    d = json.loads(p.read_text())
    assert d["version"] == SCHEMA_VERSION
    assert d["freq_reduction"] == 0.25
    for rec in d["tasks"].values():
        assert "model" in rec and "correlated" in rec["model"]


def test_roundtrip_preserves_predictions_bitexact(tmp_path):
    est = _fitted(seed=1)
    p = tmp_path / "est.json"
    est.save(p)
    loaded = LotaruEstimator.load(p)
    assert loaded.freq_reduction == est.freq_reduction
    nodes = list(est.target_benches)
    M0, S0 = est.predict_matrix(nodes, 40.0)
    M1, S1 = loaded.predict_matrix(nodes, 40.0)
    assert np.array_equal(M0, M1)
    assert np.array_equal(S0, S1)
    # scalar predictions too (incl. the median-fallback task)
    for tn in est.task_names():
        for nd in nodes:
            assert est.predict(tn, nd, 40.0) == loaded.predict(tn, nd, 40.0)
        assert est.predict_local(tn, 40.0) == loaded.predict_local(tn, 40.0)


def test_roundtrip_preserves_gating_and_weights(tmp_path):
    est = _fitted(seed=2)
    p = tmp_path / "est.json"
    est.save(p)
    loaded = LotaruEstimator.load(p)
    for tn in est.task_names():
        assert loaded.tasks[tn].model.correlated == \
            est.tasks[tn].model.correlated
        assert loaded.tasks[tn].w == est.tasks[tn].w
    assert not loaded.tasks["flat"].model.correlated
    assert loaded.tasks["lin0"].model.correlated


def test_roundtrip_after_online_observations(tmp_path):
    """Online-updated state survives persistence: the saved raw history
    includes the de-adjusted observations, so the loaded estimator's
    refit reproduces the incrementally-updated predictions."""
    est = _fitted(seed=3)
    node = list(est.target_benches)[0]
    for k in range(4):
        est.observe("lin0", node, 50.0 + k, 200.0 + 5 * k)
    p = tmp_path / "est.json"
    est.save(p)
    loaded = LotaruEstimator.load(p)
    nodes = list(est.target_benches)
    M0, _ = est.predict_matrix(nodes, 40.0)
    M1, _ = loaded.predict_matrix(nodes, 40.0)
    np.testing.assert_allclose(M0, M1, rtol=5e-4, atol=1e-5)


def test_v6_state_block_primes_batch_cache_moment_exact(tmp_path):
    """v6 persists the streamed (T, 8) moments and the stacked posterior:
    the loaded estimator's batched model must be BIT-exact to the saved
    one (a refit from raw samples sums in a different order), without
    triggering a refit."""
    est = _fitted(seed=5)
    node = list(est.target_benches)[0]
    for k in range(6):
        est.observe("lin1", node, 48.0 + k, 530.0 + 7 * k)
    names0, model0, w0 = est._batched()
    p = tmp_path / "est.json"
    est.save(p)
    d = json.loads(p.read_text())
    assert d["state"] is not None and d["state"]["tasks"] == names0
    loaded = LotaruEstimator.load(p)
    assert loaded._batch_cache is not None      # primed, not lazily refit
    names1, model1, w1 = loaded._batched()
    assert names1 == names0 and np.array_equal(w1, w0)
    assert np.array_equal(np.asarray(model1.stats.moments),
                          np.asarray(model0.stats.moments))
    for f0, f1 in [(model0.post.mu, model1.post.mu),
                   (model0.post.V, model1.post.V),
                   (model0.post.a, model1.post.a),
                   (model0.post.b, model1.post.b),
                   (model0.median, model1.median),
                   (model0.spread, model1.spread),
                   (model0.correlated, model1.correlated)]:
        assert np.array_equal(np.asarray(f0), np.asarray(f1))
    # the rebuilt raw-sample log carries every streamed observation
    log = model1.stats.log
    i = names1.index("lin1")
    assert int(log.count[i]) == len(loaded.tasks["lin1"].sizes)


def test_v5_file_without_state_block_still_loads(tmp_path):
    est = _fitted(seed=6)
    p = tmp_path / "v5.json"
    est.save(p)
    d = json.loads(p.read_text())
    d["version"] = 5
    del d["state"]
    p.write_text(json.dumps(d))
    loaded = LotaruEstimator.load(p)
    assert loaded._batch_cache is None          # refit path, as before v6
    nodes = list(est.target_benches)
    M0, _ = est.predict_matrix(nodes, 40.0)
    M1, _ = loaded.predict_matrix(nodes, 40.0)
    np.testing.assert_allclose(M0, M1, rtol=5e-4, atol=1e-6)


def test_legacy_v1_file_still_loads(tmp_path):
    est = _fitted(seed=4)
    p = tmp_path / "v1.json"
    # the seed's on-disk format: raw samples only, no version field
    out = {"local_bench": est.local_bench.to_dict(),
           "target_benches": {k: v.to_dict()
                              for k, v in est.target_benches.items()},
           "tasks": {name: {"w": ft.w,
                            "sizes": list(map(float, ft.sizes)),
                            "runtimes": list(map(float, ft.runtimes))}
                     for name, ft in est.tasks.items()}}
    p.write_text(json.dumps(out))
    loaded = LotaruEstimator.load(p)
    assert set(loaded.task_names()) == set(est.task_names())
    for tn in est.task_names():
        m0, _ = est.predict_local(tn, 40.0)
        m1, _ = loaded.predict_local(tn, 40.0)
        assert m1 == pytest.approx(m0, rel=1e-3)
