"""Data-aware HEFT: deterministic oracle + regression pins.

Three layers of protection around the new transfer term:

* **Pre-PR trace signatures** — ``heft_schedule`` with no comm inputs must
  produce BIT-IDENTICAL schedules to the pre-comm code on all five paper
  workflows (assignment, starts, finishes via ``repr`` so every mantissa
  bit counts).  The md5s below were captured on the commit *before* the
  comm term landed; if one moves, the comm=None path stopped being the
  old code.
* **Three-way oracle agreement** — dict API, array engine, and the
  independent reference implementation must agree exactly, comm on and
  off.
* **Transfer-floor semantics** — same-node edges are free, same-zone
  edges cheap, cross-zone edges expensive; the planner's own makespan is
  consistent with a neutral replay (``realized_makespan``).

The randomized counterpart (hypothesis) lives in
``test_comm_property.py``; this module runs everywhere, every time.
"""
import hashlib
import json
import warnings

import numpy as np
import pytest

from repro.core.nodes import target_nodes
from repro.data.synthetic import synthetic_dag
from repro.online import fanout_chain_dag
from repro.sched import (INPUTS, WORKFLOWS, CommCosts, Topology,
                         dag_edge_gb, heft_schedule, heft_schedule_array,
                         heft_schedule_reference, realized_makespan)
from repro.sched.simulator import ClusterSimulator

# ---------------------------------------------------------------------------
# pre-PR signature pins: comm=None must remain the old scheduler, bitwise
# ---------------------------------------------------------------------------
#: md5 over the sorted-key JSON of (assignment, repr(start), repr(finish),
#: repr(makespan), order) — captured on the pre-comm commit with the
#: exact scenario built by ``_pin_schedule`` below.
PRE_PR_SIGNATURES = {
    "eager": "8024573fdd6272adef1ffb0ab8a3c28f",
    "methylseq": "667b97a37431ca0874210f4a47ae2b67",
    "chipseq": "f7a350bf693aec0b132f3f4bdcda1fa6",
    "atacseq": "1a2188c0479acdfc1d4a40c051a0a882",
    "bacass": "a226f5af6dd7c3c7d38d2a19279da62d",
}


def _signature(s: dict) -> str:
    blob = json.dumps({
        "assignment": s["assignment"],
        "start": {k: repr(v) for k, v in s["start"].items()},
        "finish": {k: repr(v) for k, v in s["finish"].items()},
        "makespan": repr(s["makespan"]),
        "order": s["order"],
    }, sort_keys=True)
    return hashlib.md5(blob.encode()).hexdigest()


def _pin_schedule(wf: str) -> dict:
    """The estimator-free deterministic scenario the pins were captured
    on: 3 chain instances per workflow, noise-free simulator runtimes,
    2 nodes per type."""
    sim = ClusterSimulator(seed=0)
    size = INPUTS[(wf, 1)]
    by_name = {t.name: t for t in WORKFLOWS[wf]}
    tasks, task_name = fanout_chain_dag(list(by_name), 3)
    nodes = [f"{nt.name}/{i}" for nt in target_nodes() for i in range(2)]
    ntype = {f"{nt.name}/{i}": nt
             for nt in target_nodes() for i in range(2)}
    cost = {tid: {n: sim.expected_task_runtime(by_name[task_name[tid]],
                                               ntype[n], size)
                  for n in nodes} for tid in tasks}
    return heft_schedule(tasks, cost, nodes)


@pytest.mark.parametrize("wf", list(PRE_PR_SIGNATURES))
def test_comm_none_schedule_bitwise_equal_pre_pr(wf):
    assert _signature(_pin_schedule(wf)) == PRE_PR_SIGNATURES[wf]


# ---------------------------------------------------------------------------
# three-way oracle agreement on the paper workflows, comm on
# ---------------------------------------------------------------------------
def _workflow_scenario(wf: str, n_samples: int = 3):
    """Instance DAG + costs + comm inputs for one paper workflow on a
    two-rack cluster (contiguous blocks: heterogeneous racks)."""
    sim = ClusterSimulator(seed=7)
    size = INPUTS[(wf, 1)]
    by_name = {t.name: t for t in WORKFLOWS[wf]}
    tasks, task_name = fanout_chain_dag(list(by_name), n_samples)
    nodes = [f"{nt.name}/{i}" for nt in target_nodes() for i in range(2)]
    ntype = {f"{nt.name}/{i}": nt
             for nt in target_nodes() for i in range(2)}
    cost = {tid: {n: sim.expected_task_runtime(by_name[task_name[tid]],
                                               ntype[n], size)
                  for n in nodes} for tid in tasks}
    topo = Topology.blocks(nodes, 2, intra_gbps=10.0, cross_gbps=0.1)
    edge_gb = {e: g * 16.0
               for e, g in dag_edge_gb(tasks, task_name, by_name,
                                       size).items()}
    return tasks, cost, nodes, edge_gb, topo.secs_per_gb(nodes), topo


def _assert_same_schedule(a: dict, b: dict):
    assert a["assignment"] == b["assignment"]
    assert a["order"] == b["order"]
    for tid in a["start"]:
        assert a["start"][tid] == b["start"][tid], tid
        assert a["finish"][tid] == b["finish"][tid], tid
    assert a["makespan"] == b["makespan"]


@pytest.mark.parametrize("wf", list(WORKFLOWS))
def test_dict_api_matches_reference_comm_on(wf):
    tasks, cost, nodes, edge_gb, spg, _ = _workflow_scenario(wf)
    fast = heft_schedule(tasks, cost, nodes, edge_gb=edge_gb,
                         secs_per_gb=spg)
    ref = heft_schedule_reference(tasks, cost, nodes, edge_gb=edge_gb,
                                  secs_per_gb=spg)
    _assert_same_schedule(fast, ref)


@pytest.mark.parametrize("wf", ["eager", "bacass"])
def test_dict_api_matches_reference_comm_off(wf):
    tasks, cost, nodes, _, _, _ = _workflow_scenario(wf)
    _assert_same_schedule(heft_schedule(tasks, cost, nodes),
                          heft_schedule_reference(tasks, cost, nodes))


def test_comm_changes_placement_on_cross_rack_scenario():
    """The transfer term must actually bite: on the heavy-data two-rack
    scenario at least one workflow's comm-aware plan differs from its
    comm-blind plan, and replayed under the true prices it is never
    worse."""
    any_moved = False
    for wf in WORKFLOWS:
        tasks, cost, nodes, edge_gb, spg, topo = _workflow_scenario(wf)
        blind = heft_schedule(tasks, cost, nodes)
        aware = heft_schedule(tasks, cost, nodes, edge_gb=edge_gb,
                              secs_per_gb=spg)
        any_moved |= aware["assignment"] != blind["assignment"]
        ids = list(tasks)
        idx = {t: i for i, t in enumerate(ids)}
        succ = [[idx[s] for s in tasks[t].succ] for t in ids]
        pred = [[idx[p] for p in tasks[t].pred] for t in ids]
        eg = {(idx[p], idx[s]): g for (p, s), g in edge_gb.items()}
        comm = CommCosts(pred, eg,
                         topo.secs_per_gb(nodes))
        nidx = {n: j for j, n in enumerate(nodes)}
        for label, s in (("blind", blind), ("aware", aware)):
            asg = [nidx[s["assignment"][t]] for t in ids]
            dur = np.array([cost[t][s["assignment"][t]] for t in ids])
            order = [idx[t] for t in s["order"]]
            rm = realized_makespan(succ, pred, dur, asg, order, comm=comm)
            if label == "aware":
                # the aware planner priced every transfer it pays, so the
                # neutral replay reproduces its own makespan exactly
                assert rm == s["makespan"]
            else:
                assert rm >= s["makespan"] - 1e-9
    assert any_moved


# ---------------------------------------------------------------------------
# array engine vs reference on synthetic DAGs (fixed seeds; the unbounded
# random version lives in test_comm_property.py)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_array_matches_reference_on_synthetic_dags(seed):
    dag = synthetic_dag(width=5, depth=6, fanout=2.0, seed=seed)
    rng = np.random.default_rng(seed + 100)
    n_nodes = 6
    names = [f"n{j}" for j in range(n_nodes)]
    cost = dag.cost_matrix(rng.uniform(0.5, 2.0, n_nodes))
    topo = Topology.blocks(names, 2, intra_gbps=5.0, cross_gbps=0.2)
    comm = CommCosts(dag.pred, dag.edge_dict(), topo.secs_per_gb(names))
    arr = heft_schedule_array(dag.succ, dag.pred, cost, comm=comm)

    ids = [f"t{i}" for i in range(dag.n_tasks)]
    from repro.sched.heft import SchedTask
    tasks = {ids[i]: SchedTask(id=ids[i],
                               pred=[ids[p] for p in dag.pred[i]],
                               succ=[ids[s] for s in dag.succ[i]])
             for i in range(dag.n_tasks)}
    dcost = {ids[i]: {names[j]: float(cost[i, j])
                      for j in range(n_nodes)}
             for i in range(dag.n_tasks)}
    deg = {(ids[p], ids[t]): g
           for (p, t), g in dag.edge_dict().items()}
    ref = heft_schedule_reference(tasks, dcost, names, edge_gb=deg,
                                  secs_per_gb=topo.secs_per_gb(names))
    nidx = {n: j for j, n in enumerate(names)}
    assert [nidx[ref["assignment"][t]] for t in ids] == \
        list(arr["assignment"])
    assert [int(t[1:]) for t in ref["order"]] == list(arr["order"])
    for i, tid in enumerate(ids):
        assert ref["start"][tid] == arr["start"][i]
        assert ref["finish"][tid] == arr["finish"][i]
    assert ref["makespan"] == arr["makespan"]


# ---------------------------------------------------------------------------
# transfer-floor semantics on a hand-built diamond
# ---------------------------------------------------------------------------
def _diamond():
    """a -> {b, c} -> d with 1 GB on every edge."""
    succ = [[1, 2], [3], [3], []]
    pred = [[], [0], [0], [1, 2]]
    eg = {(0, 1): 1.0, (0, 2): 1.0, (1, 3): 1.0, (2, 3): 1.0}
    return succ, pred, eg


def test_same_node_transfer_is_free():
    succ, pred, eg = _diamond()
    names = ["a0", "b0"]
    topo = Topology({"a0": "r0", "b0": "r1"}, cross_gbps=0.1)
    comm = CommCosts(pred, eg, topo.secs_per_gb(names))
    # node 0 is much faster: everything lands there, and with all four
    # tasks co-located no transfer cost may appear anywhere
    cost = np.array([[1.0, 50.0]] * 4)
    s = heft_schedule_array(succ, pred, cost, comm=comm)
    assert list(s["assignment"]) == [0, 0, 0, 0]
    none = heft_schedule_array(succ, pred, cost)
    assert s["makespan"] == none["makespan"]


def test_cross_zone_edges_are_priced_and_delay_starts():
    succ, pred, eg = _diamond()
    names = ["a0", "b0"]
    topo = Topology({"a0": "r0", "b0": "r1"},
                    intra_gbps=10.0, cross_gbps=0.1)
    spg = topo.secs_per_gb(names)
    comm = CommCosts(pred, eg, spg)
    # b and c each take 10s on either node: with comm off they split
    # across nodes and finish in parallel
    cost = np.array([[1.0, 1.0], [10.0, 10.0], [10.0, 10.0], [1.0, 1.0]])
    blind = heft_schedule_array(succ, pred, cost)
    aware = heft_schedule_array(succ, pred, cost, comm=comm)
    # a 10s cross-rack copy (1 GB at 0.1 GB/s) outweighs serialising the
    # two 10s middle tasks? no: copy there + copy back = 20s > 10s, so
    # the aware plan keeps the diamond on one node
    assert len(set(aware["assignment"])) == 1
    assert len(set(blind["assignment"])) == 2
    # and every start in the aware plan respects the transfer floor
    st, fin, asg = aware["start"], aware["finish"], aware["assignment"]
    for t in range(4):
        for k, p in enumerate(pred[t]):
            gb = eg[(p, t)]
            assert st[t] >= fin[p] + gb * spg[asg[p], asg[t]] - 1e-12


def test_dead_source_is_never_cheap():
    """A dead node's rows are re-priced at the worst finite rate — the
    planner must not treat data stranded on a crashed node as local."""
    names = ["a0", "a1", "b0"]
    topo = Topology({"a0": "r0", "a1": "r0", "b0": "r1"},
                    intra_gbps=10.0, cross_gbps=0.1)
    live = topo.secs_per_gb(names)
    dead = topo.secs_per_gb(names, alive={"a0": False, "a1": True,
                                          "b0": True})
    worst = live[live < np.inf].max()
    # rows from the dead source: worst rate everywhere (diagonal excepted)
    assert (dead[0, 1:] == worst).all()
    assert dead[0, 0] == 0.0  # CommCosts invariant: zero diagonal
    # edges between live nodes are unchanged, so a later all-alive call
    # (the rejoin) restores the original pricing exactly
    assert (dead[1:, 1:] == live[1:, 1:]).all()
    again = topo.secs_per_gb(names, alive={n: True for n in names})
    assert (again == live).all()


# ---------------------------------------------------------------------------
# dict-API misuse warning
# ---------------------------------------------------------------------------
def test_edge_gb_without_bandwidth_warns_exactly_once():
    tasks, cost, nodes, edge_gb, _, _ = _workflow_scenario("bacass")
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        s = heft_schedule(tasks, cost, nodes, edge_gb=edge_gb)
    hits = [x for x in w if issubclass(x.category, UserWarning)
            and "secs_per_gb" in str(x.message)]
    assert len(hits) == 1
    # and the schedule silently fell back to the comm-blind plan
    _assert_same_schedule(s, heft_schedule(tasks, cost, nodes))


def test_edge_gb_with_bandwidth_does_not_warn():
    tasks, cost, nodes, edge_gb, spg, _ = _workflow_scenario("bacass")
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        heft_schedule(tasks, cost, nodes, edge_gb=edge_gb,
                      secs_per_gb=spg)
    assert not [x for x in w if issubclass(x.category, UserWarning)
                and "secs_per_gb" in str(x.message)]


# ---------------------------------------------------------------------------
# OnlineExecutor: comm-aware re-planning + realized staging
# ---------------------------------------------------------------------------
from repro.core.estimator import LotaruEstimator
from repro.core.nodes import get_node
from repro.core.profiler import BenchResult
from repro.online import OnlineExecutor


def _bench(name, cpu, io):
    return BenchResult(node=name, cpu_events_s=cpu, matmul_gflops=100.0,
                       mem_gbps=20.0, io_read_mbps=io, io_write_mbps=io,
                       link_gbps=0.0)


def _toy_est(n_tasks=3):
    local = _bench("local-cpu", 450.0, 420.0)
    benches = {"tpu-v2": _bench("tpu-v2", 600.0, 500.0),
               "tpu-v3": _bench("tpu-v3", 300.0, 260.0)}
    est = LotaruEstimator(local, benches)
    slopes = {f"t{i}": (i + 1) * 2.0 for i in range(n_tasks)}
    est.fit_tasks(list(slopes), 64.0,
                  lambda n, s, cf: slopes[n] * s / cf + 5.0,
                  n_partitions=8)
    return est, list(slopes)


def _scatter_tasks(n_samples: int):
    """Per-sample fan-out: t0 scatters to three t1 instances which
    gather into t2.  Unlike a chain — which any planner can pin to one
    node — the parallel middles force cross-node edges, so staging
    delays genuinely occur."""
    from repro.sched.heft import SchedTask
    tasks, task_name = {}, {}
    for s in range(n_samples):
        src, snk = f"s{s}.t0", f"s{s}.t2"
        tasks[src] = SchedTask(id=src)
        task_name[src] = "t0"
        mids = []
        for k in range(3):
            mid = f"s{s}.t1_{k}"
            tasks[mid] = SchedTask(id=mid, pred=[src])
            task_name[mid] = "t1"
            tasks[src].succ.append(mid)
            mids.append(mid)
        tasks[snk] = SchedTask(id=snk, pred=list(mids))
        task_name[snk] = "t2"
        for m in mids:
            tasks[m].succ.append(snk)
    return tasks, task_name


def _exec_scenario(edge_gb_scale=None, comm_aware=True, topology="blocks",
                   n_samples=4, structure="chain"):
    """Chain or scatter/gather instances on a 4-node, two-rack grid;
    every DAG edge ships ``edge_gb_scale`` GB (None: comm-blind
    executor)."""
    from repro.sched.simulator import GridEngine
    est, chain = _toy_est()
    if structure == "chain":
        tasks, task_name = fanout_chain_dag(chain, n_samples)
    else:
        tasks, task_name = _scatter_tasks(n_samples)
    types = [get_node("tpu-v2"), get_node("tpu-v3")]
    names = [f"{t.name}/{i}" for t in types for i in range(2)]
    topo = None
    if topology is not None:
        topo = Topology.blocks(names, 2, intra_gbps=10.0, cross_gbps=0.05)
    grid = GridEngine.from_types(nodes_per_type=2, types=types,
                                 topology=topo)
    est_truth, _ = _toy_est()

    def runtime_fn(tid, node):
        m, _ = est_truth.predict(task_name[tid],
                                 grid.type_of(node).name, 32.0)
        return m * 1.3

    eg = None
    if edge_gb_scale is not None:
        eg = {(p, t): edge_gb_scale
              for t in tasks for p in tasks[t].pred}
    return OnlineExecutor(est, tasks, task_name, 32.0, grid, runtime_fn,
                          online=True, confidence=0.2, edge_gb=eg,
                          comm_aware=comm_aware), runtime_fn


def test_executor_comm_knobs_off_is_bit_exact():
    """edge_gb without a topology (and edge_gb=None outright) must leave
    the execution byte-identical — the comm machinery may not perturb
    the pre-PR loop."""
    base = _exec_scenario(edge_gb_scale=None, topology=None)[0].run()
    inert = _exec_scenario(edge_gb_scale=5.0, topology=None)[0].run()
    assert len(base.records) == len(inert.records)
    for a, b in zip(base.records, inert.records):
        assert (a.id, a.node, a.start, a.end, a.runtime) == \
            (b.id, b.node, b.start, b.end, b.runtime)
    assert base.makespan == inert.makespan


def test_executor_staging_charges_end_not_runtime():
    ex, runtime_fn = _exec_scenario(edge_gb_scale=1.0,
                                    structure="scatter", n_samples=2)
    trace = ex.run()
    assert trace.completed_fraction() == 1.0
    waited = 0
    for r in trace.records:
        wait = r.end - r.start - r.runtime
        assert wait >= -1e-9
        waited += wait > 1e-9
        # the estimator's observation is pure compute: re-deriving the
        # ground truth for (task, node) must reproduce it exactly
        assert r.runtime == runtime_fn(r.id, r.node)
    # the parallel middles cannot all sit on the source's node, so some
    # record must have paid a real transfer before starting
    assert waited > 0


def test_executor_comm_ablation_runs_and_completes():
    """comm_aware=False keeps staging physics but plans blind — both
    arms must complete everything, and both pay real transfer delays."""
    aware = _exec_scenario(edge_gb_scale=1.0, comm_aware=True,
                           structure="scatter", n_samples=2)[0].run()
    blind = _exec_scenario(edge_gb_scale=1.0, comm_aware=False,
                           structure="scatter", n_samples=2)[0].run()
    assert aware.completed_fraction() == blind.completed_fraction() == 1.0
    assert len(aware.records) == len(blind.records)
