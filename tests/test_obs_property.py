"""Property tests for the two-heap running median (hypothesis)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev dep; skip, don't die
from hypothesis import given, settings, strategies as st

from repro.obs import running_median

floats = st.floats(min_value=-1e6, max_value=1e6,
                   allow_nan=False, allow_infinity=False)


@settings(max_examples=200, deadline=None)
@given(st.lists(floats, min_size=1, max_size=200))
def test_running_median_equals_prefix_median(xs):
    naive = np.array([np.median(xs[:k + 1]) for k in range(len(xs))])
    np.testing.assert_array_equal(running_median(xs), naive)


@settings(max_examples=100, deadline=None)
@given(st.lists(st.integers(min_value=-5, max_value=5),
                min_size=1, max_size=100))
def test_running_median_duplicate_heavy_streams(xs):
    """Plateaus of equal values exercise every heap-rebalance branch."""
    xs = [float(x) for x in xs]
    naive = np.array([np.median(xs[:k + 1]) for k in range(len(xs))])
    np.testing.assert_array_equal(running_median(xs), naive)
