"""Paper Table 2: infrastructure profiling results for all six node types.

The local node's scores are *really measured* on this host (sysbench-like
primes, JAX matmul LINPACK analogue, memory stream, fio-like file I/O);
target accelerator node types are simulated measurements.
"""
from __future__ import annotations

from repro.core import profile_cluster, profile_local, target_nodes

from .common import timed


def run() -> list[tuple]:
    local, us_local = timed(profile_local, fast=True)
    benches, us_cluster = timed(profile_cluster, target_nodes(), 0)
    rows = []
    hdr = f"{'node':10s} {'cpu_ev/s':>9s} {'gflops':>9s} {'mem GB/s':>9s} {'io MB/s':>8s} {'link GB/s':>9s}"
    print(hdr)
    for b in [local] + list(benches.values()):
        print(f"{b.node:10s} {b.cpu_events_s:9.0f} {b.matmul_gflops:9.1f} "
              f"{b.mem_gbps:9.1f} {b.io_read_mbps:8.0f} {b.link_gbps:9.1f}")
    rows.append(("table2.local_profile", us_local,
                 f"cpu={local.cpu_events_s:.0f}ev/s;gflops={local.matmul_gflops:.1f}"))
    rows.append(("table2.cluster_profile", us_cluster,
                 f"nodes={len(benches)}"))
    return rows
