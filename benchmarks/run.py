"""Benchmark harness: one module per paper table/figure (+ beyond-paper).
Prints ``name,us_per_call,derived`` CSV at the end (stdout also carries the
human-readable tables)."""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from . import (calibration, fig4_downsampling, fig5_cdf,
                   fig6_homogeneous, roofline_table, scheduler_e2e,
                   table2_microbench, table45_factors, table6_heterogeneous,
                   tpu_cells)
    mods = [
        ("table2_microbench", table2_microbench),
        ("fig4_downsampling", fig4_downsampling),
        ("fig5_cdf", fig5_cdf),
        ("fig6_homogeneous", fig6_homogeneous),
        ("table45_factors", table45_factors),
        ("table6_heterogeneous", table6_heterogeneous),
        ("tpu_cells", tpu_cells),
        ("roofline_table", roofline_table),
        ("scheduler_e2e", scheduler_e2e),
        ("calibration", calibration),
    ]
    rows = []
    failed = 0
    for name, mod in mods:
        print(f"\n=== {name} " + "=" * max(0, 60 - len(name)))
        try:
            rows.extend(mod.run())
        except Exception as e:
            # the sweep must keep going past any one table's failure (the
            # modules call into arbitrary kernels, so the catch stays
            # broad by design) — but the cause is bound, printed, and
            # carried into the CSV row instead of silently discarded
            failed += 1
            traceback.print_exc()
            rows.append((f"{name}.FAILED", 0.0, repr(e)))
    print("\n--- CSV (name,us_per_call,derived) ---")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
