"""Scheduler end-to-end (paper §2.2 motivation): HEFT fed by Lotaru
estimates vs FIFO/round-robin vs an oracle (true runtimes), plus
uncertainty-aware straggler mitigation — makespans on the heterogeneous
cluster for a fan-out physical workflow (many inputs through eager)."""
from __future__ import annotations

import time

import numpy as np

from repro.core import (LotaruEstimator, get_node, profile_cluster,
                        profile_node, target_nodes)
from repro.sched.heft import (SchedTask, heft_schedule, round_robin_schedule,
                              simulate_with_stragglers)
from repro.sched.simulator import ClusterSimulator
from repro.sched.workflows import INPUTS, WORKFLOWS


def _build_dag(n_samples: int = 8):
    """Physical eager workflow over n_samples inputs (embarrassingly
    parallel per sample, linear chain inside a sample)."""
    chain = [t.name for t in WORKFLOWS["eager"]]
    tasks: dict[str, SchedTask] = {}
    for s in range(n_samples):
        prev = None
        for name in chain:
            tid = f"s{s}.{name}"
            tasks[tid] = SchedTask(id=tid)
            if prev is not None:
                tasks[tid].pred.append(prev)
                tasks[prev].succ.append(tid)
            prev = tid
    return tasks


def run(n_samples: int = 8, nodes_per_type: int = 2) -> list[tuple]:
    t0 = time.perf_counter()
    sim = ClusterSimulator(seed=0)
    truth = ClusterSimulator(seed=2000)
    local = get_node("local-cpu")
    local_bench = profile_node(local, np.random.default_rng(7))
    tbenches = profile_cluster(target_nodes(), seed=13)
    size = INPUTS[("eager", 1)]
    by_name = {t.name: t for t in WORKFLOWS["eager"]}

    est = LotaruEstimator(local_bench, tbenches)
    est.fit_tasks(list(by_name), size,
                  lambda name, s, cf: sim.run_task(by_name[name], local, s,
                                                   cpu_factor=cf))

    node_names = []
    node_type = {}
    for nt in target_nodes():
        for i in range(nodes_per_type):
            nm = f"{nt.name}/{i}"
            node_names.append(nm)
            node_type[nm] = nt

    tasks = _build_dag(n_samples)
    # one batched call for the full (task x node-type) estimate matrix,
    # expanded to node instances by indexing — no per-pair predict loop
    type_names = [nt.name for nt in target_nodes()]
    type_idx = {n: j for j, n in enumerate(type_names)}
    task_idx = {n: i for i, n in enumerate(est.task_names())}
    mean_mat, std_mat = est.predict_matrix(type_names, size)
    cost, unc, true_cost = {}, {}, {}
    for tid in tasks:
        tname = tid.split(".", 1)[1]
        ti = task_idx[tname]
        cost[tid], unc[tid], true_cost[tid] = {}, {}, {}
        for nm in node_names:
            nj = type_idx[node_type[nm].name]
            cost[tid][nm] = mean_mat[ti, nj]
            unc[tid][nm] = std_mat[ti, nj]
            true_cost[tid][nm] = truth.run_task(by_name[tname],
                                                node_type[nm], size)

    def true_rt(tid, node):
        return true_cost[tid][node]

    def _topo_order():
        depth: dict[str, int] = {}

        def rec(tid):
            if tid in depth:
                return depth[tid]
            depth[tid] = 1 + max((rec(p) for p in tasks[tid].pred), default=0)
            return depth[tid]
        for tid in tasks:
            rec(tid)
        return sorted(tasks, key=lambda t: (depth[t], t))

    def makespan_of(assignment, order=None):
        """List-schedule in priority order against true runtimes."""
        node_free = {n: 0.0 for n in node_names}
        fin: dict[str, float] = {}
        for tid in (order or _topo_order()):
            n = assignment[tid]
            st = max(node_free[n],
                     max((fin[p] for p in tasks[tid].pred), default=0.0))
            fin[tid] = st + true_rt(tid, n)
            node_free[n] = fin[tid]
        return max(fin.values())

    heft_lotaru = heft_schedule(tasks, cost, node_names)
    heft_risk = heft_schedule(tasks, cost, node_names, uncertainty=unc,
                              risk_k=1.0)
    heft_oracle = heft_schedule(tasks, true_cost, node_names)
    rr = round_robin_schedule(tasks, node_names)

    ms = {
        "round_robin": makespan_of(rr["assignment"]),
        "heft_lotaru": makespan_of(heft_lotaru["assignment"],
                                   heft_lotaru["order"]),
        "heft_lotaru_risk": makespan_of(heft_risk["assignment"],
                                        heft_risk["order"]),
        "heft_oracle": makespan_of(heft_oracle["assignment"],
                                   heft_oracle["order"]),
    }
    for k, v in ms.items():
        print(f"  {k:18s} makespan {v:10.1f}s")
    gap = ms["heft_lotaru"] / ms["heft_oracle"]
    speedup = ms["round_robin"] / ms["heft_lotaru"]
    print(f"  lotaru-vs-oracle gap: {gap:.3f}x; speedup over RR: {speedup:.2f}x")

    # straggler mitigation: one node type is secretly 5x slow for 10% tasks
    preds = {tid: (mean_mat[task_idx[tid.split('.', 1)[1]],
                            type_idx[node_type[heft_lotaru['assignment'][tid]].name]],
                   std_mat[task_idx[tid.split('.', 1)[1]],
                           type_idx[node_type[heft_lotaru['assignment'][tid]].name]])
             for tid in tasks}
    rng = np.random.default_rng(3)

    def true_rt_straggle(tid, node):
        import zlib
        # slowness is tied to the (task, node) placement — a replica on a
        # different node runs at normal speed (degraded-host model)
        t = true_cost[tid][node]
        h = zlib.crc32(f"{tid}|{node}|straggle".encode()) % 10
        return t * (5.0 if h == 0 else 1.0)

    with_m = simulate_with_stragglers(tasks, cost, node_names,
                                      true_rt_straggle, preds,
                                      speculative=True)
    without = simulate_with_stragglers(tasks, cost, node_names,
                                       true_rt_straggle, preds,
                                       speculative=False)
    print(f"  straggler makespan: {without['makespan']:.1f}s -> "
          f"{with_m['makespan']:.1f}s (mitigated {with_m['mitigated']} tasks)")
    us = (time.perf_counter() - t0) * 1e6
    return [("scheduler.heft_vs_oracle", us,
             f"gap={gap:.3f};speedup_vs_rr={speedup:.2f}"),
            ("scheduler.straggler_mitigation", us,
             f"makespan {without['makespan']:.0f}->{with_m['makespan']:.0f}s"
             f";mitigated={with_m['mitigated']}")]
