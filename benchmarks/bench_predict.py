"""Prediction-engine throughput: scalar per-pair loop vs the batched,
jit-compiled matrix path, plus dict-HEFT vs array-HEFT — the hot path a
HEFT-class scheduler re-runs on every elastic reschedule / straggler check
(paper §2.2).  Writes ``BENCH_predict.json`` at the repo root.

Scale: ~1000 tasks x 64 nodes by default.  x64 is enabled so the
agreement check between the two paths is limited by algorithmic, not
float32, differences.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro.core import LotaruEstimator
from repro.core.blr import fit_task
from repro.core.estimator import FittedTask
from repro.core.profiler import BenchResult
from repro.sched.heft import (SchedTask, heft_schedule_array,
                              heft_schedule_reference)

OUT = Path(__file__).resolve().parents[1] / "BENCH_predict.json"


def _synthetic_estimator(n_tasks: int, n_nodes: int, seed: int = 0):
    """An estimator with T fitted tasks over N synthetic node benches —
    no simulator in the loop, so the benchmark times prediction only."""
    rng = np.random.default_rng(seed)
    local = BenchResult(node="local-cpu", cpu_events_s=450.0,
                        matmul_gflops=90.0, mem_gbps=18.0,
                        io_read_mbps=420.0, io_write_mbps=400.0,
                        link_gbps=0.0)
    benches = {}
    for j in range(n_nodes):
        nm = f"node{j:03d}"
        benches[nm] = BenchResult(
            node=nm, cpu_events_s=float(rng.uniform(150, 900)),
            matmul_gflops=float(rng.uniform(50, 5000)),
            mem_gbps=float(rng.uniform(10, 900)),
            io_read_mbps=float(rng.uniform(100, 900)),
            io_write_mbps=float(rng.uniform(100, 900)),
            link_gbps=float(rng.uniform(0, 100)))
    est = LotaruEstimator(local, benches)
    n_part = 8
    for i in range(n_tasks):
        sizes = np.geomspace(1.0, 256.0, n_part) * rng.uniform(0.5, 2.0)
        if rng.random() < 0.7:      # size-correlated task -> BLR
            rts = (rng.uniform(0.1, 5.0) * sizes + rng.uniform(1, 50)
                   + rng.normal(0, 0.05, n_part))
        else:                       # flat -> median fallback
            rts = rng.uniform(20, 200) + rng.normal(0, 0.5, n_part)
        est.tasks[f"task{i:04d}"] = FittedTask(
            model=fit_task(sizes, rts), w=float(rng.uniform(0, 1)),
            sizes=sizes, runtimes=np.abs(rts))
    return est


def _layered_dag(n_tasks: int, depth: int, rng) -> dict[str, SchedTask]:
    """Layered DAG (width = n_tasks/depth) with random cross-layer edges."""
    width = max(1, n_tasks // depth)
    ids = [f"t{i}" for i in range(n_tasks)]
    tasks = {tid: SchedTask(id=tid) for tid in ids}
    for i in range(width, n_tasks):
        for p in rng.choice(i, size=min(2, i), replace=False):
            p = int(p)
            if p >= i - 2 * width and rng.random() < 0.7:
                tasks[ids[p]].succ.append(ids[i])
                tasks[ids[i]].pred.append(ids[p])
    return tasks


def run(n_tasks: int = 1000, n_nodes: int = 64) -> list[tuple]:
    rng = np.random.default_rng(3)
    est = _synthetic_estimator(n_tasks, n_nodes)
    nodes = list(est.target_benches)
    names = est.task_names()
    size = 128.0

    # --- scalar per-pair loop (the seed's hot path) ------------------------
    t0 = time.perf_counter()
    M_s = np.empty((n_tasks, n_nodes))
    S_s = np.empty((n_tasks, n_nodes))
    for i, tn in enumerate(names):
        for j, nd in enumerate(nodes):
            M_s[i, j], S_s[i, j] = est.predict(tn, nd, size)
    scalar_s = time.perf_counter() - t0

    # --- batched matrix path ----------------------------------------------
    est.predict_matrix(nodes, size)            # build cache + jit warm-up
    t0 = time.perf_counter()
    reps = 5
    for _ in range(reps):
        M_b, S_b = est.predict_matrix(nodes, size)
    batched_s = (time.perf_counter() - t0) / reps

    rel_mean = np.max(np.abs(M_b - M_s) / np.maximum(np.abs(M_s), 1e-12))
    rel_std = np.max(np.abs(S_b - S_s) / np.maximum(np.abs(S_s), 1e-12))
    pairs = n_tasks * n_nodes
    speedup = scalar_s / batched_s

    # --- HEFT: dict reference vs ndarray fast path -------------------------
    tasks = _layered_dag(n_tasks, depth=10, rng=rng)
    ids = list(tasks)
    cost_d = {tid: {nd: float(M_s[i, j]) for j, nd in enumerate(nodes)}
              for i, tid in enumerate(ids)}
    t0 = time.perf_counter()
    ref = heft_schedule_reference(tasks, cost_d, nodes)
    heft_dict_s = time.perf_counter() - t0

    idx = {tid: i for i, tid in enumerate(ids)}
    succ = [[idx[s] for s in tasks[t].succ] for t in ids]
    pred = [[idx[p] for p in tasks[t].pred] for t in ids]
    heft_schedule_array(succ, pred, M_b)       # warm-up (numpy, ~no-op)
    t0 = time.perf_counter()
    arr = heft_schedule_array(succ, pred, M_b)
    heft_array_s = time.perf_counter() - t0
    heft_match = (abs(arr["makespan"] - ref["makespan"])
                  / max(ref["makespan"], 1e-12) < 1e-9)

    result = {
        "config": {"n_tasks": n_tasks, "n_nodes": n_nodes, "pairs": pairs,
                   "x64": True},
        "scalar_predict_s": scalar_s,
        "batched_predict_s": batched_s,
        "scalar_pairs_per_s": pairs / scalar_s,
        "batched_pairs_per_s": pairs / batched_s,
        "predict_speedup": speedup,
        "max_rel_diff_mean": float(rel_mean),
        "max_rel_diff_std": float(rel_std),
        "heft_dict_s": heft_dict_s,
        "heft_array_s": heft_array_s,
        "heft_speedup": heft_dict_s / heft_array_s,
        "heft_makespans_match": bool(heft_match),
    }
    OUT.write_text(json.dumps(result, indent=2))
    print(f"predict: scalar {scalar_s:.2f}s vs batched {batched_s*1e3:.1f}ms "
          f"for {pairs} pairs -> {speedup:.0f}x "
          f"(max rel diff mean={rel_mean:.2e}, std={rel_std:.2e})")
    print(f"HEFT {n_tasks}x{n_nodes}: dict {heft_dict_s:.2f}s vs array "
          f"{heft_array_s*1e3:.0f}ms -> {heft_dict_s/heft_array_s:.1f}x "
          f"(makespans match: {heft_match})")
    print(f"wrote {OUT}")
    return [("bench_predict.matrix_speedup", batched_s * 1e6,
             f"speedup={speedup:.0f}x;rel={rel_mean:.1e}"),
            ("bench_predict.heft_speedup", heft_array_s * 1e6,
             f"speedup={heft_dict_s/heft_array_s:.1f}x;match={heft_match}")]


if __name__ == "__main__":
    run()
