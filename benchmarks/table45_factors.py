"""Paper Tables 4+5: estimated vs actual runtime-adjustment factors
(eager-1), per node and per task."""
from __future__ import annotations

import numpy as np

from repro.core import target_nodes
from repro.sched.evaluation import factor_table

from .common import timed


def run() -> list[tuple]:
    rows, us = timed(factor_table, seed=0, workflow="eager", ds=1)
    names = [n.name for n in target_nodes()]
    med = {n: float(np.median([r[n]["diff"] for r in rows])) for n in names}
    print("median |estimated - actual| factor per node (paper Table 4: "
          "0.15/0.14/0.17/0.06/0.03):")
    print("  " + "  ".join(f"{n}={med[n]:.3f}" for n in names))
    print(f"\nper-task factors on {names[-1]} (paper Table 5):")
    print(f"{'task':24s} {'w':>5s} {'est':>6s} {'actual':>7s} {'diff':>6s}")
    for r in rows:
        e = r[names[-1]]
        print(f"{r['task']:24s} {r['w']:5.2f} {e['estimated']:6.2f} "
              f"{e['actual']:7.2f} {e['diff']:6.3f}")
    return [("table45.factor_accuracy", us,
             ";".join(f"{n}={med[n]:.3f}" for n in names))]
