"""Shared benchmark plumbing: timing + CSV emission."""
from __future__ import annotations

import time


def timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6


def emit(rows: list[tuple]) -> None:
    """rows: (name, us_per_call, derived)"""
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
