"""Paper Table 6: MPE for all approaches on all five target node types."""
from __future__ import annotations

from repro.core import target_nodes
from repro.sched.evaluation import run_evaluation

from .common import timed


def run() -> list[tuple]:
    res, us = timed(run_evaluation, seed=0, heterogeneous=True)
    names = [n.name for n in target_nodes()]
    print(f"{'approach':10s} " + " ".join(f"{n:>9s}" for n in names)
          + f" {'overall':>9s}")
    overall = {}
    for a in ("naive", "online_m", "online_p", "lotaru"):
        vals = [100 * res.mpe(a, node=n) for n in names]
        overall[a] = 100 * res.mpe(a)
        print(f"{a:10s} " + " ".join(f"{v:8.2f}%" for v in vals)
              + f" {overall[a]:8.2f}%")
    best_b = min(overall["naive"], overall["online_m"], overall["online_p"])
    red = 100 * (1 - overall["lotaru"] / best_b)
    print(f"error reduction vs best baseline: {red:.1f}% (paper: 48.25%)")
    return [("table6.heterogeneous_mpe", us,
             f"lotaru={overall['lotaru']:.2f}%;online_p={overall['online_p']:.2f}%"
             f";reduction={red:.1f}%;paper_reduction=48.25%")]
